//! Quickstart: build the zero-artifact native backend, generate a few
//! class-conditional samples with SpeCa, and print the acceptance/speedup
//! statistics. No `make artifacts` needed — swap in the PJRT backend
//! (`--features pjrt` + Manifest/ModelRuntime) for artifact execution;
//! the engine code is identical either way (DESIGN.md §3).
//!
//! The *draft* — how features are forecast between full computes — is
//! pluggable (DESIGN.md §10): `draft=<name>` in the policy string (or
//! `--draft` on the CLI) resolves through `cache::DraftRegistry`;
//! `speca --list-drafts` prints what is registered.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use speca::cache::DraftRegistry;
use speca::config::ModelConfig;
use speca::coordinator::{Engine, EngineConfig};
use speca::runtime::{ModelBackend, NativeBackend};
use speca::workload::{batch_requests, parse_policy};

fn main() -> Result<()> {
    // 1. build a seeded native DiT (pure Rust, no artifacts, Send + Sync)
    let model = NativeBackend::seeded(ModelConfig::native_dit(), 0x5EED);
    let entry = model.entry();

    // 2. pick a draft strategy by name — `taylor` is the default; try
    //    `richardson` or `learned-linear` and watch α/rejects move
    //    (full comparison: `speca bench drafts`, EXPERIMENTS.md §Drafts)
    println!("registered drafts:");
    for (name, blurb) in DraftRegistry::global().list() {
        println!("  {name:<16} {blurb}");
    }
    let policy =
        parse_policy("speca:N=5,O=2,tau0=0.3,beta=0.05,draft=taylor", entry.config.depth)?;

    // 3. build an engine and submit 8 requests under the SpeCa policy
    // (Engine owns an Arc<dyn ModelBackend>; from_ref wraps a borrow —
    //  see coordinator::pool::EngineShardPool for the multi-shard form)
    let mut engine = Engine::from_ref(&model, EngineConfig::default());
    for r in batch_requests(8, entry.config.num_classes, &policy, 0, false) {
        engine.submit(r);
    }

    // 4. run the forecast-then-verify loop to completion
    let completions = engine.run_to_completion()?;

    // 5. inspect per-request statistics (each completion carries the
    //    draft name, so acceptance-per-draft is directly reportable)
    let full1 = entry.flops.full_step[&1];
    let steps = entry.config.serve_steps;
    println!("{:<4} {:>5} {:>5} {:>4} {:>8} {:>8}", "id", "full", "spec", "rej", "lat ms", "speedup");
    for c in &completions {
        println!(
            "{:<4} {:>5} {:>5} {:>4} {:>8.1} {:>7.2}x",
            c.id,
            c.stats.full_steps,
            c.stats.spec_steps,
            c.stats.rejects,
            c.stats.latency_ms,
            c.stats.speedup(full1, steps)
        );
    }
    let f = &engine.flops;
    println!(
        "\nacceptance α={:.3}  verify cost γ={:.4}  FLOPs speedup {:.2}x \
         (paper law 1/(1−α+αγ) = {:.2}x)",
        f.acceptance_rate(),
        f.gamma(),
        f.speedup(full1),
        f.predicted_speedup()
    );

    // 6. dump the generated images as PGM grids
    speca::experiments::runner::dump_pgm(&completions, &entry.config, "out/quickstart")?;
    println!("sample images in out/quickstart/*.pgm");
    Ok(())
}
