//! END-TO-END serving driver (DESIGN.md §6, EXPERIMENTS.md §E2E): boots
//! the full stack — PJRT runtime, SpeCa engine, TCP server — then drives
//! batched client traffic with mixed policies, and reports
//! latency/throughput plus quality vs the full-compute reference.
//!
//! ```bash
//! cargo run --release --example e2e_serving            # full run
//! cargo run --release --example e2e_serving -- --quick # CI-sized
//! ```

use std::thread;

use anyhow::Result;
use speca::config::Manifest;
use speca::coordinator::{Engine, EngineConfig};
use speca::experiments::runner::{evaluate_quality, run_policy, RunOpts};
use speca::runtime::{ClassifierRuntime, ModelRuntime, ResolvedModel, Runtime};
use speca::server::{client, serve, ServerConfig};
use speca::util::cli::Args;
use speca::workload::parse_policy;

fn main() -> Result<()> {
    let args = Args::from_env();
    let quick = args.bool("quick");
    let n_requests = args.usize("n", if quick { 16 } else { 64 });
    let model_name = args.str("model", "dit-sim");
    let addr = args.str("addr", "127.0.0.1:7891");

    let manifest = Manifest::load(&speca::artifacts_dir())?;
    let entry = manifest.model(&model_name)?;
    let rt = Runtime::cpu()?;
    let model = ModelRuntime::load(&rt, entry)?;
    model.precompile(&["full", "block", "head"], &entry.config.buckets)?;
    println!("[e2e] artifacts compiled: model={model_name} depth={} tokens={}",
             entry.config.depth, entry.config.tokens);

    // ---- phase 1: serve mixed-policy traffic over TCP ------------------
    let policies = ["full", "fora:N=6", "taylorseer:N=5,O=2", "speca:N=5,O=2,tau0=0.3,beta=0.05"];
    let addr2 = addr.clone();
    let classes = entry.config.num_classes;
    let driver = thread::spawn(move || -> Vec<(String, client::LoadReport)> {
        // wait for the listener
        for _ in 0..200 {
            if std::net::TcpStream::connect(&addr2).is_ok() {
                break;
            }
            thread::sleep(std::time::Duration::from_millis(25));
        }
        let mut reports = Vec::new();
        for p in policies {
            let cfg = client::LoadConfig {
                addr: addr2.clone(),
                connections: 4,
                requests: n_requests / policies.len(),
                policy: p.to_string(),
                num_classes: classes,
            };
            match client::run_load(&cfg) {
                Ok(rep) => reports.push((p.to_string(), rep)),
                Err(e) => eprintln!("[e2e] load {p}: {e}"),
            }
        }
        client::shutdown(&addr2);
        reports
    });

    let mut engine =
        Engine::from_ref(&model, EngineConfig { max_inflight: 8, ..Default::default() });
    let served =
        serve(&mut engine, &ServerConfig { addr, max_queue: 256, ..ServerConfig::default() })?;
    let reports = driver.join().unwrap();

    println!("\n[e2e] served {served} requests over TCP (4 connections/policy)");
    println!(
        "{:<40} {:>6} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "policy", "n", "rps", "mean ms", "p50 ms", "p99 ms", "speedup"
    );
    for (p, mut rep) in reports {
        let (mean, p50, _, p99) = rep.latency.summary();
        println!(
            "{:<40} {:>6} {:>9.2} {:>9.1} {:>9.1} {:>9.1} {:>8.2}x",
            p, rep.completed, rep.throughput_rps, mean, p50, p99, rep.mean_speedup
        );
    }

    // ---- phase 2: quality vs full-compute reference ---------------------
    let cls = ClassifierRuntime::load(&rt, &manifest.classifier)?;
    let nq = if quick { 12 } else { 32 };
    println!("\n[e2e] quality check (n={nq} matched seeds per policy):");
    let resolved = ResolvedModel::Local(std::sync::Arc::new(&model));
    let opts = RunOpts { n: nq, seed: 7, ..RunOpts::default() };
    let reference =
        run_policy(&resolved, &parse_policy("full", entry.config.depth)?, "full", &opts)?;
    println!(
        "{:<40} {:>8} {:>8} {:>8} {:>9}",
        "policy", "FID*", "IS*", "ImgRwd*", "speedup"
    );
    for desc in ["full", "fora:N=6", "taylorseer:N=5,O=2", "speca:N=5,O=2,tau0=0.3,beta=0.05"] {
        let p = parse_policy(desc, entry.config.depth)?;
        let run = run_policy(&resolved, &p, desc, &opts)?;
        let q = evaluate_quality(&run, &reference, &entry.config, &cls)?;
        let speed = (nq * entry.config.serve_steps) as f64 * entry.flops.full_step[&1] as f64
            / run.flops.total().max(1) as f64;
        println!(
            "{:<40} {:>8.3} {:>8.2} {:>8.4} {:>8.2}x",
            desc, q.fid, q.is, q.fidelity, speed
        );
    }
    println!("\n[e2e] OK — full stack (PJRT runtime → engine → TCP) exercised.");
    Ok(())
}
