//! Fig. 9 reproduction + terminal visualization: PCA trajectories of the
//! last-boundary feature under full / FORA / TaylorSeer / SpeCa policies.
//! SpeCa's path should hug the full-compute path; reuse-style caches drift.
//!
//! ```bash
//! cargo run --release --example trajectory_viz
//! ```

use anyhow::Result;
use speca::util::cli::Args;
use speca::util::json::Json;

fn main() -> Result<()> {
    let mut args = Args::from_env();
    args.positional = vec!["bench".into(), "fig9".into()];
    speca::experiments::tables::run(&args)?;

    // ASCII-render results/fig9.csv
    let csv = std::fs::read_to_string("results/fig9.csv")?;
    let mut pts: Vec<(String, f64, f64)> = Vec::new();
    for line in csv.lines().skip(1) {
        let parts: Vec<&str> = line.split(',').collect();
        if parts.len() == 4 {
            pts.push((
                parts[0].to_string(),
                parts[2].parse().unwrap_or(0.0),
                parts[3].parse().unwrap_or(0.0),
            ));
        }
    }
    let (mut min_x, mut max_x) = (f64::MAX, f64::MIN);
    let (mut min_y, mut max_y) = (f64::MAX, f64::MIN);
    for (_, x, y) in &pts {
        min_x = min_x.min(*x);
        max_x = max_x.max(*x);
        min_y = min_y.min(*y);
        max_y = max_y.max(*y);
    }
    let (w, h) = (72usize, 24usize);
    let mut grid = vec![vec![' '; w]; h];
    let glyph = |p: &str| match p {
        "full" => 'o',
        "speca" => '*',
        "taylorseer" => 't',
        _ => 'f',
    };
    for (p, x, y) in &pts {
        let cx = ((x - min_x) / (max_x - min_x + 1e-12) * (w - 1) as f64) as usize;
        let cy = ((y - min_y) / (max_y - min_y + 1e-12) * (h - 1) as f64) as usize;
        let cell = &mut grid[h - 1 - cy][cx];
        // full-path marker wins ties so overlap with speca is visible
        if *cell == ' ' || glyph(p) == 'o' {
            *cell = glyph(p);
        }
    }
    println!("\nPCA trajectory plane (o=full  *=speca  t=taylorseer  f=fora):");
    for row in grid {
        println!("  {}", row.iter().collect::<String>());
    }
    let _ = Json::Null; // keep util linked for doc purposes
    println!("\nraw data: results/fig9.csv");
    Ok(())
}
