//! Ablation sweep example: regenerates the τ0/β trade-off curves of paper
//! Tables 4/5 (and Fig. 8) through the public experiments API.
//!
//! ```bash
//! cargo run --release --example ablation_sweep -- [--quick] [--n 32]
//! ```

use anyhow::Result;
use speca::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::from_env();
    args.positional = vec!["bench".into(), "table5".into()];
    speca::experiments::tables::run(&args)?;
    args.positional = vec!["bench".into(), "table4".into()];
    speca::experiments::tables::run(&args)?;
    println!("\n(see results/table4.csv and results/table5.csv)");
    Ok(())
}
