//! Paper Fig. 9: PCA feature trajectories.
//! Regenerates the paper artifact via the shared experiments runner;
//! `cargo bench` runs the CI-sized sweep (SPECA_BENCH_FULL=1 for full n).

use speca::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    args.positional = vec!["bench".into(), "fig9".into()];
    args.flags.remove("bench"); // cargo-bench harness flag
    if std::env::var("SPECA_BENCH_FULL").is_err() && !args.flags.contains_key("n") {
        args.flags.insert("quick".into(), "true".into());
    }
    let t0 = std::time::Instant::now();
    speca::experiments::tables::run(&args)?;
    println!("[bench fig9_trajectories] wall {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
