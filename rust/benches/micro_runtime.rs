//! Micro-benchmarks of the serving hot path (EXPERIMENTS.md §Perf source):
//! per-entry PJRT execution latency across batch buckets, native vs PJRT
//! draft prediction, pallas-vs-jnp full pass, batching strategies, and the
//! L3 coordinator overhead split (engine tick time minus PJRT time).

use speca::cache::{DraftKind, TapCache};
use speca::config::Manifest;
use speca::coordinator::batcher::BatchStrategy;
use speca::coordinator::{Engine, EngineConfig};
use speca::runtime::{In, ModelRuntime, Runtime};
use speca::util::rng::Rng;
use speca::util::timing::Bench;
use speca::workload::{batch_requests, parse_policy};

fn main() -> anyhow::Result<()> {
    let dir = speca::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return Ok(());
    }
    let manifest = Manifest::load(&dir)?;
    let entry = manifest.model("dit-sim")?;
    let rt = Runtime::cpu()?;
    let model = ModelRuntime::load(&rt, entry)?;
    let cfg = &entry.config;
    let latent = cfg.latent_dim;
    let feat = cfg.tokens * cfg.dim;
    let mut rng = Rng::new(0);

    println!("== micro_runtime (dit-sim: dim={} depth={} tokens={}) ==", cfg.dim, cfg.depth, cfg.tokens);

    // --- PJRT execution latency per entry × bucket ------------------------
    for entry_point in ["full", "block", "head"] {
        for &b in &cfg.buckets {
            let x = rng.normal_f32s(b * if entry_point == "full" { latent } else { feat });
            let t: Vec<f32> = vec![entry.schedule.t_model[0]; b];
            let y: Vec<i32> = vec![0; b];
            let r = Bench::new(&format!("pjrt/{entry_point}_b{b}")).min_time_ms(300).run(|| {
                match entry_point {
                    "full" => {
                        model.full(b, &x, &t, &y, false).unwrap();
                    }
                    "block" => {
                        model.block(b, (cfg.depth - 1) as i32, &x, &t, &y).unwrap();
                    }
                    _ => {
                        model.head(b, &x, &t, &y).unwrap();
                    }
                }
            });
            println!("{}", r.report());
        }
    }

    // --- verification cost ratio (measured wall-clock gamma) -------------
    {
        let x = rng.normal_f32s(latent);
        let f = rng.normal_f32s(feat);
        let t = vec![entry.schedule.t_model[0]];
        let y = vec![0i32];
        let full = Bench::new("gamma/full_b1").min_time_ms(300).run(|| {
            model.full(1, &x, &t, &y, false).unwrap();
        });
        let block = Bench::new("gamma/block_b1").min_time_ms(300).run(|| {
            model.block(1, (cfg.depth - 1) as i32, &f, &t, &y).unwrap();
        });
        println!(
            "gamma: wall-clock block/full = {:.4} (analytic {:.4}, paper expects ~1/depth = {:.4})",
            block.p50_ns / full.p50_ns,
            entry.flops.block[&1] as f64 / entry.flops.full_step[&1] as f64,
            1.0 / cfg.depth as f64
        );
    }

    // --- draft prediction: native rust vs PJRT pallas kernel -------------
    {
        let mut cache = TapCache::new(2, feat, 5);
        for s in 0..3u64 {
            let mut r2 = Rng::new(s);
            cache.refresh(&r2.normal_f32s(feat));
        }
        let mut out = vec![0f32; feat];
        let native = Bench::new("predict/native_o2").min_time_ms(200).run(|| {
            cache.predict_into(3.0, DraftKind::Taylor, &mut out);
        });
        println!("{}", native.report());
        let mut flat = Vec::new();
        for fac in cache.factors() {
            flat.extend_from_slice(fac);
        }
        let exec = model.kernel_exec("taylor_predict")?;
        let pjrt = Bench::new("predict/pjrt_kernel_o2").min_time_ms(200).run(|| {
            exec.run(&rt, &[], &[In::F32(&flat, &[3, feat]), In::ScalarF32(3.0), In::ScalarF32(5.0)])
                .unwrap();
        });
        println!("{}", pjrt.report());
        println!(
            "predict: native is {:.1}x faster than PJRT dispatch (justifies native hot path)",
            pjrt.p50_ns / native.p50_ns
        );
    }

    // --- L1 pallas-attention artifact vs fused jnp artifact ---------------
    if entry.artifacts.contains_key("full_pallas") {
        let x = rng.normal_f32s(latent);
        let t = vec![entry.schedule.t_model[0]];
        let y = vec![0i32];
        let jnp = Bench::new("full/jnp_attention_b1").min_time_ms(300).run(|| {
            model.full(1, &x, &t, &y, false).unwrap();
        });
        println!("{}", jnp.report());
        let pal = Bench::new("full/pallas_interpret_b1").min_time_ms(300).run(|| {
            model.full(1, &x, &t, &y, true).unwrap();
        });
        println!("{}", pal.report());
        println!(
            "pallas interpret-mode overhead: {:.2}x (CPU-only artifact; Mosaic on TPU inverts this)",
            pal.p50_ns / jnp.p50_ns
        );
    }

    // --- batching strategies end-to-end -----------------------------------
    for (name, strategy) in [("binary", BatchStrategy::Binary), ("padup", BatchStrategy::PadUp)] {
        let policy = parse_policy("speca:N=5,O=2,tau0=0.3,beta=0.05", cfg.depth)?;
        let r = Bench::new(&format!("engine/6req_speca_{name}"))
            .min_time_ms(400)
            .warmup(1)
            .run(|| {
                let mut engine = Engine::new(
                    &model,
                    EngineConfig { max_inflight: 6, strategy, use_pallas: false },
                );
                for req in batch_requests(6, cfg.num_classes, &policy, 1, false) {
                    engine.submit(req);
                }
                engine.run_to_completion().unwrap();
            });
        println!("{}", r.report());
    }

    // --- coordinator overhead: cache refresh + predict per tick ----------
    {
        let mut cache = TapCache::new(2, feat, 5);
        let f = rng.normal_f32s(feat);
        let r = Bench::new("cache/refresh_o2").min_time_ms(200).run(|| {
            cache.refresh(&f);
        });
        println!("{}", r.report());
    }
    Ok(())
}
