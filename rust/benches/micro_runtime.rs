//! Micro-benchmarks of the serving hot path (EXPERIMENTS.md §Perf source):
//! native-backend entry-point latency across batch buckets, L3 coordinator
//! tick overhead at batch sizes 1/4/8 (measured against a zero-cost stub
//! backend, so model time is excluded by construction), draft-prediction
//! and cache-refresh costs, batching strategies end-to-end, the shard-pool
//! scaling sweep at 1/2/4 shards, and — when built with `--features pjrt`
//! over compiled artifacts — the PJRT execution latencies, native-vs-PJRT
//! draft prediction and the pallas-vs-jnp full pass.
//!
//! `--quick` (the CI perf-gate leg: `cargo bench --bench micro_runtime
//! -- --quick`) shrinks measurement windows and workload sizes so the
//! whole suite exercises every path in seconds.
//!
//! Besides stdout, every run writes machine-readable results to
//! `results/bench_micro.json` (`--out PATH` overrides): per-bench
//! ns/iter + allocs/iter plus the deterministic steady-state
//! allocations-per-tick probes the CI perf gate (`speca perfgate`)
//! compares against the committed `BENCH_baseline.json` —
//! EXPERIMENTS.md §Perf documents the schema and thresholds. This binary
//! installs the counting allocator, so the allocs/iter column is live.

use std::sync::Arc;
use std::time::Instant;

use speca::cache::{DraftKind, DraftRegistry, TapCache};
use speca::config::{ModelConfig, ModelEntry};
use speca::coordinator::batcher::BatchStrategy;
use speca::coordinator::{Engine, EngineConfig, EngineShardPool, PoolConfig, RouterPolicy};
use speca::runtime::kernels::{scalar, Epilogue, Gemm, KernelMode, MatA, MatB, PackBufs, Prologue};
use speca::runtime::native::{synthetic_entry, NativeArch};
use speca::runtime::{ModelBackend, NativeBackend};
use speca::tensor::Tensor;
use speca::util::alloc::CountingAllocator;
use speca::util::cli::Args;
use speca::util::json::Json;
use speca::util::rng::Rng;
use speca::util::timing::{Bench, BenchResult};
use speca::workload::{batch_requests, parse_policy};

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Print one bench row and keep it for the JSON dump.
fn emit(r: BenchResult, out: &mut Vec<BenchResult>) {
    println!("{}", r.report());
    out.push(r);
}

/// Zero-cost backend: every entry point returns zeros immediately, so an
/// engine driving it measures pure coordinator overhead (planning, draft
/// prediction, gathers, bookkeeping).
struct StubBackend {
    entry: ModelEntry,
}

impl StubBackend {
    fn new() -> StubBackend {
        StubBackend {
            entry: synthetic_entry(&ModelConfig::native_test(), &NativeArch::default()),
        }
    }
}

impl ModelBackend for StubBackend {
    fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn kind(&self) -> &'static str {
        "stub"
    }

    fn supports(&self, entry_point: &str) -> bool {
        matches!(entry_point, "full" | "full_eps" | "block" | "head")
    }

    fn warmup(&self, _e: &[&str], _b: &[usize]) -> anyhow::Result<()> {
        Ok(())
    }

    fn full(
        &self,
        bucket: usize,
        _x: &[f32],
        _t: &[f32],
        _y: &[i32],
        _pallas: bool,
    ) -> anyhow::Result<(Tensor, Tensor)> {
        let c = &self.entry.config;
        Ok((
            Tensor::zeros(vec![bucket, c.latent_dim]),
            Tensor::zeros(vec![c.depth + 1, bucket, c.tokens, c.dim]),
        ))
    }

    fn full_eps(
        &self,
        bucket: usize,
        _x: &[f32],
        _t: &[f32],
        _y: &[i32],
    ) -> anyhow::Result<Tensor> {
        Ok(Tensor::zeros(vec![bucket, self.entry.config.latent_dim]))
    }

    fn block(
        &self,
        bucket: usize,
        _layer: i32,
        _feat: &[f32],
        _t: &[f32],
        _y: &[i32],
    ) -> anyhow::Result<Tensor> {
        let c = &self.entry.config;
        Ok(Tensor::zeros(vec![bucket, c.tokens, c.dim]))
    }

    fn head(&self, bucket: usize, _f: &[f32], _t: &[f32], _y: &[i32]) -> anyhow::Result<Tensor> {
        Ok(Tensor::zeros(vec![bucket, self.entry.config.latent_dim]))
    }
}

/// Steady-state tick benchmark: keep `b` requests in flight forever and
/// time individual `tick()` calls (resubmission happens outside the timed
/// closure often enough to amortize to noise; those admission
/// allocations are folded into the allocs/iter column — the strict
/// zero-allocation claim belongs to the `alloc/steady_tick_*` probes).
fn bench_ticks(name: &str, model: &dyn ModelBackend, b: usize, ms: u64) -> BenchResult {
    let cfg = &model.entry().config;
    let policy = parse_policy("speca:N=5,O=2,tau0=0.3,beta=0.05", cfg.depth).unwrap();
    let mut engine = Engine::from_ref(
        model,
        EngineConfig { max_inflight: b, ..EngineConfig::default() },
    );
    let mut seed = 0u64;
    Bench::new(name).min_time_ms(ms).run_counting(|| {
        if engine.pending() == 0 {
            seed += 1;
            for req in batch_requests(b, cfg.num_classes, &policy, seed, false) {
                engine.submit(req);
            }
        }
        engine.tick().unwrap();
        engine.drain_completions();
    })
}

/// Dump every bench row + the steady-state probes as
/// `results/bench_micro.json` (schema: EXPERIMENTS.md §Perf).
fn write_json(
    path: &str,
    quick: bool,
    results: &[BenchResult],
    steady: &[(String, u64)],
) -> anyhow::Result<()> {
    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::str(&r.name)),
                ("iters", Json::Num(r.iters as f64)),
                ("mean_ns", Json::Num(r.mean_ns)),
                ("p50_ns", Json::Num(r.p50_ns)),
                ("p99_ns", Json::Num(r.p99_ns)),
                ("min_ns", Json::Num(r.min_ns)),
                ("allocs_per_iter", r.allocs_per_iter.map(Json::Num).unwrap_or(Json::Null)),
            ])
        })
        .collect();
    let steady_rows: Vec<(&str, Json)> =
        steady.iter().map(|(k, v)| (k.as_str(), Json::Num(*v as f64))).collect();
    let doc = Json::obj(vec![
        ("schema", Json::str("speca-bench-v1")),
        ("mode", Json::str(if quick { "quick" } else { "full" })),
        ("results", Json::Arr(rows)),
        ("steady_state", Json::obj(steady_rows)),
    ]);
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, doc.dump() + "\n")?;
    println!("wrote {path}");
    Ok(())
}

/// Shard-scaling sweep: push one fixed closed-loop workload through the
/// pool at 1/2/4 shards and report wall time, merged tick count and tick
/// throughput. With a shared `Send + Sync` backend this should scale until
/// the host runs out of cores.
fn bench_shard_sweep(model: &Arc<NativeBackend>, quick: bool) -> anyhow::Result<()> {
    let cfg = model.entry().config.clone();
    let policy = parse_policy("speca:N=5,O=2,tau0=0.3,beta=0.05", cfg.depth).unwrap();
    let n = if quick { 8 } else { 32 };
    let mut base_wall = 0.0f64;
    for shards in [1usize, 2, 4] {
        let pool = EngineShardPool::new(
            model.clone(),
            PoolConfig {
                shards,
                router: RouterPolicy::LeastLoaded,
                engine: EngineConfig { max_inflight: 4, ..EngineConfig::default() },
                steal: false,
            },
        );
        let t0 = Instant::now();
        for req in batch_requests(n, cfg.num_classes, &policy, 7, false) {
            pool.submit(req)?;
        }
        let out = pool.shutdown(true)?;
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(out.completions.len(), n, "shard sweep lost completions");
        if shards == 1 {
            base_wall = wall;
        }
        println!(
            "pool/shard_sweep_s{shards}: n={n} wall {:.1} ms  ticks {}  \
             {:.0} ticks/s  {:.1} req/s  speedup vs 1 shard {:.2}x",
            wall * 1e3,
            out.stats.ticks,
            out.stats.ticks as f64 / wall,
            n as f64 / wall,
            base_wall / wall
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let quick = args.bool("quick");
    let out_path = args.str("out", "results/bench_micro.json");
    // measurement window per bench: long enough for stable p50s normally,
    // just-touch-every-path in the CI perf-gate leg
    let ms: u64 = if quick { 10 } else { 200 };
    let model = Arc::new(NativeBackend::seeded(ModelConfig::native_test(), 0xBEEF));
    let entry = model.entry();
    let cfg = entry.config.clone();
    let latent = cfg.latent_dim;
    let feat = cfg.tokens * cfg.dim;
    let mut rng = Rng::new(0);
    let mut results: Vec<BenchResult> = Vec::new();

    println!(
        "== micro_runtime (native {}: dim={} depth={} tokens={}{}) ==",
        cfg.name,
        cfg.dim,
        cfg.depth,
        cfg.tokens,
        if quick { ", quick mode" } else { "" }
    );

    // --- native execution latency per entry × bucket ----------------------
    for entry_point in ["full", "block", "head"] {
        for &b in &cfg.buckets {
            let x = rng.normal_f32s(b * if entry_point == "full" { latent } else { feat });
            let t: Vec<f32> = vec![entry.schedule.t_model[0]; b];
            let y: Vec<i32> = vec![0; b];
            let r = Bench::new(&format!("native/{entry_point}_b{b}"))
                .min_time_ms(ms)
                .run_counting(|| match entry_point {
                    "full" => {
                        model.full(b, &x, &t, &y, false).unwrap();
                    }
                    "block" => {
                        model.block(b, (cfg.depth - 1) as i32, &x, &t, &y).unwrap();
                    }
                    _ => {
                        model.head(b, &x, &t, &y).unwrap();
                    }
                });
            emit(r, &mut results);
        }
    }

    // --- verification cost ratio (measured wall-clock gamma) --------------
    {
        let x = rng.normal_f32s(latent);
        let f = rng.normal_f32s(feat);
        let t = vec![entry.schedule.t_model[0]];
        let y = vec![0i32];
        let full = Bench::new("gamma/full_b1").min_time_ms(ms).run_counting(|| {
            model.full(1, &x, &t, &y, false).unwrap();
        });
        let block = Bench::new("gamma/block_b1").min_time_ms(ms).run_counting(|| {
            model.block(1, (cfg.depth - 1) as i32, &f, &t, &y).unwrap();
        });
        println!(
            "gamma: wall-clock block/full = {:.4} (analytic {:.4}, paper expects ~1/depth = {:.4})",
            block.p50_ns / full.p50_ns,
            entry.flops.block[&1] as f64 / entry.flops.full_step[&1] as f64,
            1.0 / cfg.depth as f64
        );
        results.push(full);
        results.push(block);
    }

    // --- kernel layer: blocked GEMM + fused block vs the scalar oracle ----
    // Paired rows measured in one process via KernelMode, so the CI
    // perf-gate leg sees the blocked-vs-naive speedup on its own runner
    // (EXPERIMENTS.md §Perf records the procedure).
    let scalar_model = NativeBackend::seeded(ModelConfig::native_test(), 0xBEEF)
        .with_kernel_mode(KernelMode::Scalar);
    {
        // dit-sim qkv projection shape: [64, 64] @ [64, 192]
        let (m, k, n) = (64usize, 64usize, 192usize);
        let a = rng.normal_f32s(m * k);
        let w = rng.normal_f32s(k * n);
        let bias = rng.normal_f32s(n);
        let mut out = vec![0f32; m * n];
        let mut pa = vec![0f32; m * k];
        let mut pb = vec![0f32; k * speca::runtime::kernels::NR];
        let blocked = Bench::new("kernel/gemm_m64k64n192").min_time_ms(ms).run_counting(|| {
            Gemm {
                m,
                k,
                n,
                a: MatA::dense(&a, k),
                b: MatB::dense(&w, n),
                prologue: Prologue::None,
                bias: Some(&bias),
                epilogue: Epilogue::None,
            }
            .run(&mut out, n, &mut PackBufs { a: &mut pa, b: &mut pb });
        });
        let naive = Bench::new("kernel/gemm_m64k64n192_scalar").min_time_ms(ms).run_counting(|| {
            scalar::matmul_add(&a, &w, &bias, m, k, n, &mut out);
        });
        println!(
            "kernel: blocked gemm is {:.2}x the scalar reference",
            naive.p50_ns / blocked.p50_ns
        );
        emit(blocked, &mut results);
        emit(naive, &mut results);
    }
    {
        let f = rng.normal_f32s(feat);
        let t = vec![entry.schedule.t_model[0]];
        let y = vec![0i32];
        let blocked = Bench::new("kernel/block_apply").min_time_ms(ms).run_counting(|| {
            model.block(1, 0, &f, &t, &y).unwrap();
        });
        let naive = Bench::new("kernel/block_apply_scalar").min_time_ms(ms).run_counting(|| {
            scalar_model.block(1, 0, &f, &t, &y).unwrap();
        });
        println!(
            "kernel: fused block apply is {:.2}x the scalar reference",
            naive.p50_ns / blocked.p50_ns
        );
        emit(blocked, &mut results);
        emit(naive, &mut results);
    }

    // --- L3 coordinator overhead: tick time at batch sizes 1/4/8 ----------
    // Stub backend ⇒ model time is zero, so this is the pure per-tick cost
    // of planning + draft prediction + scratch gathers + bookkeeping.
    // These rows (and the alloc/steady probes below) are what the CI
    // perf gate tracks against BENCH_baseline.json.
    let stub = StubBackend::new();
    for b in [1usize, 4, 8] {
        let r = bench_ticks(&format!("engine/tick_overhead_b{b}_stub"), &stub, b, ms);
        emit(r, &mut results);
    }
    // Same loop against the real native model for scale, plus the scalar
    // kernel path at b=1/4 — the pair behind the headline speedup.
    for b in [1usize, 4, 8] {
        let r = bench_ticks(&format!("engine/tick_b{b}_native"), &*model, b, ms);
        emit(r, &mut results);
    }
    for b in [1usize, 4] {
        let r = bench_ticks(&format!("engine/tick_b{b}_scalar"), &scalar_model, b, ms);
        emit(r, &mut results);
    }
    let p50 = |rows: &[BenchResult], name: &str| -> f64 {
        rows.iter().find(|r| r.name == name).map(|r| r.p50_ns).unwrap_or(f64::NAN)
    };
    for b in [1usize, 4] {
        println!(
            "kernel speedup: engine/tick_b{b}_native p50 is {:.2}x faster than the scalar path",
            p50(&results, &format!("engine/tick_b{b}_scalar"))
                / p50(&results, &format!("engine/tick_b{b}_native"))
        );
    }

    // --- steady-state allocation discipline (the perf gate's hard rule,
    // measured by the same shared probe tests/alloc_discipline.rs asserts)
    let mut steady: Vec<(String, u64)> = Vec::new();
    for b in [1usize, 4] {
        let (allocs, ticks) = speca::workload::steady_state_alloc_probe(&model, b)?;
        println!(
            "alloc/steady_tick_b{b}: {allocs} allocations across {ticks} steady-state ticks \
             (expected 0)"
        );
        steady.push((format!("steady_tick_allocs_b{b}"), allocs));
    }

    // --- draft prediction + cache refresh (native hot path) ---------------
    {
        let mut cache = TapCache::new(2, feat, 5);
        for s in 0..3u64 {
            let mut r2 = Rng::new(s);
            cache.refresh(&r2.normal_f32s(feat));
        }
        let mut out = vec![0f32; feat];
        let native = Bench::new("predict/native_o2").min_time_ms(ms).run_counting(|| {
            cache.predict_into(3.0, DraftKind::Taylor, &mut out);
        });
        emit(native, &mut results);
        // every registered strategy through the trait-object path
        // (EXPERIMENTS.md §Drafts: trait-dispatch overhead vs the enum
        // path, and the relative cost of the new richardson /
        // learned-linear drafts, read straight off these rows)
        for name in DraftRegistry::global().names() {
            let strategy = DraftRegistry::global().resolve(name).unwrap();
            let r = Bench::new(&format!("predict/strategy_{name}"))
                .min_time_ms(ms)
                .run_counting(|| {
                    cache.predict_with(&*strategy, 3.0, &mut out);
                });
            emit(r, &mut results);
        }
        let f = rng.normal_f32s(feat);
        let r = Bench::new("cache/refresh_o2").min_time_ms(ms).run_counting(|| {
            cache.refresh(&f);
        });
        emit(r, &mut results);
    }

    // --- batching strategies end-to-end ------------------------------------
    for (name, strategy) in [("binary", BatchStrategy::Binary), ("padup", BatchStrategy::PadUp)] {
        let policy = parse_policy("speca:N=5,O=2,tau0=0.3,beta=0.05", cfg.depth)?;
        let r = Bench::new(&format!("engine/6req_speca_{name}"))
            .min_time_ms(ms)
            .warmup(1)
            .run_counting(|| {
                let mut engine = Engine::from_ref(
                    &*model,
                    EngineConfig { max_inflight: 6, strategy, use_pallas: false },
                );
                for req in batch_requests(6, cfg.num_classes, &policy, 1, false) {
                    engine.submit(req);
                }
                engine.run_to_completion().unwrap();
            });
        emit(r, &mut results);
    }

    // --- shard-pool scaling: 1/2/4 engine workers over one backend --------
    bench_shard_sweep(&model, quick)?;

    write_json(&out_path, quick, &results, &steady)?;

    #[cfg(feature = "pjrt")]
    pjrt_benches()?;
    Ok(())
}

/// PJRT-vs-native comparisons; requires `make artifacts`.
#[cfg(feature = "pjrt")]
fn pjrt_benches() -> anyhow::Result<()> {
    use speca::config::Manifest;
    use speca::runtime::{In, ModelRuntime, Runtime};

    let dir = speca::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP pjrt benches: artifacts not built");
        return Ok(());
    }
    let manifest = Manifest::load(&dir)?;
    let entry = manifest.model("dit-sim")?;
    let rt = Runtime::cpu()?;
    let model = ModelRuntime::load(&rt, entry)?;
    let cfg = &entry.config;
    let latent = cfg.latent_dim;
    let feat = cfg.tokens * cfg.dim;
    let mut rng = Rng::new(0);

    println!(
        "== pjrt (dit-sim: dim={} depth={} tokens={}) ==",
        cfg.dim, cfg.depth, cfg.tokens
    );

    // --- PJRT execution latency per entry × bucket ------------------------
    for entry_point in ["full", "block", "head"] {
        for &b in &cfg.buckets {
            let x = rng.normal_f32s(b * if entry_point == "full" { latent } else { feat });
            let t: Vec<f32> = vec![entry.schedule.t_model[0]; b];
            let y: Vec<i32> = vec![0; b];
            let r = Bench::new(&format!("pjrt/{entry_point}_b{b}")).min_time_ms(300).run(|| {
                match entry_point {
                    "full" => {
                        ModelRuntime::full(&model, b, &x, &t, &y, false).unwrap();
                    }
                    "block" => {
                        ModelRuntime::block(&model, b, (cfg.depth - 1) as i32, &x, &t, &y)
                            .unwrap();
                    }
                    _ => {
                        ModelRuntime::head(&model, b, &x, &t, &y).unwrap();
                    }
                }
            });
            println!("{}", r.report());
        }
    }

    // --- draft prediction: native rust vs PJRT pallas kernel -------------
    {
        let mut cache = TapCache::new(2, feat, 5);
        for s in 0..3u64 {
            let mut r2 = Rng::new(s);
            cache.refresh(&r2.normal_f32s(feat));
        }
        let mut out = vec![0f32; feat];
        let native = Bench::new("predict/native_o2").min_time_ms(200).run(|| {
            cache.predict_into(3.0, DraftKind::Taylor, &mut out);
        });
        println!("{}", native.report());
        let mut flat = Vec::new();
        for fac in cache.factors() {
            flat.extend_from_slice(fac);
        }
        let exec = model.kernel_exec("taylor_predict")?;
        let pjrt = Bench::new("predict/pjrt_kernel_o2").min_time_ms(200).run(|| {
            exec.run(&rt, &[], &[In::F32(&flat, &[3, feat]), In::ScalarF32(3.0), In::ScalarF32(5.0)])
                .unwrap();
        });
        println!("{}", pjrt.report());
        println!(
            "predict: native is {:.1}x faster than PJRT dispatch (justifies native hot path)",
            pjrt.p50_ns / native.p50_ns
        );
    }

    // --- L1 pallas-attention artifact vs fused jnp artifact ---------------
    if entry.artifacts.contains_key("full_pallas") {
        let x = rng.normal_f32s(latent);
        let t = vec![entry.schedule.t_model[0]];
        let y = vec![0i32];
        let jnp = Bench::new("full/jnp_attention_b1").min_time_ms(300).run(|| {
            ModelRuntime::full(&model, 1, &x, &t, &y, false).unwrap();
        });
        println!("{}", jnp.report());
        let pal = Bench::new("full/pallas_interpret_b1").min_time_ms(300).run(|| {
            ModelRuntime::full(&model, 1, &x, &t, &y, true).unwrap();
        });
        println!("{}", pal.report());
        println!(
            "pallas interpret-mode overhead: {:.2}x (CPU-only artifact; Mosaic on TPU inverts this)",
            pal.p50_ns / jnp.p50_ns
        );
    }
    Ok(())
}
