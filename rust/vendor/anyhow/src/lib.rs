//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io access, so this path dependency
//! provides exactly the API subset the workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`]/[`bail!`] macros and the [`Context`]
//! extension trait for `Result` and `Option`. Errors are message chains
//! (context is prepended, as in real anyhow's `{:#}` rendering); source
//! errors are stringified at conversion time.

use std::fmt;

/// A string-backed error with prepended context, mirroring anyhow's
/// user-visible behaviour for `Display`/`Debug`.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`, which
// is what makes this blanket conversion coherent (same trick as anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error branch of a `Result` or to a `None`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_prepends() {
        let r: Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            "inner",
        ));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        fn f() -> Result<()> {
            bail!("boom {}", 1)
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 1");
    }
}
