//! Compile-surface stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The offline build image has no XLA toolchain, so this crate lets the
//! `pjrt` feature *compile* without it: every entry point type-checks
//! against the API subset `speca::runtime::pjrt` uses, and the only
//! reachable constructor ([`PjRtClient::cpu`]) returns an error telling
//! the operator to link the real bindings. To run on actual PJRT, replace
//! this directory with a checkout of xla-rs (same crate name, superset
//! API) — no source change in `speca` is needed.

use std::path::Path;

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: built against the stub `xla` crate; \
     replace rust/vendor/xla with the real xla-rs bindings (DESIGN.md §3) \
     or rerun with --backend native";

/// Error type mirroring xla-rs: only `Debug` formatting is relied upon.
pub struct Error(String);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl Error {
    fn unavailable() -> Error {
        Error(UNAVAILABLE.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types transferable to device buffers.
pub trait Element: Copy {}
impl Element for f32 {}
impl Element for i32 {}

pub struct PjRtClient(());

impl PjRtClient {
    /// Always fails in the stub: there is no PJRT C API to bind.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }

    pub fn buffer_from_host_buffer<T: Element>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable())
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

pub struct Literal(());

impl Literal {
    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable())
    }
}

pub struct ArrayShape(Vec<i64>);

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not create clients");
        assert!(format!("{err:?}").contains("stub"));
    }
}
