//! `speca` CLI — leader entrypoint for the SpeCa serving stack.
//!
//! Subcommands:
//!   info                          — show backend/model inventory
//!   generate [--model M] [--policy P] [--n N] ...   — closed-loop batch
//!   serve    [--model M] [--addr A]                 — TCP JSON-lines server
//!   load     [--addr A] [--n N] [--conns C]         — load generator
//!   bench    <table1..8|fig2|fig6|fig8|fig9|speedup-law> — experiment runners
//!            (micro perf data: `cargo bench --bench micro_runtime`)
//!
//! Every command takes `--backend native|pjrt|auto` (default auto): the
//! pure-Rust native backend needs no artifacts at all; the PJRT backend
//! (cargo feature `pjrt`) executes the AOT HLO artifacts (DESIGN.md §3).

use anyhow::{bail, Result};

#[cfg(feature = "pjrt")]
use speca::config::Manifest;
use speca::coordinator::batcher::BatchStrategy;
use speca::coordinator::{Engine, EngineConfig};
use speca::runtime::{select_backend, BackendKind, ClassifierBackend, ModelBackend, NativeHub};
#[cfg(feature = "pjrt")]
use speca::runtime::{ModelRuntime, Runtime};
use speca::server::{self, client, ServerConfig};
use speca::util::cli::Args;
use speca::workload;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => info(&args),
        "generate" => generate(&args),
        "serve" => serve(&args),
        "load" => load(&args),
        "bench" => speca::experiments::tables::run(&args),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
speca — speculative feature caching for diffusion transformers (MM'25 repro)

USAGE: speca <command> [--flags]

COMMANDS:
  info                       backend + model inventory (configs, FLOPs)
  generate                   run a closed-loop batch through the engine
      --model dit-sim --policy speca:N=5,O=2,tau0=0.3,beta=0.05 --n 8
      --inflight 8 --strategy binary --seed 0 --dump-pgm out/
  serve                      start the TCP JSON-lines server
      --model dit-sim --addr 127.0.0.1:7433 --inflight 8
  load                       closed-loop load generator against a server
      --addr 127.0.0.1:7433 --n 32 --conns 4 --policy speca
  bench <name>               regenerate a paper table/figure (see DESIGN.md)
      table1..table8 | fig2|fig6|fig8|fig9 | speedup-law  [--quick] [--n N]
      (micro perf: cargo bench --bench micro_runtime)

BACKENDS (--backend native|pjrt|auto, default auto):
  native   pure-Rust DiT forward, seeded weights, zero artifacts needed
  pjrt     AOT HLO artifacts via PJRT (requires --features pjrt build and
           ./artifacts from `make artifacts`; override with SPECA_ARTIFACTS)
  --model-seed N             seed for the native models (default fixed)
";

fn backend_kind(args: &Args) -> Result<BackendKind> {
    select_backend(
        &args.str("backend", "auto"),
        speca::artifacts_dir().join("manifest.json").exists(),
    )
}

fn info(args: &Args) -> Result<()> {
    match backend_kind(args)? {
        BackendKind::Native => {
            let hub = NativeHub::seeded(args.u64("model-seed", NativeHub::DEFAULT_SEED));
            println!("backend: native (seeded, zero artifacts)");
            for (name, m) in hub.models() {
                print_model(name, m);
            }
            println!(
                "classifier: native feat_dim={} classes={}",
                hub.classifier.feat_dim(),
                hub.classifier.num_classes(),
            );
            Ok(())
        }
        BackendKind::Pjrt => pjrt_info(),
    }
}

fn print_model(name: &str, m: &dyn ModelBackend) {
    let e = m.entry();
    let c = &e.config;
    println!(
        "model {name} [{}]: dim={} depth={} heads={} tokens={} latent={} classes={} \
         schedule={:?} steps={} buckets={:?}",
        m.kind(),
        c.dim,
        c.depth,
        c.heads,
        c.tokens,
        c.latent_dim,
        c.num_classes,
        c.schedule_kind,
        c.serve_steps,
        c.buckets
    );
    println!(
        "  flops/full-step(b1)={:.3} MF  block={:.3} MF (gamma≈{:.4})",
        e.flops.full_step[&1] as f64 / 1e6,
        e.flops.block[&1] as f64 / 1e6,
        e.flops.block[&1] as f64 / e.flops.full_step[&1] as f64
    );
}

#[cfg(feature = "pjrt")]
fn pjrt_info() -> Result<()> {
    let manifest = Manifest::load(&speca::artifacts_dir())?;
    println!("artifacts: {}", manifest.root.display());
    for (name, m) in &manifest.models {
        let c = &m.config;
        println!(
            "model {name}: dim={} depth={} heads={} tokens={} latent={} classes={} \
             schedule={:?} steps={} buckets={:?}",
            c.dim, c.depth, c.heads, c.tokens, c.latent_dim, c.num_classes,
            c.schedule_kind, c.serve_steps, c.buckets
        );
        println!(
            "  flops/full-step(b1)={:.3} MF  block={:.3} MF (gamma≈{:.4})",
            m.flops.full_step[&1] as f64 / 1e6,
            m.flops.block[&1] as f64 / 1e6,
            m.flops.block[&1] as f64 / m.flops.full_step[&1] as f64
        );
        for (entry, buckets) in &m.artifacts {
            println!("  artifact {entry}: buckets {:?}", buckets.keys().collect::<Vec<_>>());
        }
    }
    println!(
        "classifier: feat_dim={} classes={} held-out acc={:.3}",
        manifest.classifier.feat_dim, manifest.classifier.num_classes, manifest.classifier.acc
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_info() -> Result<()> {
    unreachable!("select_backend rejects pjrt without the feature")
}

fn engine_config(args: &Args) -> Result<EngineConfig> {
    let strategy = args.str("strategy", "binary");
    let Some(strategy) = BatchStrategy::parse(&strategy) else {
        bail!("unknown strategy '{strategy}'");
    };
    Ok(EngineConfig {
        max_inflight: args.usize("inflight", 8),
        strategy,
        use_pallas: args.bool("pallas"),
    })
}

/// Run `f` against the model backend the flags select.
fn with_model(args: &Args, f: impl FnOnce(&dyn ModelBackend, &Args) -> Result<()>) -> Result<()> {
    let model_name = args.str("model", "dit-sim");
    match backend_kind(args)? {
        BackendKind::Native => {
            let hub = NativeHub::seeded(args.u64("model-seed", NativeHub::DEFAULT_SEED));
            return f(hub.model(&model_name)?, args);
        }
        BackendKind::Pjrt => {
            #[cfg(feature = "pjrt")]
            {
                let manifest = Manifest::load(&speca::artifacts_dir())?;
                let entry = manifest.model(&model_name)?;
                let rt = Runtime::cpu()?;
                let model = ModelRuntime::load(&rt, entry)?;
                return f(&model, args);
            }
            #[cfg(not(feature = "pjrt"))]
            {
                unreachable!("select_backend rejects pjrt without the feature");
            }
        }
    }
}

fn generate(args: &Args) -> Result<()> {
    with_model(args, |model, args| {
        let entry = model.entry();
        let mut engine = Engine::new(model, engine_config(args)?);

        let policy = workload::parse_policy(
            &args.str("policy", "speca:N=5,O=2,tau0=0.3,beta=0.05"),
            entry.config.depth,
        )?;
        let n = args.usize("n", 8);
        let reqs = workload::batch_requests(
            n,
            entry.config.num_classes,
            &policy,
            args.u64("seed", 0),
            false,
        );
        let t0 = std::time::Instant::now();
        for r in reqs {
            engine.submit(r);
        }
        let completions = engine.run_to_completion()?;
        let wall = t0.elapsed().as_secs_f64();

        let full_flops = entry.flops.full_step[&1];
        let steps = entry.config.serve_steps;
        println!(
            "{:<6} {:<10} {:>6} {:>6} {:>6} {:>7} {:>9} {:>9}",
            "id", "policy", "full", "spec", "rej", "lat ms", "GFLOPs", "speedup"
        );
        for c in &completions {
            let s = &c.stats;
            println!(
                "{:<6} {:<10} {:>6} {:>6} {:>6} {:>7.1} {:>9.4} {:>8.2}x",
                c.id,
                c.policy_name,
                s.full_steps,
                s.spec_steps + s.skip_steps + s.blend_steps,
                s.rejects,
                s.latency_ms,
                s.flops.total() as f64 / 1e9,
                s.speedup(full_flops, steps)
            );
        }
        let f = &engine.flops;
        println!(
            "batch: n={n} backend={} wall={wall:.2}s throughput={:.2} req/s alpha={:.3} \
             gamma={:.4} agg-speedup={:.2}x (law predicts {:.2}x)",
            model.kind(),
            n as f64 / wall,
            f.acceptance_rate(),
            f.gamma(),
            f.speedup(full_flops),
            f.predicted_speedup()
        );

        if let Some(dir) = args.opt("dump-pgm") {
            speca::experiments::runner::dump_pgm(&completions, &entry.config, dir)?;
            println!("wrote sample grids to {dir}/");
        }
        Ok(())
    })
}

fn serve(args: &Args) -> Result<()> {
    with_model(args, |model, args| {
        // prepare the hot entry points before admitting traffic
        model.warmup(&["full", "block", "head"], &model.entry().config.buckets)?;
        let mut engine = Engine::new(model, engine_config(args)?);
        let cfg = ServerConfig { addr: args.str("addr", "127.0.0.1:7433"), max_queue: 1024 };
        let done = server::serve(&mut engine, &cfg)?;
        println!("served {done} requests");
        Ok(())
    })
}

fn load(args: &Args) -> Result<()> {
    let cfg = client::LoadConfig {
        addr: args.str("addr", "127.0.0.1:7433"),
        connections: args.usize("conns", 4),
        requests: args.usize("n", 32),
        policy: args.str("policy", "speca:N=5,O=2"),
        num_classes: args.usize("classes", 8),
    };
    let mut report = client::run_load(&cfg)?;
    if report.completed == 0 {
        bail!("no requests completed (is the server running at {}?)", cfg.addr);
    }
    let (mean, p50, p95, p99) = report.latency.summary();
    println!(
        "completed={} errors={} wall={:.2}s throughput={:.2} req/s",
        report.completed, report.errors, report.wall_s, report.throughput_rps
    );
    println!(
        "latency ms: mean={mean:.1} p50={p50:.1} p95={p95:.1} p99={p99:.1}  \
         mean FLOPs-speedup={:.2}x",
        report.mean_speedup
    );
    Ok(())
}
