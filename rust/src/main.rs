//! `speca` CLI — leader entrypoint for the SpeCa serving stack.
//!
//! Subcommands:
//!   info                          — show backend/model inventory
//!   generate [--model M] [--policy P] [--n N] [--shards S] ...  — closed-loop batch
//!   serve    [--model M] [--addr A] [--shards S]                — TCP JSON-lines server
//!   load     [--addr A] [--n N] [--conns C]                     — load generator
//!   bench    <table1..8|drafts|adaptive|lookahead|serve-openloop|fig…>  — experiment runners
//!            (micro perf data: `cargo bench --bench micro_runtime`)
//!
//! Every command takes `--backend native|pjrt|auto` (default auto): the
//! pure-Rust native backend needs no artifacts at all; the PJRT backend
//! (cargo feature `pjrt`) executes the AOT HLO artifacts (DESIGN.md §3).
//! `--shards N` runs N engine worker threads over one shared backend
//! (native only — the PJRT client is single-threaded).

use anyhow::{bail, Result};

use speca::coordinator::batcher::BatchStrategy;
use speca::coordinator::Engine;
use speca::experiments::runner::{run_policy, RunOpts};
use speca::runtime::resolve::{self, BackendRequest};
use speca::runtime::{BackendKind, ModelBackend, NativeHub};
use speca::server::{self, client, ServerConfig};
use speca::util::cli::Args;
use speca::workload;

fn main() -> Result<()> {
    let args = Args::from_env();
    if args.bool("list-drafts") {
        return list_drafts();
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => info(&args),
        "generate" => generate(&args),
        "serve" => serve(&args),
        "load" => load(&args),
        "bench" => speca::experiments::tables::run(&args),
        "perfgate" => perfgate(&args),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

/// `speca perfgate --baseline B.json --current C.json [--tol 0.25]
/// [--metric p50_ns|min_ns]`: compare a `micro_runtime` bench JSON
/// against a baseline (EXPERIMENTS.md §Perf). Two rules:
///
/// * **steady-state allocs** — hard zero-regression: every
///   `steady_state` counter in the baseline must be present and no
///   larger in the current run (the committed baseline pins them at 0);
/// * **tick overhead** — for every name in the baseline's `time_gated`
///   list, the current time metric (default `p50_ns`; `min_ns` is the
///   jitter-resistant choice for noisy shared runners) must sit within
///   ±`tol` of the baseline's (a `null` baseline time skips that row
///   with a warning — used by the committed baseline, which gates allocs
///   machine-independently while CI gets its ±25% time check by
///   comparing two same-runner runs).
fn perfgate(args: &Args) -> Result<()> {
    use speca::util::json::Json;

    let baseline_path = args.str("baseline", "BENCH_baseline.json");
    let current_path = args.str("current", "results/bench_micro.json");
    let tol = args.f64("tol", 0.25);
    let metric = args.str("metric", "p50_ns");
    if !matches!(metric.as_str(), "p50_ns" | "min_ns" | "mean_ns" | "p99_ns") {
        bail!("--metric must be one of p50_ns|min_ns|mean_ns|p99_ns, got '{metric}'");
    }
    let load_json = |path: &str| -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))
    };
    let baseline = load_json(&baseline_path)?;
    let current = load_json(&current_path)?;
    let mut failures: Vec<String> = Vec::new();

    // hard rule: steady-state allocation counters must not regress
    if let Some(steady) = baseline.get("steady_state").and_then(|s| s.as_obj()) {
        let cur_steady = current.get("steady_state");
        for (key, want) in steady {
            let want = want.as_f64().unwrap_or(0.0);
            match cur_steady.and_then(|s| s.get(key)).and_then(|v| v.as_f64()) {
                Some(got) if got <= want => {
                    println!("perfgate: PASS  {key} = {got} (baseline {want})");
                }
                Some(got) => failures.push(format!(
                    "{key}: {got} steady-state allocations regress the baseline of {want}"
                )),
                None => failures.push(format!("{key}: missing from {current_path}")),
            }
        }
    }

    // tolerance rule: gated bench rows stay within ±tol of the baseline
    // time metric
    let row_time = |doc: &Json, name: &str| -> Option<f64> {
        doc.get("results")?.as_arr()?.iter().find_map(|r| {
            if r.get("name").and_then(|n| n.as_str()) == Some(name) {
                r.get(&metric).and_then(|v| v.as_f64())
            } else {
                None
            }
        })
    };
    if let Some(gated) = baseline.get("time_gated").and_then(|g| g.as_arr()) {
        for name in gated.iter().filter_map(|n| n.as_str()) {
            let Some(base) = row_time(&baseline, name) else {
                println!(
                    "perfgate: SKIP  {name} (baseline time is null — alloc gate only; \
                     run the bench twice and compare run-vs-run for a same-machine time check)"
                );
                continue;
            };
            match row_time(&current, name) {
                Some(cur) if (cur - base).abs() <= tol * base => println!(
                    "perfgate: PASS  {name} {metric} {cur:.0} ns within ±{:.0}% of {base:.0} ns",
                    tol * 100.0
                ),
                Some(cur) => failures.push(format!(
                    "{name}: {metric} {cur:.0} ns outside ±{:.0}% of baseline {base:.0} ns",
                    tol * 100.0
                )),
                None => failures.push(format!("{name}: missing from {current_path}")),
            }
        }
    }

    if !failures.is_empty() {
        bail!("perf gate failed:\n  {}", failures.join("\n  "));
    }
    println!("perfgate: OK ({current_path} vs {baseline_path}, tol {tol})");
    Ok(())
}

/// `speca --list-drafts`: print the draft-strategy registry.
fn list_drafts() -> Result<()> {
    println!("registered draft strategies (--draft <name> / policy draft=<name>):");
    for (name, blurb) in speca::cache::DraftRegistry::global().list() {
        println!("  {name:<16} {blurb}");
    }
    println!("\nmath + trait contract: DESIGN.md §10; comparison table: EXPERIMENTS.md §Drafts");
    Ok(())
}

const HELP: &str = "\
speca — speculative feature caching for diffusion transformers (MM'25 repro)

USAGE: speca <command> [--flags]

COMMANDS:
  info                       backend + model inventory (configs, FLOPs)
  generate                   run a closed-loop batch through the engine
      --model dit-sim --policy speca:N=5,O=2,tau0=0.3,beta=0.05 --n 8
      --inflight 8 --shards 1 --strategy binary --seed 0 --dump-pgm out/
      --lookahead K          cap SpeCa lookahead runs at K speculated
                             steps per verify point (policy key
                             lookahead=<k>, wire field lookahead:<k>;
                             default 1 = verify every speculative step;
                             DESIGN.md §16)
  serve                      start the TCP JSON-lines server (protocol v2:
      --model dit-sim --addr 127.0.0.1:7433 --inflight 8 --shards 4
      --router least-loaded|round-robin --max-queue 1024
                             async op=submit/poll/wait/cancel + job ids,
                             priorities, deadlines, preemptible:true to
                             allow mid-flight park/steal, group:N to share
                             one cancel token — op=cancel group:N sweeps
                             it; op=stats adds parked/resumed/stolen/
                             migrated + per-group counts (DESIGN.md §13);
                             v1 op=generate shim; op=hello proto check;
                             op=metrics Prometheus-style text)
  serve --fabric-router      fabric front door (DESIGN.md §15): serves
      --addr 127.0.0.1:7433 --workers-addr 127.0.0.1:7434
      --heartbeat-ms 250 --miss-limit 3 --max-queue 4096
                             protocol v2 on --addr, workers join on
                             --workers-addr; work-weighted routing off
                             heartbeat gauges; a dead worker's in-flight
                             jobs resume on live peers from spilled
                             checkpoints (no accepted job is lost)
  serve --fabric-worker      one shard-pool process joined to a router
      --join 127.0.0.1:7434 --addr 127.0.0.1:0 --model dit-sim
      --shards S             (--addr is its own direct serving port for
                             debugging; 0 picks a free port)
  load                       load generator against a server
      --addr 127.0.0.1:7433 --n 32 --conns 4 --policy speca
      --rate R               open-loop mode: Poisson arrivals at R req/s
                             (ignores --conns; plus --deadline-ms N,
                             --priority low|normal|high, --waiters W)
  bench <name>               regenerate a paper table/figure (see DESIGN.md)
      table1..table8 | drafts | fig2|fig6|fig8|fig9 | speedup-law
      | serve-openloop (p50/p99/p999 + rejection rate + checkpoint
        counters per rate → results/openloop.csv;
        --rates 0.5,1,2,4 --shards S;
        --workers N: spawn a local fabric — router + N worker
        processes' worth of pools in-process — and sweep worker counts
        1..=N for capacity scaling → results/fabric.csv)
      | adaptive (sample-adaptive error-budget sweep over scripted
        easy/medium/hard drift buckets → results/adaptive.csv;
        policy key adaptive=<budget>, wire field adaptive:<budget>)
      | lookahead (lookahead-k sweep: k × draft over scripted easy/hard
        drift buckets + accepted-prefix-length histogram →
        results/lookahead.csv; EXPERIMENTS.md §Lookahead)
      [--quick] [--n N] [--shards S]
      (micro perf: cargo bench --bench micro_runtime — also writes
       results/bench_micro.json: ns/iter + allocs/iter per bench)
  perfgate                   compare a micro_runtime bench JSON against a
      --baseline BENCH_baseline.json --current results/bench_micro.json
      --tol 0.25             baseline: hard zero-regression on steady-state
      --metric p50_ns|min_ns alloc counts, ±tol on time-gated rows
                             (EXPERIMENTS.md §Perf; the CI perf-gate leg)

DRAFT STRATEGIES (DESIGN.md §10):
  --draft <name>             draft strategy for SpeCa policies: on generate
                             and bench it overrides every SpeCa row (the
                             draft-comparison runners `drafts` and `table7`
                             reject it); on serve it is the default for
                             requests that name none (per-request
                             draft=<name> wins)
  --list-drafts              print the strategy registry and exit
  policy syntax              speca:...,draft=<name> (case-insensitive)

BACKENDS (--backend native|pjrt|auto, default auto):
  native   pure-Rust DiT forward, seeded weights, zero artifacts needed
  pjrt     AOT HLO artifacts via PJRT (requires --features pjrt build and
           ./artifacts from `make artifacts`; override with SPECA_ARTIFACTS)
  --model-seed N             seed for the native models (default fixed)
  --shards N                 engine worker threads sharing one backend
                             (native only; default 1)

KERNEL FEATURES (DESIGN.md §12; native backbone math):
  (default)                  cache-blocked GEMM with fused epilogues
  --features scalar-ref      default to the naive scalar reference path
                             (the parity oracle; for bisecting numerics)
  --features portable-simd   nightly std::simd microkernel (numerically
                             identical to the stable autovectorized path)
";

fn info(args: &Args) -> Result<()> {
    let req = BackendRequest::from_args(args);
    match req.kind()? {
        BackendKind::Native => {
            let hub = NativeHub::seeded(req.model_seed);
            println!("backend: native (seeded, zero artifacts)");
            for (name, m) in hub.models() {
                print_model(name, m.as_ref());
            }
            println!(
                "classifier: native feat_dim={} classes={}",
                hub.classifier.feat_dim(),
                hub.classifier.num_classes(),
            );
            Ok(())
        }
        BackendKind::Pjrt => pjrt_info(),
    }
}

fn print_model(name: &str, m: &dyn ModelBackend) {
    let e = m.entry();
    let c = &e.config;
    println!(
        "model {name} [{}]: dim={} depth={} heads={} tokens={} latent={} classes={} \
         schedule={:?} steps={} buckets={:?}",
        m.kind(),
        c.dim,
        c.depth,
        c.heads,
        c.tokens,
        c.latent_dim,
        c.num_classes,
        c.schedule_kind,
        c.serve_steps,
        c.buckets
    );
    println!(
        "  flops/full-step(b1)={:.3} MF  block={:.3} MF (gamma≈{:.4})",
        e.flops.full_step[&1] as f64 / 1e6,
        e.flops.block[&1] as f64 / 1e6,
        e.flops.block[&1] as f64 / e.flops.full_step[&1] as f64
    );
}

#[cfg(feature = "pjrt")]
fn pjrt_info() -> Result<()> {
    let manifest = speca::config::Manifest::load(&speca::artifacts_dir())?;
    println!("artifacts: {}", manifest.root.display());
    for (name, m) in &manifest.models {
        let c = &m.config;
        println!(
            "model {name}: dim={} depth={} heads={} tokens={} latent={} classes={} \
             schedule={:?} steps={} buckets={:?}",
            c.dim, c.depth, c.heads, c.tokens, c.latent_dim, c.num_classes,
            c.schedule_kind, c.serve_steps, c.buckets
        );
        println!(
            "  flops/full-step(b1)={:.3} MF  block={:.3} MF (gamma≈{:.4})",
            m.flops.full_step[&1] as f64 / 1e6,
            m.flops.block[&1] as f64 / 1e6,
            m.flops.block[&1] as f64 / m.flops.full_step[&1] as f64
        );
        for (entry, buckets) in &m.artifacts {
            println!("  artifact {entry}: buckets {:?}", buckets.keys().collect::<Vec<_>>());
        }
    }
    println!(
        "classifier: feat_dim={} classes={} held-out acc={:.3}",
        manifest.classifier.feat_dim, manifest.classifier.num_classes, manifest.classifier.acc
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_info() -> Result<()> {
    unreachable!("select_backend rejects pjrt without the feature")
}

/// The engine/workload options every driving command shares.
fn run_opts(args: &Args, n: usize) -> Result<RunOpts> {
    let strategy = args.str("strategy", "binary");
    let Some(strategy) = BatchStrategy::parse(&strategy) else {
        bail!("unknown strategy '{strategy}'");
    };
    Ok(RunOpts { strategy, use_pallas: args.bool("pallas"), ..RunOpts::from_args(args, n)? })
}

fn generate(args: &Args) -> Result<()> {
    let req = BackendRequest::from_args(args);
    resolve::with_model(&req, |model| {
        let entry = model.entry();
        let mut policy = workload::parse_policy(
            &args.str("policy", "speca:N=5,O=2,tau0=0.3,beta=0.05"),
            entry.config.depth,
        )?;
        if args.opt("lookahead").is_some() {
            workload::apply_lookahead(&mut policy, args.usize("lookahead", 1));
        }
        let opts = run_opts(args, args.usize("n", 8))?;
        let run = run_policy(&model, &policy, "generate", &opts)?;
        let n = opts.n;

        let full_flops = entry.flops.full_step[&1];
        let steps = entry.config.serve_steps;
        println!(
            "{:<6} {:<10} {:<16} {:>6} {:>6} {:>6} {:>7} {:>9} {:>9}",
            "id", "policy", "draft", "full", "spec", "rej", "lat ms", "GFLOPs", "speedup"
        );
        for c in run.completions_by_id.values() {
            let s = &c.stats;
            println!(
                "{:<6} {:<10} {:<16} {:>6} {:>6} {:>6} {:>7.1} {:>9.4} {:>8.2}x",
                c.id,
                c.policy_name,
                c.draft_name,
                s.full_steps,
                s.spec_steps + s.skip_steps + s.blend_steps,
                s.rejects,
                s.latency_ms,
                s.flops.total() as f64 / 1e9,
                s.speedup(full_flops, steps)
            );
        }
        let f = &run.flops;
        println!(
            "batch: n={n} backend={} shards={} wall={:.2}s throughput={:.2} req/s \
             alpha={:.3} gamma={:.4} agg-speedup={:.2}x (law predicts {:.2}x)",
            model.kind(),
            opts.shards,
            run.wall_s,
            n as f64 / run.wall_s,
            f.acceptance_rate(),
            f.gamma(),
            f.speedup(full_flops),
            f.predicted_speedup()
        );

        if let Some(dir) = args.opt("dump-pgm") {
            let completions: Vec<_> = run.completions_by_id.into_values().collect();
            speca::experiments::runner::dump_pgm(&completions, &entry.config, dir)?;
            println!("wrote sample grids to {dir}/");
        }
        Ok(())
    })
}

/// `speca serve --fabric-router`: the fabric front door. No model —
/// the router holds no engine, only sessions, the job ledger, and the
/// metrics plane; workers bring the compute when they join.
fn serve_fabric_router(args: &Args) -> Result<()> {
    let cfg = speca::fabric::RouterConfig {
        addr: args.str("addr", "127.0.0.1:7433"),
        workers_addr: args.str("workers-addr", "127.0.0.1:7434"),
        max_queue: args.usize("max-queue", 4096),
        heartbeat_ms: args.u64("heartbeat-ms", 250),
        miss_limit: args.u64("miss-limit", 3) as u32,
    };
    let handle = speca::fabric::spawn_router(&cfg)?;
    handle.join()
}

/// `speca serve --fabric-worker --join <router>`: one shard-pool
/// process joined to a router's fabric port.
fn serve_fabric_worker(args: &Args) -> Result<()> {
    let req = BackendRequest::from_args(args);
    resolve::with_model(&req, |model| {
        let backend = model.backend();
        backend.warmup(&["full", "block", "head"], &backend.entry().config.buckets)?;
        let opts = run_opts(args, 0)?;
        let Some(shared) = model.shared() else {
            bail!("--fabric-worker needs a Send + Sync backend (use --backend native)");
        };
        let cfg = speca::fabric::WorkerConfig {
            join: args.str("join", "127.0.0.1:7434"),
            addr: args.str("addr", "127.0.0.1:0"),
            max_queue: args.usize("max-queue", 1024),
            shards: opts.shards.max(1),
            router: opts.router,
            default_draft: opts.draft.clone(),
        };
        let done = speca::fabric::run_worker(shared, opts.engine_config(), &cfg)?;
        println!("served {done} requests");
        Ok(())
    })
}

fn serve(args: &Args) -> Result<()> {
    if args.bool("fabric-router") {
        return serve_fabric_router(args);
    }
    if args.bool("fabric-worker") {
        return serve_fabric_worker(args);
    }
    let req = BackendRequest::from_args(args);
    resolve::with_model(&req, |model| {
        // prepare the hot entry points before admitting traffic
        let backend = model.backend();
        backend.warmup(&["full", "block", "head"], &backend.entry().config.buckets)?;
        let opts = run_opts(args, 0)?;
        let cfg = ServerConfig {
            addr: args.str("addr", "127.0.0.1:7433"),
            max_queue: args.usize("max-queue", 1024),
            shards: opts.shards.max(1),
            router: opts.router,
            default_draft: opts.draft.clone(),
        };
        let done = match model.shared() {
            Some(shared) => server::serve_sharded(shared, opts.engine_config(), &cfg)?,
            None => {
                if cfg.shards > 1 {
                    eprintln!(
                        "speca: --shards needs a Send + Sync backend; \
                         PJRT falls back to the single-threaded loop"
                    );
                }
                let mut engine = Engine::new(backend, opts.engine_config());
                server::serve(&mut engine, &cfg)?
            }
        };
        println!("served {done} requests");
        Ok(())
    })
}

fn load(args: &Args) -> Result<()> {
    if args.opt("rate").is_some() {
        return load_open_loop(args);
    }
    let cfg = client::LoadConfig {
        addr: args.str("addr", "127.0.0.1:7433"),
        connections: args.usize("conns", 4),
        requests: args.usize("n", 32),
        policy: args.str("policy", "speca:N=5,O=2"),
        num_classes: args.usize("classes", 8),
    };
    let mut report = client::run_load(&cfg)?;
    if report.completed == 0 {
        bail!("no requests completed (is the server running at {}?)", cfg.addr);
    }
    let (mean, p50, p95, p99) = report.latency.summary();
    println!(
        "completed={} errors={} wall={:.2}s throughput={:.2} req/s",
        report.completed, report.errors, report.wall_s, report.throughput_rps
    );
    println!(
        "latency ms: mean={mean:.1} p50={p50:.1} p95={p95:.1} p99={p99:.1}  \
         mean FLOPs-speedup={:.2}x",
        report.mean_speedup
    );
    Ok(())
}

/// `speca load --rate R`: open-loop mode — protocol v2 submits at Poisson
/// arrival times, concurrent waiters, queueing-inclusive latency.
fn load_open_loop(args: &Args) -> Result<()> {
    let cfg = client::OpenLoopConfig {
        addr: args.str("addr", "127.0.0.1:7433"),
        rate: args.f64("rate", 1.0),
        requests: args.usize("n", 32),
        policy: args.str("policy", "speca:N=5,O=2"),
        num_classes: args.usize("classes", 8),
        seed: args.u64("seed", 0),
        deadline_ms: args.opt("deadline-ms").map(|_| args.u64("deadline-ms", 0)),
        priority: args.opt("priority").map(|s| s.to_string()),
        waiters: args.usize("waiters", 8),
    };
    if cfg.rate <= 0.0 {
        bail!("--rate expects a positive arrival rate in req/s");
    }
    let mut r = client::run_open_loop(&cfg)?;
    println!(
        "open-loop: offered={:.2} req/s achieved={:.2} req/s wall={:.2}s",
        r.offered_rps, r.achieved_rps, r.wall_s
    );
    println!(
        "submitted={} completed={} rejected={} aborted={} errors={} reject-rate={:.3}",
        r.submitted,
        r.completed,
        r.rejected,
        r.aborted,
        r.errors,
        r.reject_rate()
    );
    let (mean, p50, _p95, p99) = r.latency.summary();
    println!(
        "arrival→completion ms: mean={mean:.1} p50={p50:.1} p99={p99:.1} p999={:.1}",
        r.latency.percentile(0.999)
    );
    Ok(())
}
