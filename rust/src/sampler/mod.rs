//! Native sampler math: DDIM (η=0) and rectified-flow Euler updates over
//! flat latents, exactly mirroring `python/compile/kernels/ddim.py` (the
//! golden traces assert parity across the PJRT boundary).
//!
//! The schedule constants (ᾱ tables / dt / model-time values) come from the
//! manifest so Rust never re-derives them — a single source of truth with
//! the python training code.

use crate::config::{Schedule, ScheduleKind};
use crate::util::rng::Rng;

/// In-place deterministic DDIM update: x ← √ᾱ_prev·x0 + √(1−ᾱ_prev)·ε̂.
pub fn ddim_step(x: &mut [f32], eps: &[f32], ab_t: f32, ab_prev: f32) {
    debug_assert_eq!(x.len(), eps.len());
    let rs = 1.0 / (ab_t as f64).sqrt();
    let s1m = (1.0 - ab_t as f64).sqrt();
    let sp = (ab_prev as f64).sqrt();
    let s1mp = (1.0 - ab_prev as f64).sqrt();
    for (xi, ei) in x.iter_mut().zip(eps) {
        let x0 = (*xi as f64 - s1m * *ei as f64) * rs;
        *xi = (sp * x0 + s1mp * *ei as f64) as f32;
    }
}

/// In-place rectified-flow Euler step: x ← x − dt·v.
pub fn rf_step(x: &mut [f32], v: &[f32], dt: f32) {
    debug_assert_eq!(x.len(), v.len());
    for (xi, vi) in x.iter_mut().zip(v) {
        *xi -= dt * vi;
    }
}

/// Serve-time sampler driving one latent through the schedule.
pub struct Sampler<'a> {
    /// The serve-time schedule constants driving every update.
    pub schedule: &'a Schedule,
}

impl<'a> Sampler<'a> {
    /// Sampler over a schedule.
    pub fn new(schedule: &'a Schedule) -> Self {
        Sampler { schedule }
    }

    /// Serve steps in the schedule.
    pub fn steps(&self) -> usize {
        self.schedule.t_model.len()
    }

    /// Model-time value fed to the timestep embedding at serve step `i`.
    pub fn t_model(&self, i: usize) -> f32 {
        self.schedule.t_model[i]
    }

    /// Apply the i-th denoising update in place given the model output.
    pub fn apply(&self, i: usize, x: &mut [f32], model_out: &[f32]) {
        match self.schedule.kind {
            ScheduleKind::Ddim => {
                ddim_step(x, model_out, self.schedule.ab_t[i], self.schedule.ab_prev[i])
            }
            ScheduleKind::RectifiedFlow => rf_step(x, model_out, self.schedule.dt),
        }
    }

    /// Initial latent: standard normal noise.
    pub fn init_latent(&self, rng: &mut Rng, latent_dim: usize) -> Vec<f32> {
        rng.normal_f32s(latent_dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ddim_identity_at_ab_one() {
        // ᾱ_t = ᾱ_prev = 1 ⇒ x0 = x and the update is the identity.
        let mut x = vec![0.5f32, -1.0, 2.0];
        let eps = vec![0.1f32, 0.2, -0.3];
        ddim_step(&mut x, &eps, 1.0, 1.0);
        assert!((x[0] - 0.5).abs() < 1e-6);
        assert!((x[2] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn ddim_final_step_returns_x0() {
        // ᾱ_prev = 1 ⇒ output is exactly the x0 estimate.
        let mut x = vec![1.0f32];
        let eps = vec![0.5f32];
        let ab_t = 0.25f32;
        ddim_step(&mut x, &eps, ab_t, 1.0);
        let expect = (1.0 - (1.0f64 - 0.25).sqrt() * 0.5) / 0.5;
        assert!((x[0] as f64 - expect).abs() < 1e-6);
    }

    #[test]
    fn rf_linear() {
        let mut x = vec![1.0f32, 2.0];
        rf_step(&mut x, &[0.5, -0.5], 0.1);
        assert!((x[0] - 0.95).abs() < 1e-6);
        assert!((x[1] - 2.05).abs() < 1e-6);
    }

    #[test]
    fn rf_full_integration_recovers_x0() {
        // constant v = x1 - x0 integrated over 50 steps of dt=1/50 from x1
        // lands exactly on x0.
        let x0 = 0.3f32;
        let x1 = 1.7f32;
        let v = x1 - x0;
        let mut x = vec![x1];
        for _ in 0..50 {
            rf_step(&mut x, &[v], 1.0 / 50.0);
        }
        assert!((x[0] - x0).abs() < 1e-5);
    }
}
