//! Typed views over `artifacts/manifest.json` (the AOT → runtime contract)
//! plus serving/policy configuration structs.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Manifest schema version this build understands.
pub const MANIFEST_VERSION: u64 = 3;

/// Model architecture + schedule description (mirrors configs.ModelConfig).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    /// Model name (manifest key / native preset).
    pub name: String,
    /// Square image edge length.
    pub image_size: usize,
    /// Image channels.
    pub channels: usize,
    /// Patch edge length (patchify stride).
    pub patch: usize,
    /// Transformer width.
    pub dim: usize,
    /// Transformer blocks.
    pub depth: usize,
    /// Attention heads.
    pub heads: usize,
    /// Conditioning classes (or prompt ids).
    pub num_classes: usize,
    /// Frames per sample (1 for images).
    pub frames: usize,
    /// Noise-schedule family.
    pub schedule_kind: ScheduleKind,
    /// Serve steps per request.
    pub serve_steps: usize,
    /// Sequence length (frames × patches).
    pub tokens: usize,
    /// Flat latent length (frames × channels × image²).
    pub latent_dim: usize,
    /// Compiled batch buckets, sorted ascending.
    pub buckets: Vec<usize>,
}

/// Noise-schedule family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleKind {
    /// Deterministic DDIM (η = 0) over an ᾱ table.
    Ddim,
    /// Rectified-flow Euler integration.
    RectifiedFlow,
}

/// Serve-time noise schedule constants dumped by train.py (exact parity
/// with the python golden traces).
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Which update rule the constants drive.
    pub kind: ScheduleKind,
    /// value fed to the model's timestep embedding at each serve step
    pub t_model: Vec<f32>,
    /// DDIM: ᾱ_t per step
    pub ab_t: Vec<f32>,
    /// DDIM: ᾱ of the next (toward-data) point; last entry 1.0
    pub ab_prev: Vec<f32>,
    /// RF: Euler step size
    pub dt: f32,
}

/// Analytic FLOPs table (MACs×2) recorded by configs.py.
#[derive(Debug, Clone)]
pub struct FlopsTable {
    /// Full forward pass cost per batch bucket.
    pub full_step: BTreeMap<usize, u64>,
    /// Single-block (verification) cost per batch bucket.
    pub block: BTreeMap<usize, u64>,
    /// Output-head cost per batch bucket.
    pub head: BTreeMap<usize, u64>,
    /// Draft-prediction cost per series order per tap.
    pub predict_per_order: u64,
}

/// Name + shape of one stored parameter tensor.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    /// Parameter name (weights.bin key).
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
}

/// One model's manifest entry (or its native-synthesized equivalent).
#[derive(Debug, Clone)]
pub struct ModelEntry {
    /// Architecture + schedule description.
    pub config: ModelConfig,
    /// Serve-time schedule constants.
    pub schedule: Schedule,
    /// Stored parameter inventory.
    pub params: Vec<ParamSpec>,
    /// Path of `weights.bin`.
    pub weights: PathBuf,
    /// Path of the golden traces file.
    pub goldens: PathBuf,
    /// entry point -> bucket -> hlo path
    pub artifacts: BTreeMap<String, BTreeMap<usize, PathBuf>>,
    /// single-file kernel artifacts (taylor_predict, verify_stats, step, ...)
    pub kernel_artifacts: BTreeMap<String, PathBuf>,
    /// Analytic cost tables.
    pub flops: FlopsTable,
}

/// The metrics classifier's manifest entry.
#[derive(Debug, Clone)]
pub struct ClassifierEntry {
    /// Path of the classifier weights file.
    pub weights: PathBuf,
    /// Path of the classifier golden traces.
    pub goldens: PathBuf,
    /// Compiled executable per batch bucket.
    pub artifacts: BTreeMap<usize, PathBuf>,
    /// Stored parameter inventory.
    pub params: Vec<ParamSpec>,
    /// Feature dimension (FID* space).
    pub feat_dim: usize,
    /// Output classes.
    pub num_classes: usize,
    /// Input latent length (one frame).
    pub latent_dim: usize,
    /// Held-out accuracy recorded at train time.
    pub acc: f64,
}

/// Typed view of `artifacts/manifest.json`.
#[derive(Debug)]
pub struct Manifest {
    /// Artifacts directory the paths below are rooted at.
    pub root: PathBuf,
    /// Model entries by name.
    pub models: BTreeMap<String, ModelEntry>,
    /// The metrics classifier entry.
    pub classifier: ClassifierEntry,
}

fn parse_params(j: &Json) -> Vec<ParamSpec> {
    j.as_arr()
        .unwrap_or(&[])
        .iter()
        .map(|p| ParamSpec {
            name: p.req("name").as_str().unwrap().to_string(),
            shape: p.req("shape").usizes(),
        })
        .collect()
}

fn parse_flops(j: &Json) -> FlopsTable {
    let tab = |k: &str| -> BTreeMap<usize, u64> {
        j.req(k)
            .as_obj()
            .unwrap()
            .iter()
            .map(|(b, v)| (b.parse().unwrap(), v.as_u64().unwrap()))
            .collect()
    };
    FlopsTable {
        full_step: tab("full_step"),
        block: tab("block"),
        head: tab("head"),
        predict_per_order: j.req("predict_per_order").as_u64().unwrap(),
    }
}

impl Manifest {
    /// Load and validate `<root>/manifest.json`.
    pub fn load(root: &Path) -> Result<Manifest> {
        let path = root.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let version = j.req("version").as_u64().unwrap_or(0);
        if version != MANIFEST_VERSION {
            bail!("manifest version {version} != expected {MANIFEST_VERSION}; re-run `make artifacts`");
        }

        let mut models = BTreeMap::new();
        for (name, m) in j.req("models").as_obj().unwrap() {
            models.insert(name.clone(), Self::parse_model(root, m)?);
        }

        let c = j.req("classifier");
        let classifier = ClassifierEntry {
            weights: root.join(c.req("weights").as_str().unwrap()),
            goldens: root.join(c.req("goldens").as_str().unwrap()),
            artifacts: c
                .req("artifacts")
                .as_obj()
                .unwrap()
                .iter()
                .map(|(b, p)| (b.parse().unwrap(), root.join(p.as_str().unwrap())))
                .collect(),
            params: parse_params(c.req("params")),
            feat_dim: c.req("feat_dim").as_usize().unwrap(),
            num_classes: c.req("num_classes").as_usize().unwrap(),
            latent_dim: c.req("latent_dim").as_usize().unwrap(),
            acc: c.req("acc").as_f64().unwrap(),
        };

        Ok(Manifest { root: root.to_path_buf(), models, classifier })
    }

    fn parse_model(root: &Path, m: &Json) -> Result<ModelEntry> {
        let c = m.req("config");
        let schedule_kind = match m.req("schedule").req("kind").as_str().unwrap() {
            "ddim" => ScheduleKind::Ddim,
            "rf" => ScheduleKind::RectifiedFlow,
            k => bail!("unknown schedule kind {k}"),
        };
        let config = ModelConfig {
            name: c.req("name").as_str().unwrap().to_string(),
            image_size: c.req("image_size").as_usize().unwrap(),
            channels: c.req("channels").as_usize().unwrap(),
            patch: c.req("patch").as_usize().unwrap(),
            dim: c.req("dim").as_usize().unwrap(),
            depth: c.req("depth").as_usize().unwrap(),
            heads: c.req("heads").as_usize().unwrap(),
            num_classes: c.req("num_classes").as_usize().unwrap(),
            frames: c.req("frames").as_usize().unwrap(),
            schedule_kind,
            serve_steps: c.req("serve_steps").as_usize().unwrap(),
            tokens: c.req("tokens").as_usize().unwrap(),
            latent_dim: c.req("latent_dim").as_usize().unwrap(),
            buckets: c.req("buckets").usizes(),
        };
        let s = m.req("schedule");
        let schedule = Schedule {
            kind: schedule_kind,
            t_model: s.req("t_model").f32s(),
            ab_t: s.get("ab_t").map(|x| x.f32s()).unwrap_or_default(),
            ab_prev: s.get("ab_prev").map(|x| x.f32s()).unwrap_or_default(),
            dt: s.get("dt").and_then(|x| x.as_f64()).unwrap_or(0.0) as f32,
        };

        let mut artifacts = BTreeMap::new();
        let mut kernel_artifacts = BTreeMap::new();
        for (entry, v) in m.req("artifacts").as_obj().unwrap() {
            match v {
                Json::Obj(buckets) => {
                    let map = buckets
                        .iter()
                        .map(|(b, p)| {
                            (b.parse::<usize>().unwrap(), root.join(p.as_str().unwrap()))
                        })
                        .collect();
                    artifacts.insert(entry.clone(), map);
                }
                Json::Str(p) => {
                    kernel_artifacts.insert(entry.clone(), root.join(p));
                }
                _ => bail!("artifact entry {entry}: unexpected json shape"),
            }
        }

        Ok(ModelEntry {
            config,
            schedule,
            params: parse_params(m.req("params")),
            weights: root.join(m.req("weights").as_str().unwrap()),
            goldens: root.join(m.req("goldens").as_str().unwrap()),
            artifacts,
            kernel_artifacts,
            flops: parse_flops(m.req("flops")),
        })
    }

    /// Entry of a model by name (error lists what exists).
    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .with_context(|| format!("model '{name}' not in manifest ({:?})", self.models.keys()))
    }
}

impl ModelConfig {
    /// Shared plumbing for the native-backend presets: token/latent counts
    /// derived from the image geometry, standard buckets.
    fn native(
        name: &str,
        image_size: usize,
        patch: usize,
        dim: usize,
        depth: usize,
        heads: usize,
        num_classes: usize,
        frames: usize,
        schedule_kind: ScheduleKind,
        serve_steps: usize,
    ) -> ModelConfig {
        let channels = 1;
        let per_frame = (image_size / patch) * (image_size / patch);
        ModelConfig {
            name: name.to_string(),
            image_size,
            channels,
            patch,
            dim,
            depth,
            heads,
            num_classes,
            frames,
            schedule_kind,
            serve_steps,
            tokens: frames * per_frame,
            latent_dim: frames * channels * image_size * image_size,
            buckets: vec![1, 2, 4, 8],
        }
    }

    /// Class-conditional image DiT on DDIM (paper Table 3 analog). Sized
    /// for interactive CPU serving with the zero-artifact native backend;
    /// the AOT manifest configs in python/compile/configs.py stay the
    /// source of truth for the PJRT path.
    pub fn native_dit() -> ModelConfig {
        Self::native("dit-sim", 16, 2, 64, 6, 4, 8, 1, ScheduleKind::Ddim, 50)
    }

    /// "Text"-conditional rectified-flow DiT (paper Table 1 analog).
    pub fn native_flux() -> ModelConfig {
        Self::native("flux-sim", 16, 2, 48, 4, 4, 32, 1, ScheduleKind::RectifiedFlow, 28)
    }

    /// Two-frame video DiT, rectified flow (paper Table 2 analog).
    pub fn native_video() -> ModelConfig {
        Self::native("video-sim", 16, 2, 48, 4, 4, 16, 2, ScheduleKind::RectifiedFlow, 16)
    }

    /// Deliberately tiny model for the integration tests: big enough for
    /// nontrivial feature dynamics, small enough that a debug-profile
    /// `cargo test` stays fast.
    pub fn native_test() -> ModelConfig {
        Self::native("native-test", 8, 2, 24, 3, 4, 4, 1, ScheduleKind::Ddim, 12)
    }
}

impl ModelEntry {
    /// Smallest compiled bucket that fits `n` requests.
    pub fn bucket_for(&self, n: usize) -> usize {
        *self
            .config
            .buckets
            .iter()
            .find(|b| **b >= n)
            .unwrap_or(self.config.buckets.last().unwrap())
    }

    /// Flat boundary-feature length (tokens × dim).
    pub fn feat_len(&self) -> usize {
        self.config.tokens * self.config.dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let entry = ModelEntry {
            config: ModelConfig {
                name: "t".into(),
                image_size: 16,
                channels: 1,
                patch: 2,
                dim: 8,
                depth: 2,
                heads: 2,
                num_classes: 4,
                frames: 1,
                schedule_kind: ScheduleKind::Ddim,
                serve_steps: 10,
                tokens: 64,
                latent_dim: 256,
                buckets: vec![1, 2, 4, 8],
            },
            schedule: Schedule {
                kind: ScheduleKind::Ddim,
                t_model: vec![],
                ab_t: vec![],
                ab_prev: vec![],
                dt: 0.0,
            },
            params: vec![],
            weights: PathBuf::new(),
            goldens: PathBuf::new(),
            artifacts: BTreeMap::new(),
            kernel_artifacts: BTreeMap::new(),
            flops: FlopsTable {
                full_step: BTreeMap::new(),
                block: BTreeMap::new(),
                head: BTreeMap::new(),
                predict_per_order: 0,
            },
        };
        assert_eq!(entry.bucket_for(1), 1);
        assert_eq!(entry.bucket_for(3), 4);
        assert_eq!(entry.bucket_for(8), 8);
        assert_eq!(entry.bucket_for(20), 8); // clamps to largest
    }
}
