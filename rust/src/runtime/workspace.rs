//! Reusable forward-pass workspaces for CPU backends (DESIGN.md §11).
//!
//! A [`Workspace`] owns every per-block temporary of the native DiT
//! forward pass — attention score/projection buffers, the MLP hidden
//! activation, adaLN modulation scratch, timestep-embedding staging — all
//! sized once from the model config. A [`WorkspacePool`] hands workspaces
//! out per forward call (`checkout`), so a `Send + Sync` backend shared by
//! N shard worker threads materializes at most N workspaces and then
//! serves every subsequent call with **zero heap allocations**: the
//! checkout is a mutex-guarded `Vec` pop, and the guard returns the
//! workspace on drop.
//!
//! The pool lives *behind* the backend (a private field of
//! [`NativeBackend`](crate::runtime::NativeBackend)), which is why the
//! [`ModelBackend`](crate::runtime::ModelBackend) trait keeps its `&self`
//! entry points and its object safety — callers never see the arena.
//! Result tensors are recycled separately through
//! [`BufferPool`](crate::tensor::BufferPool), because they outlive the
//! call that produced them.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::config::ModelConfig;
use crate::runtime::kernels::NR;
use crate::runtime::native::NativeArch;

/// Every per-call temporary of the native DiT forward pass, sized for one
/// sample of one model (buffer lengths are fixed at construction and
/// fully overwritten by each use, so reuse across calls — and across
/// requests — cannot leak state between samples).
pub struct Workspace {
    /// Sinusoidal timestep-embedding staging `[t_freq_dim]`.
    pub temb: Vec<f32>,
    /// Conditioning MLP hidden activation `[dim]`.
    pub cond_h: Vec<f32>,
    /// silu'd conditioning vector `[dim]` (read by every adaLN site).
    pub cond: Vec<f32>,
    /// Patchified latent `[tokens, patch_dim]`.
    pub patches: Vec<f32>,
    /// Embedded token stream `[tokens, dim]` (the residual trunk).
    pub xt: Vec<f32>,
    /// Block adaLN modulation `[6·dim]` (shift/scale/gate × 2 branches).
    pub mod6: Vec<f32>,
    /// LayerNorm output `[tokens, dim]` (shared by both block branches).
    pub norm: Vec<f32>,
    /// Interleaved q/k/v projections `[tokens, 3·dim]`.
    pub qkv: Vec<f32>,
    /// Attention score/probability row `[tokens]`.
    pub probs: Vec<f32>,
    /// Attention output `[tokens, dim]`.
    pub attn: Vec<f32>,
    /// Attention out-projection `[tokens, dim]`.
    pub proj: Vec<f32>,
    /// MLP hidden activation `[tokens, mlp_ratio·dim]`.
    pub mlp_hidden: Vec<f32>,
    /// MLP output `[tokens, dim]`.
    pub mlp_out: Vec<f32>,
    /// Head adaLN modulation `[2·dim]`.
    pub mod2: Vec<f32>,
    /// Head token output `[tokens, patch_dim]` (unpatchify input).
    pub tok_out: Vec<f32>,
    /// Blocked-attention score matrix for one head `[tokens, tokens]`.
    pub scores: Vec<f32>,
    /// GEMM A-operand pack `[tokens, kmax]` (DESIGN.md §12): the prologue
    /// (adaLN modulate) is applied while copying into this buffer.
    pub pack_a: Vec<f32>,
    /// GEMM B-panel pack `[kmax, NR]`: one register-width column panel,
    /// zero-padded so remainder tiles need no edge cases.
    pub pack_b: Vec<f32>,
}

impl Workspace {
    /// A workspace sized for one sample of `cfg` under `arch`.
    pub fn for_model(cfg: &ModelConfig, arch: &NativeArch) -> Workspace {
        let (t, d) = (cfg.tokens, cfg.dim);
        let pd = cfg.patch * cfg.patch * cfg.channels;
        let md = arch.mlp_ratio * d;
        // widest contraction dimension any kernel-layer GEMM packs over:
        // patch embed (pd), MLP down-proj (md), everything D-shaped (d),
        // attention PV (t), conditioning MLP (t_freq_dim)
        let kmax = pd.max(md).max(d).max(t).max(arch.t_freq_dim);
        Workspace {
            temb: vec![0.0; arch.t_freq_dim],
            cond_h: vec![0.0; d],
            cond: vec![0.0; d],
            patches: vec![0.0; t * pd],
            xt: vec![0.0; t * d],
            mod6: vec![0.0; 6 * d],
            norm: vec![0.0; t * d],
            qkv: vec![0.0; t * 3 * d],
            probs: vec![0.0; t],
            attn: vec![0.0; t * d],
            proj: vec![0.0; t * d],
            mlp_hidden: vec![0.0; t * md],
            mlp_out: vec![0.0; t * d],
            mod2: vec![0.0; 2 * d],
            tok_out: vec![0.0; t * pd],
            scores: vec![0.0; t * t],
            pack_a: vec![0.0; t * kmax],
            pack_b: vec![0.0; kmax * NR],
        }
    }

    /// Resident bytes across all buffers (capacity-planning telemetry).
    pub fn resident_bytes(&self) -> usize {
        4 * (self.temb.len()
            + self.cond_h.len()
            + self.cond.len()
            + self.patches.len()
            + self.xt.len()
            + self.mod6.len()
            + self.norm.len()
            + self.qkv.len()
            + self.probs.len()
            + self.attn.len()
            + self.proj.len()
            + self.mlp_hidden.len()
            + self.mlp_out.len()
            + self.mod2.len()
            + self.tok_out.len()
            + self.scores.len()
            + self.pack_a.len()
            + self.pack_b.len())
    }
}

/// Checkout pool of [`Workspace`]s: one backend field, shared by every
/// thread that forwards through the backend. Grows to the peak number of
/// *concurrent* forward calls (one workspace per shard worker under the
/// pool) and never shrinks, so steady-state checkouts are allocation-free.
#[derive(Default)]
pub struct WorkspacePool {
    slots: Mutex<Vec<Box<Workspace>>>,
    created: AtomicUsize,
}

impl WorkspacePool {
    /// An empty pool.
    pub fn new() -> WorkspacePool {
        WorkspacePool::default()
    }

    /// Check a workspace out, building one with `make` only when every
    /// existing workspace is already checked out by another caller. The
    /// guard returns it on drop.
    pub fn checkout(&self, make: impl FnOnce() -> Workspace) -> WorkspaceGuard<'_> {
        let ws = self.slots.lock().unwrap().pop();
        let ws = ws.unwrap_or_else(|| {
            self.created.fetch_add(1, Ordering::Relaxed);
            Box::new(make())
        });
        WorkspaceGuard { ws: Some(ws), pool: self }
    }

    /// Workspaces materialized over this pool's lifetime (a steady-state
    /// run keeps this at the peak checkout concurrency).
    pub fn created(&self) -> usize {
        self.created.load(Ordering::Relaxed)
    }

    /// Workspaces currently checked in.
    pub fn idle(&self) -> usize {
        self.slots.lock().unwrap().len()
    }
}

/// RAII checkout of one [`Workspace`]; derefs to it and returns it to the
/// pool on drop.
pub struct WorkspaceGuard<'p> {
    ws: Option<Box<Workspace>>,
    pool: &'p WorkspacePool,
}

impl Deref for WorkspaceGuard<'_> {
    type Target = Workspace;
    fn deref(&self) -> &Workspace {
        self.ws.as_ref().expect("workspace present until drop")
    }
}

impl DerefMut for WorkspaceGuard<'_> {
    fn deref_mut(&mut self) -> &mut Workspace {
        self.ws.as_mut().expect("workspace present until drop")
    }
}

impl Drop for WorkspaceGuard<'_> {
    fn drop(&mut self) {
        if let Some(ws) = self.ws.take() {
            self.pool.slots.lock().unwrap().push(ws);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Workspace {
        Workspace::for_model(&ModelConfig::native_test(), &NativeArch::default())
    }

    #[test]
    fn workspace_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<Workspace>();
        assert_send::<WorkspacePool>();
    }

    #[test]
    fn buffers_sized_from_config() {
        let cfg = ModelConfig::native_test();
        let ws = tiny();
        assert_eq!(ws.xt.len(), cfg.tokens * cfg.dim);
        assert_eq!(ws.qkv.len(), cfg.tokens * 3 * cfg.dim);
        assert_eq!(ws.mlp_hidden.len(), cfg.tokens * 4 * cfg.dim);
        assert_eq!(ws.probs.len(), cfg.tokens);
        assert_eq!(ws.scores.len(), cfg.tokens * cfg.tokens);
        // kmax for native_test is the MLP hidden width (4·dim)
        assert_eq!(ws.pack_a.len(), cfg.tokens * 4 * cfg.dim);
        assert_eq!(ws.pack_b.len(), 4 * cfg.dim * NR);
        assert!(ws.resident_bytes() > 0);
    }

    #[test]
    fn pool_reuses_checked_in_workspaces() {
        let pool = WorkspacePool::new();
        {
            let _a = pool.checkout(tiny);
            assert_eq!(pool.created(), 1);
            // a second concurrent checkout materializes a second workspace
            let _b = pool.checkout(tiny);
            assert_eq!(pool.created(), 2);
        }
        assert_eq!(pool.idle(), 2);
        // sequential checkouts reuse — no new workspaces
        for _ in 0..10 {
            let mut ws = pool.checkout(tiny);
            ws.xt[0] = 1.0;
        }
        assert_eq!(pool.created(), 2);
        assert_eq!(pool.idle(), 2);
    }
}
