//! Cache-blocked CPU kernels with fused epilogues (DESIGN.md §12).
//!
//! The native backend's arithmetic all funnels through this module: a
//! register-tiled f32 GEMM ([`Gemm`]) whose output loop can fold the
//! surrounding elementwise work in (bias add, SiLU, adaLN modulate,
//! gated residual add, row broadcast), a single-pass [`layer_norm`], a
//! fast [`exp_f32`] shared by softmax and SiLU, and a blocked
//! [`attention`] that reuses the same microkernel for the QKᵀ and PV
//! products.
//!
//! **Tiling scheme.** `C[m,n] = A[m,k]·B[k,n]` is computed in `MR`×`NR`
//! register tiles: B is packed one `NR`-wide column panel at a time into
//! a contiguous, zero-padded `[k, NR]` buffer, A is packed once into a
//! row-major `[m, k]` buffer (with the [`Prologue`] applied during the
//! copy), and the microkernel accumulates an `[MR][NR]` block in locals
//! so stable rustc autovectorizes the `NR`-wide inner loop. Tails in `m`
//! dispatch to const-generic `MR`−1…1 variants; tails in `n` ride the
//! panel zero-padding and only the valid columns are written back. Both
//! packing buffers are caller-provided ([`PackBufs`]) and live in the
//! forward-pass [`Workspace`](crate::runtime::workspace::Workspace), so
//! steady-state calls stay allocation-free. A `1×n` row-times-matrix
//! call with a contiguous B takes a packing-free GEMV path.
//!
//! **Fusion contract.** The [`Prologue`] transforms A *elements* as they
//! are packed (adaLN modulate over the `k` axis — in a DiT block,
//! modulate always consumes a LayerNorm that immediately feeds a
//! matmul, so the standalone modulate pass disappears into the pack).
//! The [`Epilogue`] transforms *output* values after the bias add, while
//! the `MR`×`NR` accumulator block is still in registers — `silu(acc)`,
//! `acc·(1+scale)+shift`, `out += gate·acc` (the block residual), or
//! `acc + rows[i,·]` (positional-embedding style broadcasts). Epilogues
//! are applied exactly once per output element, so any epilogue
//! composes with any operand layout, including the strided attention
//! views.
//!
//! **Why the scalar reference stays.** [`scalar`] keeps the original
//! naive loops; every kernel here is parity-tested against them
//! (`tests/kernel_parity.rs`, ULP-bounded) across odd shapes, remainder
//! tiles and every `NativeArch` preset, and the `scalar-ref` cargo
//! feature flips backend defaults to the scalar path so a CI leg runs
//! the whole suite through the oracle. [`KernelMode`] selects the path
//! per backend at runtime, which is also how the micro-benches measure
//! the blocked-vs-naive speedup inside one binary.

pub mod scalar;

/// Microkernel tile height: output rows accumulated per dispatch.
pub const MR: usize = 4;

/// Microkernel tile width: output columns per packed B panel. Sixteen
/// f32 lanes = one AVX-512 register or two AVX2 registers per row, and
/// `MR`·`NR` = 64 accumulators fit the 16 × 256-bit register budget of
/// AVX2 with spill-free codegen on stable rustc.
pub const NR: usize = 16;

/// Which kernel implementation a
/// [`NativeBackend`](crate::runtime::NativeBackend) dispatches through.
///
/// The default is [`Blocked`](KernelMode::Blocked) unless the crate is
/// built with the `scalar-ref` feature, which flips the default to the
/// [`Scalar`](KernelMode::Scalar) reference so the entire test suite can
/// run against the oracle path. Runtime-selectable (not compiled out) so
/// parity tests and speedup benches compare both paths in one process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// Cache-blocked, register-tiled kernels with fused epilogues.
    Blocked,
    /// The retained naive reference loops ([`scalar`]).
    Scalar,
}

impl Default for KernelMode {
    fn default() -> KernelMode {
        if cfg!(feature = "scalar-ref") {
            KernelMode::Scalar
        } else {
            KernelMode::Blocked
        }
    }
}

/// Left GEMM operand: element `(i, kk)` is `data[i·rs + kk]` — rows may
/// be strided (attention reads Q rows out of the interleaved qkv
/// buffer) but row elements are contiguous.
#[derive(Clone, Copy)]
pub struct MatA<'a> {
    /// Backing storage; must cover `(m−1)·rs + k` elements.
    pub data: &'a [f32],
    /// Row stride in elements.
    pub rs: usize,
}

impl<'a> MatA<'a> {
    /// A dense row-major `[m, k]` view (row stride = `k`).
    pub fn dense(data: &'a [f32], k: usize) -> MatA<'a> {
        MatA { data, rs: k }
    }
}

/// Right GEMM operand: element `(kk, j)` is `data[kk·rs + j·cs]`. Fully
/// strided, so the same packing routine serves dense weights (`cs` = 1),
/// transposed views (Kᵀ: `rs` = 1, `cs` = row stride) and interleaved
/// value matrices.
#[derive(Clone, Copy)]
pub struct MatB<'a> {
    /// Backing storage; must cover `(k−1)·rs + (n−1)·cs + 1` elements.
    pub data: &'a [f32],
    /// Row stride in elements.
    pub rs: usize,
    /// Column stride in elements.
    pub cs: usize,
}

impl<'a> MatB<'a> {
    /// A dense row-major `[k, n]` view (row stride = `n`, unit columns).
    pub fn dense(data: &'a [f32], n: usize) -> MatB<'a> {
        MatB { data, rs: n, cs: 1 }
    }
}

/// Input-side fusion: a transform applied to A elements while they are
/// packed, indexed by the `k`-axis position (broadcast over rows).
#[derive(Clone, Copy)]
pub enum Prologue<'a> {
    /// Pack A unchanged.
    None,
    /// adaLN modulate: `a·(1 + scale[kk]) + shift[kk]`. Fusing it here
    /// (rather than as a separate pass over the LayerNorm output) means
    /// the modulated activations are materialized only inside the pack
    /// buffer.
    Modulate {
        /// Per-`k`-position shift, length ≥ `k`.
        shift: &'a [f32],
        /// Per-`k`-position scale, length ≥ `k`.
        scale: &'a [f32],
    },
}

impl Prologue<'_> {
    #[inline(always)]
    fn apply(&self, v: f32, kk: usize) -> f32 {
        match *self {
            Prologue::None => v,
            Prologue::Modulate { shift, scale } => v * (1.0 + scale[kk]) + shift[kk],
        }
    }
}

/// Output-side fusion: applied to `acc + bias` while the accumulator
/// tile is still in registers, exactly once per output element.
#[derive(Clone, Copy)]
pub enum Epilogue<'a> {
    /// `out = acc + bias`.
    None,
    /// `out = silu(acc + bias)` (via [`exp_f32`]).
    Silu,
    /// `out = (acc + bias)·(1 + scale[j]) + shift[j]`, indexed by the
    /// output column.
    Modulate {
        /// Per-column shift, length ≥ `n`.
        shift: &'a [f32],
        /// Per-column scale, length ≥ `n`.
        scale: &'a [f32],
    },
    /// `out += gate[j]·(acc + bias)` — the adaLN-gated residual add of a
    /// DiT block, folded into the matmul so the projection result is
    /// never materialized.
    GatedResidual {
        /// Per-column gate, length ≥ `n`.
        gate: &'a [f32],
    },
    /// `out = acc + bias + rows[i·rs + j]` — per-row broadcast add
    /// (positional embeddings, class embeddings).
    AddRows {
        /// Broadcast table, `rows[i·rs + j]` addressed per output row.
        rows: &'a [f32],
        /// Row stride of the table.
        rs: usize,
    },
}

/// Caller-provided packing scratch for [`Gemm::run`] and [`attention`]:
/// `a` holds the packed `[m, k]` left operand, `b` one `[k, NR]` column
/// panel. Sized by the workspace at construction (`m·k ≤ tokens·kmax`),
/// so the steady state never allocates.
pub struct PackBufs<'a> {
    /// Packed-A backing, at least `m·k` elements.
    pub a: &'a mut [f32],
    /// Packed-B panel backing, at least `k·NR` elements.
    pub b: &'a mut [f32],
}

/// One fused matmul: `out[m, n] = epilogue(prologue(A)[m, k] · B[k, n]
/// + bias)`. Built as a plain struct so call sites read like a kernel
/// launch; `run` executes it.
pub struct Gemm<'a> {
    /// Output rows.
    pub m: usize,
    /// Contraction length.
    pub k: usize,
    /// Output columns.
    pub n: usize,
    /// Left operand view.
    pub a: MatA<'a>,
    /// Right operand view.
    pub b: MatB<'a>,
    /// A-side fusion applied during packing.
    pub prologue: Prologue<'a>,
    /// Per-column bias added before the epilogue (`None` = zero).
    pub bias: Option<&'a [f32]>,
    /// Output-side fusion.
    pub epilogue: Epilogue<'a>,
}

impl Gemm<'_> {
    /// Execute into `out`, whose element `(i, j)` is `out[i·out_rs + j]`
    /// (strided outputs let attention write per-head column bands).
    /// `pack` must satisfy the [`PackBufs`] size contract.
    pub fn run(&self, out: &mut [f32], out_rs: usize, pack: &mut PackBufs<'_>) {
        debug_assert!(self.m >= 1 && self.k >= 1 && self.n >= 1);
        debug_assert!(self.a.data.len() >= (self.m - 1) * self.a.rs + self.k);
        let bmin = (self.k - 1) * self.b.rs + (self.n - 1) * self.b.cs + 1;
        debug_assert!(self.b.data.len() >= bmin);
        debug_assert!(out.len() >= (self.m - 1) * out_rs + self.n);
        // Row-vector times contiguous-row matrix: skip packing entirely.
        // (GatedResidual needs the accumulator separate from `out`, so it
        // always takes the blocked path, where acc lives in registers.)
        let gated = matches!(self.epilogue, Epilogue::GatedResidual { .. });
        if self.m == 1 && self.b.cs == 1 && !gated {
            self.run_gemv(out);
        } else {
            self.run_blocked(out, out_rs, pack);
        }
    }

    /// m = 1 fast path: accumulate straight into the output row (init to
    /// bias), then apply the epilogue in place. All the adaLN-projection
    /// and conditioning-MLP calls (m = 1 by construction) land here with
    /// zero packing traffic.
    fn run_gemv(&self, out: &mut [f32]) {
        let n = self.n;
        let orow = &mut out[..n];
        match self.bias {
            Some(b) => orow.copy_from_slice(&b[..n]),
            None => orow.fill(0.0),
        }
        for kk in 0..self.k {
            let aik = self.prologue.apply(self.a.data[kk], kk);
            let wrow = &self.b.data[kk * self.b.rs..kk * self.b.rs + n];
            for (o, &w) in orow.iter_mut().zip(wrow) {
                *o += aik * w;
            }
        }
        match self.epilogue {
            Epilogue::None => {}
            Epilogue::Silu => {
                for o in orow.iter_mut() {
                    *o = silu(*o);
                }
            }
            Epilogue::Modulate { shift, scale } => {
                for ((o, &sh), &sc) in orow.iter_mut().zip(shift).zip(scale) {
                    *o = *o * (1.0 + sc) + sh;
                }
            }
            Epilogue::AddRows { rows, .. } => {
                for (o, &r) in orow.iter_mut().zip(rows) {
                    *o += r;
                }
            }
            Epilogue::GatedResidual { .. } => {
                unreachable!("GatedResidual is routed to the blocked path")
            }
        }
    }

    /// The general blocked path: pack A once, then stream NR-wide B
    /// panels through the register-tiled microkernel.
    fn run_blocked(&self, out: &mut [f32], out_rs: usize, pack: &mut PackBufs<'_>) {
        let (m, k, n) = (self.m, self.k, self.n);
        let pa = &mut pack.a[..m * k];
        self.pack_a(pa);
        let pb = &mut pack.b[..k * NR];
        let mut jp = 0;
        while jp < n {
            let nr = NR.min(n - jp);
            self.pack_b_panel(jp, nr, pb);
            let mut ip = 0;
            while ip < m {
                let mr = MR.min(m - ip);
                let mut acc = [[0.0f32; NR]; MR];
                let a_tile = &pa[ip * k..];
                match mr {
                    4 => microkernel::<4>(k, a_tile, pb, &mut acc),
                    3 => microkernel::<3>(k, a_tile, pb, &mut acc),
                    2 => microkernel::<2>(k, a_tile, pb, &mut acc),
                    _ => microkernel::<1>(k, a_tile, pb, &mut acc),
                }
                for (r, acc_row) in acc.iter().take(mr).enumerate() {
                    self.apply_row(acc_row, ip + r, jp, nr, out, out_rs);
                }
                ip += mr;
            }
            jp += nr;
        }
    }

    /// Pack A row-major `[m, k]` with the prologue applied element-wise.
    fn pack_a(&self, pa: &mut [f32]) {
        let k = self.k;
        for i in 0..self.m {
            let src = &self.a.data[i * self.a.rs..i * self.a.rs + k];
            let dst = &mut pa[i * k..(i + 1) * k];
            match self.prologue {
                Prologue::None => dst.copy_from_slice(src),
                Prologue::Modulate { shift, scale } => {
                    for ((d, &s), (&sh, &sc)) in
                        dst.iter_mut().zip(src).zip(shift.iter().zip(scale))
                    {
                        *d = s * (1.0 + sc) + sh;
                    }
                }
            }
        }
    }

    /// Pack B columns `jp..jp+nr` into a `[k, NR]` panel, zero-padding
    /// the tail columns so the microkernel never branches on `nr`.
    fn pack_b_panel(&self, jp: usize, nr: usize, pb: &mut [f32]) {
        let b = &self.b;
        for kk in 0..self.k {
            let row = &mut pb[kk * NR..kk * NR + NR];
            if b.cs == 1 {
                row[..nr].copy_from_slice(&b.data[kk * b.rs + jp..kk * b.rs + jp + nr]);
            } else {
                let base = kk * b.rs + jp * b.cs;
                for (j, r) in row[..nr].iter_mut().enumerate() {
                    *r = b.data[base + j * b.cs];
                }
            }
            row[nr..].fill(0.0);
        }
    }

    /// Write one accumulator row back: add the bias, apply the epilogue,
    /// store columns `jp..jp+nr` of output row `i`.
    fn apply_row(
        &self,
        acc: &[f32; NR],
        i: usize,
        jp: usize,
        nr: usize,
        out: &mut [f32],
        out_rs: usize,
    ) {
        let mut vals = [0.0f32; NR];
        match self.bias {
            Some(b) => {
                for ((v, &a), &bb) in vals[..nr].iter_mut().zip(acc).zip(&b[jp..jp + nr]) {
                    *v = a + bb;
                }
            }
            None => vals[..nr].copy_from_slice(&acc[..nr]),
        }
        let base = i * out_rs + jp;
        let orow = &mut out[base..base + nr];
        match self.epilogue {
            Epilogue::None => orow.copy_from_slice(&vals[..nr]),
            Epilogue::Silu => {
                for (o, &v) in orow.iter_mut().zip(&vals[..nr]) {
                    *o = silu(v);
                }
            }
            Epilogue::Modulate { shift, scale } => {
                let sh = &shift[jp..jp + nr];
                let sc = &scale[jp..jp + nr];
                for ((o, &v), (&s0, &s1)) in
                    orow.iter_mut().zip(&vals[..nr]).zip(sh.iter().zip(sc))
                {
                    *o = v * (1.0 + s1) + s0;
                }
            }
            Epilogue::GatedResidual { gate } => {
                for ((o, &v), &g) in orow.iter_mut().zip(&vals[..nr]).zip(&gate[jp..jp + nr]) {
                    *o += g * v;
                }
            }
            Epilogue::AddRows { rows, rs } => {
                let rrow = &rows[i * rs + jp..i * rs + jp + nr];
                for ((o, &v), &r) in orow.iter_mut().zip(&vals[..nr]).zip(rrow) {
                    *o = v + r;
                }
            }
        }
    }
}

/// `MRT`×`NR` register tile: `acc[r][j] += Σ_kk a[r·k + kk] · pb[kk·NR
/// + j]`. `a` is the packed row-major tile (row stride `k`), `pb` the
/// packed `[k, NR]` panel. The fixed-width inner loop over a contiguous
/// panel row is what stable rustc autovectorizes.
#[cfg(not(feature = "portable-simd"))]
#[inline(always)]
fn microkernel<const MRT: usize>(k: usize, a: &[f32], pb: &[f32], acc: &mut [[f32; NR]; MR]) {
    for (kk, bv) in pb.chunks_exact(NR).take(k).enumerate() {
        for r in 0..MRT {
            let av = a[r * k + kk];
            for (ac, &b) in acc[r].iter_mut().zip(bv) {
                *ac += av * b;
            }
        }
    }
}

/// Explicit `std::simd` variant of the microkernel (nightly, behind the
/// `portable-simd` feature). Plain mul + add — not FMA — so both
/// microkernels produce bit-identical results and the parity bounds are
/// feature-independent.
#[cfg(feature = "portable-simd")]
#[inline(always)]
fn microkernel<const MRT: usize>(k: usize, a: &[f32], pb: &[f32], acc: &mut [[f32; NR]; MR]) {
    use std::simd::f32x16;
    let mut vacc = [f32x16::splat(0.0); MRT];
    for (kk, bv) in pb.chunks_exact(NR).take(k).enumerate() {
        let b = f32x16::from_slice(bv);
        for (r, va) in vacc.iter_mut().enumerate() {
            *va += f32x16::splat(a[r * k + kk]) * b;
        }
    }
    for (va, row) in vacc.iter().zip(acc.iter_mut()) {
        row.copy_from_slice(va.as_array());
    }
}

/// Fast `exp` for f32: Cody–Waite range reduction (`x = n·ln2 + r`,
/// two-constant ln2 split) and a degree-6 Taylor polynomial on the
/// reduced `r ∈ [−ln2/2, ln2/2]`, rescaled through the exponent bits.
/// Max relative error ≈ 1e-7 (about 1 ulp); inputs are clamped to
/// `[−87, 88]` so the result stays finite and normal (NaN propagates).
/// Softmax and SiLU spend most of the non-GEMM forward-pass time in
/// `exp`, which is why this is hand-rolled instead of calling libm.
#[inline(always)]
pub fn exp_f32(x: f32) -> f32 {
    #[allow(clippy::excessive_precision)]
    const LN2_HI: f32 = 0.693_359_375; // exact in f32
    #[allow(clippy::excessive_precision)]
    const LN2_LO: f32 = -2.121_944_4e-4;
    let x = x.clamp(-87.0, 88.0);
    let n = (x * std::f32::consts::LOG2_E).round();
    let r = (x - n * LN2_HI) - n * LN2_LO;
    // degree-6 Taylor of exp on |r| ≤ ln2/2, Horner form
    let mut p = 1.0 / 720.0;
    p = p * r + 1.0 / 120.0;
    p = p * r + 1.0 / 24.0;
    p = p * r + 1.0 / 6.0;
    p = p * r + 0.5;
    p = p * r + 1.0;
    p = p * r + 1.0;
    // n ∈ [−126, 127] by the clamp, so the biased exponent is normal
    let scale = f32::from_bits(((n as i32 + 127) << 23) as u32);
    p * scale
}

/// silu(x) = x · σ(x), via [`exp_f32`].
#[inline(always)]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + exp_f32(-x))
}

/// Single-pass per-token LayerNorm (population variance, eps 1e-6 —
/// matches model.py and the scalar reference). Sums and sums-of-squares
/// accumulate in four independent f64 lanes merged at the end
/// (Chan-style lane partitioning), so one sweep yields both moments
/// without the two-pass reference's second read of `x`.
pub fn layer_norm(x: &[f32], out: &mut [f32], tokens: usize, d: usize) {
    debug_assert!(x.len() >= tokens * d && out.len() >= tokens * d);
    for (row, orow) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)).take(tokens) {
        let (mu, var) = moments(row);
        let rs = 1.0 / (var + 1e-6).sqrt();
        for (o, &v) in orow.iter_mut().zip(row) {
            *o = (v - mu) * rs;
        }
    }
}

/// One-sweep mean and population variance of a row: 4 f64 accumulator
/// lanes over `chunks_exact(4)` plus a scalar remainder, merged at the
/// end. `var = E[x²] − E[x]²`, clamped at 0 against cancellation.
fn moments(row: &[f32]) -> (f32, f32) {
    let mut s = [0.0f64; 4];
    let mut sq = [0.0f64; 4];
    let chunks = row.chunks_exact(4);
    let rem = chunks.remainder();
    for c in chunks {
        for (lane, &v) in c.iter().enumerate() {
            let v = v as f64;
            s[lane] += v;
            sq[lane] += v * v;
        }
    }
    let mut sum: f64 = s.iter().sum();
    let mut sumsq: f64 = sq.iter().sum();
    for &v in rem {
        let v = v as f64;
        sum += v;
        sumsq += v * v;
    }
    let n = row.len() as f64;
    let mu = sum / n;
    let var = (sumsq / n - mu * mu).max(0.0);
    (mu as f32, var as f32)
}

/// Row-wise softmax over a `[rows, cols]` score buffer with the
/// attention scale folded into the exponent: `p = exp(scale·(s −
/// max(s))) / Σ`. Uses [`exp_f32`].
pub fn softmax_rows(s: &mut [f32], rows: usize, cols: usize, scale: f32) {
    for row in s.chunks_exact_mut(cols).take(rows) {
        let mut maxv = f32::NEG_INFINITY;
        for &v in row.iter() {
            if v > maxv {
                maxv = v;
            }
        }
        let mut denom = 0.0f32;
        for v in row.iter_mut() {
            *v = exp_f32(scale * (*v - maxv));
            denom += *v;
        }
        let inv = 1.0 / denom;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Blocked softmax attention over an interleaved qkv buffer `[T, 3D]`,
/// writing `[T, D]`. Per head: `S = Q·Kᵀ` through the GEMM microkernel
/// (Kᵀ is just a strided [`MatB`] view — no transpose copy), a row-wise
/// softmax over the full `[T, T]` score matrix in `scores`, then `O =
/// P·V` through the same microkernel into the head's output column
/// band. `scores` needs `tokens²` elements; `pack` follows the
/// [`PackBufs`] contract with `k` up to `max(tokens, d/heads)`.
pub fn attention(
    qkv: &[f32],
    tokens: usize,
    d: usize,
    heads: usize,
    out: &mut [f32],
    scores: &mut [f32],
    pack: &mut PackBufs<'_>,
) {
    let dh = d / heads;
    debug_assert!(dh >= 1);
    debug_assert!(scores.len() >= tokens * tokens);
    let scale = 1.0 / (dh as f32).sqrt();
    let row = 3 * d;
    if heads * dh != d {
        // ragged head split: the uncovered tail columns must read zero,
        // matching the scalar reference's o.fill(0.0)
        out[..tokens * d].fill(0.0);
    }
    for h in 0..heads {
        let off = h * dh;
        Gemm {
            m: tokens,
            k: dh,
            n: tokens,
            a: MatA { data: &qkv[off..], rs: row },
            b: MatB { data: &qkv[d + off..], rs: 1, cs: row },
            prologue: Prologue::None,
            bias: None,
            epilogue: Epilogue::None,
        }
        .run(scores, tokens, pack);
        softmax_rows(scores, tokens, tokens, scale);
        Gemm {
            m: tokens,
            k: tokens,
            n: dh,
            a: MatA { data: &*scores, rs: tokens },
            b: MatB { data: &qkv[2 * d + off..], rs: row, cs: 1 },
            prologue: Prologue::None,
            bias: None,
            epilogue: Epilogue::None,
        }
        .run(&mut out[off..], d, pack);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn exp_tracks_libm() {
        for i in -1740..=1760 {
            let x = i as f32 * 0.05; // [-87, 88]
            let got = exp_f32(x);
            let want = x.exp();
            let rel = ((got - want) / want).abs();
            assert!(rel < 3e-7, "exp({x}): got {got}, want {want}, rel {rel}");
        }
        assert_eq!(exp_f32(0.0), 1.0);
        assert!(exp_f32(-1000.0) > 0.0); // clamped, finite
        assert!(exp_f32(1000.0).is_finite());
        assert!(exp_f32(f32::NAN).is_nan());
    }

    #[test]
    fn gemm_matches_scalar_reference() {
        let mut rng = Rng::new(42);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (4, 8, 16), (5, 7, 19), (16, 24, 96)] {
            let a = rng.normal_f32s(m * k);
            let w = rng.normal_f32s(k * n);
            let bias = rng.normal_f32s(n);
            let mut want = vec![0.0f32; m * n];
            scalar::matmul_add(&a, &w, &bias, m, k, n, &mut want);
            let (mut pa, mut pb) = (vec![0.0f32; m * k], vec![0.0f32; k * NR]);
            let mut got = vec![0.0f32; m * n];
            Gemm {
                m,
                k,
                n,
                a: MatA::dense(&a, k),
                b: MatB::dense(&w, n),
                prologue: Prologue::None,
                bias: Some(&bias),
                epilogue: Epilogue::None,
            }
            .run(&mut got, n, &mut PackBufs { a: &mut pa, b: &mut pb });
            for (g, w2) in got.iter().zip(&want) {
                assert!((g - w2).abs() < 1e-4, "({m},{k},{n}): {g} vs {w2}");
            }
        }
    }

    #[test]
    fn gemv_and_blocked_paths_agree() {
        let mut rng = Rng::new(43);
        let (k, n) = (13, 37);
        let a = rng.normal_f32s(k);
        let w = rng.normal_f32s(k * n);
        let bias = rng.normal_f32s(n);
        let (mut pa, mut pb) = (vec![0.0; k], vec![0.0; k * NR]);
        let mk = |epi| Gemm {
            m: 1,
            k,
            n,
            a: MatA::dense(&a, k),
            b: MatB::dense(&w, n),
            prologue: Prologue::None,
            bias: Some(&bias),
            epilogue: epi,
        };
        let mut gemv = vec![0.0f32; n];
        mk(Epilogue::Silu).run(&mut gemv, n, &mut PackBufs { a: &mut pa, b: &mut pb });
        // strided B (cs > 1) forces the blocked path for the same math
        let mut wt = vec![0.0f32; k * n];
        for kk in 0..k {
            for j in 0..n {
                wt[j * k + kk] = w[kk * n + j];
            }
        }
        let mut blocked = vec![0.0f32; n];
        Gemm {
            m: 1,
            k,
            n,
            a: MatA::dense(&a, k),
            b: MatB { data: &wt, rs: 1, cs: k },
            prologue: Prologue::None,
            bias: Some(&bias),
            epilogue: Epilogue::Silu,
        }
        .run(&mut blocked, n, &mut PackBufs { a: &mut pa, b: &mut pb });
        for (g, b2) in gemv.iter().zip(&blocked) {
            assert!((g - b2).abs() < 1e-5, "{g} vs {b2}");
        }
    }

    #[test]
    fn layer_norm_matches_scalar() {
        let mut rng = Rng::new(44);
        for &(t, d) in &[(1usize, 5usize), (3, 7), (16, 24)] {
            let x = rng.normal_f32s(t * d);
            let mut want = vec![0.0f32; t * d];
            let mut got = vec![0.0f32; t * d];
            scalar::layer_norm(&x, &mut want, t, d);
            layer_norm(&x, &mut got, t, d);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-5, "({t},{d}): {g} vs {w}");
            }
        }
    }

    #[test]
    fn attention_matches_scalar() {
        let mut rng = Rng::new(45);
        // (tokens, d, heads) incl. a ragged split (heads·dh < d)
        for &(t, d, h) in &[(4usize, 8usize, 2usize), (7, 10, 3), (16, 24, 4)] {
            let qkv = rng.normal_f32s(t * 3 * d);
            let mut want = vec![0.0f32; t * d];
            let mut probs = vec![0.0f32; t];
            scalar::attention(&qkv, t, d, h, &mut want, &mut probs);
            let mut got = vec![0.0f32; t * d];
            let mut scores = vec![0.0f32; t * t];
            let kmax = t.max(d / h);
            let (mut pa, mut pb) = (vec![0.0; t * kmax], vec![0.0; kmax * NR]);
            attention(
                &qkv,
                t,
                d,
                h,
                &mut got,
                &mut scores,
                &mut PackBufs { a: &mut pa, b: &mut pb },
            );
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "({t},{d},{h}): {g} vs {w}");
            }
        }
    }

    #[test]
    fn default_mode_tracks_feature() {
        let want =
            if cfg!(feature = "scalar-ref") { KernelMode::Scalar } else { KernelMode::Blocked };
        assert_eq!(KernelMode::default(), want);
    }
}
