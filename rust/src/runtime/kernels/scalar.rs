//! Scalar reference kernels: the original naive loops of the native
//! backend, kept verbatim as the parity oracle for the blocked kernel
//! layer in [`super`] (DESIGN.md §12).
//!
//! These are deliberately the simplest correct implementations — ikj
//! triple-loop matmul, two-pass LayerNorm, per-query attention with
//! `libm` `exp` — so a disagreement between paths always indicts the
//! fast one. `tests/kernel_parity.rs` sweeps both over odd shapes and
//! every [`NativeArch`](crate::runtime::native::NativeArch) preset, and
//! the `scalar-ref` cargo feature makes backends default to this path
//! so a dedicated CI leg runs the entire test suite through it.

/// out[m, n] = a[m, k] @ w[k, n] + bias[n] (ikj loop order: the inner
/// loop runs down contiguous rows of `w` and `out`, which vectorizes).
pub fn matmul_add(
    a: &[f32],
    w: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let out_row = &mut out[i * n..(i + 1) * n];
        out_row.copy_from_slice(bias);
        let a_row = &a[i * k..(i + 1) * k];
        for (kk, &aik) in a_row.iter().enumerate() {
            let w_row = &w[kk * n..(kk + 1) * n];
            for (o, &wv) in out_row.iter_mut().zip(w_row) {
                *o += aik * wv;
            }
        }
    }
}

/// silu(x) = x · σ(x), via `libm` exp.
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Per-token LayerNorm (population variance, eps 1e-6 — matches
/// model.py). Two-pass: f32 mean, then f32 centered variance.
pub fn layer_norm(x: &[f32], out: &mut [f32], tokens: usize, d: usize) {
    for t in 0..tokens {
        let row = &x[t * d..(t + 1) * d];
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let rs = 1.0 / (var + 1e-6).sqrt();
        for (o, &v) in out[t * d..(t + 1) * d].iter_mut().zip(row) {
            *o = (v - mu) * rs;
        }
    }
}

/// x ← x·(1 + scale) + shift, broadcast over tokens.
pub fn modulate(x: &mut [f32], shift: &[f32], scale: &[f32], tokens: usize, d: usize) {
    for t in 0..tokens {
        for (j, v) in x[t * d..(t + 1) * d].iter_mut().enumerate() {
            *v = *v * (1.0 + scale[j]) + shift[j];
        }
    }
}

/// Softmax attention over an interleaved qkv buffer [T, 3D], writing
/// [T, D]. `probs` is caller-provided score scratch of length `tokens`
/// (fully overwritten per query row).
pub fn attention(
    qkv: &[f32],
    tokens: usize,
    d: usize,
    heads: usize,
    o: &mut [f32],
    probs: &mut [f32],
) {
    debug_assert_eq!(probs.len(), tokens);
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let row = 3 * d;
    o.fill(0.0);
    for h in 0..heads {
        let off = h * dh;
        for tq in 0..tokens {
            let q_row = &qkv[tq * row + off..tq * row + off + dh];
            let mut maxv = f32::NEG_INFINITY;
            for (tk, p) in probs.iter_mut().enumerate() {
                let k_row = &qkv[tk * row + d + off..tk * row + d + off + dh];
                let dot: f32 = q_row.iter().zip(k_row).map(|(a, b)| a * b).sum();
                *p = dot * scale;
                maxv = maxv.max(*p);
            }
            let mut denom = 0f32;
            for p in probs.iter_mut() {
                *p = (*p - maxv).exp();
                denom += *p;
            }
            let inv = 1.0 / denom;
            let o_row = &mut o[tq * d + off..tq * d + off + dh];
            for (tk, &p) in probs.iter().enumerate() {
                let v_row = &qkv[tk * row + 2 * d + off..tk * row + 2 * d + off + dh];
                let pw = p * inv;
                for (ov, &vv) in o_row.iter_mut().zip(v_row) {
                    *ov += pw * vv;
                }
            }
        }
    }
}
