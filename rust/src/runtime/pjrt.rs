//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! client from the Rust request path (adapted from /opt/xla-example/load_hlo).
//! Compiled only with the `pjrt` cargo feature; the default build serves
//! through [`crate::runtime::native`] instead.
//!
//! Performance notes (EXPERIMENTS.md §Perf):
//! * model weights are uploaded to device buffers **once** at load time and
//!   passed by handle via `execute_b` — the per-step host→device traffic is
//!   only the latent/feature inputs;
//! * executables are compiled lazily per (entry, bucket) and memoized;
//! * `PjRtClient` is `Rc`-based (not `Send`) so the engine owns the runtime
//!   on a single thread; server threads talk to it over channels. (The
//!   native backend has no such constraint — see DESIGN.md §3.)

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{ClassifierEntry, ModelEntry};
use crate::runtime::backend::{ClassifierBackend, ModelBackend};
use crate::tensor::Tensor;
use crate::weights::TensorFile;

/// Convert an xla crate error into anyhow (xla::Error is not Send+Sync).
macro_rules! xerr {
    ($e:expr, $ctx:expr) => {
        $e.map_err(|e| anyhow!("{}: {e:?}", $ctx))
    };
}

/// A live PJRT client (CPU plugin).
pub struct Runtime {
    /// The underlying PJRT client handle.
    pub client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xerr!(xla::PjRtClient::cpu(), "creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Parse HLO text and compile on this client.
    pub fn compile_hlo(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xerr!(
            xla::HloModuleProto::from_text_file(path),
            format!("parsing HLO text {}", path.display())
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        xerr!(self.client.compile(&comp), format!("compiling {}", path.display()))
    }

    /// Upload an f32 host buffer to the device.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        xerr!(self.client.buffer_from_host_buffer(data, dims, None), "uploading f32 buffer")
    }

    /// Upload an i32 host buffer to the device.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        xerr!(self.client.buffer_from_host_buffer(data, dims, None), "uploading i32 buffer")
    }
}

/// One positional input for a generic execution.
pub enum In<'a> {
    /// f32 tensor: data + dims.
    F32(&'a [f32], &'a [usize]),
    /// i32 tensor: data + dims.
    I32(&'a [i32], &'a [usize]),
    /// Rank-0 f32.
    ScalarF32(f32),
    /// Rank-0 i32.
    ScalarI32(i32),
}

fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = xerr!(lit.array_shape(), "output shape")?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    let data = xerr!(lit.to_vec::<f32>(), "output to_vec")?;
    Ok(Tensor::new(dims, data))
}

/// A compiled artifact; weights (if any) are passed in per call as
/// device-buffer handles.
pub struct Exec {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Exec {
    /// Execute with `weights ++ inputs`; returns every tuple output.
    pub fn run(
        &self,
        rt: &Runtime,
        weights: &[xla::PjRtBuffer],
        inputs: &[In<'_>],
    ) -> Result<Vec<Tensor>> {
        let mut owned: Vec<xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        for inp in inputs {
            let b = match inp {
                In::F32(d, dims) => rt.upload_f32(d, dims)?,
                In::I32(d, dims) => rt.upload_i32(d, dims)?,
                In::ScalarF32(v) => rt.upload_f32(std::slice::from_ref(v), &[])?,
                In::ScalarI32(v) => rt.upload_i32(std::slice::from_ref(v), &[])?,
            };
            owned.push(b);
        }
        let mut bufs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(weights.len() + owned.len());
        bufs.extend(weights.iter());
        bufs.extend(owned.iter());
        let out = xerr!(self.exe.execute_b(&bufs), format!("executing {}", self.name))?;
        let lit = xerr!(out[0][0].to_literal_sync(), "fetching output")?;
        let parts = xerr!(lit.to_tuple(), "untupling output")?;
        parts.iter().map(literal_to_tensor).collect()
    }
}

/// All executables + device-resident weights for one model.
pub struct ModelRuntime<'rt> {
    rt: &'rt Runtime,
    /// The manifest entry this runtime executes.
    pub entry: ModelEntry,
    weights: Vec<xla::PjRtBuffer>,
    execs: RefCell<BTreeMap<(String, usize), Rc<Exec>>>,
}

impl<'rt> ModelRuntime<'rt> {
    /// Upload weights and prepare lazy per-(entry, bucket) compilation.
    pub fn load(rt: &'rt Runtime, entry: &ModelEntry) -> Result<ModelRuntime<'rt>> {
        let wf = TensorFile::load(&entry.weights)?;
        let mut weights = Vec::new();
        for spec in &entry.params {
            let t = wf
                .f32(&spec.name)
                .with_context(|| format!("weights.bin missing {}", spec.name))?;
            if t.shape != spec.shape {
                bail!("weight {}: shape {:?} != manifest {:?}", spec.name, t.shape, spec.shape);
            }
            weights.push(rt.upload_f32(&t.data, &t.shape)?);
        }
        Ok(ModelRuntime {
            rt,
            entry: entry.clone(),
            weights,
            execs: RefCell::new(BTreeMap::new()),
        })
    }

    /// Compile (or fetch memoized) executable for (entry_point, bucket).
    pub fn exec(&self, entry_point: &str, bucket: usize) -> Result<Rc<Exec>> {
        let key = (entry_point.to_string(), bucket);
        if let Some(e) = self.execs.borrow().get(&key) {
            return Ok(e.clone());
        }
        let path = self
            .entry
            .artifacts
            .get(entry_point)
            .and_then(|m| m.get(&bucket))
            .with_context(|| format!("no artifact for {entry_point} bucket {bucket}"))?;
        let exe = self.rt.compile_hlo(path)?;
        let e = Rc::new(Exec { exe, name: format!("{entry_point}_b{bucket}") });
        self.execs.borrow_mut().insert(key, e.clone());
        Ok(e)
    }

    /// Compile a standalone kernel artifact (no weight closure).
    pub fn kernel_exec(&self, name: &str) -> Result<Exec> {
        let path = self
            .entry
            .kernel_artifacts
            .get(name)
            .with_context(|| format!("no kernel artifact {name}"))?;
        Ok(Exec { exe: self.rt.compile_hlo(path)?, name: name.to_string() })
    }

    /// Warm up the executables the serving engine needs (compile is the
    /// expensive part; do it before admitting traffic).
    pub fn precompile(&self, entries: &[&str], buckets: &[usize]) -> Result<()> {
        for e in entries {
            for b in buckets {
                self.exec(e, *b)?;
            }
        }
        Ok(())
    }

    /// Eps-only full pass: skips the boundary-stack device→host transfer
    /// (perf-pass variant for policies that never read the feature cache).
    pub fn full_eps(&self, bucket: usize, x: &[f32], t: &[f32], y: &[i32]) -> Result<Tensor> {
        debug_assert_eq!(x.len(), bucket * self.entry.config.latent_dim);
        let e = self.exec("full_eps", bucket)?;
        let latent = self.entry.config.latent_dim;
        let out = e.run(
            self.rt,
            &self.weights,
            &[In::F32(x, &[bucket, latent]), In::F32(t, &[bucket]), In::I32(y, &[bucket])],
        )?;
        out.into_iter().next().context("missing eps output")
    }

    /// Full forward pass: (eps [B, latent], boundaries [L+1, B, T, D]).
    pub fn full(
        &self,
        bucket: usize,
        x: &[f32],
        t: &[f32],
        y: &[i32],
        pallas: bool,
    ) -> Result<(Tensor, Tensor)> {
        let entry_point = if pallas { "full_pallas" } else { "full" };
        debug_assert_eq!(x.len(), bucket * self.entry.config.latent_dim);
        let e = self.exec(entry_point, bucket)?;
        let latent = self.entry.config.latent_dim;
        let out = e.run(
            self.rt,
            &self.weights,
            &[In::F32(x, &[bucket, latent]), In::F32(t, &[bucket]), In::I32(y, &[bucket])],
        )?;
        let mut it = out.into_iter();
        let eps = it.next().context("missing eps output")?;
        let bounds = it.next().context("missing boundaries output")?;
        Ok((eps, bounds))
    }

    /// Verification block: feat [B, T, D] -> block(layer) output [B, T, D].
    pub fn block(
        &self,
        bucket: usize,
        layer: i32,
        feat: &[f32],
        t: &[f32],
        y: &[i32],
    ) -> Result<Tensor> {
        let cfg = &self.entry.config;
        let e = self.exec("block", bucket)?;
        let out = e.run(
            self.rt,
            &self.weights,
            &[
                In::ScalarI32(layer),
                In::F32(feat, &[bucket, cfg.tokens, cfg.dim]),
                In::F32(t, &[bucket]),
                In::I32(y, &[bucket]),
            ],
        )?;
        out.into_iter().next().context("missing block output")
    }

    /// Output head on a (predicted) last-boundary feature.
    pub fn head(&self, bucket: usize, feat: &[f32], t: &[f32], y: &[i32]) -> Result<Tensor> {
        let cfg = &self.entry.config;
        let e = self.exec("head", bucket)?;
        let out = e.run(
            self.rt,
            &self.weights,
            &[
                In::F32(feat, &[bucket, cfg.tokens, cfg.dim]),
                In::F32(t, &[bucket]),
                In::I32(y, &[bucket]),
            ],
        )?;
        out.into_iter().next().context("missing head output")
    }
}

impl ModelBackend for ModelRuntime<'_> {
    fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn supports(&self, entry_point: &str) -> bool {
        self.entry.artifacts.contains_key(entry_point)
    }

    fn warmup(&self, entry_points: &[&str], buckets: &[usize]) -> Result<()> {
        self.precompile(entry_points, buckets)
    }

    fn full(
        &self,
        bucket: usize,
        x: &[f32],
        t: &[f32],
        y: &[i32],
        pallas: bool,
    ) -> Result<(Tensor, Tensor)> {
        ModelRuntime::full(self, bucket, x, t, y, pallas)
    }

    fn full_eps(&self, bucket: usize, x: &[f32], t: &[f32], y: &[i32]) -> Result<Tensor> {
        ModelRuntime::full_eps(self, bucket, x, t, y)
    }

    fn block(
        &self,
        bucket: usize,
        layer: i32,
        feat: &[f32],
        t: &[f32],
        y: &[i32],
    ) -> Result<Tensor> {
        ModelRuntime::block(self, bucket, layer, feat, t, y)
    }

    fn head(&self, bucket: usize, feat: &[f32], t: &[f32], y: &[i32]) -> Result<Tensor> {
        ModelRuntime::head(self, bucket, feat, t, y)
    }
}

/// Metrics classifier runtime (FID features + IS posteriors).
pub struct ClassifierRuntime<'rt> {
    rt: &'rt Runtime,
    /// The manifest entry this runtime executes.
    pub entry: ClassifierEntry,
    weights: Vec<xla::PjRtBuffer>,
    execs: RefCell<BTreeMap<usize, Rc<Exec>>>,
    /// Stored FID* reference mean.
    pub fid_mu: Tensor,
    /// Stored FID* reference covariance.
    pub fid_cov: Tensor,
    /// Stored sFID* reference mean.
    pub sfid_mu: Tensor,
    /// Stored sFID* reference covariance.
    pub sfid_cov: Tensor,
}

impl<'rt> ClassifierRuntime<'rt> {
    /// Upload classifier weights and reference Gaussians.
    pub fn load(rt: &'rt Runtime, entry: &ClassifierEntry) -> Result<ClassifierRuntime<'rt>> {
        let wf = TensorFile::load(&entry.weights)?;
        let mut weights = Vec::new();
        for spec in &entry.params {
            let t = wf.f32(&spec.name)?;
            weights.push(rt.upload_f32(&t.data, &t.shape)?);
        }
        Ok(ClassifierRuntime {
            rt,
            entry: entry.clone(),
            weights,
            execs: RefCell::new(BTreeMap::new()),
            fid_mu: wf.f32("fid_mu")?.clone(),
            fid_cov: wf.f32("fid_cov")?.clone(),
            sfid_mu: wf.f32("sfid_mu")?.clone(),
            sfid_cov: wf.f32("sfid_cov")?.clone(),
        })
    }

    fn exec(&self, bucket: usize) -> Result<Rc<Exec>> {
        if let Some(e) = self.execs.borrow().get(&bucket) {
            return Ok(e.clone());
        }
        let path = self
            .entry
            .artifacts
            .get(&bucket)
            .with_context(|| format!("no classifier artifact for bucket {bucket}"))?;
        let e = Rc::new(Exec { exe: self.rt.compile_hlo(path)?, name: format!("cls_b{bucket}") });
        self.execs.borrow_mut().insert(bucket, e.clone());
        Ok(e)
    }

    /// Compiled classifier batch buckets.
    pub fn buckets(&self) -> Vec<usize> {
        self.entry.artifacts.keys().copied().collect()
    }

    /// x: [B, latent] -> (logits [B, K], feats [B, feat_dim]).
    pub fn classify(&self, bucket: usize, x: &[f32]) -> Result<(Tensor, Tensor)> {
        debug_assert_eq!(x.len(), bucket * self.entry.latent_dim);
        let e = self.exec(bucket)?;
        let out =
            e.run(self.rt, &self.weights, &[In::F32(x, &[bucket, self.entry.latent_dim])])?;
        let mut it = out.into_iter();
        let logits = it.next().context("missing logits")?;
        let feats = it.next().context("missing feats")?;
        Ok((logits, feats))
    }
}

impl ClassifierBackend for ClassifierRuntime<'_> {
    fn latent_dim(&self) -> usize {
        self.entry.latent_dim
    }

    fn num_classes(&self) -> usize {
        self.entry.num_classes
    }

    fn feat_dim(&self) -> usize {
        self.entry.feat_dim
    }

    fn buckets(&self) -> Vec<usize> {
        ClassifierRuntime::buckets(self)
    }

    fn classify(&self, bucket: usize, x: &[f32]) -> Result<(Tensor, Tensor)> {
        ClassifierRuntime::classify(self, bucket, x)
    }

    fn fid_mu(&self) -> &Tensor {
        &self.fid_mu
    }

    fn fid_cov(&self) -> &Tensor {
        &self.fid_cov
    }

    fn sfid_mu(&self) -> &Tensor {
        &self.sfid_mu
    }

    fn sfid_cov(&self) -> &Tensor {
        &self.sfid_cov
    }
}
