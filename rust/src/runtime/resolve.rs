//! Backend resolution shared by the CLI and the experiment runners —
//! the one place that turns `--backend native|pjrt|auto` (+ `--model`,
//! `--model-seed`) into live backend objects. Replaces the ladder that
//! was previously duplicated in `main.rs` and `experiments/tables.rs`.
//!
//! `--backend auto` behaviour: prefer PJRT when the feature is compiled
//! in and artifacts are present, but *probe* the runtime first — a build
//! against the stub `xla` crate (rust/vendor/xla) fails at
//! `Runtime::cpu()`, and auto falls back to the native backend with a
//! warning instead of erroring. An explicit `--backend pjrt` still fails
//! loudly.

use std::sync::Arc;

use anyhow::Result;

use crate::config::ModelEntry;
use crate::runtime::{select_backend, BackendKind, ClassifierBackend, ModelBackend, NativeHub};
use crate::util::cli::Args;

/// The backend-selection flags of one CLI/bench invocation.
#[derive(Debug, Clone)]
pub struct BackendRequest {
    /// `--backend` value (`native` / `pjrt` / `auto`).
    pub backend: String,
    /// `--model` name.
    pub model: String,
    /// `--model-seed` for the native weights.
    pub model_seed: u64,
}

impl BackendRequest {
    /// Read the backend-selection flags (with defaults).
    pub fn from_args(args: &Args) -> BackendRequest {
        BackendRequest {
            backend: args.str("backend", "auto"),
            model: args.str("model", "dit-sim"),
            model_seed: args.u64("model-seed", NativeHub::DEFAULT_SEED),
        }
    }

    /// Same request, different model name (experiment runners pin one).
    pub fn with_model(mut self, model: &str) -> BackendRequest {
        self.model = model.to_string();
        self
    }

    /// Which backend the flags select, before any runtime probing.
    pub fn kind(&self) -> Result<BackendKind> {
        select_backend(&self.backend, artifacts_present())
    }
}

/// Whether an artifacts manifest exists at the configured location.
pub fn artifacts_present() -> bool {
    crate::artifacts_dir().join("manifest.json").exists()
}

/// A resolved model backend. `Shared` (native) can fan out across shard
/// worker threads; `Local` (PJRT — `Rc`-based client) is pinned to the
/// resolving thread.
pub enum ResolvedModel<'env> {
    /// Thread-shareable backend (native) — shard pools fan out over it.
    Shared(Arc<dyn ModelBackend + Send + Sync>),
    /// Thread-pinned backend (PJRT's `Rc`-based client).
    Local(Arc<dyn ModelBackend + 'env>),
}

impl<'env> ResolvedModel<'env> {
    /// The backend as a uniform `Arc` handle (engine constructor input).
    pub fn backend(&self) -> Arc<dyn ModelBackend + 'env> {
        match self {
            ResolvedModel::Shared(m) => m.clone(),
            ResolvedModel::Local(m) => m.clone(),
        }
    }

    /// The thread-shareable handle, when this backend supports one
    /// (required by `--shards > 1`).
    pub fn shared(&self) -> Option<Arc<dyn ModelBackend + Send + Sync>> {
        match self {
            ResolvedModel::Shared(m) => Some(m.clone()),
            ResolvedModel::Local(_) => None,
        }
    }

    /// The model's config/schedule/FLOPs description.
    pub fn entry(&self) -> &ModelEntry {
        match self {
            ResolvedModel::Shared(m) => m.entry(),
            ResolvedModel::Local(m) => m.entry(),
        }
    }

    /// Backend tag ("native" / "pjrt").
    pub fn kind(&self) -> &'static str {
        match self {
            ResolvedModel::Shared(m) => m.kind(),
            ResolvedModel::Local(m) => m.kind(),
        }
    }
}

/// Resolve a model + classifier pair and run `f` against them.
pub fn with_backends<R>(
    req: &BackendRequest,
    f: impl FnOnce(ResolvedModel<'_>, &dyn ClassifierBackend) -> Result<R>,
) -> Result<R> {
    match req.kind()? {
        BackendKind::Native => native_backends(req, f),
        BackendKind::Pjrt => pjrt_backends(req, f),
    }
}

/// Model-only variant for callers that need no classifier.
pub fn with_model<R>(
    req: &BackendRequest,
    f: impl FnOnce(ResolvedModel<'_>) -> Result<R>,
) -> Result<R> {
    with_backends(req, |model, _cls| f(model))
}

fn native_backends<R>(
    req: &BackendRequest,
    f: impl FnOnce(ResolvedModel<'_>, &dyn ClassifierBackend) -> Result<R>,
) -> Result<R> {
    let hub = NativeHub::seeded(req.model_seed);
    let model = hub.model_shared(&req.model)?;
    f(ResolvedModel::Shared(model), &hub.classifier)
}

#[cfg(feature = "pjrt")]
fn pjrt_backends<R>(
    req: &BackendRequest,
    f: impl FnOnce(ResolvedModel<'_>, &dyn ClassifierBackend) -> Result<R>,
) -> Result<R> {
    use crate::config::Manifest;
    use crate::runtime::{ClassifierRuntime, ModelRuntime, Runtime};

    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) if req.backend == "auto" => {
            eprintln!(
                "speca: PJRT runtime unavailable ({e:#}); --backend auto falling back to native"
            );
            return native_backends(req, f);
        }
        Err(e) => return Err(e),
    };
    let manifest = Manifest::load(&crate::artifacts_dir())?;
    let entry = manifest.model(&req.model)?;
    let model = ModelRuntime::load(&rt, entry)?;
    let cls = ClassifierRuntime::load(&rt, &manifest.classifier)?;
    f(ResolvedModel::Local(Arc::new(model)), &cls)
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backends<R>(
    _req: &BackendRequest,
    _f: impl FnOnce(ResolvedModel<'_>, &dyn ClassifierBackend) -> Result<R>,
) -> Result<R> {
    unreachable!("select_backend rejects pjrt without the feature")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Args;

    fn argv(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn request_reads_flags_with_defaults() {
        let r = BackendRequest::from_args(&argv("bench --backend native --model flux-sim"));
        assert_eq!(r.backend, "native");
        assert_eq!(r.model, "flux-sim");
        assert_eq!(r.model_seed, NativeHub::DEFAULT_SEED);
        let d = BackendRequest::from_args(&argv("serve"));
        assert_eq!(d.backend, "auto");
        assert_eq!(d.model, "dit-sim");
        assert_eq!(d.with_model("video-sim").model, "video-sim");
    }

    #[test]
    fn native_resolution_is_shared_and_shardable() {
        let req = BackendRequest::from_args(&argv("x --backend native --model dit-sim"));
        with_backends(&req, |model, cls| {
            assert_eq!(model.kind(), "native");
            assert!(model.shared().is_some(), "native must support sharding");
            assert_eq!(model.entry().config.name, "dit-sim");
            assert!(cls.num_classes() > 0);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn unknown_backend_is_rejected() {
        let req = BackendRequest::from_args(&argv("x --backend warp"));
        assert!(with_model(&req, |_| Ok(())).is_err());
    }
}
