//! Execution-backend traits — the seam between the serving coordinator and
//! whatever actually runs the DiT forward pass (DESIGN.md §3).
//!
//! The engine, server, experiment runners and benches are written against
//! `&dyn ModelBackend`; concrete implementations are
//! [`crate::runtime::native::NativeBackend`] (pure Rust, `Send`, zero
//! artifacts) and, behind the `pjrt` cargo feature,
//! [`crate::runtime::pjrt::ModelRuntime`] (AOT HLO via the PJRT C API).
//! Draft-strategy plugins and sharded/multi-threaded engines plug in at
//! this same seam in later PRs.

use anyhow::Result;

use crate::config::ModelEntry;
use crate::tensor::Tensor;

/// One diffusion-transformer model with the four entry points the SpeCa
/// engine schedules (paper §3.2): the full pass, its eps-only perf
/// variant, the single verification block, and the output head.
///
/// Contract (shapes are row-major, flat `f32`):
/// * `full(b, x[b·latent], t[b], y[b])` → `(eps [b, latent],
///   boundaries [depth+1, b, tokens·dim])`; `boundaries[i]` is the input
///   to block `i`, `boundaries[depth]` the head input;
/// * `full_eps` returns only `eps` (backends may skip the boundary-stack
///   transfer — EXPERIMENTS.md §Perf);
/// * `block(b, layer, feat[b·tokens·dim], ..)` runs exactly block `layer`
///   (runtime index) on the given features;
/// * `head(b, feat, ..)` maps a last-boundary feature to `eps`;
/// * batching must be transparent: row `i` of a bucket-`b` call equals the
///   same input run at bucket 1 (padding rows are ignored by callers);
/// * all calls are `&self`: backends are internally synchronized or
///   immutable, so a `Send + Sync` backend can serve multiple engines.
pub trait ModelBackend {
    /// Model description: config, schedule and FLOPs tables. For artifact
    /// backends this mirrors the manifest; native backends synthesize it.
    fn entry(&self) -> &ModelEntry;

    /// Short backend tag for logs and `speca info` ("native", "pjrt").
    fn kind(&self) -> &'static str;

    /// Whether an entry point ("full", "full_eps", "full_pallas", "block",
    /// "head") is available on this backend.
    fn supports(&self, entry_point: &str) -> bool;

    /// Prepare the given entry points across batch buckets (compile and
    /// memoize for AOT backends; a no-op for native execution). Called
    /// before admitting traffic so the hot path never pays startup cost.
    fn warmup(&self, entry_points: &[&str], buckets: &[usize]) -> Result<()>;

    /// Full forward pass: `(eps, boundaries)`. `pallas` selects the
    /// pallas-attention artifact variant where supported; backends without
    /// one fall back to their default attention path.
    fn full(
        &self,
        bucket: usize,
        x: &[f32],
        t: &[f32],
        y: &[i32],
        pallas: bool,
    ) -> Result<(Tensor, Tensor)>;

    /// Eps-only full pass (no boundary stack materialized).
    fn full_eps(&self, bucket: usize, x: &[f32], t: &[f32], y: &[i32]) -> Result<Tensor>;

    /// Verification block: feat [b, tokens·dim] → block(`layer`) output.
    fn block(&self, bucket: usize, layer: i32, feat: &[f32], t: &[f32], y: &[i32])
        -> Result<Tensor>;

    /// Output head on a (predicted) last-boundary feature → eps.
    fn head(&self, bucket: usize, feat: &[f32], t: &[f32], y: &[i32]) -> Result<Tensor>;
}

/// References delegate, so a stack-owned backend can be handed to an
/// `Arc<dyn ModelBackend>`-owning [`crate::coordinator::Engine`] without
/// giving up ownership (`Engine::from_ref`).
impl<B: ModelBackend + ?Sized> ModelBackend for &B {
    fn entry(&self) -> &ModelEntry {
        (**self).entry()
    }

    fn kind(&self) -> &'static str {
        (**self).kind()
    }

    fn supports(&self, entry_point: &str) -> bool {
        (**self).supports(entry_point)
    }

    fn warmup(&self, entry_points: &[&str], buckets: &[usize]) -> Result<()> {
        (**self).warmup(entry_points, buckets)
    }

    fn full(
        &self,
        bucket: usize,
        x: &[f32],
        t: &[f32],
        y: &[i32],
        pallas: bool,
    ) -> Result<(Tensor, Tensor)> {
        (**self).full(bucket, x, t, y, pallas)
    }

    fn full_eps(&self, bucket: usize, x: &[f32], t: &[f32], y: &[i32]) -> Result<Tensor> {
        (**self).full_eps(bucket, x, t, y)
    }

    fn block(&self, bucket: usize, layer: i32, feat: &[f32], t: &[f32], y: &[i32])
        -> Result<Tensor> {
        (**self).block(bucket, layer, feat, t, y)
    }

    fn head(&self, bucket: usize, feat: &[f32], t: &[f32], y: &[i32]) -> Result<Tensor> {
        (**self).head(bucket, feat, t, y)
    }
}

/// Metrics classifier (FID* features + IS* posteriors, DESIGN.md §2).
///
/// `classify(b, x[b·latent])` → `(logits [b, num_classes],
/// feats [b, feat_dim])`, batching-transparent like [`ModelBackend`]. The
/// `fid_*`/`sfid_*` tensors are the stored reference Gaussians
/// (mean [d], covariance [d, d]) the Fréchet metrics compare against.
pub trait ClassifierBackend {
    /// Input latent length (one frame).
    fn latent_dim(&self) -> usize;
    /// Output classes.
    fn num_classes(&self) -> usize;
    /// Feature dimension of the FID* space.
    fn feat_dim(&self) -> usize;

    /// Available batch buckets, sorted ascending.
    fn buckets(&self) -> Vec<usize>;

    /// Classify a batch: `(logits, features)`.
    fn classify(&self, bucket: usize, x: &[f32]) -> Result<(Tensor, Tensor)>;

    /// Reference feature mean for FID*.
    fn fid_mu(&self) -> &Tensor;
    /// Reference feature covariance for FID*.
    fn fid_cov(&self) -> &Tensor;
    /// Reference pooled-pixel mean for sFID*.
    fn sfid_mu(&self) -> &Tensor;
    /// Reference pooled-pixel covariance for sFID*.
    fn sfid_cov(&self) -> &Tensor;
}
