//! Native execution backend: a pure-Rust, `Send + Sync` CPU reference of
//! the DiT forward pass, faithful to `python/compile/model.py` (patchify /
//! embed, adaLN-modulated attention + MLP blocks with boundary taps, adaLN
//! head). It runs with **zero artifacts** — weights come either from an
//! AOT `weights.bin` ([`NativeBackend::from_entry`]) or from a seeded
//! deterministic initializer ([`NativeBackend::seeded`]) — which is what
//! lets the engine tests, the server and the bench harness execute on a
//! bare checkout, and what removes the single-thread PJRT constraint from
//! the serving path (DESIGN.md §3).
//!
//! Numerical contract: batching is transparent (each sample is computed
//! independently, so bucket-B row `i` is bitwise equal to a bucket-1 run),
//! and the entry points satisfy `block(l, boundaries[l]) == boundaries[l+1]`
//! and `head(boundaries[depth]) == eps` — the invariants the golden-parity
//! suite asserts for the PJRT backend.
//!
//! Allocation contract (DESIGN.md §11): every per-call temporary lives in
//! a [`Workspace`] checked out of a per-backend [`WorkspacePool`], and
//! every result tensor draws its storage from a per-backend
//! [`BufferPool`] that result drops refill — so after
//! [`ModelBackend::warmup`] (or one call per entry point × bucket) the
//! steady-state forward pass performs **zero heap allocations**. The
//! trait signature is unchanged: the arena lives behind `&self`.
//!
//! Compute contract (DESIGN.md §12): the arithmetic itself runs through
//! the [`kernels`] layer — a cache-blocked GEMM with the adaLN modulate
//! fused into the operand pack and SiLU / gated-residual / broadcast
//! adds fused into the output loop, plus single-pass layer-norm and
//! blocked attention. [`KernelMode`] selects at runtime between that
//! path and the retained [`kernels::scalar`] reference (the original
//! naive loops), which is what the parity suite and the speedup benches
//! compare.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::{
    FlopsTable, ModelConfig, ModelEntry, ParamSpec, Schedule, ScheduleKind,
};
use crate::math::timestep_embedding_into;
use crate::runtime::backend::{ClassifierBackend, ModelBackend};
use crate::runtime::kernels::{
    self, scalar, Epilogue, Gemm, KernelMode, MatA, MatB, PackBufs, Prologue,
};
use crate::runtime::workspace::{Workspace, WorkspaceGuard, WorkspacePool};
use crate::tensor::{BufferPool, Tensor};
use crate::util::rng::Rng;
use crate::weights::TensorFile;

/// Architecture knobs not captured by [`ModelConfig`] (the AOT manifest
/// folds them into the compiled artifacts; the native backend needs them
/// explicitly). Derived from tensor shapes when loading `weights.bin`.
#[derive(Debug, Clone, Copy)]
pub struct NativeArch {
    /// MLP hidden width as a multiple of `dim`.
    pub mlp_ratio: usize,
    /// Sinusoidal timestep-embedding frequency count.
    pub t_freq_dim: usize,
}

impl Default for NativeArch {
    fn default() -> Self {
        NativeArch { mlp_ratio: 4, t_freq_dim: 64 }
    }
}

struct BlockW {
    adaln_w: Vec<f32>, // [D, 6D]
    adaln_b: Vec<f32>, // [6D]
    qkv_w: Vec<f32>,   // [D, 3D]
    qkv_b: Vec<f32>,   // [3D]
    proj_w: Vec<f32>,  // [D, D]
    proj_b: Vec<f32>,  // [D]
    mlp_w1: Vec<f32>,  // [D, M·D]
    mlp_b1: Vec<f32>,  // [M·D]
    mlp_w2: Vec<f32>,  // [M·D, D]
    mlp_b2: Vec<f32>,  // [D]
}

struct Weights {
    patch_w: Vec<f32>,      // [pd, D]
    patch_b: Vec<f32>,      // [D]
    pos_emb: Vec<f32>,      // [T, D]
    t_w1: Vec<f32>,         // [fd, D]
    t_b1: Vec<f32>,         // [D]
    t_w2: Vec<f32>,         // [D, D]
    t_b2: Vec<f32>,         // [D]
    y_emb: Vec<f32>,        // [K, D]
    blocks: Vec<BlockW>,    // depth entries
    head_adaln_w: Vec<f32>, // [D, 2D]
    head_adaln_b: Vec<f32>, // [2D]
    head_w: Vec<f32>,       // [D, pd]
    head_b: Vec<f32>,       // [pd]
}

/// Pure-Rust, `Send + Sync` CPU implementation of the DiT forward pass
/// (faithful to `python/compile/model.py`; zero artifacts needed).
pub struct NativeBackend {
    entry: ModelEntry,
    arch: NativeArch,
    w: Weights,
    /// Per-call temporaries, checked out per forward (DESIGN.md §11).
    ws: WorkspacePool,
    /// Recycling pool for result-tensor storage.
    out: BufferPool,
    /// Blocked kernels or the scalar reference (DESIGN.md §12).
    kernels: KernelMode,
}

// ---------------------------------------------------------------------------
// Synthetic model description (zero-artifact path)
// ---------------------------------------------------------------------------

/// Serve schedule for a synthetic native model: cosine ᾱ over the serve
/// steps for DDIM (clamped away from 0/1 so untrained nets stay finite),
/// uniform Euler steps for rectified flow.
fn synth_schedule(cfg: &ModelConfig) -> Schedule {
    let steps = cfg.serve_steps;
    match cfg.schedule_kind {
        ScheduleKind::Ddim => {
            let mut t_model = Vec::with_capacity(steps);
            let mut ab_t = Vec::with_capacity(steps);
            for i in 0..steps {
                let frac = (steps - i) as f64 / steps as f64; // 1 = noisiest
                t_model.push((1000.0 * frac) as f32);
                let a = (((frac + 0.008) / 1.008) * std::f64::consts::FRAC_PI_2).cos();
                ab_t.push((a * a).clamp(0.01, 0.9995) as f32);
            }
            let mut ab_prev = Vec::with_capacity(steps);
            for i in 0..steps {
                ab_prev.push(if i + 1 < steps { ab_t[i + 1] } else { 1.0 });
            }
            Schedule { kind: cfg.schedule_kind, t_model, ab_t, ab_prev, dt: 0.0 }
        }
        ScheduleKind::RectifiedFlow => {
            let t_model =
                (0..steps).map(|i| (steps - i) as f32 / steps as f32).collect();
            Schedule {
                kind: cfg.schedule_kind,
                t_model,
                ab_t: Vec::new(),
                ab_prev: Vec::new(),
                dt: 1.0 / steps as f32,
            }
        }
    }
}

/// Analytic FLOPs tables, mirroring `python/compile/configs.py` (MACs×2).
fn synth_flops(cfg: &ModelConfig, arch: &NativeArch) -> FlopsTable {
    let (t, d, m) = (cfg.tokens as u64, cfg.dim as u64, arch.mlp_ratio as u64);
    let pd = (cfg.patch * cfg.patch * cfg.channels) as u64;
    let fd = arch.t_freq_dim as u64;
    let per_tok = 2 * d * 3 * d + 2 * d * d + 2 * d * m * d * 2 + 2 * d * 6 * d;
    let attn = 2 * 2 * t * t * d;
    let block1 = t * per_tok + attn;
    let head1 = t * (2 * d * pd + 2 * d * 2 * d);
    let embed1 = t * 2 * pd * d + 2 * fd * d + 2 * d * d;
    let full1 = embed1 + cfg.depth as u64 * block1 + head1;
    let tab = |per: u64| -> BTreeMap<usize, u64> {
        cfg.buckets.iter().map(|b| (*b, per * *b as u64)).collect()
    };
    FlopsTable {
        full_step: tab(full1),
        block: tab(block1),
        head: tab(head1),
        // Matches aot.py's manifest value (predict_flops(1, 1)//2 =
        // 6·T·D, taps folded in) so alpha/gamma/speedup bookkeeping is
        // identical across native and PJRT backends.
        predict_per_order: 6 * t * d,
    }
}

fn param_specs(cfg: &ModelConfig, arch: &NativeArch) -> Vec<ParamSpec> {
    let (d, l, t) = (cfg.dim, cfg.depth, cfg.tokens);
    let m = arch.mlp_ratio;
    let pd = cfg.patch * cfg.patch * cfg.channels;
    let fd = arch.t_freq_dim;
    let spec = |name: &str, shape: Vec<usize>| ParamSpec { name: name.to_string(), shape };
    vec![
        spec("patch_w", vec![pd, d]),
        spec("patch_b", vec![d]),
        spec("pos_emb", vec![t, d]),
        spec("t_w1", vec![fd, d]),
        spec("t_b1", vec![d]),
        spec("t_w2", vec![d, d]),
        spec("t_b2", vec![d]),
        spec("y_emb", vec![cfg.num_classes, d]),
        spec("blk_adaln_w", vec![l, d, 6 * d]),
        spec("blk_adaln_b", vec![l, 6 * d]),
        spec("blk_qkv_w", vec![l, d, 3 * d]),
        spec("blk_qkv_b", vec![l, 3 * d]),
        spec("blk_proj_w", vec![l, d, d]),
        spec("blk_proj_b", vec![l, d]),
        spec("blk_mlp_w1", vec![l, d, m * d]),
        spec("blk_mlp_b1", vec![l, m * d]),
        spec("blk_mlp_w2", vec![l, m * d, d]),
        spec("blk_mlp_b2", vec![l, d]),
        spec("head_adaln_w", vec![d, 2 * d]),
        spec("head_adaln_b", vec![2 * d]),
        spec("head_w", vec![d, pd]),
        spec("head_b", vec![pd]),
    ]
}

/// Synthesize a complete [`ModelEntry`] (config + schedule + FLOPs tables,
/// no artifact paths) for a native model. Public so harness code (e.g. the
/// coordinator-overhead bench) can build stub backends against it.
pub fn synthetic_entry(cfg: &ModelConfig, arch: &NativeArch) -> ModelEntry {
    ModelEntry {
        schedule: synth_schedule(cfg),
        params: param_specs(cfg, arch),
        weights: PathBuf::new(),
        goldens: PathBuf::new(),
        artifacts: BTreeMap::new(),
        kernel_artifacts: BTreeMap::new(),
        flops: synth_flops(cfg, arch),
        config: cfg.clone(),
    }
}

impl NativeBackend {
    /// Deterministic random model (DiT-style init, but with *non-zero*
    /// adaLN/head weights: adaLN-zero would make every block the identity,
    /// which is the right training init but a degenerate serving fixture —
    /// feature trajectories would carry no layer dynamics to forecast).
    pub fn seeded(cfg: ModelConfig, seed: u64) -> NativeBackend {
        let arch = NativeArch::default();
        let entry = synthetic_entry(&cfg, &arch);
        let (d, fd, m) = (cfg.dim, arch.t_freq_dim, arch.mlp_ratio);
        let pd = cfg.patch * cfg.patch * cfg.channels;
        let mut rng = Rng::new(seed);
        let mut randn = |n: usize, scale: f32| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32 * scale).collect()
        };
        let inv = |fan_in: usize| 1.0 / (fan_in as f32).sqrt();
        let patch_w = randn(pd * d, inv(pd));
        let pos_emb = randn(cfg.tokens * d, 0.02);
        let t_w1 = randn(fd * d, inv(fd));
        let t_w2 = randn(d * d, inv(d));
        let y_emb = randn(cfg.num_classes * d, 0.02);
        let mut blocks = Vec::with_capacity(cfg.depth);
        for _ in 0..cfg.depth {
            blocks.push(BlockW {
                adaln_w: randn(d * 6 * d, 0.2 * inv(d)),
                adaln_b: vec![0.0; 6 * d],
                qkv_w: randn(d * 3 * d, inv(d)),
                qkv_b: vec![0.0; 3 * d],
                proj_w: randn(d * d, inv(d)),
                proj_b: vec![0.0; d],
                mlp_w1: randn(d * m * d, inv(d)),
                mlp_b1: vec![0.0; m * d],
                mlp_w2: randn(m * d * d, inv(m * d)),
                mlp_b2: vec![0.0; d],
            });
        }
        let head_adaln_w = randn(d * 2 * d, 0.2 * inv(d));
        let head_w = randn(d * pd, inv(d));
        let w = Weights {
            patch_w,
            patch_b: vec![0.0; d],
            pos_emb,
            t_w1,
            t_b1: vec![0.0; d],
            t_w2,
            t_b2: vec![0.0; d],
            y_emb,
            blocks,
            head_adaln_w,
            head_adaln_b: vec![0.0; 2 * d],
            head_w,
            head_b: vec![0.0; pd],
        };
        NativeBackend {
            entry,
            arch,
            w,
            ws: WorkspacePool::new(),
            out: BufferPool::new(),
            kernels: KernelMode::default(),
        }
    }

    /// Load trained weights from an AOT manifest entry's `weights.bin`
    /// (same tensor names/stacking as `python/compile/model.py`).
    pub fn from_entry(entry: &ModelEntry) -> Result<NativeBackend> {
        let tf = TensorFile::load(&entry.weights)?;
        Self::from_tensor_file(entry.clone(), &tf)
    }

    fn from_tensor_file(entry: ModelEntry, tf: &TensorFile) -> Result<NativeBackend> {
        let cfg = &entry.config;
        let (d, l) = (cfg.dim, cfg.depth);
        let pd = cfg.patch * cfg.patch * cfg.channels;
        let t_w1 = tf.f32("t_w1")?;
        let fd = *t_w1.shape.first().context("t_w1 has no shape")?;
        let mlp_w1 = tf.f32("blk_mlp_w1")?;
        if mlp_w1.shape.len() != 3 || mlp_w1.shape[0] != l || mlp_w1.shape[1] != d {
            bail!("blk_mlp_w1 shape {:?} inconsistent with depth {l} / dim {d}", mlp_w1.shape);
        }
        let m = mlp_w1.shape[2] / d;
        let arch = NativeArch { mlp_ratio: m, t_freq_dim: fd };

        let full = |name: &str, len: usize| -> Result<Vec<f32>> {
            let t = tf.f32(name)?;
            if t.data.len() != len {
                bail!("weight {name}: {} elements, expected {len}", t.data.len());
            }
            Ok(t.data.to_vec())
        };
        // Stacked per-layer tensors [L, ...] are sliced into per-block rows.
        let layer = |name: &str, per: usize, li: usize| -> Result<Vec<f32>> {
            let t = tf.f32(name)?;
            if t.data.len() != l * per {
                bail!("weight {name}: {} elements, expected {}", t.data.len(), l * per);
            }
            Ok(t.data[li * per..(li + 1) * per].to_vec())
        };
        let mut blocks = Vec::with_capacity(l);
        for li in 0..l {
            blocks.push(BlockW {
                adaln_w: layer("blk_adaln_w", d * 6 * d, li)?,
                adaln_b: layer("blk_adaln_b", 6 * d, li)?,
                qkv_w: layer("blk_qkv_w", d * 3 * d, li)?,
                qkv_b: layer("blk_qkv_b", 3 * d, li)?,
                proj_w: layer("blk_proj_w", d * d, li)?,
                proj_b: layer("blk_proj_b", d, li)?,
                mlp_w1: layer("blk_mlp_w1", d * m * d, li)?,
                mlp_b1: layer("blk_mlp_b1", m * d, li)?,
                mlp_w2: layer("blk_mlp_w2", m * d * d, li)?,
                mlp_b2: layer("blk_mlp_b2", d, li)?,
            });
        }
        let w = Weights {
            patch_w: full("patch_w", pd * d)?,
            patch_b: full("patch_b", d)?,
            pos_emb: full("pos_emb", cfg.tokens * d)?,
            t_w1: full("t_w1", fd * d)?,
            t_b1: full("t_b1", d)?,
            t_w2: full("t_w2", d * d)?,
            t_b2: full("t_b2", d)?,
            y_emb: full("y_emb", cfg.num_classes * d)?,
            blocks,
            head_adaln_w: full("head_adaln_w", d * 2 * d)?,
            head_adaln_b: full("head_adaln_b", 2 * d)?,
            head_w: full("head_w", d * pd)?,
            head_b: full("head_b", pd)?,
        };
        Ok(NativeBackend {
            entry,
            arch,
            w,
            ws: WorkspacePool::new(),
            out: BufferPool::new(),
            kernels: KernelMode::default(),
        })
    }

    /// The architecture knobs this backend was built with.
    pub fn arch(&self) -> &NativeArch {
        &self.arch
    }

    /// Override the kernel path (builder style). The default is
    /// [`KernelMode::Blocked`], or [`KernelMode::Scalar`] under the
    /// `scalar-ref` feature; parity tests and the speedup benches build
    /// one backend per mode and compare.
    pub fn with_kernel_mode(mut self, mode: KernelMode) -> NativeBackend {
        self.kernels = mode;
        self
    }

    /// Which kernel path this backend dispatches through.
    pub fn kernel_mode(&self) -> KernelMode {
        self.kernels
    }

    /// Result-buffer pool misses (checkouts that had to allocate) over
    /// this backend's lifetime. After warmup — or after one settling
    /// round at peak concurrency — this stops growing; the sharded
    /// allocation probe in `tests/shard_pool.rs` asserts exactly that.
    pub fn result_pool_misses(&self) -> usize {
        self.out.misses()
    }

    fn patch_dim(&self) -> usize {
        let cfg = &self.entry.config;
        cfg.patch * cfg.patch * cfg.channels
    }

    /// Check a forward-pass workspace out of this backend's pool.
    fn workspace(&self) -> WorkspaceGuard<'_> {
        self.ws.checkout(|| Workspace::for_model(&self.entry.config, &self.arch))
    }

    /// Workspaces materialized so far (≈ peak concurrent forward calls;
    /// the alloc-discipline suite asserts it stops growing after warmup).
    pub fn workspaces_created(&self) -> usize {
        self.ws.created()
    }

    /// [latent] -> token patches [T, pd] (layout mirrors model.py),
    /// written into `out` (fully overwritten).
    fn patchify_into(&self, x: &[f32], out: &mut [f32]) {
        let cfg = &self.entry.config;
        let (fr, ch, img, p) = (cfg.frames, cfg.channels, cfg.image_size, cfg.patch);
        let hb = img / p;
        let pd = self.patch_dim();
        debug_assert_eq!(out.len(), cfg.tokens * pd);
        for f in 0..fr {
            for bi in 0..hb {
                for bj in 0..hb {
                    let tok = (f * hb + bi) * hb + bj;
                    for pi in 0..p {
                        for pj in 0..p {
                            for c in 0..ch {
                                let src = ((f * ch + c) * img + (bi * p + pi)) * img
                                    + (bj * p + pj);
                                out[tok * pd + (pi * p + pj) * ch + c] = x[src];
                            }
                        }
                    }
                }
            }
        }
    }

    /// [T, pd] -> [latent] (exact inverse of `patchify_into`), written
    /// into `out` (fully overwritten).
    fn unpatchify_into(&self, tok: &[f32], out: &mut [f32]) {
        let cfg = &self.entry.config;
        let (fr, ch, img, p) = (cfg.frames, cfg.channels, cfg.image_size, cfg.patch);
        let hb = img / p;
        let pd = self.patch_dim();
        debug_assert_eq!(out.len(), cfg.latent_dim);
        for f in 0..fr {
            for bi in 0..hb {
                for bj in 0..hb {
                    let t = (f * hb + bi) * hb + bj;
                    for pi in 0..p {
                        for pj in 0..p {
                            for c in 0..ch {
                                let dst = ((f * ch + c) * img + (bi * p + pi)) * img
                                    + (bj * p + pj);
                                out[dst] = tok[t * pd + (pi * p + pj) * ch + c];
                            }
                        }
                    }
                }
            }
        }
    }

    /// silu(conditioning vector) for one sample into `ws.cond`:
    /// silu(MLP(sin-embed(t)) + y_emb[y]). The silu is pre-applied because
    /// every consumer (block adaLN, head adaLN) immediately feeds it
    /// through silu.
    fn cond_silu_into(&self, ws: &mut Workspace, t: f32, y: i32) {
        match self.kernels {
            KernelMode::Blocked => self.cond_silu_into_blocked(ws, t, y),
            KernelMode::Scalar => self.cond_silu_into_scalar(ws, t, y),
        }
    }

    /// Kernel-layer conditioning MLP: two GEMV dispatches with the SiLU
    /// and the class-embedding add fused as epilogues.
    fn cond_silu_into_blocked(&self, ws: &mut Workspace, t: f32, y: i32) {
        let d = self.entry.config.dim;
        let fd = self.arch.t_freq_dim;
        timestep_embedding_into(t, fd, &mut ws.temb);
        let cls = (y.rem_euclid(self.entry.config.num_classes as i32)) as usize;
        let Workspace { temb, cond_h, cond, pack_a, pack_b, .. } = ws;
        let mut pack = PackBufs { a: pack_a.as_mut_slice(), b: pack_b.as_mut_slice() };
        Gemm {
            m: 1,
            k: fd,
            n: d,
            a: MatA::dense(temb, fd),
            b: MatB::dense(&self.w.t_w1, d),
            prologue: Prologue::None,
            bias: Some(&self.w.t_b1),
            epilogue: Epilogue::Silu,
        }
        .run(cond_h, d, &mut pack);
        Gemm {
            m: 1,
            k: d,
            n: d,
            a: MatA::dense(cond_h, d),
            b: MatB::dense(&self.w.t_w2, d),
            prologue: Prologue::None,
            bias: Some(&self.w.t_b2),
            epilogue: Epilogue::AddRows { rows: &self.w.y_emb[cls * d..(cls + 1) * d], rs: d },
        }
        .run(cond, d, &mut pack);
        for v in cond.iter_mut() {
            *v = kernels::silu(*v);
        }
    }

    /// Scalar-reference conditioning MLP (the original unfused loops).
    fn cond_silu_into_scalar(&self, ws: &mut Workspace, t: f32, y: i32) {
        let d = self.entry.config.dim;
        let fd = self.arch.t_freq_dim;
        timestep_embedding_into(t, fd, &mut ws.temb);
        scalar::matmul_add(&ws.temb, &self.w.t_w1, &self.w.t_b1, 1, fd, d, &mut ws.cond_h);
        for v in ws.cond_h.iter_mut() {
            *v = scalar::silu(*v);
        }
        scalar::matmul_add(&ws.cond_h, &self.w.t_w2, &self.w.t_b2, 1, d, d, &mut ws.cond);
        let k = (y.rem_euclid(self.entry.config.num_classes as i32)) as usize;
        for (cv, ev) in ws.cond.iter_mut().zip(&self.w.y_emb[k * d..(k + 1) * d]) {
            *cv += ev;
        }
        for v in ws.cond.iter_mut() {
            *v = scalar::silu(*v);
        }
    }

    /// [latent] -> embedded tokens, written into `xt` (staged through
    /// `ws.patches`).
    fn embed_tokens_into(&self, x_flat: &[f32], ws: &mut Workspace, xt: &mut [f32]) {
        let cfg = &self.entry.config;
        let (t, d) = (cfg.tokens, cfg.dim);
        let pd = self.patch_dim();
        self.patchify_into(x_flat, &mut ws.patches);
        match self.kernels {
            KernelMode::Blocked => {
                let Workspace { patches, pack_a, pack_b, .. } = ws;
                let mut pack = PackBufs { a: pack_a.as_mut_slice(), b: pack_b.as_mut_slice() };
                // patch embedding with the positional add fused into the
                // output loop
                Gemm {
                    m: t,
                    k: pd,
                    n: d,
                    a: MatA::dense(patches, pd),
                    b: MatB::dense(&self.w.patch_w, d),
                    prologue: Prologue::None,
                    bias: Some(&self.w.patch_b),
                    epilogue: Epilogue::AddRows { rows: &self.w.pos_emb, rs: d },
                }
                .run(xt, d, &mut pack);
            }
            KernelMode::Scalar => {
                scalar::matmul_add(&ws.patches, &self.w.patch_w, &self.w.patch_b, t, pd, d, xt);
                for (v, p) in xt.iter_mut().zip(&self.w.pos_emb) {
                    *v += p;
                }
            }
        }
    }

    /// One adaLN-zero DiT block in place on [T, D] tokens `x`, reading the
    /// conditioning from `ws.cond` and staging through the workspace
    /// buffers (`x` must not alias the workspace — callers temporarily
    /// move `ws.xt` out when the trunk itself is block-applied).
    fn block_apply(&self, l: usize, x: &mut [f32], ws: &mut Workspace) {
        match self.kernels {
            KernelMode::Blocked => self.block_apply_blocked(l, x, ws),
            KernelMode::Scalar => self.block_apply_scalar(l, x, ws),
        }
    }

    /// Kernel-layer DiT block. Fusion map (DESIGN.md §12): the adaLN
    /// modulate rides the A-pack of the qkv / mlp1 GEMMs (modulate always
    /// consumes a LayerNorm that immediately feeds a matmul), SiLU rides
    /// the mlp1 output loop, and both branch residuals are gated-add
    /// epilogues on the proj / mlp2 GEMMs — so `ws.proj` / `ws.mlp_out`
    /// are never materialized on this path.
    fn block_apply_blocked(&self, l: usize, x: &mut [f32], ws: &mut Workspace) {
        let cfg = &self.entry.config;
        let (t, d) = (cfg.tokens, cfg.dim);
        let heads = cfg.heads;
        let md = self.arch.mlp_ratio * d;
        let bw = &self.w.blocks[l];
        let Workspace { cond, mod6, norm, qkv, attn, scores, mlp_hidden, pack_a, pack_b, .. } = ws;
        let mut pack = PackBufs { a: pack_a.as_mut_slice(), b: pack_b.as_mut_slice() };
        Gemm {
            m: 1,
            k: d,
            n: 6 * d,
            a: MatA::dense(cond, d),
            b: MatB::dense(&bw.adaln_w, 6 * d),
            prologue: Prologue::None,
            bias: Some(&bw.adaln_b),
            epilogue: Epilogue::None,
        }
        .run(mod6, 6 * d, &mut pack);
        let (sh1, rest) = mod6.split_at(d);
        let (s1, rest) = rest.split_at(d);
        let (g1, rest) = rest.split_at(d);
        let (sh2, rest) = rest.split_at(d);
        let (s2, g2) = rest.split_at(d);
        // attention branch
        kernels::layer_norm(x, norm, t, d);
        Gemm {
            m: t,
            k: d,
            n: 3 * d,
            a: MatA::dense(norm, d),
            b: MatB::dense(&bw.qkv_w, 3 * d),
            prologue: Prologue::Modulate { shift: sh1, scale: s1 },
            bias: Some(&bw.qkv_b),
            epilogue: Epilogue::None,
        }
        .run(qkv, 3 * d, &mut pack);
        kernels::attention(qkv, t, d, heads, attn, scores, &mut pack);
        Gemm {
            m: t,
            k: d,
            n: d,
            a: MatA::dense(attn, d),
            b: MatB::dense(&bw.proj_w, d),
            prologue: Prologue::None,
            bias: Some(&bw.proj_b),
            epilogue: Epilogue::GatedResidual { gate: g1 },
        }
        .run(x, d, &mut pack);
        // MLP branch
        kernels::layer_norm(x, norm, t, d);
        Gemm {
            m: t,
            k: d,
            n: md,
            a: MatA::dense(norm, d),
            b: MatB::dense(&bw.mlp_w1, md),
            prologue: Prologue::Modulate { shift: sh2, scale: s2 },
            bias: Some(&bw.mlp_b1),
            epilogue: Epilogue::Silu,
        }
        .run(mlp_hidden, md, &mut pack);
        Gemm {
            m: t,
            k: md,
            n: d,
            a: MatA::dense(mlp_hidden, md),
            b: MatB::dense(&bw.mlp_w2, d),
            prologue: Prologue::None,
            bias: Some(&bw.mlp_b2),
            epilogue: Epilogue::GatedResidual { gate: g2 },
        }
        .run(x, d, &mut pack);
    }

    /// Scalar-reference DiT block (the original unfused loops).
    fn block_apply_scalar(&self, l: usize, x: &mut [f32], ws: &mut Workspace) {
        let cfg = &self.entry.config;
        let (t, d) = (cfg.tokens, cfg.dim);
        let bw = &self.w.blocks[l];
        scalar::matmul_add(&ws.cond, &bw.adaln_w, &bw.adaln_b, 1, d, 6 * d, &mut ws.mod6);
        let (sh1, rest) = ws.mod6.split_at(d);
        let (s1, rest) = rest.split_at(d);
        let (g1, rest) = rest.split_at(d);
        let (sh2, rest) = rest.split_at(d);
        let (s2, g2) = rest.split_at(d);
        // attention branch
        scalar::layer_norm(x, &mut ws.norm, t, d);
        scalar::modulate(&mut ws.norm, sh1, s1, t, d);
        scalar::matmul_add(&ws.norm, &bw.qkv_w, &bw.qkv_b, t, d, 3 * d, &mut ws.qkv);
        scalar::attention(&ws.qkv, t, d, cfg.heads, &mut ws.attn, &mut ws.probs);
        scalar::matmul_add(&ws.attn, &bw.proj_w, &bw.proj_b, t, d, d, &mut ws.proj);
        for tok in 0..t {
            for j in 0..d {
                x[tok * d + j] += g1[j] * ws.proj[tok * d + j];
            }
        }
        // MLP branch
        scalar::layer_norm(x, &mut ws.norm, t, d);
        scalar::modulate(&mut ws.norm, sh2, s2, t, d);
        let md = self.arch.mlp_ratio * d;
        scalar::matmul_add(&ws.norm, &bw.mlp_w1, &bw.mlp_b1, t, d, md, &mut ws.mlp_hidden);
        for v in ws.mlp_hidden.iter_mut() {
            *v = scalar::silu(*v);
        }
        scalar::matmul_add(&ws.mlp_hidden, &bw.mlp_w2, &bw.mlp_b2, t, md, d, &mut ws.mlp_out);
        for tok in 0..t {
            for j in 0..d {
                x[tok * d + j] += g2[j] * ws.mlp_out[tok * d + j];
            }
        }
    }

    /// Final adaLN + linear head on [T, D] tokens `x` -> eps written into
    /// `out` (conditioning from `ws.cond`; `x` must not alias `ws`).
    fn head_tokens_into(&self, x: &[f32], ws: &mut Workspace, out: &mut [f32]) {
        match self.kernels {
            KernelMode::Blocked => self.head_tokens_into_blocked(x, ws, out),
            KernelMode::Scalar => self.head_tokens_into_scalar(x, ws, out),
        }
    }

    /// Kernel-layer head: the final modulate is fused into the head
    /// GEMM's A-pack, exactly like the block branches.
    fn head_tokens_into_blocked(&self, x: &[f32], ws: &mut Workspace, out: &mut [f32]) {
        let cfg = &self.entry.config;
        let (t, d) = (cfg.tokens, cfg.dim);
        let pd = self.patch_dim();
        let Workspace { cond, mod2, norm, tok_out, pack_a, pack_b, .. } = ws;
        let mut pack = PackBufs { a: pack_a.as_mut_slice(), b: pack_b.as_mut_slice() };
        Gemm {
            m: 1,
            k: d,
            n: 2 * d,
            a: MatA::dense(cond, d),
            b: MatB::dense(&self.w.head_adaln_w, 2 * d),
            prologue: Prologue::None,
            bias: Some(&self.w.head_adaln_b),
            epilogue: Epilogue::None,
        }
        .run(mod2, 2 * d, &mut pack);
        let (shift, scale) = mod2.split_at(d);
        kernels::layer_norm(x, norm, t, d);
        Gemm {
            m: t,
            k: d,
            n: pd,
            a: MatA::dense(norm, d),
            b: MatB::dense(&self.w.head_w, pd),
            prologue: Prologue::Modulate { shift, scale },
            bias: Some(&self.w.head_b),
            epilogue: Epilogue::None,
        }
        .run(tok_out, pd, &mut pack);
        self.unpatchify_into(tok_out, out);
    }

    /// Scalar-reference head (the original unfused loops).
    fn head_tokens_into_scalar(&self, x: &[f32], ws: &mut Workspace, out: &mut [f32]) {
        let cfg = &self.entry.config;
        let (t, d) = (cfg.tokens, cfg.dim);
        let pd = self.patch_dim();
        scalar::matmul_add(
            &ws.cond,
            &self.w.head_adaln_w,
            &self.w.head_adaln_b,
            1,
            d,
            2 * d,
            &mut ws.mod2,
        );
        let (shift, scale) = ws.mod2.split_at(d);
        scalar::layer_norm(x, &mut ws.norm, t, d);
        scalar::modulate(&mut ws.norm, shift, scale, t, d);
        scalar::matmul_add(&ws.norm, &self.w.head_w, &self.w.head_b, t, d, pd, &mut ws.tok_out);
        self.unpatchify_into(&ws.tok_out, out);
    }

    fn check_batch(&self, bucket: usize, t: &[f32], y: &[i32]) -> Result<()> {
        if bucket == 0 || t.len() != bucket || y.len() != bucket {
            bail!(
                "batch mismatch: bucket {bucket}, t len {}, y len {}",
                t.len(),
                y.len()
            );
        }
        Ok(())
    }

    /// Shared full pass; materializes boundaries only when requested.
    /// Temporaries come from the workspace checkout, result storage from
    /// the recycling pool — zero allocations once both are warm.
    fn forward(
        &self,
        bucket: usize,
        x: &[f32],
        t: &[f32],
        y: &[i32],
        with_bounds: bool,
    ) -> Result<(Tensor, Option<Tensor>)> {
        self.check_batch(bucket, t, y)?;
        let cfg = &self.entry.config;
        let (tokens, d, depth, latent) = (cfg.tokens, cfg.dim, cfg.depth, cfg.latent_dim);
        if x.len() != bucket * latent {
            bail!("full: x len {} != bucket {bucket} · latent {latent}", x.len());
        }
        let feat = tokens * d;
        let mut ws = self.workspace();
        let mut eps = self.out.take(bucket * latent);
        let mut bounds = if with_bounds {
            Some(self.out.take((depth + 1) * bucket * feat))
        } else {
            None
        };
        for s in 0..bucket {
            self.cond_silu_into(&mut ws, t[s], y[s]);
            // the trunk is block-applied in place, so move it out of the
            // workspace for the duration (zero-cost Vec moves)
            let mut xt = std::mem::take(&mut ws.xt);
            self.embed_tokens_into(&x[s * latent..(s + 1) * latent], &mut ws, &mut xt);
            if let Some(b) = &mut bounds {
                b[s * feat..(s + 1) * feat].copy_from_slice(&xt);
            }
            for l in 0..depth {
                self.block_apply(l, &mut xt, &mut ws);
                if let Some(b) = &mut bounds {
                    let off = ((l + 1) * bucket + s) * feat;
                    b[off..off + feat].copy_from_slice(&xt);
                }
            }
            self.head_tokens_into(&xt, &mut ws, &mut eps[s * latent..(s + 1) * latent]);
            ws.xt = xt;
        }
        let eps = Tensor::from_storage(vec![bucket, latent], eps);
        let bounds = bounds.map(|b| Tensor::from_storage(vec![depth + 1, bucket, tokens, d], b));
        Ok((eps, bounds))
    }
}

impl ModelBackend for NativeBackend {
    fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn kind(&self) -> &'static str {
        "native"
    }

    fn supports(&self, entry_point: &str) -> bool {
        matches!(entry_point, "full" | "full_eps" | "block" | "head")
    }

    /// Pre-size the workspace pool and one result buffer per entry-point
    /// shape × bucket, so the first real call after warmup is already
    /// allocation-free (the alloc-discipline suite relies on this).
    fn warmup(&self, entry_points: &[&str], buckets: &[usize]) -> Result<()> {
        let cfg = &self.entry.config;
        let feat = cfg.tokens * cfg.dim;
        drop(self.workspace());
        for &b in buckets {
            for ep in entry_points {
                match *ep {
                    "full" | "full_pallas" => {
                        self.out.prewarm(b * cfg.latent_dim);
                        self.out.prewarm((cfg.depth + 1) * b * feat);
                    }
                    "full_eps" | "head" => self.out.prewarm(b * cfg.latent_dim),
                    "block" => self.out.prewarm(b * feat),
                    _ => {}
                }
            }
        }
        Ok(())
    }

    fn full(
        &self,
        bucket: usize,
        x: &[f32],
        t: &[f32],
        y: &[i32],
        _pallas: bool,
    ) -> Result<(Tensor, Tensor)> {
        let (eps, bounds) = self.forward(bucket, x, t, y, true)?;
        Ok((eps, bounds.expect("boundaries requested")))
    }

    fn full_eps(&self, bucket: usize, x: &[f32], t: &[f32], y: &[i32]) -> Result<Tensor> {
        Ok(self.forward(bucket, x, t, y, false)?.0)
    }

    fn block(
        &self,
        bucket: usize,
        layer: i32,
        feat: &[f32],
        t: &[f32],
        y: &[i32],
    ) -> Result<Tensor> {
        self.check_batch(bucket, t, y)?;
        let cfg = &self.entry.config;
        let flen = cfg.tokens * cfg.dim;
        if layer < 0 || layer as usize >= cfg.depth {
            bail!("block layer {layer} out of range (depth {})", cfg.depth);
        }
        if feat.len() != bucket * flen {
            bail!("block: feat len {} != bucket {bucket} · feat {flen}", feat.len());
        }
        let mut ws = self.workspace();
        let mut out = self.out.take(bucket * flen);
        for s in 0..bucket {
            self.cond_silu_into(&mut ws, t[s], y[s]);
            let row = &mut out[s * flen..(s + 1) * flen];
            row.copy_from_slice(&feat[s * flen..(s + 1) * flen]);
            self.block_apply(layer as usize, row, &mut ws);
        }
        Ok(Tensor::from_storage(vec![bucket, cfg.tokens, cfg.dim], out))
    }

    fn head(&self, bucket: usize, feat: &[f32], t: &[f32], y: &[i32]) -> Result<Tensor> {
        self.check_batch(bucket, t, y)?;
        let cfg = &self.entry.config;
        let flen = cfg.tokens * cfg.dim;
        if feat.len() != bucket * flen {
            bail!("head: feat len {} != bucket {bucket} · feat {flen}", feat.len());
        }
        let mut ws = self.workspace();
        let mut out = self.out.take(bucket * cfg.latent_dim);
        for s in 0..bucket {
            self.cond_silu_into(&mut ws, t[s], y[s]);
            self.head_tokens_into(
                &feat[s * flen..(s + 1) * flen],
                &mut ws,
                &mut out[s * cfg.latent_dim..(s + 1) * cfg.latent_dim],
            );
        }
        Ok(Tensor::from_storage(vec![bucket, cfg.latent_dim], out))
    }
}

// ---------------------------------------------------------------------------
// Native metrics classifier
// ---------------------------------------------------------------------------

/// Seeded tanh-MLP classifier (cls_fwd in model.py) with identity-Gaussian
/// FID references — meaningless in absolute terms but finite, smooth and
/// deterministic, so the experiment harness runs end-to-end with zero
/// artifacts.
/// Pure-Rust metrics classifier (three dense layers; see
/// [`crate::runtime::backend::ClassifierBackend`]).
pub struct NativeClassifier {
    latent: usize,
    hidden: usize,
    feat: usize,
    classes: usize,
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
    w3: Vec<f32>,
    b3: Vec<f32>,
    fid_mu: Tensor,
    fid_cov: Tensor,
    sfid_mu: Tensor,
    sfid_cov: Tensor,
}

fn identity_gaussian(d: usize) -> (Tensor, Tensor) {
    let mut cov = vec![0f32; d * d];
    for i in 0..d {
        cov[i * d + i] = 1.0;
    }
    (Tensor::zeros(vec![d]), Tensor::new(vec![d, d], cov))
}

impl NativeClassifier {
    /// Deterministically initialized classifier (identity reference
    /// Gaussians; quality numbers are comparative only).
    pub fn seeded(latent: usize, classes: usize, seed: u64) -> NativeClassifier {
        let (hidden, feat) = (64, 32);
        let mut rng = Rng::new(seed);
        let mut randn = |n: usize, scale: f32| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32 * scale).collect()
        };
        let inv = |fan_in: usize| 1.0 / (fan_in as f32).sqrt();
        let w1 = randn(latent * hidden, inv(latent));
        let w2 = randn(hidden * feat, inv(hidden));
        let w3 = randn(feat * classes, inv(feat));
        let (fid_mu, fid_cov) = identity_gaussian(feat);
        let (sfid_mu, sfid_cov) = identity_gaussian(64);
        NativeClassifier {
            latent,
            hidden,
            feat,
            classes,
            w1,
            b1: vec![0.0; hidden],
            w2,
            b2: vec![0.0; feat],
            w3,
            b3: vec![0.0; classes],
            fid_mu,
            fid_cov,
            sfid_mu,
            sfid_cov,
        }
    }
}

impl ClassifierBackend for NativeClassifier {
    fn latent_dim(&self) -> usize {
        self.latent
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn feat_dim(&self) -> usize {
        self.feat
    }

    fn buckets(&self) -> Vec<usize> {
        vec![1, 2, 4, 8]
    }

    fn classify(&self, bucket: usize, x: &[f32]) -> Result<(Tensor, Tensor)> {
        if x.len() != bucket * self.latent {
            bail!("classify: x len {} != bucket {bucket} · latent {}", x.len(), self.latent);
        }
        let mut logits = vec![0f32; bucket * self.classes];
        let mut feats = vec![0f32; bucket * self.feat];
        let mut h = vec![0f32; self.hidden];
        let mut f = vec![0f32; self.feat];
        for s in 0..bucket {
            let row = &x[s * self.latent..(s + 1) * self.latent];
            scalar::matmul_add(row, &self.w1, &self.b1, 1, self.latent, self.hidden, &mut h);
            for v in h.iter_mut() {
                *v = v.tanh();
            }
            scalar::matmul_add(&h, &self.w2, &self.b2, 1, self.hidden, self.feat, &mut f);
            for v in f.iter_mut() {
                *v = v.tanh();
            }
            scalar::matmul_add(
                &f,
                &self.w3,
                &self.b3,
                1,
                self.feat,
                self.classes,
                &mut logits[s * self.classes..(s + 1) * self.classes],
            );
            feats[s * self.feat..(s + 1) * self.feat].copy_from_slice(&f);
        }
        Ok((
            Tensor::new(vec![bucket, self.classes], logits),
            Tensor::new(vec![bucket, self.feat], feats),
        ))
    }

    fn fid_mu(&self) -> &Tensor {
        &self.fid_mu
    }

    fn fid_cov(&self) -> &Tensor {
        &self.fid_cov
    }

    fn sfid_mu(&self) -> &Tensor {
        &self.sfid_mu
    }

    fn sfid_cov(&self) -> &Tensor {
        &self.sfid_cov
    }
}

// ---------------------------------------------------------------------------
// Hub: the native analog of the artifact manifest
// ---------------------------------------------------------------------------

/// The zero-artifact inventory: one seeded native model per simulated
/// backbone name (mirroring the AOT manifest's `dit-sim` / `flux-sim` /
/// `video-sim`) plus the metrics classifier. Models are stored behind
/// `Arc` so the shard pool (and any other thread) can share one instance
/// without the hub outliving the caller.
pub struct NativeHub {
    models: BTreeMap<String, Arc<NativeBackend>>,
    /// The metrics classifier shared by every experiment runner.
    pub classifier: NativeClassifier,
}

impl NativeHub {
    /// Default seed for the zero-artifact models (`--model-seed` overrides).
    pub const DEFAULT_SEED: u64 = 0x5EC_A001;

    /// Build the full inventory from one seed.
    pub fn seeded(seed: u64) -> NativeHub {
        let mut models = BTreeMap::new();
        // classifier latent = one frame of the (shared) image geometry,
        // derived from the presets so the two can't silently diverge
        let dit = ModelConfig::native_dit();
        let frame_latent = dit.latent_dim / dit.frames;
        let classes = dit.num_classes;
        for (i, cfg) in [dit, ModelConfig::native_flux(), ModelConfig::native_video()]
            .into_iter()
            .enumerate()
        {
            debug_assert_eq!(cfg.latent_dim / cfg.frames, frame_latent, "{}", cfg.name);
            let name = cfg.name.clone();
            models
                .insert(name, Arc::new(NativeBackend::seeded(cfg, seed ^ ((i as u64 + 1) << 32))));
        }
        let classifier = NativeClassifier::seeded(frame_latent, classes, seed ^ 0xC1A5_51F1);
        NativeHub { models, classifier }
    }

    /// Borrow a model by name (error lists what exists).
    pub fn model(&self, name: &str) -> Result<&NativeBackend> {
        Ok(self.lookup(name)?.as_ref())
    }

    /// Owning handle to a model, shareable across shard worker threads.
    pub fn model_shared(&self, name: &str) -> Result<Arc<NativeBackend>> {
        Ok(self.lookup(name)?.clone())
    }

    fn lookup(&self, name: &str) -> Result<&Arc<NativeBackend>> {
        self.models.get(name).with_context(|| {
            format!("model '{name}' not in native hub ({:?})", self.models.keys())
        })
    }

    /// Iterate the inventory (name, shared backend).
    pub fn models(&self) -> impl Iterator<Item = (&String, &Arc<NativeBackend>)> {
        self.models.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::weights::Stored;

    fn tiny() -> NativeBackend {
        NativeBackend::seeded(ModelConfig::native_test(), 7)
    }

    fn rand_inputs(b: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let x = rng.normal_f32s(b * n);
        let t: Vec<f32> = (0..b).map(|i| 1000.0 - 37.0 * i as f32).collect();
        let y: Vec<i32> = (0..b).map(|i| i as i32).collect();
        (x, t, y)
    }

    #[test]
    fn backend_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<NativeBackend>();
        assert_send_sync::<NativeClassifier>();
    }

    #[test]
    fn shapes_and_finiteness() {
        let m = tiny();
        let cfg = &m.entry().config;
        let (x, t, y) = rand_inputs(2, cfg.latent_dim, 1);
        let (eps, bounds) = ModelBackend::full(&m, 2, &x, &t, &y, false).unwrap();
        assert_eq!(eps.shape, vec![2, cfg.latent_dim]);
        assert_eq!(bounds.shape, vec![cfg.depth + 1, 2, cfg.tokens, cfg.dim]);
        assert!(eps.data.iter().all(|v| v.is_finite()));
        assert!(bounds.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn deterministic_across_instances() {
        let a = tiny();
        let b = tiny();
        let cfg = &a.entry().config;
        let (x, t, y) = rand_inputs(1, cfg.latent_dim, 2);
        let (ea, _) = ModelBackend::full(&a, 1, &x, &t, &y, false).unwrap();
        let (eb, _) = ModelBackend::full(&b, 1, &x, &t, &y, false).unwrap();
        assert_eq!(ea.data, eb.data);
    }

    #[test]
    fn batching_is_transparent() {
        let m = tiny();
        let cfg = &m.entry().config;
        let latent = cfg.latent_dim;
        let (x, t, y) = rand_inputs(4, latent, 3);
        let (eps4, bounds4) = ModelBackend::full(&m, 4, &x, &t, &y, false).unwrap();
        let feat = cfg.tokens * cfg.dim;
        for i in 0..4 {
            let (eps1, bounds1) = ModelBackend::full(
                &m,
                1,
                &x[i * latent..(i + 1) * latent],
                &t[i..i + 1],
                &y[i..i + 1],
                false,
            )
            .unwrap();
            assert_eq!(eps4.row(i), &eps1.data[..], "row {i}");
            for b in 0..=cfg.depth {
                let off4 = (b * 4 + i) * feat;
                let off1 = b * feat;
                assert_eq!(
                    &bounds4.data[off4..off4 + feat],
                    &bounds1.data[off1..off1 + feat],
                    "row {i} boundary {b}"
                );
            }
        }
    }

    #[test]
    fn block_and_head_match_full_boundaries() {
        // The same invariants golden_parity.rs asserts over PJRT artifacts:
        // block(l, boundaries[l]) == boundaries[l+1], head(last) == eps.
        let m = tiny();
        let cfg = &m.entry().config;
        let feat = cfg.tokens * cfg.dim;
        let (x, t, y) = rand_inputs(1, cfg.latent_dim, 4);
        let (eps, bounds) = ModelBackend::full(&m, 1, &x, &t, &y, false).unwrap();
        for l in 0..cfg.depth {
            let out = m
                .block(1, l as i32, &bounds.data[l * feat..(l + 1) * feat], &t, &y)
                .unwrap();
            let expect = &bounds.data[(l + 1) * feat..(l + 2) * feat];
            let err: f32 = out
                .data
                .iter()
                .zip(expect)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max);
            assert!(err < 1e-5, "block {l}: max err {err}");
        }
        let depth = cfg.depth;
        let head = m
            .head(1, &bounds.data[depth * feat..(depth + 1) * feat], &t, &y)
            .unwrap();
        let err: f32 = head
            .data
            .iter()
            .zip(&eps.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 1e-5, "head max err {err}");
    }

    #[test]
    fn full_eps_matches_full() {
        let m = tiny();
        let cfg = &m.entry().config;
        let (x, t, y) = rand_inputs(2, cfg.latent_dim, 5);
        let (eps, _) = ModelBackend::full(&m, 2, &x, &t, &y, false).unwrap();
        let eps_only = ModelBackend::full_eps(&m, 2, &x, &t, &y).unwrap();
        assert_eq!(eps.data, eps_only.data);
    }

    #[test]
    fn kernel_modes_agree_end_to_end() {
        // Same seeded weights, same inputs, one backend per KernelMode:
        // the fused blocked path must track the scalar reference within
        // accumulation-order tolerance through the full forward pass.
        let blocked = tiny().with_kernel_mode(KernelMode::Blocked);
        let scalar_m = tiny().with_kernel_mode(KernelMode::Scalar);
        let cfg = &blocked.entry().config;
        let (x, t, y) = rand_inputs(2, cfg.latent_dim, 21);
        let (eb, bb) = ModelBackend::full(&blocked, 2, &x, &t, &y, false).unwrap();
        let (es, bs) = ModelBackend::full(&scalar_m, 2, &x, &t, &y, false).unwrap();
        for (i, (a, b)) in eb.data.iter().zip(&es.data).enumerate() {
            assert!((a - b).abs() <= 1e-3 + 1e-3 * b.abs(), "eps[{i}]: {a} vs {b}");
        }
        for (i, (a, b)) in bb.data.iter().zip(&bs.data).enumerate() {
            assert!((a - b).abs() <= 1e-3 + 1e-3 * b.abs(), "bound[{i}]: {a} vs {b}");
        }
    }

    #[test]
    fn patchify_roundtrip() {
        let m = NativeBackend::seeded(ModelConfig::native_video(), 11);
        let cfg = &m.entry().config;
        let mut rng = Rng::new(9);
        let x = rng.normal_f32s(cfg.latent_dim);
        let mut patches = vec![0f32; cfg.tokens * m.patch_dim()];
        let mut back = vec![0f32; cfg.latent_dim];
        m.patchify_into(&x, &mut patches);
        m.unpatchify_into(&patches, &mut back);
        assert_eq!(x, back);
    }

    #[test]
    fn workspace_pool_stops_growing_after_first_call() {
        let m = tiny();
        let cfg = &m.entry().config;
        let (x, t, y) = rand_inputs(2, cfg.latent_dim, 12);
        for _ in 0..4 {
            ModelBackend::full(&m, 2, &x, &t, &y, false).unwrap();
            m.full_eps(2, &x, &t, &y).unwrap();
        }
        // single-threaded callers share one workspace across every call
        assert_eq!(m.workspaces_created(), 1);
    }

    #[test]
    fn warmup_presizes_result_buffers() {
        let m = tiny();
        let cfg = &m.entry().config;
        m.warmup(&["full", "full_eps", "block", "head"], &cfg.buckets).unwrap();
        assert_eq!(m.workspaces_created(), 1);
        // pooled result storage exists before the first real call
        assert!(m.out.idle() > 0);
        let (x, t, y) = rand_inputs(1, cfg.latent_dim, 13);
        let (eps, _) = ModelBackend::full(&m, 1, &x, &t, &y, false).unwrap();
        assert!(eps.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn synthetic_schedule_is_consistent() {
        let cfg = ModelConfig::native_test();
        let e = synthetic_entry(&cfg, &NativeArch::default());
        let s = &e.schedule;
        assert_eq!(s.t_model.len(), cfg.serve_steps);
        assert_eq!(s.ab_t.len(), cfg.serve_steps);
        assert!(s.ab_t.windows(2).all(|w| w[0] <= w[1]), "ab_t must increase");
        for i in 0..cfg.serve_steps - 1 {
            assert_eq!(s.ab_prev[i], s.ab_t[i + 1]);
        }
        assert_eq!(*s.ab_prev.last().unwrap(), 1.0);
        let rf = synthetic_entry(&ModelConfig::native_flux(), &NativeArch::default());
        assert!(rf.schedule.dt > 0.0);
        assert_eq!(rf.schedule.t_model.len(), ModelConfig::native_flux().serve_steps);
    }

    #[test]
    fn flops_tables_scale_linearly() {
        let e = synthetic_entry(&ModelConfig::native_test(), &NativeArch::default());
        let f1 = e.flops.full_step[&1];
        assert!(f1 > 0);
        assert_eq!(e.flops.full_step[&4], 4 * f1);
        // verification is one block: gamma ≈ 1/depth
        let gamma = e.flops.block[&1] as f64 / f1 as f64;
        assert!(gamma < 0.5, "gamma {gamma}");
    }

    #[test]
    fn loads_from_tensor_file() {
        // Export a seeded model's weights in the stacked AOT layout and
        // reload them through the weights.bin path; forwards must agree.
        let a = tiny();
        let cfg = &a.entry().config;
        let (d, l) = (cfg.dim, cfg.depth);
        let m = a.arch.mlp_ratio;
        let pd = a.patch_dim();
        let fd = a.arch.t_freq_dim;
        let mut tf = TensorFile::default();
        let mut put = |name: &str, shape: Vec<usize>, data: Vec<f32>| {
            tf.order.push(name.to_string());
            tf.tensors.insert(name.to_string(), Stored::F32(Tensor::new(shape, data)));
        };
        let stack = |get: &dyn Fn(&BlockW) -> &Vec<f32>| -> Vec<f32> {
            a.w.blocks.iter().flat_map(|b| get(b).clone()).collect()
        };
        put("patch_w", vec![pd, d], a.w.patch_w.clone());
        put("patch_b", vec![d], a.w.patch_b.clone());
        put("pos_emb", vec![cfg.tokens, d], a.w.pos_emb.clone());
        put("t_w1", vec![fd, d], a.w.t_w1.clone());
        put("t_b1", vec![d], a.w.t_b1.clone());
        put("t_w2", vec![d, d], a.w.t_w2.clone());
        put("t_b2", vec![d], a.w.t_b2.clone());
        put("y_emb", vec![cfg.num_classes, d], a.w.y_emb.clone());
        put("blk_adaln_w", vec![l, d, 6 * d], stack(&|b| &b.adaln_w));
        put("blk_adaln_b", vec![l, 6 * d], stack(&|b| &b.adaln_b));
        put("blk_qkv_w", vec![l, d, 3 * d], stack(&|b| &b.qkv_w));
        put("blk_qkv_b", vec![l, 3 * d], stack(&|b| &b.qkv_b));
        put("blk_proj_w", vec![l, d, d], stack(&|b| &b.proj_w));
        put("blk_proj_b", vec![l, d], stack(&|b| &b.proj_b));
        put("blk_mlp_w1", vec![l, d, m * d], stack(&|b| &b.mlp_w1));
        put("blk_mlp_b1", vec![l, m * d], stack(&|b| &b.mlp_b1));
        put("blk_mlp_w2", vec![l, m * d, d], stack(&|b| &b.mlp_w2));
        put("blk_mlp_b2", vec![l, d], stack(&|b| &b.mlp_b2));
        put("head_adaln_w", vec![d, 2 * d], a.w.head_adaln_w.clone());
        put("head_adaln_b", vec![2 * d], a.w.head_adaln_b.clone());
        put("head_w", vec![d, pd], a.w.head_w.clone());
        put("head_b", vec![pd], a.w.head_b.clone());
        let b = NativeBackend::from_tensor_file(a.entry.clone(), &tf).unwrap();
        let (x, t, y) = rand_inputs(1, cfg.latent_dim, 6);
        let (ea, _) = ModelBackend::full(&a, 1, &x, &t, &y, false).unwrap();
        let (eb, _) = ModelBackend::full(&b, 1, &x, &t, &y, false).unwrap();
        assert_eq!(ea.data, eb.data);
    }

    #[test]
    fn classifier_is_batch_transparent() {
        let cls = NativeClassifier::seeded(64, 8, 3);
        let mut rng = Rng::new(8);
        let x = rng.normal_f32s(4 * 64);
        let (l4, f4) = cls.classify(4, &x).unwrap();
        for i in 0..4 {
            let (l1, f1) = cls.classify(1, &x[i * 64..(i + 1) * 64]).unwrap();
            assert_eq!(l4.row(i), &l1.data[..]);
            assert_eq!(f4.row(i), &f1.data[..]);
        }
        assert!(l4.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn hub_has_all_simulated_backbones() {
        let hub = NativeHub::seeded(1);
        for name in ["dit-sim", "flux-sim", "video-sim"] {
            let m = hub.model(name).unwrap();
            assert_eq!(m.entry().config.name, name);
            // classifier latent = one frame of every model
            let frame = m.entry().config.latent_dim / m.entry().config.frames;
            assert_eq!(frame, hub.classifier.latent_dim());
        }
        assert!(hub.model("nope").is_err());
    }

    #[test]
    fn rejects_bad_inputs() {
        let m = tiny();
        let cfg = &m.entry().config;
        let (x, t, y) = rand_inputs(1, cfg.latent_dim, 10);
        assert!(ModelBackend::full(&m, 2, &x, &t, &y, false).is_err());
        let feat = vec![0f32; cfg.tokens * cfg.dim];
        assert!(m.block(1, cfg.depth as i32, &feat, &t, &y).is_err());
        assert!(m.block(1, -1, &feat, &t, &y).is_err());
        assert!(m.head(1, &feat[..10], &t, &y).is_err());
    }
}
