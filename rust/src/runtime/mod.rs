//! Execution backends (DESIGN.md §3).
//!
//! * [`backend`] — the `ModelBackend` / `ClassifierBackend` traits every
//!   layer above (engine, server, experiments, benches) is written
//!   against;
//! * [`native`] — pure-Rust, `Send + Sync` CPU reference of the DiT
//!   forward pass; runs with zero artifacts (always compiled, the
//!   default);
//! * [`kernels`] — the cache-blocked GEMM / layer-norm / attention
//!   kernel layer with fused epilogues the native backend computes
//!   through, plus the retained scalar reference path (DESIGN.md §12);
//! * [`pjrt`] — AOT HLO artifacts executed through the PJRT C API;
//!   compiled only with the `pjrt` cargo feature;
//! * [`resolve`] — the shared `--backend native|pjrt|auto` resolver used
//!   by the CLI and every experiment runner;
//! * [`workspace`] — checkout pool of per-call forward-pass arenas, the
//!   zero-allocation discipline behind the native hot path (DESIGN.md
//!   §11).

pub mod backend;
pub mod kernels;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod resolve;
pub mod workspace;

pub use backend::{ClassifierBackend, ModelBackend};
pub use kernels::KernelMode;
pub use native::{NativeBackend, NativeClassifier, NativeHub};
pub use workspace::{Workspace, WorkspacePool};
pub use resolve::{BackendRequest, ResolvedModel};
#[cfg(feature = "pjrt")]
pub use pjrt::{ClassifierRuntime, Exec, In, ModelRuntime, Runtime};

/// Which backend a CLI/bench invocation should use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust CPU backend (zero artifacts).
    Native,
    /// AOT HLO artifacts via the PJRT C API.
    Pjrt,
}

/// Resolve a `--backend native|pjrt|auto` request. `auto` prefers PJRT
/// when the feature is compiled in and artifacts are present; `pjrt` is
/// rejected outright on builds without the feature.
pub fn select_backend(requested: &str, artifacts_present: bool) -> anyhow::Result<BackendKind> {
    match requested {
        "native" => Ok(BackendKind::Native),
        "pjrt" => {
            if cfg!(feature = "pjrt") {
                Ok(BackendKind::Pjrt)
            } else {
                anyhow::bail!("--backend pjrt requires building with --features pjrt")
            }
        }
        "auto" => Ok(if cfg!(feature = "pjrt") && artifacts_present {
            BackendKind::Pjrt
        } else {
            BackendKind::Native
        }),
        other => anyhow::bail!("unknown backend '{other}' (expected native|pjrt|auto)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_selection_rules() {
        assert_eq!(select_backend("native", true).unwrap(), BackendKind::Native);
        assert!(select_backend("warp", false).is_err());
        if cfg!(feature = "pjrt") {
            assert_eq!(select_backend("pjrt", false).unwrap(), BackendKind::Pjrt);
            assert_eq!(select_backend("auto", true).unwrap(), BackendKind::Pjrt);
        } else {
            assert!(select_backend("pjrt", false).is_err());
            assert_eq!(select_backend("auto", true).unwrap(), BackendKind::Native);
        }
        assert_eq!(select_backend("auto", false).unwrap(), BackendKind::Native);
    }
}
