//! Load-generating client for the serving benches (open/closed loop over N
//! TCP connections, latency/throughput reporting).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::metrics::stats::Histogram;
use crate::util::json::Json;

#[derive(Debug, Clone)]
/// Load-generator parameters.
pub struct LoadConfig {
    /// Server address to hit.
    pub addr: String,
    /// Concurrent connections.
    pub connections: usize,
    /// Total requests across connections.
    pub requests: usize,
    /// policy description string (workload::parse_policy syntax)
    pub policy: String,
    /// Conditioning classes cycled round-robin.
    pub num_classes: usize,
}

#[derive(Debug)]
/// Aggregated outcome of one load run.
pub struct LoadReport {
    /// Requests that completed successfully.
    pub completed: usize,
    /// Requests that errored.
    pub errors: usize,
    /// Wall-clock seconds of the whole load run.
    pub wall_s: f64,
    /// Completed requests per second.
    pub throughput_rps: f64,
    /// Per-request latency distribution.
    pub latency: Histogram,
    /// mean per-request FLOPs speedup reported by the server
    pub mean_speedup: f64,
}

/// Issue one generate request on an open connection; returns (latency_ms,
/// reported speedup).
pub fn generate_once(
    stream: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    cond: i32,
    seed: u64,
    policy: &str,
) -> Result<(f64, f64)> {
    let req = Json::obj(vec![
        ("op", Json::str("generate")),
        ("cond", Json::Num(cond as f64)),
        ("seed", Json::Num(seed as f64)),
        ("policy", Json::str(policy)),
    ]);
    let t0 = Instant::now();
    stream.write_all(req.dump().as_bytes())?;
    stream.write_all(b"\n")?;
    let mut line = String::new();
    reader.read_line(&mut line).context("reading response")?;
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    let resp = Json::parse(&line).context("parsing response")?;
    if resp.get("ok").and_then(|b| b.as_bool()) != Some(true) {
        bail!("server error: {line}");
    }
    let speedup = resp
        .get("stats")
        .and_then(|s| s.get("speedup"))
        .and_then(|v| v.as_f64())
        .unwrap_or(1.0);
    Ok((ms, speedup))
}

/// Closed-loop load: `connections` workers, each issuing its share of
/// `requests` back-to-back.
pub fn run_load(cfg: &LoadConfig) -> Result<LoadReport> {
    let t0 = Instant::now();
    let mut handles = Vec::new();
    let per = cfg.requests / cfg.connections.max(1);
    for w in 0..cfg.connections.max(1) {
        let addr = cfg.addr.clone();
        let policy = cfg.policy.clone();
        let classes = cfg.num_classes.max(1);
        let n = if w == cfg.connections - 1 { cfg.requests - per * w } else { per };
        handles.push(thread::spawn(move || -> (Vec<f64>, Vec<f64>, usize) {
            let mut lats = Vec::new();
            let mut speeds = Vec::new();
            let mut errors = 0usize;
            let Ok(mut stream) = TcpStream::connect(&addr) else {
                return (lats, speeds, n);
            };
            let Ok(rs) = stream.try_clone() else {
                return (lats, speeds, n);
            };
            let mut reader = BufReader::new(rs);
            for i in 0..n {
                let cond = ((w * 131 + i * 7) % classes) as i32;
                let seed = (w * 100_000 + i) as u64;
                match generate_once(&mut stream, &mut reader, cond, seed, &policy) {
                    Ok((ms, sp)) => {
                        lats.push(ms);
                        speeds.push(sp);
                    }
                    Err(_) => errors += 1,
                }
            }
            (lats, speeds, errors)
        }));
    }
    let mut latency = Histogram::new();
    let mut speeds = Vec::new();
    let mut errors = 0;
    for h in handles {
        let (lats, sps, errs) = h.join().unwrap();
        for l in lats {
            latency.record(l);
        }
        speeds.extend(sps);
        errors += errs;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let completed = latency.len();
    Ok(LoadReport {
        completed,
        errors,
        wall_s,
        throughput_rps: completed as f64 / wall_s.max(1e-9),
        latency,
        mean_speedup: if speeds.is_empty() {
            0.0
        } else {
            speeds.iter().sum::<f64>() / speeds.len() as f64
        },
    })
}

/// Ask the server to shut down (best effort).
pub fn shutdown(addr: &str) {
    if let Ok(mut s) = TcpStream::connect(addr) {
        let _ = s.write_all(b"{\"op\":\"shutdown\"}\n");
    }
}

/// Fetch engine stats.
pub fn stats(addr: &str) -> Result<Json> {
    let mut s = TcpStream::connect(addr)?;
    s.write_all(b"{\"op\":\"stats\"}\n")?;
    let mut reader = BufReader::new(s.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(Json::parse(&line)?)
}
