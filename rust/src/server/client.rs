//! Load-generating client for the serving benches: closed-loop
//! ([`run_load`] — N connections issuing blocking v1 generates
//! back-to-back) and open-loop ([`run_open_loop`] — protocol v2 submits
//! fired at Poisson arrival times regardless of completions, the
//! arrival process the server cannot push back on), with
//! latency/throughput/rejection reporting.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc::{channel, Sender};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::fabric::{WIRE_PROTO, WIRE_VERSION};
use crate::metrics::stats::Histogram;
use crate::util::json::Json;
use crate::workload::poisson_arrivals;

#[derive(Debug, Clone)]
/// Load-generator parameters.
pub struct LoadConfig {
    /// Server address to hit.
    pub addr: String,
    /// Concurrent connections.
    pub connections: usize,
    /// Total requests across connections.
    pub requests: usize,
    /// policy description string (workload::parse_policy syntax)
    pub policy: String,
    /// Conditioning classes cycled round-robin.
    pub num_classes: usize,
}

#[derive(Debug)]
/// Aggregated outcome of one load run.
pub struct LoadReport {
    /// Requests that completed successfully.
    pub completed: usize,
    /// Requests that errored.
    pub errors: usize,
    /// Wall-clock seconds of the whole load run.
    pub wall_s: f64,
    /// Completed requests per second.
    pub throughput_rps: f64,
    /// Per-request latency distribution.
    pub latency: Histogram,
    /// mean per-request FLOPs speedup reported by the server
    pub mean_speedup: f64,
}

/// Lead a v2 connection with the `op:"hello"` protocol exchange:
/// announce `speca` v2, verify the peer answers with the same protocol
/// and version, and fail fast with the peer's structured error (never a
/// hang) on a mismatch — a v1-only server, or a fabric port dialed by
/// mistake, is caught here before any job is submitted. Returns the
/// peer's advertised role (`server`, `router`, `worker`).
pub fn hello_exchange(
    stream: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
) -> Result<String> {
    let req = Json::obj(vec![
        ("op", Json::str("hello")),
        ("proto", Json::str(WIRE_PROTO)),
        ("version", Json::Num(WIRE_VERSION as f64)),
    ]);
    stream.write_all(req.dump().as_bytes())?;
    stream.write_all(b"\n")?;
    let mut line = String::new();
    reader.read_line(&mut line).context("reading hello reply")?;
    let resp = Json::parse(&line).context("parsing hello reply")?;
    if resp.get("ok").and_then(|b| b.as_bool()) != Some(true) {
        let why = resp.get("error").and_then(|e| e.as_str()).unwrap_or(line.trim());
        bail!("protocol mismatch: {why}");
    }
    let version = resp.get("version").and_then(|v| v.as_u64()).unwrap_or(0);
    if version != WIRE_VERSION {
        bail!("peer speaks protocol v{version}, this client needs v{WIRE_VERSION}");
    }
    Ok(resp.get("role").and_then(|r| r.as_str()).unwrap_or("server").to_string())
}

/// Issue one generate request on an open connection; returns (latency_ms,
/// reported speedup).
pub fn generate_once(
    stream: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    cond: i32,
    seed: u64,
    policy: &str,
) -> Result<(f64, f64)> {
    let req = Json::obj(vec![
        ("op", Json::str("generate")),
        ("cond", Json::Num(cond as f64)),
        ("seed", Json::Num(seed as f64)),
        ("policy", Json::str(policy)),
    ]);
    let t0 = Instant::now();
    stream.write_all(req.dump().as_bytes())?;
    stream.write_all(b"\n")?;
    let mut line = String::new();
    reader.read_line(&mut line).context("reading response")?;
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    let resp = Json::parse(&line).context("parsing response")?;
    if resp.get("ok").and_then(|b| b.as_bool()) != Some(true) {
        bail!("server error: {line}");
    }
    let speedup = resp
        .get("stats")
        .and_then(|s| s.get("speedup"))
        .and_then(|v| v.as_f64())
        .unwrap_or(1.0);
    Ok((ms, speedup))
}

/// Closed-loop load: `connections` workers, each issuing its share of
/// `requests` back-to-back.
pub fn run_load(cfg: &LoadConfig) -> Result<LoadReport> {
    let t0 = Instant::now();
    let mut handles = Vec::new();
    let per = cfg.requests / cfg.connections.max(1);
    for w in 0..cfg.connections.max(1) {
        let addr = cfg.addr.clone();
        let policy = cfg.policy.clone();
        let classes = cfg.num_classes.max(1);
        let n = if w == cfg.connections - 1 { cfg.requests - per * w } else { per };
        handles.push(thread::spawn(move || -> (Vec<f64>, Vec<f64>, usize) {
            let mut lats = Vec::new();
            let mut speeds = Vec::new();
            let mut errors = 0usize;
            let Ok(mut stream) = TcpStream::connect(&addr) else {
                return (lats, speeds, n);
            };
            let Ok(rs) = stream.try_clone() else {
                return (lats, speeds, n);
            };
            let mut reader = BufReader::new(rs);
            for i in 0..n {
                let cond = ((w * 131 + i * 7) % classes) as i32;
                let seed = (w * 100_000 + i) as u64;
                match generate_once(&mut stream, &mut reader, cond, seed, &policy) {
                    Ok((ms, sp)) => {
                        lats.push(ms);
                        speeds.push(sp);
                    }
                    Err(_) => errors += 1,
                }
            }
            (lats, speeds, errors)
        }));
    }
    let mut latency = Histogram::new();
    let mut speeds = Vec::new();
    let mut errors = 0;
    for h in handles {
        let (lats, sps, errs) = h.join().unwrap();
        for l in lats {
            latency.record(l);
        }
        speeds.extend(sps);
        errors += errs;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let completed = latency.len();
    Ok(LoadReport {
        completed,
        errors,
        wall_s,
        throughput_rps: completed as f64 / wall_s.max(1e-9),
        latency,
        mean_speedup: if speeds.is_empty() {
            0.0
        } else {
            speeds.iter().sum::<f64>() / speeds.len() as f64
        },
    })
}

// ---------------------------------------------------------------------------
// Open-loop load (protocol v2)
// ---------------------------------------------------------------------------

/// Open-loop load-generator parameters.
#[derive(Debug, Clone)]
pub struct OpenLoopConfig {
    /// Server address to hit.
    pub addr: String,
    /// Target arrival rate in requests/second (Poisson process).
    pub rate: f64,
    /// Total requests to submit.
    pub requests: usize,
    /// policy description string (workload::parse_policy syntax)
    pub policy: String,
    /// Conditioning classes cycled round-robin.
    pub num_classes: usize,
    /// Seed of the arrival process (and of request seeds).
    pub seed: u64,
    /// Per-request relative deadline forwarded to the server (admission
    /// sheds infeasible work; queued work past it is rejected).
    pub deadline_ms: Option<u64>,
    /// Priority class forwarded with every submit (`low|normal|high`).
    pub priority: Option<String>,
    /// Connections collecting completions via `op:"wait"` (jobs are
    /// distributed round-robin; waits run concurrently with submission).
    pub waiters: usize,
}

impl Default for OpenLoopConfig {
    fn default() -> Self {
        OpenLoopConfig {
            addr: "127.0.0.1:7433".into(),
            rate: 1.0,
            requests: 32,
            policy: "speca:N=5,O=2".into(),
            num_classes: 8,
            seed: 0,
            deadline_ms: None,
            priority: None,
            waiters: 8,
        }
    }
}

/// Aggregated outcome of one open-loop run. Latency is measured from
/// each request's *scheduled arrival time* to the return of its `wait`
/// (so queueing delay counts, the open-loop convention). Each waiter
/// connection waits its assigned jobs serially, so a job that finished
/// while its waiter was still blocked on an earlier, slower job is
/// attributed the later wait-return — recorded latency is an *upper
/// bound*, tight when completions are roughly in submission order
/// (FIFO shard queues) and when `waiters` comfortably exceeds the
/// completion disorder; raise `waiters` to tighten tail percentiles.
#[derive(Debug)]
pub struct OpenLoopReport {
    /// Submits attempted.
    pub submitted: usize,
    /// Jobs that completed.
    pub completed: usize,
    /// Jobs the server shed (admission or queued-deadline expiry).
    pub rejected: usize,
    /// Jobs cancelled/aborted server-side.
    pub aborted: usize,
    /// Protocol/transport failures.
    pub errors: usize,
    /// Wall-clock seconds of the whole run.
    pub wall_s: f64,
    /// Offered arrival rate: requests over the span the submits
    /// actually covered (the ideal Poisson schedule, stretched when
    /// submit-ack round-trips throttled it — so this is the attained
    /// rate, not the requested one).
    pub offered_rps: f64,
    /// Completed requests per wall second.
    pub achieved_rps: f64,
    /// Arrival-to-completion latency distribution (ms).
    pub latency: Histogram,
}

impl OpenLoopReport {
    /// Fraction of submitted jobs the server shed.
    pub fn reject_rate(&self) -> f64 {
        if self.submitted == 0 {
            return 0.0;
        }
        self.rejected as f64 / self.submitted as f64
    }
}

/// Build the v2 submit line for one open-loop request.
fn submit_line(cfg: &OpenLoopConfig, i: usize) -> String {
    let mut pairs = vec![
        ("op", Json::str("submit")),
        ("cond", Json::Num((i % cfg.num_classes.max(1)) as f64)),
        ("seed", Json::Num((cfg.seed.wrapping_mul(1_000_000) + i as u64) as f64)),
        ("policy", Json::str(&cfg.policy)),
    ];
    if let Some(ms) = cfg.deadline_ms {
        pairs.push(("deadline_ms", Json::Num(ms as f64)));
    }
    if let Some(p) = &cfg.priority {
        pairs.push(("priority", Json::str(p)));
    }
    Json::obj(pairs).dump()
}

/// Waiter thread: collect terminal states for its share of the jobs.
/// Returns (latencies ms, rejected, aborted, errors).
fn open_loop_waiter(
    addr: String,
    rx: std::sync::mpsc::Receiver<(u64, Instant)>,
) -> (Vec<f64>, usize, usize, usize) {
    let (mut lats, mut rejected, mut aborted, mut errors) = (Vec::new(), 0usize, 0usize, 0usize);
    let stream = TcpStream::connect(&addr).ok();
    let mut io = stream.and_then(|s| {
        let r = s.try_clone().ok()?;
        let mut s = s;
        let mut reader = BufReader::new(r);
        hello_exchange(&mut s, &mut reader).ok()?;
        Some((s, reader))
    });
    for (job, sched) in rx.iter() {
        let Some((stream, reader)) = io.as_mut() else {
            errors += 1;
            continue;
        };
        let ok = stream
            .write_all(format!("{{\"op\":\"wait\",\"job\":{job}}}\n").as_bytes())
            .is_ok();
        let mut line = String::new();
        if !ok || reader.read_line(&mut line).is_err() {
            errors += 1;
            io = None;
            continue;
        }
        match Json::parse(&line) {
            Err(_) => errors += 1,
            Ok(resp) => match resp.get("state").and_then(|s| s.as_str()) {
                Some("completed") => {
                    lats.push(Instant::now().saturating_duration_since(sched).as_secs_f64() * 1e3);
                }
                Some("rejected") => rejected += 1,
                Some("cancelled") | Some("aborted") => aborted += 1,
                _ => errors += 1,
            },
        }
    }
    (lats, rejected, aborted, errors)
}

/// Drive the server open-loop: submits fire at Poisson arrival times
/// ([`poisson_arrivals`]) on one connection (each acked immediately by
/// the async `op:"submit"`), while `cfg.waiters` connections concurrently
/// collect completions with consuming `op:"wait"`s. Unlike the
/// closed-loop generator, a slow server does not throttle the arrival
/// process — backlog, deadline shedding and rejection behaviour become
/// observable.
pub fn run_open_loop(cfg: &OpenLoopConfig) -> Result<OpenLoopReport> {
    if cfg.rate <= 0.0 || !cfg.rate.is_finite() {
        // rate 0 would make the Poisson gaps infinite and panic inside
        // Duration::from_secs_f64 — fail with a message instead
        bail!("open-loop rate must be a positive, finite req/s value (got {})", cfg.rate);
    }
    let arrivals = poisson_arrivals(cfg.requests, cfg.rate, cfg.seed);
    let span_s = arrivals.last().copied().unwrap_or(0.0);
    let mut stream =
        TcpStream::connect(&cfg.addr).with_context(|| format!("connecting to {}", cfg.addr))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    hello_exchange(&mut stream, &mut reader).context("protocol hello")?;

    let waiters = cfg.waiters.max(1);
    let mut txs: Vec<Sender<(u64, Instant)>> = Vec::with_capacity(waiters);
    let mut handles = Vec::with_capacity(waiters);
    for _ in 0..waiters {
        let (tx, rx) = channel::<(u64, Instant)>();
        let addr = cfg.addr.clone();
        txs.push(tx);
        handles.push(thread::spawn(move || open_loop_waiter(addr, rx)));
    }

    let t0 = Instant::now();
    let (mut rejected, mut aborted, mut errors) = (0usize, 0usize, 0usize);
    for (i, arr) in arrivals.iter().enumerate() {
        let sched = t0 + Duration::from_secs_f64(*arr);
        let now = Instant::now();
        if sched > now {
            thread::sleep(sched - now);
        }
        stream.write_all(submit_line(cfg, i).as_bytes())?;
        stream.write_all(b"\n")?;
        let mut line = String::new();
        reader.read_line(&mut line).context("reading submit ack")?;
        let resp = Json::parse(&line).context("parsing submit ack")?;
        match (
            resp.get("ok").and_then(|b| b.as_bool()),
            resp.get("job").and_then(|j| j.as_u64()),
            resp.get("state").and_then(|s| s.as_str()),
        ) {
            (Some(true), Some(job), _) => {
                let _ = txs[i % waiters].send((job, sched));
            }
            (Some(false), _, Some("rejected")) => rejected += 1,
            // admission-time aborts (unroutable submit / dead shards)
            // are answered in the ack too — they are shed jobs, not
            // protocol failures
            (Some(false), _, Some("aborted") | Some("cancelled")) => aborted += 1,
            _ => errors += 1,
        }
    }
    // measure the span the submits actually covered: at rates near the
    // ack round-trip the synchronous ack read throttles arrivals, and
    // reporting the ideal schedule's rate would overstate offered load
    let submit_span_s = t0.elapsed().as_secs_f64();
    drop(txs);
    let mut latency = Histogram::new();
    let mut completed = 0usize;
    for h in handles {
        let (lats, rej, abt, errs) = h.join().unwrap();
        completed += lats.len();
        for l in lats {
            latency.record(l);
        }
        rejected += rej;
        aborted += abt;
        errors += errs;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(OpenLoopReport {
        submitted: cfg.requests,
        completed,
        rejected,
        aborted,
        errors,
        wall_s,
        offered_rps: cfg.requests as f64 / submit_span_s.max(span_s).max(1e-9),
        achieved_rps: completed as f64 / wall_s.max(1e-9),
        latency,
    })
}

/// Ask the server to shut down (best effort).
pub fn shutdown(addr: &str) {
    if let Ok(mut s) = TcpStream::connect(addr) {
        let _ = s.write_all(b"{\"op\":\"shutdown\"}\n");
    }
}

/// Fetch engine stats.
pub fn stats(addr: &str) -> Result<Json> {
    let mut s = TcpStream::connect(addr)?;
    s.write_all(b"{\"op\":\"stats\"}\n")?;
    let mut reader = BufReader::new(s.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(Json::parse(&line)?)
}

/// Fetch the Prometheus-style exposition text behind `op:"metrics"`
/// (works against a single-process server, a fabric worker, or the
/// router — they export the same families).
pub fn metrics(addr: &str) -> Result<String> {
    let mut s = TcpStream::connect(addr)?;
    s.write_all(b"{\"op\":\"metrics\"}\n")?;
    let mut reader = BufReader::new(s.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let j = Json::parse(&line)?;
    match j.get("metrics").and_then(|m| m.as_str()) {
        Some(text) => Ok(text.to_string()),
        None => bail!("peer returned no metrics text: {}", line.trim()),
    }
}
