//! TCP JSON-lines serving front-end.
//!
//! Two serving modes (DESIGN.md §8):
//!
//! * [`serve_sharded`] — the default for `Send + Sync` backends (native).
//!   A [`JobManager`] runs an `EngineShardPool` (N engine loops over one
//!   shared backend) plus the shared job table; connection threads talk
//!   straight to the manager — submission routes to shard queues through
//!   its router, and status/wait reads go through the table's condvar,
//!   so there is no central engine funnel and no per-request reply
//!   channel plumbing.
//! * [`serve`] — the legacy single-threaded loop, kept for backends whose
//!   client is not `Send` (PJRT's is `Rc`-based): the engine runs on the
//!   calling thread and connection threads hand work over one channel.
//!   It speaks protocol v1 only.
//!
//! ## Protocol v2 (one JSON object per line)
//!
//! Job lifecycle ops — submission is asynchronous and acks immediately:
//!
//! ```text
//! → {"op":"submit","cond":3,"seed":7,"policy":"speca","tau0":0.3,
//!    "priority":"high","deadline_ms":5000,"return_latent":false,
//!    "preemptible":true,"group":4}
//! ← {"ok":true,"job":12,"state":"queued"}        (or "rejected" + error)
//! → {"op":"poll","job":12}
//! ← {"ok":true,"job":12,"state":"running","step":9,"accepts":6,"rejects":0}
//! → {"op":"wait","job":12,"timeout_ms":30000}    (timeout optional)
//! ← {"ok":true,"state":"completed","id":12,"stats":{...},"latent":[...]?}
//! → {"op":"cancel","job":12}                     (or "group":4 — fires the
//! ← {"ok":true,"job":12,"state":"cancelling"}     group's shared token)
//! ```
//!
//! `"preemptible":true` lets the engine park the job mid-flight — its
//! checkpoint resumes bitwise-identically, possibly on another shard —
//! to free its slot for higher-priority work or work-stealing
//! (DESIGN.md §13). `"group":N` joins a job group: members share one
//! cancel token, and `op:"stats"` reports per-group counts.
//!
//! A `wait` that returns a terminal state **consumes** the job record
//! (freeing its memory); `poll` never does, so polling a finished job is
//! idempotent until some `wait` collects it. Terminal failures reply
//! `ok:false` with `state` = `rejected` / `cancelled` / `aborted` and a
//! human-readable `error`.
//!
//! v1 compatibility: `op:"generate"` (also the default when `op` is
//! omitted) is a thin submit+wait shim — same reply shape as before,
//! byte-identical error strings (`"queue full"`), so existing clients
//! and tests keep working. `op:"stats"` reports pool counters plus
//! per-shard live loads, dead-shard count, the job counters, the
//! checkpoint counters (`parked`/`resumed`/`stolen`/`migrated`) and
//! per-group counts; `op:"shutdown"` drains in-flight work, then stops.
//!
//! See `client.rs` for the closed-loop and open-loop load generators.

pub mod client;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::cache::Draft;
use crate::coordinator::job::{GroupId, JobManager, JobStatus, Priority, SubmitOptions};
use crate::coordinator::state::{Completion, RequestSpec};
use crate::coordinator::{Engine, EngineConfig, JobMeta, Policy, PoolConfig, RouterPolicy};
use crate::runtime::ModelBackend;
use crate::util::json::Json;
use crate::workload::policy_from_json_with;

/// A parsed client request paired with its reply channel (legacy loop).
enum FrontendMsg {
    Generate { spec_body: Json, reply: Sender<String>, return_latent: bool },
    Stats { reply: Sender<String> },
    Shutdown,
}

/// Serving front-end configuration.
pub struct ServerConfig {
    /// TCP listen address.
    pub addr: String,
    /// maximum jobs in a non-terminal state (admission sheds the rest)
    pub max_queue: usize,
    /// engine worker threads for [`serve_sharded`]
    pub shards: usize,
    /// How submissions spread over shards.
    pub router: RouterPolicy,
    /// Default draft strategy for SpeCa requests that name none
    /// (`--draft` on `speca serve`; an explicit per-request draft wins).
    pub default_draft: Option<Draft>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7433".into(),
            max_queue: 1024,
            shards: 1,
            router: RouterPolicy::LeastLoaded,
            default_draft: None,
        }
    }
}

fn completion_json(c: &Completion, return_latent: bool, full_flops: u64, steps: usize) -> Json {
    let s = &c.stats;
    let mut pairs = vec![
        ("id", Json::Num(c.id as f64)),
        ("ok", Json::Bool(true)),
        ("policy", Json::str(&c.policy_name)),
        ("draft", Json::str(&c.draft_name)),
        ("cond", Json::Num(c.cond as f64)),
        (
            "stats",
            Json::obj(vec![
                ("full_steps", Json::Num(s.full_steps as f64)),
                ("spec_steps", Json::Num(s.spec_steps as f64)),
                ("skip_steps", Json::Num(s.skip_steps as f64)),
                ("blend_steps", Json::Num(s.blend_steps as f64)),
                ("elided_steps", Json::Num(s.elided_steps as f64)),
                ("rejects", Json::Num(s.rejects as f64)),
                ("latency_ms", Json::Num(s.latency_ms)),
                ("flops", Json::Num(s.flops.total() as f64)),
                ("speedup", Json::Num(s.speedup(full_flops, steps))),
            ]),
        ),
    ];
    if return_latent {
        pairs.push(("latent", Json::arr_f32(&c.latent)));
    }
    Json::obj(pairs)
}

pub(crate) fn error_json(msg: &str) -> String {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(msg))]).dump()
}

/// The wire defaults shared by both serving modes, so they cannot
/// drift: `cond` defaults to 0; a missing `seed` is `None` and the
/// consumer substitutes the request id.
fn wire_cond_seed(req: &Json) -> (i32, Option<u64>) {
    (
        req.get("cond").and_then(|c| c.as_f64()).unwrap_or(0.0) as i32,
        req.get("seed").and_then(|s| s.as_u64()),
    )
}

/// Build a [`RequestSpec`] from a v1 protocol request (legacy
/// single-threaded loop; the sharded path builds specs inside
/// [`JobManager::submit`] from the same [`wire_cond_seed`] defaults).
fn spec_from_json(req: &Json, id: u64, policy: Policy) -> RequestSpec {
    let (cond, seed) = wire_cond_seed(req);
    RequestSpec {
        id,
        cond,
        seed: seed.unwrap_or(id),
        policy,
        record_traj: false,
        meta: JobMeta::default(),
    }
}

// ---------------------------------------------------------------------------
// Sharded serving (native / any Send + Sync backend): protocol v2
// ---------------------------------------------------------------------------

/// Everything a connection thread needs; cloned per connection. Shared
/// with the fabric module: a worker process runs this exact connection
/// handler on its own serving port (so `stats`/`metrics`/direct submits
/// work per-process), and the fabric worker loop reuses the submit path.
#[derive(Clone)]
pub(crate) struct ConnCtx {
    pub(crate) manager: Arc<JobManager>,
    pub(crate) accepting: Arc<AtomicBool>,
    pub(crate) shutdown: Sender<()>,
    pub(crate) depth: usize,
    pub(crate) steps: usize,
    pub(crate) full_flops: u64,
    pub(crate) default_draft: Option<Draft>,
    /// What `op:"hello"` reports this process as (`server` / `worker`;
    /// the fabric router speaks for itself).
    pub(crate) role: &'static str,
}

/// Parse the v2 job options (`priority`, `deadline_ms`, `return_latent`,
/// `preemptible`, `group`, `adaptive`, `lookahead`) shared by `submit` and the v1
/// `generate` shim. Built through the [`SubmitOptions`] builder — the
/// struct is `#[non_exhaustive]`, so this is also the canonical
/// construction path.
fn submit_options_from_json(req: &Json) -> Result<SubmitOptions> {
    let mut opts = SubmitOptions::new()
        .return_latent(req.get("return_latent").and_then(|b| b.as_bool()).unwrap_or(false))
        .preemptible(req.get("preemptible").and_then(|b| b.as_bool()).unwrap_or(false));
    if let Some(p) = req.get("priority") {
        let Some(s) = p.as_str() else {
            bail!("'priority' must be \"low\"|\"normal\"|\"high\"");
        };
        let parsed = Priority::parse(s)
            .ok_or_else(|| anyhow::anyhow!("unknown priority '{s}' (low|normal|high)"))?;
        opts = opts.priority(parsed);
    }
    if let Some(d) = req.get("deadline_ms") {
        let Some(ms) = d.as_f64() else {
            bail!("'deadline_ms' must be a number of milliseconds");
        };
        if ms < 0.0 {
            bail!("'deadline_ms' must be non-negative, got {ms}");
        }
        opts = opts.deadline_ms(ms as u64);
    }
    if let Some(g) = req.get("group") {
        let Some(gid) = g.as_u64() else {
            bail!("'group' must be a non-negative integer id");
        };
        opts = opts.group(GroupId(gid));
    }
    if let Some(a) = req.get("adaptive") {
        let Some(b) = a.as_f64() else {
            bail!("'adaptive' must be a number (total relative-error budget)");
        };
        if b < 0.0 {
            bail!("'adaptive' must be non-negative, got {b}");
        }
        opts = opts.adaptive(b);
    }
    if let Some(l) = req.get("lookahead") {
        let Some(k) = l.as_u64() else {
            bail!("'lookahead' must be an integer >= 1 (speculated steps per verify point)");
        };
        if k < 1 {
            bail!("'lookahead' must be >= 1, got {k}");
        }
        opts = opts.lookahead(k as usize);
    }
    Ok(opts)
}

/// Render a [`JobStatus`] as a protocol reply object (callers dump it,
/// possibly after adding reply-specific fields like `timed_out`).
pub(crate) fn status_json(ctx: &ConnCtx, id: u64, status: &JobStatus, return_latent: bool) -> Json {
    let base = |ok: bool| {
        vec![
            ("ok", Json::Bool(ok)),
            ("job", Json::Num(id as f64)),
            ("state", Json::str(status.label())),
        ]
    };
    match status {
        JobStatus::Queued => Json::obj(base(true)),
        JobStatus::Admitted { shard } => {
            let mut p = base(true);
            p.push(("shard", Json::Num(*shard as f64)));
            Json::obj(p)
        }
        JobStatus::Running { step, accepts, rejects } => {
            let mut p = base(true);
            p.push(("step", Json::Num(*step as f64)));
            p.push(("accepts", Json::Num(*accepts as f64)));
            p.push(("rejects", Json::Num(*rejects as f64)));
            Json::obj(p)
        }
        JobStatus::Completed(c) => {
            // the v1 completion shape plus a state marker
            match completion_json(c, return_latent, ctx.full_flops, ctx.steps) {
                Json::Obj(mut m) => {
                    m.insert("state".to_string(), Json::str("completed"));
                    Json::Obj(m)
                }
                other => other,
            }
        }
        JobStatus::Rejected { reason } => {
            let mut p = base(false);
            p.push(("error", Json::str(&reason.to_string())));
            Json::obj(p)
        }
        JobStatus::Cancelled => {
            let mut p = base(false);
            p.push(("error", Json::str("cancelled by client")));
            Json::obj(p)
        }
        JobStatus::Aborted { error } => {
            let mut p = base(false);
            p.push(("error", Json::str(error)));
            Json::obj(p)
        }
    }
}

/// Parse + submit a job; shared by `op:"submit"`, the v1 shim, and the
/// fabric worker loop (router-forwarded jobs are submit bodies).
pub(crate) fn submit_from_json(
    ctx: &ConnCtx,
    req: &Json,
) -> Result<crate::coordinator::JobHandle> {
    let opts = submit_options_from_json(req)?;
    let policy = policy_from_json_with(req, ctx.depth, ctx.default_draft.as_ref())?;
    let (cond, seed) = wire_cond_seed(req);
    Ok(ctx.manager.submit(cond, seed, policy, opts))
}

/// `op:"submit"`: async job submission, acks immediately with the id.
fn handle_submit(ctx: &ConnCtx, req: &Json) -> String {
    if !ctx.accepting.load(Ordering::SeqCst) {
        return error_json("server is shutting down");
    }
    let handle = match submit_from_json(ctx, req) {
        Ok(h) => h,
        Err(e) => return error_json(&format!("{e}")),
    };
    let id = handle.id().0;
    // an admission-time failure (queue full / infeasible deadline /
    // unroutable) is already terminal — surface it in the ack instead
    // of a fake "queued". A job that merely raced ahead (admitted, or
    // even completed on a fast backend) still acks "queued": it *was*
    // queued, and poll/wait report the current state.
    let status = handle.poll();
    if matches!(status, JobStatus::Rejected { .. } | JobStatus::Aborted { .. }) {
        let line = status_json(ctx, id, &status, false).dump();
        // the ack itself is this job's final answer — no consuming wait
        // will ever come. Admission rejections never entered the table;
        // an unroutable-submit abort did, so reclaim that record now.
        ctx.manager.forget(id);
        line
    } else {
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("job", Json::Num(id as f64)),
            ("state", Json::str("queued")),
        ])
        .dump()
    }
}

fn job_id_of(req: &Json) -> Result<u64> {
    req.get("job")
        .and_then(|j| j.as_u64())
        .ok_or_else(|| anyhow::anyhow!("missing numeric 'job' field"))
}

/// `op:"poll"`: non-blocking status snapshot; idempotent.
fn handle_poll(ctx: &ConnCtx, req: &Json) -> String {
    let id = match job_id_of(req) {
        Ok(id) => id,
        Err(e) => return error_json(&format!("{e}")),
    };
    match ctx.manager.poll(id) {
        None => error_json(&format!("unknown job {id}")),
        Some((status, rl)) => status_json(ctx, id, &status, rl).dump(),
    }
}

/// `op:"wait"`: block until terminal (or `timeout_ms`); a terminal reply
/// consumes the job record.
fn handle_wait(ctx: &ConnCtx, req: &Json) -> String {
    let id = match job_id_of(req) {
        Ok(id) => id,
        Err(e) => return error_json(&format!("{e}")),
    };
    let timeout = req
        .get("timeout_ms")
        .and_then(|t| t.as_f64())
        .map(|ms| Duration::from_millis(ms.max(0.0) as u64));
    match ctx.manager.wait(id, timeout, true) {
        None => error_json(&format!("unknown job {id}")),
        Some((status, rl)) => {
            let mut j = status_json(ctx, id, &status, rl);
            if !status.is_terminal() {
                // timeout elapsed: mark it so clients can distinguish a
                // still-running reply from a terminal one
                if let Json::Obj(m) = &mut j {
                    m.insert("timed_out".to_string(), Json::Bool(true));
                }
            }
            j.dump()
        }
    }
}

/// `op:"cancel"`: fire the job's cancel token (the engine drops it at
/// the next step boundary); acks immediately. With `group` instead of
/// `job`, fires the group's shared token — one sweep retires every
/// live member.
fn handle_cancel(ctx: &ConnCtx, req: &Json) -> String {
    if let (None, Some(g)) = (req.get("job"), req.get("group")) {
        let Some(gid) = g.as_u64() else {
            return error_json("'group' must be a non-negative integer id");
        };
        return if ctx.manager.cancel_group(GroupId(gid)) {
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("group", Json::Num(gid as f64)),
                ("state", Json::str("cancelling")),
            ])
            .dump()
        } else {
            error_json(&format!("unknown group {gid}"))
        };
    }
    let id = match job_id_of(req) {
        Ok(id) => id,
        Err(e) => return error_json(&format!("{e}")),
    };
    match ctx.manager.cancel(id) {
        None => error_json(&format!("unknown job {id}")),
        Some(status) => {
            let state = if status.is_terminal() { status.label() } else { "cancelling" };
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("job", Json::Num(id as f64)),
                ("state", Json::str(state)),
            ])
            .dump()
        }
    }
}

/// v1 `op:"generate"` — the compat shim: submit + consuming wait, with
/// the original reply shape and error strings.
fn handle_generate(ctx: &ConnCtx, req: &Json) -> String {
    if !ctx.accepting.load(Ordering::SeqCst) {
        return error_json("server is shutting down");
    }
    let handle = match submit_from_json(ctx, req) {
        Ok(h) => h,
        Err(e) => return error_json(&format!("{e}")),
    };
    let id = handle.id().0;
    match ctx.manager.wait(id, None, true) {
        // no table record: admission rejections never enter the table —
        // the verdict lives on the handle (this is what keeps the v1
        // "queue full" reply byte-identical)
        None => match handle.poll() {
            JobStatus::Rejected { reason } => error_json(&reason.to_string()),
            JobStatus::Aborted { error } => error_json(&format!("request aborted: {error}")),
            other => error_json(&format!("request did not finish (state {})", other.label())),
        },
        Some((status, rl)) => match status {
            JobStatus::Completed(c) => {
                completion_json(&c, rl, ctx.full_flops, ctx.steps).dump()
            }
            JobStatus::Rejected { reason } => error_json(&reason.to_string()),
            JobStatus::Cancelled => error_json("request cancelled"),
            JobStatus::Aborted { error } => error_json(&format!("request aborted: {error}")),
            other => error_json(&format!("request did not finish (state {})", other.label())),
        },
    }
}

/// `op:"stats"`: pool counters plus per-shard live data so operators can
/// see load skew and dead shards without attaching a debugger.
fn handle_stats(ctx: &ConnCtx) -> String {
    let mut pairs = vec![("ok", Json::Bool(true))];
    pairs.extend(stats_pairs(&ctx.manager));
    Json::obj(pairs).dump()
}

/// The `op:"stats"` body (everything but `ok`). Shared with the fabric:
/// a worker ships exactly this object in heartbeat replies, so the
/// router's per-worker breakdown is byte-compatible with asking the
/// worker directly.
pub(crate) fn stats_pairs(manager: &JobManager) -> Vec<(&'static str, Json)> {
    let s = manager.stats();
    let counts = manager.counts();
    let loads = manager.shard_loads();
    let dead = loads.iter().filter(|l| **l == usize::MAX).count();
    let shard_loads = Json::Arr(
        loads
            .iter()
            .map(|l| if *l == usize::MAX { Json::Null } else { Json::Num(*l as f64) })
            .collect(),
    );
    vec![
        ("completed", Json::Num(counts.completed as f64)),
        ("inflight", Json::Num(s.inflight as f64)),
        ("shards", Json::Num(manager.shards() as f64)),
        ("shard_loads", shard_loads),
        ("dead_shards", Json::Num(dead as f64)),
        ("ticks", Json::Num(s.ticks as f64)),
        ("alpha", Json::Num(s.flops.acceptance_rate())),
        ("gamma", Json::Num(s.flops.gamma())),
        ("total_flops", Json::Num(s.flops.total() as f64)),
        ("est_service_ms", Json::Num(manager.est_service_ms())),
        ("parked", Json::Num(s.parked as f64)),
        ("resumed", Json::Num(s.resumed as f64)),
        ("stolen", Json::Num(s.stolen as f64)),
        ("migrated", Json::Num(s.migrated as f64)),
        (
            "groups",
            Json::Arr(
                manager
                    .group_counts()
                    .iter()
                    .map(|g| {
                        Json::obj(vec![
                            ("id", Json::Num(g.id as f64)),
                            ("submitted", Json::Num(g.submitted as f64)),
                            ("completed", Json::Num(g.completed as f64)),
                            ("live", Json::Num(g.live as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "jobs",
            Json::obj(vec![
                ("submitted", Json::Num(counts.submitted as f64)),
                ("completed", Json::Num(counts.completed as f64)),
                ("rejected", Json::Num(counts.rejected as f64)),
                ("cancelled", Json::Num(counts.cancelled as f64)),
                ("aborted", Json::Num(counts.aborted as f64)),
                ("live", Json::Num(manager.live() as f64)),
            ]),
        ),
    ]
}

/// `op:"hello"`: protocol negotiation (satellite of DESIGN.md §15).
/// Clients lead with `{"op":"hello","proto":"speca","version":2}`; a
/// matching peer learns the server's role (`server`/`worker`), a
/// mismatched peer gets a structured error naming what this port
/// speaks instead of a hang or a confusing downstream failure.
fn handle_hello(ctx: &ConnCtx, req: &Json) -> String {
    use crate::fabric::{WIRE_PROTO, WIRE_VERSION};
    let proto = req.get("proto").and_then(|p| p.as_str()).unwrap_or(WIRE_PROTO);
    if proto != WIRE_PROTO {
        return error_json(&format!(
            "unknown protocol '{proto}' (this port speaks '{WIRE_PROTO}' v{WIRE_VERSION})"
        ));
    }
    let version = req.get("version").and_then(|v| v.as_u64()).unwrap_or(WIRE_VERSION);
    if version != WIRE_VERSION {
        return error_json(&format!(
            "unsupported protocol version {version} (this port speaks v{WIRE_VERSION})"
        ));
    }
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("proto", Json::str(WIRE_PROTO)),
        ("version", Json::Num(WIRE_VERSION as f64)),
        ("role", Json::str(ctx.role)),
        ("shards", Json::Num(ctx.manager.shards() as f64)),
    ])
    .dump()
}

/// `op:"metrics"`: Prometheus-style exposition text (one JSON line with
/// the document in `metrics`; see [`crate::fabric::metrics`]).
fn handle_metrics(ctx: &ConnCtx) -> String {
    let text = crate::fabric::metrics::render_manager_metrics(&ctx.manager);
    Json::obj(vec![("ok", Json::Bool(true)), ("metrics", Json::str(&text))]).dump()
}

pub(crate) fn handle_conn_sharded(stream: TcpStream, ctx: ConnCtx) {
    let Ok(mut writer) = stream.try_clone() else { return };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply_line = match Json::parse(&line) {
            Err(e) => error_json(&e.to_string()),
            Ok(req) => {
                let op = req.get("op").and_then(|o| o.as_str()).unwrap_or("generate");
                match op {
                    "shutdown" => {
                        ctx.accepting.store(false, Ordering::SeqCst);
                        let _ = ctx.shutdown.send(());
                        Json::obj(vec![("ok", Json::Bool(true))]).dump()
                    }
                    "hello" => handle_hello(&ctx, &req),
                    "stats" => handle_stats(&ctx),
                    "metrics" => handle_metrics(&ctx),
                    "generate" => handle_generate(&ctx, &req),
                    "submit" => handle_submit(&ctx, &req),
                    "poll" => handle_poll(&ctx, &req),
                    "wait" => handle_wait(&ctx, &req),
                    "cancel" => handle_cancel(&ctx, &req),
                    // A request without an "op" key defaults to generate
                    // (matched above); anything else is a protocol error —
                    // falling through to generate would silently burn a
                    // full denoising run on a typo.
                    other => error_json(&format!("unknown op '{other}'")),
                }
            }
        };
        if writer.write_all(reply_line.as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
            break;
        }
    }
}

/// Accept loop over `listener`: one thread per connection running
/// [`handle_conn_sharded`], until `ctx.accepting` clears (poke the port
/// with a throwaway connect to wake a blocked accept). Shared with the
/// fabric worker, which serves the same protocol on its own port.
pub(crate) fn spawn_client_listener(listener: TcpListener, ctx: ConnCtx) -> thread::JoinHandle<()> {
    thread::spawn(move || {
        for stream in listener.incoming() {
            if !ctx.accepting.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(s) => {
                    let conn_ctx = ctx.clone();
                    thread::spawn(move || handle_conn_sharded(s, conn_ctx));
                }
                Err(_) => break,
            }
        }
    })
}

/// Serve over a [`JobManager`]: N engine loops on worker threads, the
/// full protocol v2 job lifecycle plus the v1 `generate` shim. Blocks
/// until a shutdown request arrives, drains in-flight work, then joins
/// every thread. Every accepted job reaches exactly one terminal state
/// (its completion under normal drain, or a structured
/// rejected/cancelled/aborted reply), so a blocked `wait` can never
/// hang. Returns total completed requests.
pub fn serve_sharded(
    model: Arc<dyn ModelBackend + Send + Sync>,
    engine_cfg: EngineConfig,
    cfg: &ServerConfig,
) -> Result<u64> {
    let (depth, steps, full_flops) = {
        let entry = model.entry();
        (
            entry.config.depth,
            entry.config.serve_steps,
            entry.flops.full_step.get(&1).copied().unwrap_or(0),
        )
    };

    let manager = Arc::new(JobManager::new(
        model,
        PoolConfig {
            shards: cfg.shards.max(1),
            router: cfg.router,
            engine: engine_cfg,
            // serving is open-loop and skew-prone: let idle shards pull
            // mid-flight work from loaded peers (DESIGN.md §13)
            steal: true,
        },
        cfg.max_queue,
    ));

    let listener = TcpListener::bind(&cfg.addr)?;
    let accepting = Arc::new(AtomicBool::new(true));
    let (shutdown_tx, shutdown_rx) = channel::<()>();

    // acceptor: one thread per connection, each with its own manager Arc
    let ctx = ConnCtx {
        manager: manager.clone(),
        accepting: accepting.clone(),
        shutdown: shutdown_tx.clone(),
        depth,
        steps,
        full_flops,
        default_draft: cfg.default_draft.clone(),
        role: "server",
    };
    let acceptor = spawn_client_listener(listener.try_clone()?, ctx);
    drop(shutdown_tx);
    eprintln!(
        "speca: serving on {} (protocol v2, {} shard(s), {:?} router)",
        cfg.addr,
        manager.shards(),
        cfg.router
    );

    // block until a shutdown op (or the acceptor and every connection die)
    let _ = shutdown_rx.recv();
    accepting.store(false, Ordering::SeqCst);
    // wake the acceptor so it observes the flag and exits
    let _ = TcpStream::connect(&cfg.addr);
    let _ = acceptor.join();

    // drain the shards: every live job reaches a terminal state, which
    // wakes every blocked wait through the job table's condvar — no
    // waiter backstop needed
    let out = manager.shutdown(true)?;
    Ok(out.counts.completed)
}

// ---------------------------------------------------------------------------
// Legacy single-threaded serving (non-Send backends, e.g. PJRT): v1 only
// ---------------------------------------------------------------------------

fn handle_conn(stream: TcpStream, tx: Sender<FrontendMsg>) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply_line = match Json::parse(&line) {
            Err(e) => error_json(&e.to_string()),
            Ok(req) => {
                let op = req.get("op").and_then(|o| o.as_str()).unwrap_or("generate");
                match op {
                    "shutdown" => {
                        let _ = tx.send(FrontendMsg::Shutdown);
                        Json::obj(vec![("ok", Json::Bool(true))]).dump()
                    }
                    // protocol negotiation: this loop speaks v1 only,
                    // and says so — a v2 client's hello check fails
                    // structurally instead of on a confusing job op
                    "hello" => Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("proto", Json::str(crate::fabric::WIRE_PROTO)),
                        ("version", Json::Num(1.0)),
                        ("role", Json::str("server-v1")),
                    ])
                    .dump(),
                    "stats" => {
                        let (rtx, rrx) = channel();
                        if tx.send(FrontendMsg::Stats { reply: rtx }).is_err() {
                            break;
                        }
                        rrx.recv().unwrap_or_else(|_| "{\"ok\":false}".to_string())
                    }
                    "generate" => {
                        let return_latent =
                            req.get("return_latent").and_then(|b| b.as_bool()).unwrap_or(false);
                        let (rtx, rrx) = channel();
                        if tx
                            .send(FrontendMsg::Generate { spec_body: req, reply: rtx, return_latent })
                            .is_err()
                        {
                            break;
                        }
                        rrx.recv().unwrap_or_else(|_| "{\"ok\":false}".to_string())
                    }
                    // the async job lifecycle needs the shard pool's event
                    // stream; the single-threaded loop has no dispatcher
                    "submit" | "poll" | "wait" | "cancel" => error_json(
                        "protocol v2 job ops need the sharded serving path \
                         (a Send + Sync backend, e.g. --backend native)",
                    ),
                    // see handle_conn_sharded for why unknown ops are errors
                    other => error_json(&format!("unknown op '{other}'")),
                }
            }
        };
        if writer.write_all(reply_line.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
        {
            break;
        }
    }
}

/// Run the serving loop on the current thread (owns the engine) until a
/// shutdown request arrives. Returns total completed requests. Kept for
/// backends that are not `Send` — prefer [`serve_sharded`] elsewhere.
/// Speaks protocol v1 only (v2 job ops are rejected with a structured
/// error naming the sharded path).
pub fn serve(engine: &mut Engine<'_>, cfg: &ServerConfig) -> Result<u64> {
    let listener = TcpListener::bind(&cfg.addr)?;
    listener.set_nonblocking(false)?;
    let (tx, rx): (Sender<FrontendMsg>, Receiver<FrontendMsg>) = channel();
    let ltx = tx.clone();
    let listener = Arc::new(listener);
    let l2 = listener.clone();
    thread::spawn(move || {
        for stream in l2.incoming() {
            match stream {
                Ok(s) => {
                    let txc = ltx.clone();
                    thread::spawn(move || handle_conn(s, txc));
                }
                Err(_) => break,
            }
        }
    });
    eprintln!("speca: serving on {} (single-threaded engine loop, protocol v1)", cfg.addr);

    let (depth, steps, full_flops) = {
        let entry = engine.model().entry();
        (
            entry.config.depth,
            entry.config.serve_steps,
            entry.flops.full_step.get(&1).copied().unwrap_or(0),
        )
    };
    let mut next_id: u64 = 0;
    let mut waiting: std::collections::BTreeMap<u64, (Sender<String>, bool)> =
        std::collections::BTreeMap::new();
    let mut completed: u64 = 0;

    'outer: loop {
        // ingest as much frontend work as available without blocking
        loop {
            let msg = if engine.pending() > 0 {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(std::sync::mpsc::TryRecvError::Empty) => None,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => break 'outer,
                }
            } else {
                // idle: block briefly so shutdown stays responsive
                match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(m) => Some(m),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break 'outer,
                }
            };
            let Some(msg) = msg else { break };
            match msg {
                FrontendMsg::Shutdown => {
                    // drain: finish everything already admitted so
                    // in-flight clients get their completions (the same
                    // contract serve_sharded's drain shutdown honors)
                    while engine.pending() > 0 {
                        engine.tick()?;
                        for c in engine.drain_completions() {
                            completed += 1;
                            if let Some((reply, rl)) = waiting.remove(&c.id) {
                                let line = completion_json(&c, rl, full_flops, steps).dump();
                                let _ = reply.send(line);
                            }
                        }
                    }
                    break 'outer;
                }
                FrontendMsg::Stats { reply } => {
                    let f = &engine.flops;
                    let j = Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("completed", Json::Num(completed as f64)),
                        ("inflight", Json::Num(engine.pending() as f64)),
                        ("shards", Json::Num(1.0)),
                        ("ticks", Json::Num(engine.ticks as f64)),
                        ("alpha", Json::Num(f.acceptance_rate())),
                        ("gamma", Json::Num(f.gamma())),
                        ("total_flops", Json::Num(f.total() as f64)),
                    ]);
                    let _ = reply.send(j.dump());
                }
                FrontendMsg::Generate { spec_body, reply, return_latent } => {
                    if waiting.len() >= cfg.max_queue {
                        let _ = reply.send(error_json("queue full"));
                        continue;
                    }
                    match policy_from_json_with(&spec_body, depth, cfg.default_draft.as_ref()) {
                        Err(e) => {
                            let _ = reply.send(error_json(&format!("{e}")));
                        }
                        Ok(policy) => {
                            let id = next_id;
                            next_id += 1;
                            waiting.insert(id, (reply, return_latent));
                            engine.submit(spec_from_json(&spec_body, id, policy));
                        }
                    }
                }
            }
        }

        if engine.pending() > 0 {
            engine.tick()?;
            for c in engine.drain_completions() {
                completed += 1;
                if let Some((reply, return_latent)) = waiting.remove(&c.id) {
                    let _ =
                        reply.send(completion_json(&c, return_latent, full_flops, steps).dump());
                }
            }
        }
    }
    Ok(completed)
}
