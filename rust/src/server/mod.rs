//! TCP JSON-lines serving front-end.
//!
//! The engine runs on the thread that calls [`serve`]; connection threads
//! only parse/serialize and exchange work through channels (vLLM-router-
//! style separation of front-end and engine loop). This layout is forced
//! by the PJRT backend (its client is `Rc`-based, not `Send`) and merely
//! convenient for the native backend, which is `Send + Sync` — moving the
//! engine loop onto a worker pool is the follow-up the backend seam
//! enables (DESIGN.md §3, ROADMAP).
//!
//! Protocol (one JSON object per line):
//!   → {"op":"generate","cond":3,"seed":7,"policy":"speca","tau0":0.3,
//!      "return_latent":false}
//!   ← {"id":0,"ok":true,"stats":{...},"latent":[...]?}
//!   → {"op":"stats"}            ← engine-level counters
//!   → {"op":"shutdown"}         ← stops the server loop
//!
//! See `client.rs` for the load generator used by the serving benches.

pub mod client;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::state::{Completion, RequestSpec};
use crate::coordinator::Engine;
use crate::util::json::Json;
use crate::workload::policy_from_json;

/// A parsed client request paired with its reply channel.
enum FrontendMsg {
    Generate { spec_body: Json, reply: Sender<String>, return_latent: bool },
    Stats { reply: Sender<String> },
    Shutdown,
}

pub struct ServerConfig {
    pub addr: String,
    /// maximum requests in flight inside the engine
    pub max_queue: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { addr: "127.0.0.1:7433".into(), max_queue: 1024 }
    }
}

fn completion_json(c: &Completion, return_latent: bool, full_flops: u64, steps: usize) -> Json {
    let s = &c.stats;
    let mut pairs = vec![
        ("id", Json::Num(c.id as f64)),
        ("ok", Json::Bool(true)),
        ("policy", Json::str(&c.policy_name)),
        ("cond", Json::Num(c.cond as f64)),
        (
            "stats",
            Json::obj(vec![
                ("full_steps", Json::Num(s.full_steps as f64)),
                ("spec_steps", Json::Num(s.spec_steps as f64)),
                ("skip_steps", Json::Num(s.skip_steps as f64)),
                ("blend_steps", Json::Num(s.blend_steps as f64)),
                ("elided_steps", Json::Num(s.elided_steps as f64)),
                ("rejects", Json::Num(s.rejects as f64)),
                ("latency_ms", Json::Num(s.latency_ms)),
                ("flops", Json::Num(s.flops.total() as f64)),
                ("speedup", Json::Num(s.speedup(full_flops, steps))),
            ]),
        ),
    ];
    if return_latent {
        pairs.push(("latent", Json::arr_f32(&c.latent)));
    }
    Json::obj(pairs)
}

fn handle_conn(stream: TcpStream, tx: Sender<FrontendMsg>) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply_line = match Json::parse(&line) {
            Err(e) => {
                format!("{}", Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(&e.to_string()))]).dump())
            }
            Ok(req) => {
                let op = req.get("op").and_then(|o| o.as_str()).unwrap_or("generate");
                match op {
                    "shutdown" => {
                        let _ = tx.send(FrontendMsg::Shutdown);
                        Json::obj(vec![("ok", Json::Bool(true))]).dump()
                    }
                    "stats" => {
                        let (rtx, rrx) = channel();
                        if tx.send(FrontendMsg::Stats { reply: rtx }).is_err() {
                            break;
                        }
                        rrx.recv().unwrap_or_else(|_| "{\"ok\":false}".to_string())
                    }
                    "generate" => {
                        let return_latent =
                            req.get("return_latent").and_then(|b| b.as_bool()).unwrap_or(false);
                        let (rtx, rrx) = channel();
                        if tx
                            .send(FrontendMsg::Generate { spec_body: req, reply: rtx, return_latent })
                            .is_err()
                        {
                            break;
                        }
                        rrx.recv().unwrap_or_else(|_| "{\"ok\":false}".to_string())
                    }
                    // A request without an "op" key defaults to generate
                    // (matched above); anything else is a protocol error —
                    // falling through to generate would silently burn a
                    // full denoising run on a typo.
                    other => Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        ("error", Json::str(&format!("unknown op '{other}'"))),
                    ])
                    .dump(),
                }
            }
        };
        if writer.write_all(reply_line.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
        {
            break;
        }
    }
    let _ = peer;
}

/// Run the serving loop on the current thread (owns the engine) until a
/// shutdown request arrives. Returns total completed requests.
pub fn serve(engine: &mut Engine<'_>, cfg: &ServerConfig) -> Result<u64> {
    let listener = TcpListener::bind(&cfg.addr)?;
    listener.set_nonblocking(false)?;
    let (tx, rx): (Sender<FrontendMsg>, Receiver<FrontendMsg>) = channel();
    let ltx = tx.clone();
    let listener = Arc::new(listener);
    let l2 = listener.clone();
    thread::spawn(move || {
        for stream in l2.incoming() {
            match stream {
                Ok(s) => {
                    let txc = ltx.clone();
                    thread::spawn(move || handle_conn(s, txc));
                }
                Err(_) => break,
            }
        }
    });
    eprintln!("speca: serving on {}", cfg.addr);

    let entry = engine.model.entry();
    let depth = entry.config.depth;
    let steps = entry.config.serve_steps;
    let full_flops = entry.flops.full_step.get(&1).copied().unwrap_or(0);
    let mut next_id: u64 = 0;
    let mut waiting: std::collections::BTreeMap<u64, (Sender<String>, bool)> =
        std::collections::BTreeMap::new();
    let mut completed: u64 = 0;

    'outer: loop {
        // ingest as much frontend work as available without blocking
        loop {
            let msg = if engine.pending() > 0 {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(std::sync::mpsc::TryRecvError::Empty) => None,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => break 'outer,
                }
            } else {
                // idle: block briefly so shutdown stays responsive
                match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(m) => Some(m),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break 'outer,
                }
            };
            let Some(msg) = msg else { break };
            match msg {
                FrontendMsg::Shutdown => break 'outer,
                FrontendMsg::Stats { reply } => {
                    let f = &engine.flops;
                    let j = Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("completed", Json::Num(completed as f64)),
                        ("inflight", Json::Num(engine.pending() as f64)),
                        ("ticks", Json::Num(engine.ticks as f64)),
                        ("alpha", Json::Num(f.acceptance_rate())),
                        ("gamma", Json::Num(f.gamma())),
                        ("total_flops", Json::Num(f.total() as f64)),
                    ]);
                    let _ = reply.send(j.dump());
                }
                FrontendMsg::Generate { spec_body, reply, return_latent } => {
                    if waiting.len() >= cfg.max_queue {
                        let _ = reply.send(
                            Json::obj(vec![
                                ("ok", Json::Bool(false)),
                                ("error", Json::str("queue full")),
                            ])
                            .dump(),
                        );
                        continue;
                    }
                    match policy_from_json(&spec_body, depth) {
                        Err(e) => {
                            let _ = reply.send(
                                Json::obj(vec![
                                    ("ok", Json::Bool(false)),
                                    ("error", Json::str(&format!("{e}"))),
                                ])
                                .dump(),
                            );
                        }
                        Ok(policy) => {
                            let id = next_id;
                            next_id += 1;
                            let spec = RequestSpec {
                                id,
                                cond: spec_body
                                    .get("cond")
                                    .and_then(|c| c.as_f64())
                                    .unwrap_or(0.0) as i32,
                                seed: spec_body
                                    .get("seed")
                                    .and_then(|s| s.as_u64())
                                    .unwrap_or(id),
                                policy,
                                record_traj: false,
                            };
                            waiting.insert(id, (reply, return_latent));
                            engine.submit(spec);
                        }
                    }
                }
            }
        }

        if engine.pending() > 0 {
            engine.tick()?;
            for c in engine.drain_completions() {
                completed += 1;
                if let Some((reply, return_latent)) = waiting.remove(&c.id) {
                    let _ =
                        reply.send(completion_json(&c, return_latent, full_flops, steps).dump());
                }
            }
        }
    }
    Ok(completed)
}
