//! TCP JSON-lines serving front-end.
//!
//! Two serving modes (DESIGN.md §8):
//!
//! * [`serve_sharded`] — the default for `Send + Sync` backends (native).
//!   An [`EngineShardPool`] runs N engine loops over one shared backend;
//!   connection threads route requests straight to shard queues through a
//!   cloned [`ShardRouter`] (round-robin or least-loaded), and a single
//!   dispatcher thread merges per-shard completion streams back to the
//!   per-request reply channels. There is no central engine funnel.
//! * [`serve`] — the legacy single-threaded loop, kept for backends whose
//!   client is not `Send` (PJRT's is `Rc`-based): the engine runs on the
//!   calling thread and connection threads hand work over one channel.
//!
//! Protocol (one JSON object per line):
//!   → {"op":"generate","cond":3,"seed":7,"policy":"speca","tau0":0.3,
//!      "return_latent":false}
//!   ← {"id":0,"ok":true,"stats":{...},"latent":[...]?}
//!   → {"op":"stats"}            ← engine/pool-level counters
//!   → {"op":"shutdown"}         ← drains in-flight work, then stops
//!
//! See `client.rs` for the load generator used by the serving benches.

pub mod client;

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::Result;

use crate::cache::Draft;
use crate::coordinator::state::{Completion, RequestSpec};
use crate::coordinator::{
    Engine, EngineConfig, EngineShardPool, Policy, PoolConfig, PoolEvent, RouterPolicy,
    ShardRouter,
};
use crate::runtime::ModelBackend;
use crate::util::json::Json;
use crate::workload::policy_from_json_with;

/// A parsed client request paired with its reply channel (legacy loop).
enum FrontendMsg {
    Generate { spec_body: Json, reply: Sender<String>, return_latent: bool },
    Stats { reply: Sender<String> },
    Shutdown,
}

/// Serving front-end configuration.
pub struct ServerConfig {
    /// TCP listen address.
    pub addr: String,
    /// maximum requests in flight inside the engine(s)
    pub max_queue: usize,
    /// engine worker threads for [`serve_sharded`]
    pub shards: usize,
    /// How submissions spread over shards.
    pub router: RouterPolicy,
    /// Default draft strategy for SpeCa requests that name none
    /// (`--draft` on `speca serve`; an explicit per-request draft wins).
    pub default_draft: Option<Draft>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7433".into(),
            max_queue: 1024,
            shards: 1,
            router: RouterPolicy::LeastLoaded,
            default_draft: None,
        }
    }
}

fn completion_json(c: &Completion, return_latent: bool, full_flops: u64, steps: usize) -> Json {
    let s = &c.stats;
    let mut pairs = vec![
        ("id", Json::Num(c.id as f64)),
        ("ok", Json::Bool(true)),
        ("policy", Json::str(&c.policy_name)),
        ("draft", Json::str(&c.draft_name)),
        ("cond", Json::Num(c.cond as f64)),
        (
            "stats",
            Json::obj(vec![
                ("full_steps", Json::Num(s.full_steps as f64)),
                ("spec_steps", Json::Num(s.spec_steps as f64)),
                ("skip_steps", Json::Num(s.skip_steps as f64)),
                ("blend_steps", Json::Num(s.blend_steps as f64)),
                ("elided_steps", Json::Num(s.elided_steps as f64)),
                ("rejects", Json::Num(s.rejects as f64)),
                ("latency_ms", Json::Num(s.latency_ms)),
                ("flops", Json::Num(s.flops.total() as f64)),
                ("speedup", Json::Num(s.speedup(full_flops, steps))),
            ]),
        ),
    ];
    if return_latent {
        pairs.push(("latent", Json::arr_f32(&c.latent)));
    }
    Json::obj(pairs)
}

fn error_json(msg: &str) -> String {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(msg))]).dump()
}

/// Build a [`RequestSpec`] from a protocol request. Shared by both
/// serving modes so the wire defaults (cond 0, seed = request id) cannot
/// drift between them.
fn spec_from_json(req: &Json, id: u64, policy: Policy) -> RequestSpec {
    RequestSpec {
        id,
        cond: req.get("cond").and_then(|c| c.as_f64()).unwrap_or(0.0) as i32,
        seed: req.get("seed").and_then(|s| s.as_u64()).unwrap_or(id),
        policy,
        record_traj: false,
    }
}

// ---------------------------------------------------------------------------
// Sharded serving (native / any Send + Sync backend)
// ---------------------------------------------------------------------------

/// A reply slot for one in-flight request.
struct Waiter {
    reply: Sender<String>,
    return_latent: bool,
}

/// Everything a connection thread needs; cloned per connection.
#[derive(Clone)]
struct ConnCtx {
    router: ShardRouter,
    waiting: Arc<Mutex<HashMap<u64, Waiter>>>,
    accepting: Arc<AtomicBool>,
    shutdown: Sender<()>,
    completed: Arc<AtomicU64>,
    next_id: Arc<AtomicU64>,
    max_queue: usize,
    depth: usize,
    default_draft: Option<Draft>,
}

fn handle_generate(ctx: &ConnCtx, req: &Json) -> String {
    if !ctx.accepting.load(Ordering::SeqCst) {
        return error_json("server is shutting down");
    }
    let return_latent = req.get("return_latent").and_then(|b| b.as_bool()).unwrap_or(false);
    let policy = match policy_from_json_with(req, ctx.depth, ctx.default_draft.as_ref()) {
        Ok(p) => p,
        Err(e) => return error_json(&format!("{e}")),
    };
    let id = ctx.next_id.fetch_add(1, Ordering::SeqCst);
    let spec = spec_from_json(req, id, policy);
    let (rtx, rrx) = channel();
    // admission + reply-slot registration are one critical section: the
    // waiting map is exactly the set of admitted-but-unanswered requests,
    // so checking its size under the lock enforces max_queue precisely
    // even with many connection threads racing (check-then-submit on the
    // router's load gauges would overshoot). Registering before
    // submitting also means the completion can race ahead of this thread
    // once the spec is on a shard queue.
    {
        let mut waiting = ctx.waiting.lock().unwrap();
        if waiting.len() >= ctx.max_queue {
            return error_json("queue full");
        }
        waiting.insert(id, Waiter { reply: rtx, return_latent });
    }
    if let Err(e) = ctx.router.submit(spec) {
        ctx.waiting.lock().unwrap().remove(&id);
        return error_json(&format!("{e}"));
    }
    rrx.recv().unwrap_or_else(|_| error_json("server stopped"))
}

fn handle_stats(ctx: &ConnCtx) -> String {
    let s = ctx.router.stats();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("completed", Json::Num(ctx.completed.load(Ordering::SeqCst) as f64)),
        ("inflight", Json::Num(s.inflight as f64)),
        ("shards", Json::Num(ctx.router.shards() as f64)),
        ("ticks", Json::Num(s.ticks as f64)),
        ("alpha", Json::Num(s.flops.acceptance_rate())),
        ("gamma", Json::Num(s.flops.gamma())),
        ("total_flops", Json::Num(s.flops.total() as f64)),
    ])
    .dump()
}

fn handle_conn_sharded(stream: TcpStream, ctx: ConnCtx) {
    let Ok(mut writer) = stream.try_clone() else { return };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply_line = match Json::parse(&line) {
            Err(e) => error_json(&e.to_string()),
            Ok(req) => {
                let op = req.get("op").and_then(|o| o.as_str()).unwrap_or("generate");
                match op {
                    "shutdown" => {
                        ctx.accepting.store(false, Ordering::SeqCst);
                        let _ = ctx.shutdown.send(());
                        Json::obj(vec![("ok", Json::Bool(true))]).dump()
                    }
                    "stats" => handle_stats(&ctx),
                    "generate" => handle_generate(&ctx, &req),
                    // A request without an "op" key defaults to generate
                    // (matched above); anything else is a protocol error —
                    // falling through to generate would silently burn a
                    // full denoising run on a typo.
                    other => error_json(&format!("unknown op '{other}'")),
                }
            }
        };
        if writer.write_all(reply_line.as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
            break;
        }
    }
}

/// Serve over an [`EngineShardPool`]: N engine loops on worker threads,
/// direct connection→shard routing, merged completion dispatch. Blocks
/// until a shutdown request arrives, drains in-flight work, then joins
/// every thread. Every accepted request gets a reply: its completion
/// under normal drain, or an explicit error if it raced the shutdown
/// edge or its shard died — never a hang. Returns total completed
/// requests.
pub fn serve_sharded(
    model: Arc<dyn ModelBackend + Send + Sync>,
    engine_cfg: EngineConfig,
    cfg: &ServerConfig,
) -> Result<u64> {
    let (depth, steps, full_flops) = {
        let entry = model.entry();
        (
            entry.config.depth,
            entry.config.serve_steps,
            entry.flops.full_step.get(&1).copied().unwrap_or(0),
        )
    };

    let mut pool = EngineShardPool::new(
        model,
        PoolConfig { shards: cfg.shards.max(1), router: cfg.router, engine: engine_cfg },
    );
    let router = pool.router();
    let events = pool.take_event_rx().expect("fresh pool has its event stream");

    let listener = TcpListener::bind(&cfg.addr)?;
    let accepting = Arc::new(AtomicBool::new(true));
    let waiting: Arc<Mutex<HashMap<u64, Waiter>>> = Arc::new(Mutex::new(HashMap::new()));
    let completed = Arc::new(AtomicU64::new(0));
    let (shutdown_tx, shutdown_rx) = channel::<()>();

    // dispatcher: merge per-shard events back to connection threads.
    // Completions answer their waiter; aborts (a shard died on a backend
    // error with this request in flight) answer with an explicit error,
    // so no connection thread ever hangs on a dead shard.
    let dispatcher = {
        let waiting = waiting.clone();
        let completed = completed.clone();
        thread::spawn(move || {
            for ev in events.iter() {
                match ev {
                    PoolEvent::Completed(c) => {
                        completed.fetch_add(1, Ordering::SeqCst);
                        let waiter = waiting.lock().unwrap().remove(&c.id);
                        if let Some(w) = waiter {
                            let line =
                                completion_json(&c, w.return_latent, full_flops, steps).dump();
                            let _ = w.reply.send(line);
                        }
                    }
                    PoolEvent::Aborted { id, error } => {
                        let waiter = waiting.lock().unwrap().remove(&id);
                        if let Some(w) = waiter {
                            let _ = w.reply.send(error_json(&format!("request aborted: {error}")));
                        }
                    }
                }
            }
        })
    };

    // acceptor: one thread per connection, each with its own router clone
    let acceptor = {
        let ctx = ConnCtx {
            router: router.clone(),
            waiting: waiting.clone(),
            accepting: accepting.clone(),
            shutdown: shutdown_tx.clone(),
            completed: completed.clone(),
            next_id: Arc::new(AtomicU64::new(0)),
            max_queue: cfg.max_queue,
            depth,
            default_draft: cfg.default_draft.clone(),
        };
        let accepting = accepting.clone();
        let listener = listener.try_clone()?;
        thread::spawn(move || {
            for stream in listener.incoming() {
                if !accepting.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let conn_ctx = ctx.clone();
                        thread::spawn(move || handle_conn_sharded(s, conn_ctx));
                    }
                    Err(_) => break,
                }
            }
        })
    };
    drop(shutdown_tx);
    eprintln!(
        "speca: serving on {} ({} shard(s), {:?} router)",
        cfg.addr,
        router.shards(),
        cfg.router
    );

    // block until a shutdown op (or the acceptor and every connection die)
    let _ = shutdown_rx.recv();
    accepting.store(false, Ordering::SeqCst);
    // wake the acceptor so it observes the flag and exits
    let _ = TcpStream::connect(&cfg.addr);
    let _ = acceptor.join();

    // drain the shards (in-flight requests finish and reply), then stop
    let drained = pool.shutdown(true);
    let _ = dispatcher.join();
    // backstop: no waiter may hang. Anything still in the map (a request
    // that raced the shutdown edge, or one stranded on a shard that died
    // with an error) gets an explicit error reply instead of silence.
    for (_, w) in waiting.lock().unwrap().drain() {
        let _ = w.reply.send(error_json("server stopped before completion"));
    }
    drained?;
    Ok(completed.load(Ordering::SeqCst))
}

// ---------------------------------------------------------------------------
// Legacy single-threaded serving (non-Send backends, e.g. PJRT)
// ---------------------------------------------------------------------------

fn handle_conn(stream: TcpStream, tx: Sender<FrontendMsg>) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply_line = match Json::parse(&line) {
            Err(e) => error_json(&e.to_string()),
            Ok(req) => {
                let op = req.get("op").and_then(|o| o.as_str()).unwrap_or("generate");
                match op {
                    "shutdown" => {
                        let _ = tx.send(FrontendMsg::Shutdown);
                        Json::obj(vec![("ok", Json::Bool(true))]).dump()
                    }
                    "stats" => {
                        let (rtx, rrx) = channel();
                        if tx.send(FrontendMsg::Stats { reply: rtx }).is_err() {
                            break;
                        }
                        rrx.recv().unwrap_or_else(|_| "{\"ok\":false}".to_string())
                    }
                    "generate" => {
                        let return_latent =
                            req.get("return_latent").and_then(|b| b.as_bool()).unwrap_or(false);
                        let (rtx, rrx) = channel();
                        if tx
                            .send(FrontendMsg::Generate { spec_body: req, reply: rtx, return_latent })
                            .is_err()
                        {
                            break;
                        }
                        rrx.recv().unwrap_or_else(|_| "{\"ok\":false}".to_string())
                    }
                    // see handle_conn_sharded for why unknown ops are errors
                    other => error_json(&format!("unknown op '{other}'")),
                }
            }
        };
        if writer.write_all(reply_line.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
        {
            break;
        }
    }
}

/// Run the serving loop on the current thread (owns the engine) until a
/// shutdown request arrives. Returns total completed requests. Kept for
/// backends that are not `Send` — prefer [`serve_sharded`] elsewhere.
pub fn serve(engine: &mut Engine<'_>, cfg: &ServerConfig) -> Result<u64> {
    let listener = TcpListener::bind(&cfg.addr)?;
    listener.set_nonblocking(false)?;
    let (tx, rx): (Sender<FrontendMsg>, Receiver<FrontendMsg>) = channel();
    let ltx = tx.clone();
    let listener = Arc::new(listener);
    let l2 = listener.clone();
    thread::spawn(move || {
        for stream in l2.incoming() {
            match stream {
                Ok(s) => {
                    let txc = ltx.clone();
                    thread::spawn(move || handle_conn(s, txc));
                }
                Err(_) => break,
            }
        }
    });
    eprintln!("speca: serving on {} (single-threaded engine loop)", cfg.addr);

    let (depth, steps, full_flops) = {
        let entry = engine.model().entry();
        (
            entry.config.depth,
            entry.config.serve_steps,
            entry.flops.full_step.get(&1).copied().unwrap_or(0),
        )
    };
    let mut next_id: u64 = 0;
    let mut waiting: std::collections::BTreeMap<u64, (Sender<String>, bool)> =
        std::collections::BTreeMap::new();
    let mut completed: u64 = 0;

    'outer: loop {
        // ingest as much frontend work as available without blocking
        loop {
            let msg = if engine.pending() > 0 {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(std::sync::mpsc::TryRecvError::Empty) => None,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => break 'outer,
                }
            } else {
                // idle: block briefly so shutdown stays responsive
                match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(m) => Some(m),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break 'outer,
                }
            };
            let Some(msg) = msg else { break };
            match msg {
                FrontendMsg::Shutdown => {
                    // drain: finish everything already admitted so
                    // in-flight clients get their completions (the same
                    // contract serve_sharded's drain shutdown honors)
                    while engine.pending() > 0 {
                        engine.tick()?;
                        for c in engine.drain_completions() {
                            completed += 1;
                            if let Some((reply, rl)) = waiting.remove(&c.id) {
                                let line = completion_json(&c, rl, full_flops, steps).dump();
                                let _ = reply.send(line);
                            }
                        }
                    }
                    break 'outer;
                }
                FrontendMsg::Stats { reply } => {
                    let f = &engine.flops;
                    let j = Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("completed", Json::Num(completed as f64)),
                        ("inflight", Json::Num(engine.pending() as f64)),
                        ("shards", Json::Num(1.0)),
                        ("ticks", Json::Num(engine.ticks as f64)),
                        ("alpha", Json::Num(f.acceptance_rate())),
                        ("gamma", Json::Num(f.gamma())),
                        ("total_flops", Json::Num(f.total() as f64)),
                    ]);
                    let _ = reply.send(j.dump());
                }
                FrontendMsg::Generate { spec_body, reply, return_latent } => {
                    if waiting.len() >= cfg.max_queue {
                        let _ = reply.send(error_json("queue full"));
                        continue;
                    }
                    match policy_from_json_with(&spec_body, depth, cfg.default_draft.as_ref()) {
                        Err(e) => {
                            let _ = reply.send(error_json(&format!("{e}")));
                        }
                        Ok(policy) => {
                            let id = next_id;
                            next_id += 1;
                            waiting.insert(id, (reply, return_latent));
                            engine.submit(spec_from_json(&spec_body, id, policy));
                        }
                    }
                }
            }
        }

        if engine.pending() > 0 {
            engine.tick()?;
            for c in engine.drain_completions() {
                completed += 1;
                if let Some((reply, return_latent)) = waiting.remove(&c.id) {
                    let _ =
                        reply.send(completion_json(&c, return_latent, full_flops, steps).dump());
                }
            }
        }
    }
    Ok(completed)
}
