//! SpeCa: Accelerating Diffusion Transformers with Speculative Feature
//! Caching — Rust + JAX + Pallas reproduction (ACM MM '25,
//! DOI 10.1145/3746027.3755331).
//!
//! Three-layer architecture (see DESIGN.md §1):
//! * L3 (this crate): serving coordinator — router, dynamic batcher, the
//!   SpeCa forecast-then-verify engine, baselines, metrics, TCP server;
//! * L2: the DiT forward pass, behind the `runtime::ModelBackend` trait —
//!   either the pure-Rust native backend (default, zero artifacts) or JAX
//!   models AOT-lowered to HLO text (`python/compile/`, cargo feature
//!   `pjrt`);
//! * L1: Pallas kernels for attention / Taylor drafts / verification
//!   (PJRT artifacts only).
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/` once; the default build does not need Python or XLA at
//! all (DESIGN.md §3).
//!
//! Draft models (the forecasting half of forecast-then-verify) are
//! pluggable: see [`cache::draft`] and DESIGN.md §10.

#![cfg_attr(feature = "portable-simd", feature(portable_simd))]
#![warn(missing_docs)]

pub mod cache;
pub mod config;
pub mod experiments;
pub mod coordinator;
pub mod fabric;
pub mod math;
pub mod metrics;
pub mod runtime;
pub mod sampler;
pub mod server;
pub mod tensor;
pub mod util;
pub mod weights;
pub mod workload;

use std::path::PathBuf;

/// Default artifacts directory: $SPECA_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("SPECA_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
        // allow running from repo root or a subdirectory
        let cands = ["artifacts", "../artifacts"];
        for c in cands {
            let p = PathBuf::from(c);
            if p.join("manifest.json").exists() {
                return p;
            }
        }
        PathBuf::from("artifacts")
    })
}
