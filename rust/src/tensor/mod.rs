//! Minimal host-side tensor: shape + contiguous f32 storage.
//!
//! The Rust coordinator only needs host staging buffers around PJRT
//! executions plus a handful of reductions (norms, stats) for the
//! verification fast path and the metrics pipeline — this is deliberately
//! not a general ndarray.
//!
//! Storage is a [`Storage`] wrapper around `Vec<f32>` rather than a bare
//! vector so a backend can hand out *recyclable* result tensors: a tensor
//! whose storage came from a [`BufferPool`] returns its heap block to the
//! pool when dropped, which is what lets the native backend's steady-state
//! forward pass run without touching the allocator (DESIGN.md §11).

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Owned flat `f32` storage for a [`Tensor`]: a `Vec<f32>` plus an
/// optional return-to-pool hook. In every read/write context it behaves
/// like the vector it wraps (it derefs to `Vec<f32>`); the hook only
/// matters at drop time, when pooled storage gives its allocation back to
/// the [`BufferPool`] it was checked out of instead of freeing it.
pub struct Storage {
    vec: Vec<f32>,
    home: Option<BufferPool>,
}

impl Storage {
    /// Take the underlying vector out (the storage will not return
    /// anything to its pool afterwards).
    pub fn into_vec(mut self) -> Vec<f32> {
        std::mem::take(&mut self.vec)
    }
}

impl Deref for Storage {
    type Target = Vec<f32>;
    fn deref(&self) -> &Vec<f32> {
        &self.vec
    }
}

impl DerefMut for Storage {
    fn deref_mut(&mut self) -> &mut Vec<f32> {
        &mut self.vec
    }
}

impl Clone for Storage {
    /// Clones detach from the pool: the copy is plain heap storage.
    fn clone(&self) -> Storage {
        Storage { vec: self.vec.clone(), home: None }
    }
}

impl fmt::Debug for Storage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Storage(len={}, pooled={})", self.vec.len(), self.home.is_some())
    }
}

impl PartialEq for Storage {
    fn eq(&self, other: &Storage) -> bool {
        self.vec == other.vec
    }
}

impl PartialEq<Vec<f32>> for Storage {
    fn eq(&self, other: &Vec<f32>) -> bool {
        &self.vec == other
    }
}

impl From<Vec<f32>> for Storage {
    fn from(vec: Vec<f32>) -> Storage {
        Storage { vec, home: None }
    }
}

impl Drop for Storage {
    fn drop(&mut self) {
        if let Some(pool) = self.home.take() {
            pool.put(std::mem::take(&mut self.vec));
        }
    }
}

/// Cap on buffers retained per pool: enough for every (entry point ×
/// bucket) result shape of a backend plus transient concurrency; beyond
/// it, returned buffers are simply freed.
const POOL_CAP: usize = 64;

/// A recycling pool of `Vec<f32>` heap blocks shared by reference
/// (cloning the pool clones a handle to the same buffers). `take(len)`
/// checks out the best-fitting retained buffer — or allocates one when
/// nothing fits, which after warmup never happens — and the returned
/// [`Storage`] checks itself back in on drop. Thread-safe, so one
/// backend's pool serves every shard worker.
#[derive(Clone, Default)]
pub struct BufferPool {
    inner: Arc<Mutex<Vec<Vec<f32>>>>,
    /// `take` calls that had to allocate because nothing retained fit —
    /// flat after warmup; growth under load is a recycling regression
    /// (asserted by the sharded allocation probe in `tests/shard_pool.rs`).
    misses: Arc<AtomicUsize>,
}

impl BufferPool {
    /// An empty pool.
    pub fn new() -> BufferPool {
        BufferPool::default()
    }

    /// Check out storage of exactly `len` elements, reusing the smallest
    /// retained buffer whose capacity covers it (no allocation on a
    /// hit). **Contents are unspecified** — zeroed when freshly
    /// allocated, stale values from the previous checkout when recycled
    /// — because every consumer overwrites its result buffers in full,
    /// and re-zeroing the whole activation volume per dispatch would
    /// reintroduce exactly the memset this pool exists to avoid.
    pub fn take(&self, len: usize) -> Storage {
        let mut g = self.inner.lock().unwrap();
        let mut best: Option<(usize, usize)> = None; // (index, capacity)
        for (i, b) in g.iter().enumerate() {
            let c = b.capacity();
            let better = match best {
                None => true,
                Some((_, bc)) => c < bc,
            };
            if c >= len && better {
                best = Some((i, c));
            }
        }
        let mut vec = match best {
            Some((i, _)) => g.swap_remove(i),
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(len)
            }
        };
        drop(g);
        // only the length change is initialized (zeros); surviving
        // elements keep their old values — never uninitialized memory
        if vec.len() > len {
            vec.truncate(len);
        } else {
            vec.resize(len, 0.0);
        }
        Storage { vec, home: Some(self.clone()) }
    }

    /// Ensure a retained buffer of capacity ≥ `len` exists (backend
    /// warmup: pre-size every result shape so the first real call is
    /// already allocation-free).
    pub fn prewarm(&self, len: usize) {
        let mut g = self.inner.lock().unwrap();
        if !g.iter().any(|b| b.capacity() >= len) && g.len() < POOL_CAP {
            g.push(Vec::with_capacity(len));
        }
    }

    /// Buffers currently retained (checked-out storage excluded).
    pub fn idle(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// `take` calls that allocated fresh storage (pool misses) over this
    /// pool's lifetime. Steady state after warmup holds this constant.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    fn put(&self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        if g.len() < POOL_CAP {
            g.push(buf);
        }
    }
}

/// Shape + contiguous row-major `f32` storage.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    /// Dimension sizes, outermost first (empty = scalar).
    pub shape: Vec<usize>,
    /// Flat element storage (`shape.iter().product()` values). Derefs to
    /// `Vec<f32>`; may be pool-backed (see [`Storage`]).
    pub data: Storage,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}(len={})", self.shape, self.data.len())
    }
}

impl Tensor {
    /// Tensor from a shape and matching flat data (panics on mismatch).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        Tensor::from_storage(shape, data.into())
    }

    /// Tensor over existing [`Storage`] — the pool-recycling path
    /// backends hand results back through (panics on mismatch).
    pub fn from_storage(shape: Vec<usize>, data: Storage) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} != data len {}",
            data.len()
        );
        Tensor { shape, data }
    }

    /// Zero-filled tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0f32; n].into() }
    }

    /// Rank-0 tensor holding one value.
    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v].into() }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of axes.
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Size of one index step along axis 0 (row size).
    pub fn row_len(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape[1..].iter().product()
        }
    }

    /// Borrow the i-th slice along axis 0.
    pub fn row(&self, i: usize) -> &[f32] {
        let r = self.row_len();
        &self.data[i * r..(i + 1) * r]
    }

    /// Mutably borrow the i-th slice along axis 0.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let r = self.row_len();
        &mut self.data[i * r..(i + 1) * r]
    }

    /// Owned copy of the i-th slice along axis 0 (shape drops the axis).
    pub fn index0(&self, i: usize) -> Tensor {
        assert!(i < self.shape[0], "index {i} out of {}", self.shape[0]);
        Tensor::new(self.shape[1..].to_vec(), self.row(i).to_vec())
    }

    /// Stack equal-shaped tensors along a new axis 0.
    pub fn stack(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let inner = &parts[0].shape;
        let mut data = Vec::with_capacity(parts.len() * parts[0].len());
        for p in parts {
            assert_eq!(&p.shape, inner, "stack shape mismatch");
            data.extend_from_slice(&p.data);
        }
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(inner);
        Tensor::new(shape, data)
    }

    /// Same data under a new shape (panics if sizes differ).
    pub fn reshape(mut self, shape: Vec<usize>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    // ---- reductions used on the hot path ---------------------------------

    /// Euclidean norm of a slice (f64 accumulation).
    pub fn l2_norm(v: &[f32]) -> f64 {
        v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
    }

    /// Euclidean distance between two equal-length slices.
    pub fn l2_dist(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        a.iter()
            .zip(b)
            .map(|(x, y)| {
                let d = (*x - *y) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }

    /// Arithmetic mean (0 for an empty slice).
    pub fn mean(v: &[f32]) -> f64 {
        if v.is_empty() {
            return 0.0;
        }
        v.iter().map(|x| *x as f64).sum::<f64>() / v.len() as f64
    }

    /// Mean squared error between two equal-length slices.
    pub fn mse(a: &[f32], b: &[f32]) -> f64 {
        if a.is_empty() {
            return 0.0;
        }
        a.iter()
            .zip(b)
            .map(|(x, y)| {
                let d = (*x - *y) as f64;
                d * d
            })
            .sum::<f64>()
            / a.len() as f64
    }

    /// axpy: y ← y + alpha·x
    pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
    }

    /// y ← alpha·y + beta·x
    pub fn scale_add(alpha: f32, y: &mut [f32], beta: f32, x: &[f32]) {
        debug_assert_eq!(x.len(), y.len());
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi = alpha * *yi + beta * xi;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_index() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(1), &[3., 4.]);
        let r = t.index0(2);
        assert_eq!(r.shape, vec![2]);
        assert_eq!(r.data, vec![5., 6.]);
    }

    #[test]
    fn stack_roundtrip() {
        let a = Tensor::new(vec![2], vec![1., 2.]);
        let b = Tensor::new(vec![2], vec![3., 4.]);
        let s = Tensor::stack(&[&a, &b]);
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.index0(1).data, vec![3., 4.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn norms() {
        assert!((Tensor::l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert!((Tensor::l2_dist(&[1.0, 1.0], &[4.0, 5.0]) - 5.0).abs() < 1e-12);
        assert!((Tensor::mse(&[1.0, 2.0], &[2.0, 4.0]) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn pool_recycles_storage() {
        let pool = BufferPool::new();
        let s = pool.take(8);
        assert_eq!(s.len(), 8);
        assert_eq!(pool.misses(), 1);
        // fresh allocations are zeroed; *recycled* contents are
        // unspecified (consumers overwrite in full)
        assert!(s.iter().all(|v| *v == 0.0));
        let cap = s.capacity();
        drop(s); // returns to the pool
        assert_eq!(pool.idle(), 1);
        let t = pool.take(4); // best fit: reuses the returned buffer
        assert_eq!(pool.idle(), 0);
        assert_eq!(pool.misses(), 1, "recycled checkout is not a miss");
        assert_eq!(t.len(), 4);
        assert!(t.capacity() >= cap.min(8));
        let tensor = Tensor::from_storage(vec![2, 2], t);
        drop(tensor); // pooled storage returns through the tensor drop too
        assert_eq!(pool.idle(), 1);
    }

    #[test]
    fn pool_prewarm_sizes_buffers() {
        let pool = BufferPool::new();
        pool.prewarm(16);
        pool.prewarm(8); // covered by the 16-capacity buffer: no new entry
        assert_eq!(pool.idle(), 1);
        pool.prewarm(32);
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    fn storage_clone_detaches_from_pool() {
        let pool = BufferPool::new();
        let s = pool.take(3);
        let c = s.clone();
        drop(s);
        assert_eq!(pool.idle(), 1);
        drop(c); // plain storage: freed, not pooled
        assert_eq!(pool.idle(), 1);
        let v: Storage = vec![1.0f32, 2.0].into();
        assert_eq!(v, vec![1.0, 2.0]);
        assert_eq!(v.clone().into_vec(), vec![1.0, 2.0]);
    }

    #[test]
    fn blas_like() {
        let mut y = vec![1.0, 2.0];
        Tensor::axpy(2.0, &[10.0, 20.0], &mut y);
        assert_eq!(y, vec![21.0, 42.0]);
        Tensor::scale_add(0.5, &mut y, 1.0, &[1.0, 0.0]);
        assert_eq!(y, vec![11.5, 21.0]);
    }
}
