//! Minimal host-side tensor: shape + contiguous f32 storage.
//!
//! The Rust coordinator only needs host staging buffers around PJRT
//! executions plus a handful of reductions (norms, stats) for the
//! verification fast path and the metrics pipeline — this is deliberately
//! not a general ndarray.

use std::fmt;

/// Shape + contiguous row-major `f32` storage.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    /// Dimension sizes, outermost first (empty = scalar).
    pub shape: Vec<usize>,
    /// Flat element storage (`shape.iter().product()` values).
    pub data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}(len={})", self.shape, self.data.len())
    }
}

impl Tensor {
    /// Tensor from a shape and matching flat data (panics on mismatch).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} != data len {}",
            data.len()
        );
        Tensor { shape, data }
    }

    /// Zero-filled tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Rank-0 tensor holding one value.
    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of axes.
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Size of one index step along axis 0 (row size).
    pub fn row_len(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape[1..].iter().product()
        }
    }

    /// Borrow the i-th slice along axis 0.
    pub fn row(&self, i: usize) -> &[f32] {
        let r = self.row_len();
        &self.data[i * r..(i + 1) * r]
    }

    /// Mutably borrow the i-th slice along axis 0.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let r = self.row_len();
        &mut self.data[i * r..(i + 1) * r]
    }

    /// Owned copy of the i-th slice along axis 0 (shape drops the axis).
    pub fn index0(&self, i: usize) -> Tensor {
        assert!(i < self.shape[0], "index {i} out of {}", self.shape[0]);
        Tensor::new(self.shape[1..].to_vec(), self.row(i).to_vec())
    }

    /// Stack equal-shaped tensors along a new axis 0.
    pub fn stack(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let inner = &parts[0].shape;
        let mut data = Vec::with_capacity(parts.len() * parts[0].len());
        for p in parts {
            assert_eq!(&p.shape, inner, "stack shape mismatch");
            data.extend_from_slice(&p.data);
        }
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(inner);
        Tensor::new(shape, data)
    }

    /// Same data under a new shape (panics if sizes differ).
    pub fn reshape(mut self, shape: Vec<usize>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    // ---- reductions used on the hot path ---------------------------------

    /// Euclidean norm of a slice (f64 accumulation).
    pub fn l2_norm(v: &[f32]) -> f64 {
        v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
    }

    /// Euclidean distance between two equal-length slices.
    pub fn l2_dist(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        a.iter()
            .zip(b)
            .map(|(x, y)| {
                let d = (*x - *y) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }

    /// Arithmetic mean (0 for an empty slice).
    pub fn mean(v: &[f32]) -> f64 {
        if v.is_empty() {
            return 0.0;
        }
        v.iter().map(|x| *x as f64).sum::<f64>() / v.len() as f64
    }

    /// Mean squared error between two equal-length slices.
    pub fn mse(a: &[f32], b: &[f32]) -> f64 {
        if a.is_empty() {
            return 0.0;
        }
        a.iter()
            .zip(b)
            .map(|(x, y)| {
                let d = (*x - *y) as f64;
                d * d
            })
            .sum::<f64>()
            / a.len() as f64
    }

    /// axpy: y ← y + alpha·x
    pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
    }

    /// y ← alpha·y + beta·x
    pub fn scale_add(alpha: f32, y: &mut [f32], beta: f32, x: &[f32]) {
        debug_assert_eq!(x.len(), y.len());
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi = alpha * *yi + beta * xi;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_index() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(1), &[3., 4.]);
        let r = t.index0(2);
        assert_eq!(r.shape, vec![2]);
        assert_eq!(r.data, vec![5., 6.]);
    }

    #[test]
    fn stack_roundtrip() {
        let a = Tensor::new(vec![2], vec![1., 2.]);
        let b = Tensor::new(vec![2], vec![3., 4.]);
        let s = Tensor::stack(&[&a, &b]);
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.index0(1).data, vec![3., 4.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn norms() {
        assert!((Tensor::l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert!((Tensor::l2_dist(&[1.0, 1.0], &[4.0, 5.0]) - 5.0).abs() < 1e-12);
        assert!((Tensor::mse(&[1.0, 2.0], &[2.0, 4.0]) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn blas_like() {
        let mut y = vec![1.0, 2.0];
        Tensor::axpy(2.0, &[10.0, 20.0], &mut y);
        assert_eq!(y, vec![21.0, 42.0]);
        Tensor::scale_add(0.5, &mut y, 1.0, &[1.0, 0.0]);
        assert_eq!(y, vec![11.5, 21.0]);
    }
}
