//! Workload generation: policy parsing (shared by CLI, server protocol and
//! the bench harness) and request stream generators (closed-loop batches
//! and open-loop Poisson arrivals).

pub mod scripted;

use anyhow::{bail, Result};

use crate::cache::{Draft, DraftRegistry};
use crate::coordinator::job::JobMeta;
use crate::coordinator::policy::{ErrorMetric, Policy, SpeCaConfig};
use crate::coordinator::state::RequestSpec;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Parse a policy description string:
///   `full`
///   `steps:keep=10`
///   `fora:N=6`
///   `teacache:l=0.8`
///   `toca:N=8,R=0.9` / `duca:N=8,R=0.9`
///   `taylorseer:N=5,O=2`
///   `speca:N=5,O=2,tau0=0.3,beta=0.05,layer=7,draft=taylor,metric=l2`
///   `speca:N=5,adaptive=0.5` (sample-adaptive error budget; see
///   [`AdaptiveController`](crate::coordinator::adaptive::AdaptiveController))
///   `speca:N=8,lookahead=4` (lookahead-k speculation: one verify may
///   ratify a run of up to k steps; DESIGN.md §16)
/// Unspecified keys take the defaults above (`layer` defaults to depth−1).
/// Malformed numeric values are an error naming the key (a typo like
/// `tau0=abc` must not silently run with the default). `draft=<name>`
/// resolves through [`DraftRegistry::global`] (case-insensitive; unknown
/// names error with the list of registered strategies).
pub fn parse_policy(desc: &str, depth: usize) -> Result<Policy> {
    let (name, rest) = match desc.split_once(':') {
        Some((n, r)) => (n, r),
        None => (desc, ""),
    };
    let mut kv = std::collections::BTreeMap::new();
    for part in rest.split(',').filter(|p| !p.is_empty()) {
        let Some((k, v)) = part.split_once('=') else {
            bail!("policy '{desc}': bad key=value '{part}'");
        };
        kv.insert(k.trim().to_string(), v.trim().to_string());
    }
    let get_f = |k: &str, d: f64| -> Result<f64> {
        match kv.get(k) {
            None => Ok(d),
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!("policy '{desc}': key '{k}' expects a number, got '{v}'")
            }),
        }
    };
    let get_u = |k: &str, d: usize| -> Result<usize> {
        match kv.get(k) {
            None => Ok(d),
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!(
                    "policy '{desc}': key '{k}' expects a non-negative integer, got '{v}'"
                )
            }),
        }
    };

    Ok(match name {
        "full" => Policy::Full,
        "steps" | "step-reduction" => Policy::StepReduction { keep: get_u("keep", 25)? },
        "fora" => Policy::Fora { interval: get_u("N", 6)? },
        "teacache" => Policy::TeaCache { threshold: get_f("l", 0.8)? },
        "toca" | "toca-sim" => {
            Policy::TocaSim { interval: get_u("N", 8)?, reuse_frac: get_f("R", 0.9)? }
        }
        "duca" | "duca-sim" => {
            Policy::DucaSim { interval: get_u("N", 8)?, reuse_frac: get_f("R", 0.9)? }
        }
        "taylorseer" | "taylor" => {
            Policy::TaylorSeer { interval: get_u("N", 5)?, order: get_u("O", 2)? }
        }
        "speca" => {
            let mut c = SpeCaConfig::default_for_depth(depth);
            c.interval = get_u("N", c.interval)?;
            c.order = get_u("O", c.order)?;
            c.tau0 = get_f("tau0", c.tau0)?;
            c.beta = get_f("beta", c.beta)?;
            c.verify_layer = get_u("layer", c.verify_layer)?;
            if let Some(d) = kv.get("draft") {
                c.draft = DraftRegistry::global().resolve(d)?;
            }
            if let Some(m) = kv.get("metric") {
                c.metric = ErrorMetric::parse(m)
                    .ok_or_else(|| anyhow::anyhow!("unknown metric '{m}'"))?;
            }
            if kv.contains_key("adaptive") {
                let b = get_f("adaptive", 0.0)?;
                if !(b >= 0.0) {
                    bail!("policy '{desc}': key 'adaptive' expects a budget >= 0, got '{b}'");
                }
                c.adaptive = Some(b);
            }
            if kv.contains_key("lookahead") {
                let k = get_u("lookahead", 1)?;
                if k < 1 {
                    bail!("policy '{desc}': key 'lookahead' expects an integer >= 1, got '{k}'");
                }
                c.lookahead = k;
            }
            Policy::SpeCa(c)
        }
        _ => bail!("unknown policy '{name}'"),
    })
}

/// Parse a policy from the server protocol's JSON request body.
pub fn policy_from_json(j: &Json, depth: usize) -> Result<Policy> {
    policy_from_json_with(j, depth, None)
}

/// [`policy_from_json`] with a server-side default draft strategy: when
/// the request names no draft (neither a `draft` JSON field nor a
/// `draft=` key inside the policy string) and the policy is SpeCa, the
/// default is applied — how `speca serve --draft <name>` works.
///
/// Unlike the other structured overrides (which are ignored when the
/// policy string already carries a `key=value` section), a `draft` JSON
/// field is honored for *any* policy string without a `draft=` key, so
/// `{"policy":"speca:N=5","draft":"reuse"}` runs the reuse draft rather
/// than silently dropping the field.
pub fn policy_from_json_with(
    j: &Json,
    depth: usize,
    default_draft: Option<&Draft>,
) -> Result<Policy> {
    let desc = j.get("policy").and_then(|p| p.as_str()).unwrap_or("speca");
    // allow structured overrides: {"policy":"speca","tau0":0.5,...}
    let mut s = desc.to_string();
    let keys =
        ["N", "O", "keep", "l", "R", "tau0", "beta", "layer", "metric", "adaptive", "lookahead"];
    let mut parts = Vec::new();
    for k in keys {
        if let Some(v) = j.get(k) {
            let vs = match v {
                Json::Str(x) => x.clone(),
                Json::Num(x) => format!("{x}"),
                _ => continue,
            };
            parts.push(format!("{k}={vs}"));
        }
    }
    if !parts.is_empty() && !s.contains(':') {
        s = format!("{s}:{}", parts.join(","));
    }
    let mut policy = parse_policy(&s, depth)?;
    // a `draft=` key inside the policy string wins; otherwise the JSON
    // field, otherwise the server default
    if !desc.contains("draft=") {
        match j.get("draft") {
            Some(v) => {
                let Some(name) = v.as_str() else {
                    bail!("request 'draft' field must be a strategy name string");
                };
                apply_draft(&mut policy, &DraftRegistry::global().resolve(name)?);
            }
            None => {
                if let Some(d) = default_draft {
                    apply_draft(&mut policy, d);
                }
            }
        }
    }
    Ok(policy)
}

/// Override the draft strategy of a SpeCa policy in place (no-op for
/// policies without a pluggable draft). Shared by `--draft` handling on
/// generate, serve and the bench runners.
pub fn apply_draft(policy: &mut Policy, draft: &Draft) {
    if let Policy::SpeCa(c) = policy {
        c.draft = draft.clone();
    }
}

/// Override the lookahead cap of a SpeCa policy in place (no-op for
/// other policies; clamped to ≥ 1). Shared by `--lookahead` handling on
/// generate and the bench runners — see DESIGN.md §16.
pub fn apply_lookahead(policy: &mut Policy, k: usize) {
    if let Policy::SpeCa(c) = policy {
        c.lookahead = k.max(1);
    }
}

/// Closed-loop batch: n requests, conditions round-robin over num_classes,
/// deterministic seeds derived from `seed`.
pub fn batch_requests(
    n: usize,
    num_classes: usize,
    policy: &Policy,
    seed: u64,
    record_traj: bool,
) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| RequestSpec {
            id: i as u64,
            cond: (i % num_classes) as i32,
            seed: rng.next_u64(),
            policy: policy.clone(),
            record_traj,
            meta: JobMeta::default(),
        })
        .collect()
}

/// Open-loop Poisson arrival times (seconds) for `n` requests at `rate` rps.
pub fn poisson_arrivals(n: usize, rate: f64, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(rate);
            t
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_policies() {
        for desc in [
            "full",
            "steps:keep=10",
            "fora:N=7",
            "teacache:l=1.2",
            "toca:N=8,R=0.9",
            "duca:N=12,R=0.8",
            "taylorseer:N=5,O=2",
            "speca:N=5,O=2,tau0=0.5,beta=0.08,layer=3,draft=adams,metric=cos",
        ] {
            let p = parse_policy(desc, 8).unwrap_or_else(|e| panic!("{desc}: {e}"));
            assert!(!p.name().is_empty());
        }
    }

    #[test]
    fn speca_fields_land() {
        let p = parse_policy("speca:tau0=0.7,beta=0.1,N=9", 8).unwrap();
        let Policy::SpeCa(c) = p else { panic!() };
        assert!((c.tau0 - 0.7).abs() < 1e-12);
        assert!((c.beta - 0.1).abs() < 1e-12);
        assert_eq!(c.interval, 9);
        assert_eq!(c.verify_layer, 7);
        assert_eq!(c.adaptive, None, "adaptive allocation is opt-in");
        assert_eq!(c.lookahead, 1, "lookahead-k speculation is opt-in");
    }

    #[test]
    fn adaptive_key_parses_and_validates() {
        let Policy::SpeCa(c) = parse_policy("speca:N=5,adaptive=0.5", 8).unwrap() else {
            panic!()
        };
        assert_eq!(c.adaptive, Some(0.5));
        // 0 is legal (fully dense from the first step), negatives are not
        let Policy::SpeCa(c) = parse_policy("speca:adaptive=0", 8).unwrap() else { panic!() };
        assert_eq!(c.adaptive, Some(0.0));
        let err = parse_policy("speca:adaptive=-1", 8).unwrap_err().to_string();
        assert!(err.contains("adaptive"), "{err}");
        assert!(parse_policy("speca:adaptive=lots", 8).is_err());
        // and through the JSON structured-override surface
        let j = Json::parse(r#"{"policy":"speca","adaptive":0.25}"#).unwrap();
        let Policy::SpeCa(c) = policy_from_json(&j, 8).unwrap() else { panic!() };
        assert_eq!(c.adaptive, Some(0.25));
    }

    #[test]
    fn lookahead_key_parses_and_validates() {
        let Policy::SpeCa(c) = parse_policy("speca:N=8,lookahead=4", 8).unwrap() else {
            panic!()
        };
        assert_eq!(c.lookahead, 4);
        // k=1 is the explicit spelling of the default; 0 and garbage are not
        let Policy::SpeCa(c) = parse_policy("speca:lookahead=1", 8).unwrap() else { panic!() };
        assert_eq!(c.lookahead, 1);
        let err = parse_policy("speca:lookahead=0", 8).unwrap_err().to_string();
        assert!(err.contains("lookahead"), "{err}");
        assert!(parse_policy("speca:lookahead=many", 8).is_err());
        // describe() is the parse inverse: emitted only when non-default
        let p = parse_policy("speca:N=8,lookahead=4", 8).unwrap();
        assert!(p.describe().contains("lookahead=4"), "{}", p.describe());
        let rt = parse_policy(&p.describe(), 8).unwrap();
        assert_eq!(rt.describe(), p.describe());
        let p1 = parse_policy("speca:lookahead=1", 8).unwrap();
        assert!(!p1.describe().contains("lookahead"), "{}", p1.describe());
        // and through the JSON structured-override surface
        let j = Json::parse(r#"{"policy":"speca","lookahead":3}"#).unwrap();
        let Policy::SpeCa(c) = policy_from_json(&j, 8).unwrap() else { panic!() };
        assert_eq!(c.lookahead, 3);
        // apply_lookahead is the CLI override hook and clamps to >= 1
        let mut p = parse_policy("speca", 8).unwrap();
        apply_lookahead(&mut p, 5);
        let Policy::SpeCa(c) = &p else { panic!() };
        assert_eq!(c.lookahead, 5);
        apply_lookahead(&mut p, 0);
        let Policy::SpeCa(c) = &p else { panic!() };
        assert_eq!(c.lookahead, 1);
    }

    #[test]
    fn malformed_numeric_values_error_naming_the_key() {
        // a typo must not silently run with the default value
        for (desc, key) in [
            ("speca:tau0=abc", "tau0"),
            ("speca:N=x", "N"),
            ("speca:beta=", "beta"),
            ("speca:layer=2.5", "layer"),
            ("fora:N=six", "N"),
            ("steps:keep=-3", "keep"),
            ("teacache:l=high", "l"),
            ("toca:R=90%", "R"),
            ("taylorseer:O=two", "O"),
        ] {
            let err = parse_policy(desc, 8).unwrap_err().to_string();
            assert!(err.contains(&format!("'{key}'")), "{desc}: {err}");
            assert!(err.contains(desc.split(':').next().unwrap()), "{desc}: {err}");
        }
        // well-formed values still parse
        assert!(parse_policy("speca:tau0=0.3", 8).is_ok());
    }

    #[test]
    fn rejects_unknown() {
        assert!(parse_policy("warp-drive", 8).is_err());
        let err = parse_policy("speca:draft=magic", 8).unwrap_err().to_string();
        // the registry error names every valid strategy
        for name in DraftRegistry::global().names() {
            assert!(err.contains(name), "'{name}' missing from: {err}");
        }
        assert!(parse_policy("speca:metric=magic", 8).is_err());
    }

    #[test]
    fn draft_names_resolve_case_insensitively() {
        for (desc, expect) in [
            ("speca:draft=Taylor", "taylor"),
            ("speca:draft=ADAMS", "adams-bashforth"),
            ("speca:draft=richardson", "richardson"),
            ("speca:draft=Learned-Linear", "learned-linear"),
            ("speca:draft=specdiff", "learned-linear"),
        ] {
            let p = parse_policy(desc, 8).unwrap_or_else(|e| panic!("{desc}: {e}"));
            assert_eq!(p.draft_name(), expect, "{desc}");
        }
    }

    #[test]
    fn server_default_draft_applies_only_when_unspecified() {
        let default = Draft::named("richardson").unwrap();
        let j = Json::parse(r#"{"policy":"speca","tau0":0.9}"#).unwrap();
        let p = policy_from_json_with(&j, 8, Some(&default)).unwrap();
        assert_eq!(p.draft_name(), "richardson");
        // explicit JSON field wins over the server default
        let j = Json::parse(r#"{"policy":"speca","draft":"reuse"}"#).unwrap();
        let p = policy_from_json_with(&j, 8, Some(&default)).unwrap();
        assert_eq!(p.draft_name(), "reuse");
        // explicit key inside the policy string wins too
        let j = Json::parse(r#"{"policy":"speca:N=5,draft=taylor"}"#).unwrap();
        let p = policy_from_json_with(&j, 8, Some(&default)).unwrap();
        assert_eq!(p.draft_name(), "taylor");
        // a JSON draft field applies even to a compound policy string
        // (where the other structured overrides are ignored) — and it
        // beats the server default
        let j = Json::parse(r#"{"policy":"speca:N=5","draft":"reuse"}"#).unwrap();
        let p = policy_from_json_with(&j, 8, Some(&default)).unwrap();
        assert_eq!(p.draft_name(), "reuse");
        // malformed / unknown JSON draft fields error instead of silently
        // falling back
        let j = Json::parse(r#"{"policy":"speca","draft":7}"#).unwrap();
        assert!(policy_from_json_with(&j, 8, Some(&default)).is_err());
        let j = Json::parse(r#"{"policy":"speca","draft":"magic"}"#).unwrap();
        assert!(policy_from_json_with(&j, 8, None).is_err());
        // non-draft policies are untouched
        let j = Json::parse(r#"{"policy":"fora"}"#).unwrap();
        let p = policy_from_json_with(&j, 8, Some(&default)).unwrap();
        assert_eq!(p.draft_name(), "-");
    }

    #[test]
    fn json_policy_overrides() {
        let j = Json::parse(r#"{"policy":"speca","tau0":0.9,"N":7}"#).unwrap();
        let Policy::SpeCa(c) = policy_from_json(&j, 8).unwrap() else { panic!() };
        assert!((c.tau0 - 0.9).abs() < 1e-12);
        assert_eq!(c.interval, 7);
    }

    #[test]
    fn batch_round_robin() {
        let reqs = batch_requests(10, 4, &Policy::Full, 1, false);
        assert_eq!(reqs.len(), 10);
        assert_eq!(reqs[5].cond, 1);
        // distinct seeds
        assert_ne!(reqs[0].seed, reqs[1].seed);
    }

    #[test]
    fn batch_requests_ids_seeds_and_meta() {
        let reqs = batch_requests(16, 4, &Policy::Full, 7, false);
        // ids are sequential from 0 (the engine/pool contract)
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.cond, (i % 4) as i32);
            // default job meta: old fire-and-forget semantics
            assert_eq!(r.meta.priority, crate::coordinator::Priority::Normal);
            assert!(r.meta.deadline.is_none());
            assert!(!r.meta.cancel.is_cancelled());
        }
        // seeds are pairwise distinct and deterministic in the batch seed
        let mut seeds: Vec<u64> = reqs.iter().map(|r| r.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 16, "seeds must be pairwise distinct");
        let again = batch_requests(16, 4, &Policy::Full, 7, false);
        assert!(reqs.iter().zip(&again).all(|(a, b)| a.seed == b.seed));
        let other = batch_requests(16, 4, &Policy::Full, 8, false);
        assert!(reqs.iter().zip(&other).any(|(a, b)| a.seed != b.seed));
    }

    #[test]
    fn poisson_monotone() {
        let arr = poisson_arrivals(100, 50.0, 3);
        assert!(arr.windows(2).all(|w| w[0] < w[1]));
        // mean gap ≈ 1/rate
        let mean_gap = arr.last().unwrap() / 100.0;
        assert!((mean_gap - 0.02).abs() < 0.01, "{mean_gap}");
    }

    #[test]
    fn poisson_deterministic_under_fixed_seed() {
        let a = poisson_arrivals(256, 20.0, 42);
        let b = poisson_arrivals(256, 20.0, 42);
        assert_eq!(a, b, "same seed must reproduce the arrival process");
        let c = poisson_arrivals(256, 20.0, 43);
        assert_ne!(a, c, "different seeds must give different arrivals");
        // prefix property: a shorter stream is a prefix of a longer one
        let short = poisson_arrivals(64, 20.0, 42);
        assert_eq!(&a[..64], &short[..]);
    }

    #[test]
    fn poisson_empirical_rate_within_tolerance() {
        for rate in [5.0, 50.0, 500.0] {
            let n = 4000;
            let arr = poisson_arrivals(n, rate, 9);
            assert!(arr.windows(2).all(|w| w[0] < w[1]), "timestamps must be monotone");
            assert!(arr[0] > 0.0);
            let empirical = n as f64 / arr.last().unwrap();
            let rel = (empirical - rate).abs() / rate;
            // 4000 samples ⇒ the mean gap is within a few percent whp
            assert!(rel < 0.08, "rate {rate}: empirical {empirical} (rel err {rel})");
        }
    }
}

/// Drive the canonical steady-state allocation window over a warmed
/// native backend and return `(allocations_observed, ticks_measured)`:
/// a `b`-request speca workload runs once to completion (warmup), an
/// identical workload is submitted, the admission tick runs uncounted,
/// and the process-wide allocation counter is sampled around the
/// remaining mid-flight ticks (the completion tick is excluded too).
///
/// This is **the single definition of the measured window** shared by
/// `tests/alloc_discipline.rs` (which asserts the result is 0) and the
/// `micro_runtime` bench (whose `steady_state` JSON probes the CI perf
/// gate holds at 0) — so the gate and the test provably measure the
/// same thing (DESIGN.md §11). The counter only moves in binaries that
/// install [`CountingAllocator`](crate::util::alloc::CountingAllocator).
pub fn steady_state_alloc_probe(
    model: &crate::runtime::NativeBackend,
    b: usize,
) -> Result<(u64, usize)> {
    use crate::coordinator::{Engine, EngineConfig};
    use crate::runtime::ModelBackend;

    let cfg = model.entry().config.clone();
    // pre-size the result-buffer pool for every bucket the batcher can
    // dispatch (a measured-window reject mix can hit buckets the warmup
    // workload's accept/reject trace happened to skip)
    model.warmup(&["full", "full_eps", "block", "head"], &cfg.buckets)?;
    let policy = parse_policy("speca:N=5,O=2,tau0=0.3,beta=0.05", cfg.depth)?;
    let mut engine =
        Engine::from_ref(model, EngineConfig { max_inflight: b, ..EngineConfig::default() });
    // warm lifecycle: settles engine scratch capacities and exercises
    // every dispatch kind (full, verify, head)
    for req in batch_requests(b, cfg.num_classes, &policy, 1, false) {
        engine.submit(req);
    }
    engine.run_to_completion()?;
    // measured lifecycle: the admission tick allocates per-request state
    // and is excluded; so is the completion tick
    for req in batch_requests(b, cfg.num_classes, &policy, 2, false) {
        engine.submit(req);
    }
    engine.tick()?;
    let a0 = crate::util::alloc::allocations();
    let ticks = cfg.serve_steps - 2;
    for _ in 0..ticks {
        engine.tick()?;
    }
    let spent = crate::util::alloc::allocations().saturating_sub(a0);
    let done = engine.run_to_completion()?;
    debug_assert_eq!(done.len(), b, "probe workload must complete");
    Ok((spent, ticks))
}
