//! Deterministic error-injection model backend for controller tests.
//!
//! [`ScriptedBackend`] replaces the seeded native DiT with a model whose
//! per-step feature drift follows a *scripted* rel-error sequence, so
//! accept/reject decisions at every verify boundary are decided by the
//! script, not by emergent network dynamics. The construction:
//!
//! * every boundary feature at serve step `s` is the constant vector
//!   `level(s)·1`, with `level(0) = 1` and
//!   `level(s) = level(s−1) / (1 − drift[s])`;
//! * the verification block ignores its input and returns `level(s)·1`
//!   for the step encoded in the timestep value.
//!
//! With the `reuse` draft (prediction = cached tap from the last refresh
//! step `r`), the verify error under any of the relative metrics (the
//! vectors are constant, so rel-L1 = rel-L2 = rel-L∞) is exactly
//! `1 − level(r)/level(s)` — i.e. `drift[s]` one step after a refresh,
//! compounding monotonically on longer speculative runs. Scripting
//! `drift` therefore scripts the accept/reject trace against any fixed
//! threshold, which is what the adaptive-controller transition tests and
//! the `bench adaptive` difficulty buckets are built on.
//!
//! Every entry point is a pure function of its inputs (step is recovered
//! from the timestep value, never from internal state), so parked and
//! resumed requests replay bitwise-identically — the property the
//! checkpoint acceptance tests lean on. An optional per-dispatch
//! [`delay`](ScriptedBackend::with_delay) inflates step residency for
//! work-stealing tests.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::config::{ModelConfig, ModelEntry};
use crate::runtime::native::{synthetic_entry, NativeArch};
use crate::runtime::ModelBackend;
use crate::tensor::Tensor;

/// Largest accepted per-step drift: keeps `level(s)` (a product of
/// `1/(1−drift)` factors) finite in `f32` over any realistic schedule
/// (0.75 compounds to ~1.3e30 over 50 steps).
pub const MAX_DRIFT: f32 = 0.75;

/// Scale factor from feature level to eps magnitude; small enough that
/// the DDIM latent update stays finite over a full schedule.
const EPS_SCALE: f32 = 1e-3;

/// Deterministic scripted-drift backend (see the module docs).
pub struct ScriptedBackend {
    entry: ModelEntry,
    /// Clamped per-step drift, length `serve_steps`.
    drift: Vec<f32>,
    /// `level(s)` per serve step.
    levels: Vec<f32>,
    /// Optional sleep per dispatch (steal-test residency).
    delay: Option<Duration>,
}

impl ScriptedBackend {
    /// Build over the synthetic entry for `cfg`, cycling `drift` to
    /// `serve_steps` entries (so a one-element script is a constant
    /// difficulty and a short pattern repeats). Drift values are clamped
    /// into `[0, MAX_DRIFT]`; an empty script means zero drift.
    pub fn new(cfg: ModelConfig, drift: &[f32]) -> ScriptedBackend {
        let entry = synthetic_entry(&cfg, &NativeArch::default());
        let steps = cfg.serve_steps;
        let mut script = vec![0.0f32; steps];
        if !drift.is_empty() {
            for (s, d) in script.iter_mut().enumerate() {
                *d = drift[s % drift.len()].clamp(0.0, MAX_DRIFT);
            }
        }
        let drift = script;
        let mut levels = Vec::with_capacity(steps);
        let mut l = 1.0f32;
        for &d in &drift {
            // level(0) keeps drift[0] out of the product: step 0 is
            // always a dense refresh, there is nothing to drift *from*
            if !levels.is_empty() {
                l /= 1.0 - d;
            }
            levels.push(l);
        }
        ScriptedBackend { entry, drift, levels, delay: None }
    }

    /// Attach a per-dispatch sleep (every `full`/`block`/`head` call
    /// blocks this long), inflating step residency so shard workers stay
    /// visibly busy for work-stealing and preemption tests.
    pub fn with_delay(mut self, delay: Duration) -> ScriptedBackend {
        self.delay = Some(delay);
        self
    }

    /// The clamped per-step drift script actually in effect.
    pub fn drift(&self) -> &[f32] {
        &self.drift
    }

    /// `level(s)`: the constant boundary-feature value at serve step `s`.
    pub fn level(&self, step: usize) -> f32 {
        self.levels[step]
    }

    /// Recover the serve step from a timestep-embedding value. The
    /// synthetic DDIM schedule emits a distinct `t_model` value per step,
    /// so the position is unambiguous.
    fn step_of(&self, t: f32) -> Result<usize> {
        match self.entry.schedule.t_model.iter().position(|v| *v == t) {
            Some(s) => Ok(s),
            None => bail!("scripted backend: timestep {t} is not on the serve schedule"),
        }
    }

    fn pause(&self) {
        if let Some(d) = self.delay {
            std::thread::sleep(d);
        }
    }

    fn check_batch(&self, bucket: usize, t: &[f32], y: &[i32]) -> Result<()> {
        if !self.entry.config.buckets.contains(&bucket) {
            bail!("scripted backend: bucket {bucket} not in {:?}", self.entry.config.buckets);
        }
        if t.len() != bucket || y.len() != bucket {
            bail!("scripted backend: t/y len {}/{} != bucket {bucket}", t.len(), y.len());
        }
        Ok(())
    }

    /// The eps value a dense pass emits at `step` (constant across the
    /// latent; a pure function of the step so replays are bitwise).
    fn dense_eps(&self, step: usize) -> f32 {
        self.levels[step] * EPS_SCALE
    }
}

impl ModelBackend for ScriptedBackend {
    fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn kind(&self) -> &'static str {
        "scripted"
    }

    fn supports(&self, entry_point: &str) -> bool {
        matches!(entry_point, "full" | "full_eps" | "block" | "head")
    }

    fn warmup(&self, _entry_points: &[&str], _buckets: &[usize]) -> Result<()> {
        Ok(())
    }

    fn full(
        &self,
        bucket: usize,
        x: &[f32],
        t: &[f32],
        y: &[i32],
        _pallas: bool,
    ) -> Result<(Tensor, Tensor)> {
        self.check_batch(bucket, t, y)?;
        let cfg = &self.entry.config;
        let (latent, feat) = (cfg.latent_dim, cfg.tokens * cfg.dim);
        if x.len() != bucket * latent {
            bail!("scripted backend: x len {} != bucket {bucket} · latent {latent}", x.len());
        }
        self.pause();
        let mut eps = vec![0.0f32; bucket * latent];
        let mut bounds = vec![0.0f32; (cfg.depth + 1) * bucket * feat];
        for slot in 0..bucket {
            let step = self.step_of(t[slot])?;
            eps[slot * latent..(slot + 1) * latent].fill(self.dense_eps(step));
            for b in 0..=cfg.depth {
                let off = (b * bucket + slot) * feat;
                bounds[off..off + feat].fill(self.levels[step]);
            }
        }
        Ok((
            Tensor::new(vec![bucket, latent], eps),
            Tensor::new(vec![cfg.depth + 1, bucket, cfg.tokens, cfg.dim], bounds),
        ))
    }

    fn full_eps(&self, bucket: usize, x: &[f32], t: &[f32], y: &[i32]) -> Result<Tensor> {
        self.check_batch(bucket, t, y)?;
        let latent = self.entry.config.latent_dim;
        if x.len() != bucket * latent {
            bail!("scripted backend: x len {} != bucket {bucket} · latent {latent}", x.len());
        }
        self.pause();
        let mut eps = vec![0.0f32; bucket * latent];
        for slot in 0..bucket {
            let step = self.step_of(t[slot])?;
            eps[slot * latent..(slot + 1) * latent].fill(self.dense_eps(step));
        }
        Ok(Tensor::new(vec![bucket, latent], eps))
    }

    fn block(
        &self,
        bucket: usize,
        layer: i32,
        feat: &[f32],
        t: &[f32],
        y: &[i32],
    ) -> Result<Tensor> {
        self.check_batch(bucket, t, y)?;
        let cfg = &self.entry.config;
        let flen = cfg.tokens * cfg.dim;
        if layer < 0 || layer as usize >= cfg.depth {
            bail!("scripted backend: block layer {layer} out of range (depth {})", cfg.depth);
        }
        if feat.len() != bucket * flen {
            bail!("scripted backend: feat len {} != bucket {bucket} · feat {flen}", feat.len());
        }
        self.pause();
        // the "ground truth" at this step, independent of the predicted
        // input: verify error is then exactly the scripted cumulative
        // drift between refresh and now
        let mut out = vec![0.0f32; bucket * flen];
        for slot in 0..bucket {
            let step = self.step_of(t[slot])?;
            out[slot * flen..(slot + 1) * flen].fill(self.levels[step]);
        }
        Ok(Tensor::new(vec![bucket, cfg.tokens, cfg.dim], out))
    }

    fn head(&self, bucket: usize, feat: &[f32], t: &[f32], y: &[i32]) -> Result<Tensor> {
        self.check_batch(bucket, t, y)?;
        let cfg = &self.entry.config;
        let (latent, flen) = (cfg.latent_dim, cfg.tokens * cfg.dim);
        if feat.len() != bucket * flen {
            bail!("scripted backend: feat len {} != bucket {bucket} · feat {flen}", feat.len());
        }
        self.pause();
        // eps from the *predicted* feature level: accepted speculation
        // carries the (stale) cached level into the latent, exactly the
        // approximation error the adaptive budget is metering
        let mut eps = vec![0.0f32; bucket * latent];
        for slot in 0..bucket {
            let row = &feat[slot * flen..(slot + 1) * flen];
            let mean = row.iter().sum::<f32>() / flen as f32;
            eps[slot * latent..(slot + 1) * latent].fill(mean * EPS_SCALE);
        }
        Ok(Tensor::new(vec![bucket, latent], eps))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend(drift: &[f32]) -> ScriptedBackend {
        ScriptedBackend::new(ModelConfig::native_test(), drift)
    }

    #[test]
    fn levels_encode_the_scripted_relative_drift() {
        let b = backend(&[0.1]);
        let steps = b.entry().config.serve_steps;
        assert_eq!(b.drift().len(), steps);
        assert_eq!(b.level(0), 1.0);
        for s in 1..steps {
            // single-step rel error of a reuse prediction from step s−1
            let e = 1.0 - b.level(s - 1) / b.level(s);
            assert!((e - 0.1).abs() < 1e-6, "step {s}: {e}");
        }
    }

    #[test]
    fn drift_is_cycled_and_clamped() {
        let b = backend(&[0.2, 5.0]);
        assert_eq!(b.drift()[0], 0.2);
        assert_eq!(b.drift()[1], MAX_DRIFT, "over-unity drift must clamp");
        assert_eq!(b.drift()[2], 0.2, "short scripts cycle");
        let z = backend(&[]);
        assert!(z.drift().iter().all(|d| *d == 0.0));
        assert!(z.levels.iter().all(|l| *l == 1.0));
    }

    #[test]
    fn block_is_ground_truth_of_the_step_not_the_input() {
        let b = backend(&[0.25]);
        let cfg = b.entry().config.clone();
        let flen = cfg.tokens * cfg.dim;
        let t = [b.entry().schedule.t_model[3]];
        let junk = vec![42.0f32; flen];
        let out = b.block(1, 0, &junk, &t, &[0]).unwrap();
        assert!(out.data.iter().all(|v| *v == b.level(3)));
        // rel-L1 of a reuse prediction from step 2 against it
        let pred = vec![b.level(2); flen];
        let e = crate::coordinator::policy::ErrorMetric::L1.eval(&pred, out.row(0));
        assert!((e - 0.25).abs() < 1e-5, "{e}");
    }

    #[test]
    fn entry_points_are_pure_functions() {
        let b = backend(&[0.3, 0.01]);
        let cfg = b.entry().config.clone();
        let x = vec![0.5f32; cfg.latent_dim];
        let t = [b.entry().schedule.t_model[5]];
        let (e1, b1) = b.full(1, &x, &t, &[1], false).unwrap();
        let (e2, b2) = b.full(1, &x, &t, &[1], false).unwrap();
        assert_eq!(e1.data, e2.data);
        assert_eq!(b1.data, b2.data);
        assert_eq!(b1.shape, vec![cfg.depth + 1, 1, cfg.tokens, cfg.dim]);
        assert_eq!(e1.data, b.full_eps(1, &x, &t, &[1]).unwrap().data);
        let feat = vec![2.0f32; cfg.tokens * cfg.dim];
        let h1 = b.head(1, &feat, &t, &[1]).unwrap();
        let h2 = b.head(1, &feat, &t, &[1]).unwrap();
        assert_eq!(h1.data, h2.data);
        assert!(h1.data.iter().all(|v| *v == 2.0 * EPS_SCALE));
    }

    #[test]
    fn off_schedule_timesteps_and_bad_shapes_error() {
        let b = backend(&[0.1]);
        let cfg = b.entry().config.clone();
        let x = vec![0.0f32; cfg.latent_dim];
        assert!(b.full_eps(1, &x, &[12345.0], &[0]).is_err());
        assert!(b.full_eps(1, &x[..1], &[b.entry().schedule.t_model[0]], &[0]).is_err());
        assert!(b.block(1, cfg.depth as i32, &[], &[0.0], &[0]).is_err());
    }
}
