//! Minimal JSON parser + serializer (no serde on this offline image).
//!
//! Covers the full JSON grammar we use: the AOT `manifest.json`, the TCP
//! JSON-lines serving protocol, and experiment result dumps. Numbers are
//! kept as f64 (manifest FLOP counts fit exactly below 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (kept as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with its byte offset.
#[derive(Debug)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset of the failure.
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    /// Object member by key (None for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Object member by key; panics when missing (manifest loading).
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key '{key}'"))
    }
    /// Numeric value, if a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// Numeric value truncated to u64.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    /// Numeric value truncated to usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    /// String value, if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Boolean value, if a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Array elements, if an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    /// Object map, if an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Array of numbers as f32 (empty for non-arrays).
    pub fn f32s(&self) -> Vec<f32> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|f| f as f32).collect())
            .unwrap_or_default()
    }
    /// Array of numbers as usize (empty for non-arrays).
    pub fn usizes(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
            .unwrap_or_default()
    }

    // ---- builders ----------------------------------------------------------
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    /// Build a numeric array from f64s.
    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }
    /// Build a numeric array from f32s.
    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }
    /// Build a string value.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Serialize to compact JSON text.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("eof in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i..self.i + 4])
                                            .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 4;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("invalid codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                c => {
                    // multi-byte utf8: copy the remaining continuation bytes
                    let len = utf8_len(c);
                    let start = self.i - 1;
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b >= 0xF0 {
        4
    } else if b >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\n", "d": null}, "e": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("a").f32s(), vec![1.0, 2.5, -300.0]);
        assert_eq!(v.req("b").req("c").as_str(), Some("hi\n"));
        assert_eq!(v.req("e").as_bool(), Some(true));
        let again = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀x""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀x"));
        let again = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo — 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — 世界"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01a").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn int_formatting() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(0.5).dump(), "0.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse(" { } ").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
