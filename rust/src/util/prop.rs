//! Hand-rolled property-testing harness (proptest is not vendored).
//!
//! `prop_check(cases, seed, |rng| ...)` runs a randomized predicate many
//! times with independent deterministic streams and reports the failing
//! case's stream id so a failure reproduces with `rng = Rng::new(seed).fork(id)`.

use super::rng::Rng;

/// Run `f` on `cases` independent RNG streams; panic with the failing
/// stream index on the first counterexample.
pub fn prop_check<F: FnMut(&mut Rng) -> Result<(), String>>(
    cases: usize,
    seed: u64,
    mut f: F,
) {
    let base = Rng::new(seed);
    for case in 0..cases {
        let mut rng = base.fork(case as u64);
        if let Err(msg) = f(&mut rng) {
            panic!("property failed (seed={seed}, case={case}): {msg}");
        }
    }
}

/// Assert two slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32, ctx: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{ctx}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("{ctx}: idx {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check(50, 1, |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure() {
        prop_check(50, 2, |rng| {
            if rng.uniform() < 0.9 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn close_check() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, "t").is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-5, "t").is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-5, "t").is_err());
    }
}
