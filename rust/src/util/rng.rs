//! Deterministic RNG (SplitMix64 + xoshiro256**) — no `rand` crate offline.
//!
//! Used for workload generation, initial latent noise and the property-test
//! harness. Seeded streams are stable across runs so every experiment in
//! EXPERIMENTS.md is reproducible.

/// Deterministic xoshiro256** stream seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller sample
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Stream from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (e.g. per request id).
    pub fn fork(&self, stream: u64) -> Rng {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0x9E3779B97F4A7C15);
        let mut r = Rng::new(splitmix64(&mut sm));
        r.s[2] ^= stream;
        r
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let x = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        x
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let (u1, u2) = (self.uniform().max(1e-300), self.uniform());
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * th.sin());
        r * th.cos()
    }

    /// n standard-normal samples as f32.
    pub fn normal_f32s(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Exponential with rate lambda (Poisson arrival gaps for the workload
    /// generator).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.uniform().max(1e-300).ln() / lambda
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Uniformly random element (panics on an empty slice).
    pub fn choice<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let base = Rng::new(7);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..20).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
        assert_ne!(v, (0..20).collect::<Vec<_>>());
    }
}
