//! Counting global allocator for the perf/alloc instrumentation
//! (DESIGN.md §11, EXPERIMENTS.md §Perf).
//!
//! The type is always compiled (it is a zero-state wrapper over
//! [`System`]) but counts nothing until a binary *installs* it:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: speca::util::alloc::CountingAllocator =
//!     speca::util::alloc::CountingAllocator;
//! ```
//!
//! Only the alloc-discipline test binary (`tests/alloc_discipline.rs`)
//! and the `micro_runtime` bench install it, so the serving binary and
//! the rest of the test suite pay nothing. Counters are process-wide
//! relaxed atomics: one increment per allocator call, which is cheap
//! enough that the bench numbers stay representative.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static DEALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// Pass-through [`System`] allocator that counts every allocation call
/// (plain, zeroed and reallocations) and deallocation, process-wide.
pub struct CountingAllocator;

// SAFETY: pure delegation to `System`; the counters have no effect on the
// returned memory.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        DEALLOCS.fetch_add(1, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // a realloc is allocator traffic whether it grows in place or
        // moves — count it as one allocation
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Allocation calls observed so far (0 unless the counting allocator is
/// installed as the binary's `#[global_allocator]`).
pub fn allocations() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Deallocation calls observed so far.
pub fn deallocations() -> u64 {
    DEALLOCS.load(Ordering::Relaxed)
}

/// Bytes requested across all observed allocation calls.
pub fn allocated_bytes() -> u64 {
    ALLOC_BYTES.load(Ordering::Relaxed)
}
