//! Substrate utilities written from scratch for the offline image:
//! JSON, RNG, CLI parsing, timing/bench harness, property-test helpers,
//! and the opt-in counting allocator behind the perf/alloc gate.

pub mod alloc;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod timing;
