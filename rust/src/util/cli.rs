//! Tiny CLI argument parser (no clap offline): `--key value`, `--flag`,
//! positional args. Typed getters with defaults.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Non-flag arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` / bare `--flag` (stored as "true").
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// String flag with a default.
    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string flag.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Integer flag with a default (panics on a malformed value).
    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    /// u64 flag with a default (panics on a malformed value).
    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    /// Float flag with a default (panics on a malformed value).
    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    /// Boolean flag (`--x`, `--x true|1|yes`).
    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_mixed() {
        let a = argv("serve --port 9000 --verbose --ratio=0.5 extra");
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.usize("port", 0), 9000);
        assert!(a.bool("verbose"));
        assert_eq!(a.f64("ratio", 0.0), 0.5);
    }

    #[test]
    fn defaults() {
        let a = argv("x");
        assert_eq!(a.str("model", "dit-sim"), "dit-sim");
        assert_eq!(a.usize("n", 3), 3);
        assert!(!a.bool("flag"));
    }

    #[test]
    fn flag_before_flag() {
        let a = argv("--a --b 2");
        assert!(a.bool("a"));
        assert_eq!(a.usize("b", 0), 2);
    }
}
