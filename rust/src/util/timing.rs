//! Wall-clock helpers + a self-contained micro-bench harness (criterion is
//! not available offline). Used by `rust/benches/*` and the perf pass.

use std::time::{Duration, Instant};

/// Run `f` until `min_time` has elapsed (after `warmup` iterations) and
/// report per-iteration statistics.
pub struct Bench {
    /// Report label.
    pub name: String,
    /// Untimed warmup iterations.
    pub warmup: usize,
    /// Minimum total measurement time.
    pub min_time: Duration,
    /// Hard iteration cap.
    pub max_iters: usize,
}

/// Per-iteration timing statistics of one bench.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Report label.
    pub name: String,
    /// Timed iterations.
    pub iters: usize,
    /// Mean nanoseconds per iteration.
    pub mean_ns: f64,
    /// Median nanoseconds per iteration.
    pub p50_ns: f64,
    /// 99th-percentile nanoseconds per iteration.
    pub p99_ns: f64,
    /// Fastest iteration.
    pub min_ns: f64,
    /// Mean heap allocations per iteration, when the harness measured
    /// them (binaries that install
    /// [`CountingAllocator`](crate::util::alloc::CountingAllocator) —
    /// see [`Bench::run_counting`]).
    pub allocs_per_iter: Option<f64>,
}

impl Bench {
    /// Bench with the default window (400 ms, 3 warmups).
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            warmup: 3,
            min_time: Duration::from_millis(400),
            max_iters: 10_000,
        }
    }

    /// Set the warmup iteration count.
    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    /// Set the minimum measurement window.
    pub fn min_time_ms(mut self, ms: u64) -> Self {
        self.min_time = Duration::from_millis(ms);
        self
    }

    /// Measure `f` until the window elapses; returns the statistics.
    pub fn run<F: FnMut()>(&self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.max_iters);
        self.timed_loop(&mut f, &mut samples);
        self.finalize(samples, None)
    }

    /// [`Self::run`] plus allocation accounting: warmup and the sample
    /// buffer's one allocation happen first, then `allocations()` is
    /// sampled around exactly the timed loop (which pushes within the
    /// preallocated capacity), so the mean per-iteration delta in
    /// [`BenchResult::allocs_per_iter`] reflects only the measured
    /// closure. Meaningful only in binaries that install
    /// [`CountingAllocator`](crate::util::alloc::CountingAllocator) —
    /// elsewhere the counter never moves and the mean reads 0.
    pub fn run_counting<F: FnMut()>(&self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.max_iters);
        let a0 = crate::util::alloc::allocations();
        self.timed_loop(&mut f, &mut samples);
        let spent = crate::util::alloc::allocations().saturating_sub(a0);
        let per_iter = spent as f64 / samples.len().max(1) as f64;
        self.finalize(samples, Some(per_iter))
    }

    /// The measurement loop. Allocation-free: `samples` must arrive with
    /// capacity for the iteration cap (both callers preallocate before
    /// `run_counting` reads its counter baseline), so the counted window
    /// sees only the closure's allocator traffic.
    fn timed_loop<F: FnMut()>(&self, f: &mut F, samples: &mut Vec<f64>) {
        let start = Instant::now();
        while start.elapsed() < self.min_time && samples.len() < self.max_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
    }

    fn finalize(&self, mut samples: Vec<f64>, allocs_per_iter: Option<f64>) -> BenchResult {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len().max(1);
        let mean = samples.iter().sum::<f64>() / n as f64;
        let pick = |q: f64| samples[((n as f64 - 1.0) * q) as usize];
        BenchResult {
            name: self.name.clone(),
            iters: n,
            mean_ns: mean,
            p50_ns: if samples.is_empty() { 0.0 } else { pick(0.5) },
            p99_ns: if samples.is_empty() { 0.0 } else { pick(0.99) },
            min_ns: samples.first().copied().unwrap_or(0.0),
            allocs_per_iter,
        }
    }
}

impl BenchResult {
    /// One formatted report line.
    pub fn report(&self) -> String {
        let mut line = format!(
            "{:<44} {:>8} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns)
        );
        if let Some(a) = self.allocs_per_iter {
            line.push_str(&format!("  allocs/iter {a:>8.1}"));
        }
        line
    }
}

/// Human-scale a nanosecond count (ns/µs/ms/s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Simple stopwatch for coarse phases.
pub struct Stopwatch(Instant);

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    /// Elapsed milliseconds.
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs() {
        let r = Bench::new("noop").min_time_ms(10).run(|| {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters > 10);
        assert!(r.p50_ns <= r.p99_ns);
    }

    #[test]
    fn run_counting_reports_alloc_column() {
        // the lib test binary does not install the counting allocator,
        // so the column is present and trivially zero here; the real
        // nonzero/zero assertions live in tests/alloc_discipline.rs
        let r = Bench::new("noop").min_time_ms(5).run_counting(|| {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters > 1);
        assert_eq!(r.allocs_per_iter, Some(0.0));
        assert!(r.report().contains("allocs/iter"));
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
