//! Reader for the AOT tensor container (`weights.bin` / `goldens.bin`).
//!
//! Format written by `python/compile/aot.py::write_tensors` (little-endian):
//!
//! ```text
//! magic "SPCA" | u32 version | u32 n_tensors
//! per tensor: u16 name_len | name | u8 dtype (0=f32,1=i32) | u8 ndim |
//!             u32 dims[ndim] | u64 byte_len | raw data
//! ```

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

/// One stored tensor, by element type.
#[derive(Debug, Clone)]
pub enum Stored {
    /// Float tensor.
    F32(Tensor),
    /// Integer tensor.
    I32 {
        /// Dimension sizes.
        shape: Vec<usize>,
        /// Flat element storage.
        data: Vec<i32>,
    },
}

/// Parsed SPCA tensor file (`weights.bin` / golden traces).
#[derive(Debug, Default)]
pub struct TensorFile {
    /// Stored tensors by name.
    pub tensors: BTreeMap<String, Stored>,
    /// insertion order as written by python (PARAM_NAMES order for weights)
    pub order: Vec<String>,
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated tensor file at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
}

impl TensorFile {
    /// Read and parse a tensor file from disk.
    pub fn load(path: &Path) -> Result<TensorFile> {
        let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&bytes).with_context(|| format!("parsing {}", path.display()))
    }

    /// Parse the SPCA binary format from memory.
    pub fn parse(bytes: &[u8]) -> Result<TensorFile> {
        let mut c = Cursor { b: bytes, i: 0 };
        if c.take(4)? != b"SPCA" {
            bail!("bad magic (not a SPCA tensor file)");
        }
        let version = c.u32()?;
        if version != 1 {
            bail!("unsupported tensor file version {version}");
        }
        let n = c.u32()? as usize;
        let mut out = TensorFile::default();
        for _ in 0..n {
            let name_len = c.u16()? as usize;
            let name = String::from_utf8(c.take(name_len)?.to_vec())?;
            let dtype = c.u8()?;
            let ndim = c.u8()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(c.u32()? as usize);
            }
            let nbytes = c.u64()? as usize;
            let raw = c.take(nbytes)?;
            let numel: usize = shape.iter().product();
            let stored = match dtype {
                0 => {
                    if nbytes != numel * 4 {
                        bail!("{name}: byte len {nbytes} != 4*{numel}");
                    }
                    let mut data = vec![0f32; numel];
                    for (i, ch) in raw.chunks_exact(4).enumerate() {
                        data[i] = f32::from_le_bytes(ch.try_into().unwrap());
                    }
                    Stored::F32(Tensor::new(shape, data))
                }
                1 => {
                    if nbytes != numel * 4 {
                        bail!("{name}: byte len {nbytes} != 4*{numel}");
                    }
                    let mut data = vec![0i32; numel];
                    for (i, ch) in raw.chunks_exact(4).enumerate() {
                        data[i] = i32::from_le_bytes(ch.try_into().unwrap());
                    }
                    Stored::I32 { shape, data }
                }
                d => bail!("{name}: unknown dtype {d}"),
            };
            out.order.push(name.clone());
            out.tensors.insert(name, stored);
        }
        Ok(out)
    }

    /// Float tensor by name (errors on missing or wrong type).
    pub fn f32(&self, name: &str) -> Result<&Tensor> {
        match self.tensors.get(name) {
            Some(Stored::F32(t)) => Ok(t),
            Some(_) => bail!("tensor '{name}' is not f32"),
            None => bail!("tensor '{name}' not found"),
        }
    }

    /// Integer tensor data by name (errors on missing or wrong type).
    pub fn i32(&self, name: &str) -> Result<&[i32]> {
        match self.tensors.get(name) {
            Some(Stored::I32 { data, .. }) => Ok(data),
            Some(_) => bail!("tensor '{name}' is not i32"),
            None => bail!("tensor '{name}' not found"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a file in-memory with the same layout as aot.py.
    fn encode(tensors: &[(&str, &[usize], Vec<f32>)]) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(b"SPCA");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for (name, shape, data) in tensors {
            b.extend_from_slice(&(name.len() as u16).to_le_bytes());
            b.extend_from_slice(name.as_bytes());
            b.push(0); // f32
            b.push(shape.len() as u8);
            for d in *shape {
                b.extend_from_slice(&(*d as u32).to_le_bytes());
            }
            b.extend_from_slice(&((data.len() * 4) as u64).to_le_bytes());
            for v in data {
                b.extend_from_slice(&v.to_le_bytes());
            }
        }
        b
    }

    #[test]
    fn parse_roundtrip() {
        let bytes = encode(&[
            ("a", &[2, 2], vec![1., 2., 3., 4.]),
            ("b", &[3], vec![5., 6., 7.]),
        ]);
        let tf = TensorFile::parse(&bytes).unwrap();
        assert_eq!(tf.order, vec!["a", "b"]);
        assert_eq!(tf.f32("a").unwrap().shape, vec![2, 2]);
        assert_eq!(tf.f32("b").unwrap().data, vec![5., 6., 7.]);
        assert!(tf.f32("c").is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(TensorFile::parse(b"XXXX").is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut bytes = encode(&[("a", &[4], vec![1., 2., 3., 4.])]);
        bytes.truncate(bytes.len() - 3);
        assert!(TensorFile::parse(&bytes).is_err());
    }
}
