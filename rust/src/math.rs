//! Shared numeric helpers used across layers: the sinusoidal timestep
//! embedding (conditioning path of the native backend, TeaCache drift
//! signal in the engine) and the relative-L1 drift metric. Lives outside
//! `coordinator` so L2 (`runtime/native.rs`) never imports from L3.

/// Sinusoidal timestep embedding matching `python/compile/model.py`.
pub fn timestep_embedding(t: f32, dim: usize) -> Vec<f32> {
    let half = dim / 2;
    let mut out = vec![0f32; dim];
    for i in 0..half {
        let freq = (-(10000f64.ln()) * i as f64 / half as f64).exp();
        let arg = t as f64 * freq;
        out[i] = arg.cos() as f32;
        out[half + i] = arg.sin() as f32;
    }
    out
}

/// Relative L1 distance `‖a − b‖₁ / (‖b‖₁ + ε)` (TeaCache's drift signal).
pub fn rel_l1(a: &[f32], b: &[f32]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        num += ((*x - *y) as f64).abs();
        den += (*y as f64).abs();
    }
    num / (den + 1e-8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn temb_shape_and_range() {
        let e = timestep_embedding(500.0, 64);
        assert_eq!(e.len(), 64);
        assert!(e.iter().all(|v| v.abs() <= 1.0 + 1e-6));
        // embeddings of distinct timesteps differ
        let e2 = timestep_embedding(400.0, 64);
        assert!(rel_l1(&e, &e2) > 1e-3);
    }

    #[test]
    fn rel_l1_zero_on_equal() {
        let a = vec![1.0f32, -2.0];
        assert!(rel_l1(&a, &a) < 1e-12);
    }
}
