//! Shared numeric helpers used across layers: the sinusoidal timestep
//! embedding (conditioning path of the native backend, TeaCache drift
//! signal in the engine) and the relative-L1 drift metric. Lives outside
//! `coordinator` so L2 (`runtime/native.rs`) never imports from L3.

/// Sinusoidal timestep embedding matching `python/compile/model.py`.
pub fn timestep_embedding(t: f32, dim: usize) -> Vec<f32> {
    let mut out = Vec::new();
    timestep_embedding_into(t, dim, &mut out);
    out
}

/// [`timestep_embedding`] into a reusable buffer (resized to `dim`, fully
/// overwritten) — the hot-path variant the native conditioning path
/// stages through (and the engine's TeaCache drift precomputation uses
/// at construction), so steady-state embedding evaluations never touch
/// the allocator.
pub fn timestep_embedding_into(t: f32, dim: usize, out: &mut Vec<f32>) {
    let half = dim / 2;
    out.clear();
    out.resize(dim, 0.0);
    for i in 0..half {
        let freq = (-(10000f64.ln()) * i as f64 / half as f64).exp();
        let arg = t as f64 * freq;
        out[i] = arg.cos() as f32;
        out[half + i] = arg.sin() as f32;
    }
}

/// Relative L1 distance `‖a − b‖₁ / (‖b‖₁ + ε)` (TeaCache's drift signal).
pub fn rel_l1(a: &[f32], b: &[f32]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        num += ((*x - *y) as f64).abs();
        den += (*y as f64).abs();
    }
    num / (den + 1e-8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn temb_shape_and_range() {
        let e = timestep_embedding(500.0, 64);
        assert_eq!(e.len(), 64);
        assert!(e.iter().all(|v| v.abs() <= 1.0 + 1e-6));
        // embeddings of distinct timesteps differ
        let e2 = timestep_embedding(400.0, 64);
        assert!(rel_l1(&e, &e2) > 1e-3);
    }

    #[test]
    fn rel_l1_zero_on_equal() {
        let a = vec![1.0f32, -2.0];
        assert!(rel_l1(&a, &a) < 1e-12);
    }

    #[test]
    fn into_variant_matches_and_reuses_capacity() {
        let mut buf = Vec::new();
        timestep_embedding_into(321.0, 64, &mut buf);
        assert_eq!(buf, timestep_embedding(321.0, 64));
        let cap = buf.capacity();
        timestep_embedding_into(9.0, 64, &mut buf);
        assert_eq!(buf.capacity(), cap, "steady-state reuse must not reallocate");
        assert_eq!(buf, timestep_embedding(9.0, 64));
    }
}
