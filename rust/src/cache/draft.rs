//! Pluggable draft strategies — the forecasting half of SpeCa's
//! forecast-then-verify loop, lifted behind an object-safe trait so new
//! drafts (learned, low-rank, higher-order) plug in without touching the
//! engine (DESIGN.md §10).
//!
//! A [`DraftStrategy`] maps one tap's cached trajectory state (a
//! [`TapHistory`] view over the rolling backward differences Δ⁰..Δᵐ kept
//! by [`TapCache`](crate::cache::TapCache)) plus a horizon `k` to a
//! predicted feature. Six strategies ship:
//!
//! * `reuse` — F̂(k) = Δ⁰ (order-0, FORA-style);
//! * `adams-bashforth` — F̂(k) = Δ⁰ + r·Δ¹ with r = k/N (2-point linear
//!   multistep);
//! * `taylor` — F̂(k) = Σᵢ Δⁱ·rⁱ/i! truncated at the configured order
//!   (TaylorSeer, the paper's draft; the default);
//! * `richardson` — two linear extrapolations at refresh spacings N and
//!   2N combined to cancel the leading error term:
//!   F̂(k) = 2·L_N(k) − L_2N(k) = Δ⁰ + r·Δ¹ + (r/2)·Δ²;
//! * `learned-linear` — SpecDiff-flavored online ridge fit: per channel,
//!   a line anchored at the newest snapshot is fit over the reconstructed
//!   refresh-point history and extrapolated to `k` (no offline training,
//!   no artifacts);
//! * `spectral` — damped DCT extrapolation over the reconstructed
//!   refresh-point history (Adaptive Spectral Feature
//!   Forecasting-style): the high-frequency tail is shrunk by `damp`ⁿ
//!   before evaluating the basis past the window, trading a little lag
//!   for much smoother long-horizon forecasts (lookahead-k runs,
//!   DESIGN.md §16).
//!
//! Strategies are resolved by name through a [`DraftRegistry`]
//! (case-insensitive, with aliases), shared across engine shards as
//! `Arc<dyn DraftStrategy + Send + Sync>` inside a cloneable [`Draft`]
//! handle, and carried per request by
//! [`SpeCaConfig`](crate::coordinator::policy::SpeCaConfig). The exact
//! update equations and the trait contract are documented in
//! DESIGN.md §10; `tests/draft_parity.rs` asserts the shipped strategies
//! are bitwise-identical to the legacy [`DraftKind`](super::DraftKind)
//! enum paths.

use std::collections::BTreeMap;
use std::f32::consts::PI;
use std::fmt;
use std::sync::{Arc, OnceLock};

use anyhow::{anyhow, Result};

use crate::tensor::Tensor;

/// Read-only view of one tap's cached trajectory state, handed to
/// [`DraftStrategy::predict_into`].
///
/// `factor(i)` is the i-th rolling backward difference ΔⁱF at the last
/// refresh (Eq. 3); `usable_order()` caps how many of them are backed by
/// data (it ramps up as refreshes accumulate, so drafts degrade
/// gracefully during warmup); `interval()` is the nominal refresh
/// spacing N that normalizes the horizon (`r = k / N`).
pub struct TapHistory<'a> {
    factors: &'a [Vec<f32>],
    usable_order: usize,
    interval: f32,
}

impl<'a> TapHistory<'a> {
    /// Wrap raw difference factors (mostly used by tests and benches;
    /// engine code goes through
    /// [`TapCache::history`](crate::cache::TapCache::history)).
    pub fn new(factors: &'a [Vec<f32>], usable_order: usize, interval: f32) -> TapHistory<'a> {
        debug_assert!(!factors.is_empty());
        debug_assert!(usable_order < factors.len());
        TapHistory { factors, usable_order, interval }
    }

    /// The i-th backward difference ΔⁱF (length [`Self::feat_len`]).
    pub fn factor(&self, i: usize) -> &[f32] {
        &self.factors[i]
    }

    /// Highest difference order the cache allocates (Δ⁰..Δᵐ ⇒ m).
    pub fn max_order(&self) -> usize {
        self.factors.len() - 1
    }

    /// Highest difference order currently backed by observed refreshes.
    pub fn usable_order(&self) -> usize {
        self.usable_order
    }

    /// Nominal refresh spacing N (serve steps between full computes).
    pub fn interval(&self) -> f32 {
        self.interval
    }

    /// Feature length of every factor.
    pub fn feat_len(&self) -> usize {
        self.factors[0].len()
    }
}

/// One draft model: predicts a tap's feature `k` serve steps past its
/// last refresh from the cached difference history.
///
/// Contract (DESIGN.md §10):
/// * object-safe and `Send + Sync` — an instance may be shared by every
///   engine shard and every in-flight request (registry-resolved drafts
///   are), exactly like the model backend, so implementations must be
///   stateless or keep only thread-safe *aggregate* interior state
///   (tuning statistics across all traffic — never per-request state,
///   which a shared instance cannot key). A draft that needs genuinely
///   per-request state must be instantiated per request
///   ([`Draft::new`] on a fresh `Arc` in that request's `SpeCaConfig`)
///   rather than resolved from the shared registry;
/// * `predict_into` fully overwrites `out` (`out.len() ==
///   history.feat_len()`) and must not allocate per call beyond what the
///   strategy itself owns — callers pass reusable scratch buffers;
/// * predictions must degrade gracefully: when
///   `history.usable_order()` is below what the strategy wants, it uses
///   what is available (every shipped strategy falls back to reuse at
///   usable order 0);
/// * `reset` is an advisory, instance-wide signal: the engine invokes it
///   on a request's strategy when that request's speculation run ends in
///   rejection. On a shared (registry) instance this means "some
///   speculation run was just rejected" — decay aggregate adaptation;
///   only a per-request instance may treat it as "clear this run's
///   state". Shipped strategies are stateless and inherit the no-op
///   default.
pub trait DraftStrategy: Send + Sync {
    /// Registry key and reporting label (lowercase kebab-case).
    fn name(&self) -> &str;

    /// Highest difference order this strategy reads when the policy asks
    /// for order `configured`; sizes the per-tap cache allocation.
    fn max_order(&self, configured: usize) -> usize;

    /// Write the prediction for horizon `k` (serve steps since the last
    /// refresh) into `out`.
    fn predict_into(&self, history: &TapHistory<'_>, k: f32, out: &mut [f32]);

    /// Notify the strategy that a speculative run was rejected (see the
    /// trait docs). No-op by default.
    fn reset(&self) {}
}

/// Truncated-Taylor evaluation shared by every polynomial strategy *and*
/// the legacy [`DraftKind`](super::DraftKind) enum path, so the two stay
/// bitwise-identical by construction: out = Σ_{i≤order} Δⁱ·rⁱ/i!.
pub(crate) fn eval_taylor_into(factors: &[Vec<f32>], order: usize, ratio: f32, out: &mut [f32]) {
    out.copy_from_slice(&factors[0]);
    let mut coeff = 1.0f32;
    for (i, factor) in factors.iter().enumerate().take(order + 1).skip(1) {
        coeff *= ratio / i as f32;
        Tensor::axpy(coeff, factor, out);
    }
}

/// Order-0 feature reuse: F̂(k) = Δ⁰ (what FORA-style caches do).
#[derive(Debug, Clone, Copy, Default)]
pub struct ReuseDraft;

impl DraftStrategy for ReuseDraft {
    fn name(&self) -> &str {
        "reuse"
    }

    fn max_order(&self, _configured: usize) -> usize {
        0
    }

    fn predict_into(&self, history: &TapHistory<'_>, _k: f32, out: &mut [f32]) {
        out.copy_from_slice(history.factor(0));
    }
}

/// Two-point Adams–Bashforth linear multistep: F̂(k) = Δ⁰ + (k/N)·Δ¹.
#[derive(Debug, Clone, Copy, Default)]
pub struct AdamsBashforthDraft;

impl DraftStrategy for AdamsBashforthDraft {
    fn name(&self) -> &str {
        "adams-bashforth"
    }

    fn max_order(&self, _configured: usize) -> usize {
        1
    }

    fn predict_into(&self, history: &TapHistory<'_>, k: f32, out: &mut [f32]) {
        let order = history.usable_order().min(1);
        eval_taylor_into(history.factors, order, k / history.interval(), out);
    }
}

/// Truncated Taylor series of the configured order (TaylorSeer; the
/// paper's draft model and the registry default).
#[derive(Debug, Clone, Copy, Default)]
pub struct TaylorDraft;

impl DraftStrategy for TaylorDraft {
    fn name(&self) -> &str {
        "taylor"
    }

    fn max_order(&self, configured: usize) -> usize {
        configured
    }

    fn predict_into(&self, history: &TapHistory<'_>, k: f32, out: &mut [f32]) {
        let order = history.max_order().min(history.usable_order());
        eval_taylor_into(history.factors, order, k / history.interval(), out);
    }
}

/// Richardson extrapolation over two refresh spacings.
///
/// Linear extrapolation at the fine spacing N uses (F₀, F₋₁):
/// L_N(k) = Δ⁰ + r·Δ¹; at the coarse spacing 2N it uses (F₀, F₋₂):
/// L_2N(k) = Δ⁰ + (r/2)·(2Δ¹ − Δ²). The Richardson combination
/// 2·L_N − L_2N cancels the O(N) slope bias shared by both and leaves
///
///   F̂(k) = Δ⁰ + r·Δ¹ + (r/2)·Δ²,  r = k/N
///
/// — a genuinely different Δ² weighting than Taylor's r²/2 (linear
/// rather than quadratic in the horizon, so curvature is damped for
/// long speculative runs). Always a fixed order-2 scheme; with fewer
/// refreshes observed it degrades to Adams–Bashforth, then reuse.
#[derive(Debug, Clone, Copy, Default)]
pub struct RichardsonDraft;

impl DraftStrategy for RichardsonDraft {
    fn name(&self) -> &str {
        "richardson"
    }

    fn max_order(&self, _configured: usize) -> usize {
        2
    }

    fn predict_into(&self, history: &TapHistory<'_>, k: f32, out: &mut [f32]) {
        let r = k / history.interval();
        out.copy_from_slice(history.factor(0));
        let usable = history.usable_order().min(history.max_order());
        if usable >= 1 {
            Tensor::axpy(r, history.factor(1), out);
        }
        if usable >= 2 {
            Tensor::axpy(r * 0.5, history.factor(2), out);
        }
    }
}

/// SpecDiff-style learned linear draft: an online per-channel ridge fit
/// over the reconstructed refresh-point history, no offline training and
/// no artifacts.
///
/// The cached differences reconstruct the raw snapshots at the last m+1
/// refresh points (F₋ⱼ = Σᵢ (−1)ⁱ·C(j,i)·Δⁱ at normalized time t = −j).
/// Per channel, fit the line F ≈ F₀ + b·t anchored at the newest
/// snapshot by ridge regression on the slope:
///
///   b = Σⱼ tⱼ·(F₋ⱼ − F₀) / (Σⱼ tⱼ² + λ),   then   F̂(k) = F₀ + b·r
///
/// with r = k/N. Because every F₋ⱼ is a fixed linear combination of the
/// factors, the whole fit collapses to scalar weights over Δ¹..Δᵐ
/// computed once per call — the per-channel work is the same axpy sweep
/// the polynomial drafts do. λ = 0 recovers exact least squares (exact
/// on linear trajectories); λ → ∞ shrinks the slope to zero and the
/// draft degrades to reuse. "Trained online" means exactly this: the fit
/// is recomputed from the live trajectory at every prediction, so it
/// adapts within a request with zero cross-request state.
#[derive(Debug, Clone, Copy)]
pub struct LearnedLinearDraft {
    /// Ridge penalty λ on the slope (in units of squared refresh
    /// intervals).
    lambda: f32,
}

impl LearnedLinearDraft {
    /// Draft with an explicit ridge penalty λ ≥ 0.
    pub fn new(lambda: f32) -> LearnedLinearDraft {
        LearnedLinearDraft { lambda }
    }

    /// The ridge penalty this instance fits with.
    pub fn lambda(&self) -> f32 {
        self.lambda
    }
}

impl Default for LearnedLinearDraft {
    /// The registry default: λ = 0.1, a light shrink toward reuse.
    fn default() -> LearnedLinearDraft {
        LearnedLinearDraft::new(0.1)
    }
}

/// Binomial coefficient C(j, i) for the small j ≤ m orders used here.
fn binom(j: usize, i: usize) -> f32 {
    let mut c = 1.0f64;
    for step in 0..i {
        c = c * (j - step) as f64 / (step + 1) as f64;
    }
    c as f32
}

impl DraftStrategy for LearnedLinearDraft {
    fn name(&self) -> &str {
        "learned-linear"
    }

    fn max_order(&self, configured: usize) -> usize {
        configured
    }

    fn predict_into(&self, history: &TapHistory<'_>, k: f32, out: &mut [f32]) {
        out.copy_from_slice(history.factor(0));
        let m = history.usable_order().min(history.max_order());
        if m == 0 {
            return;
        }
        let r = k / history.interval();
        // denom = Σ_{j=1..m} tⱼ² + λ with tⱼ = −j
        let denom: f32 = (1..=m).map(|j| (j * j) as f32).sum::<f32>() + self.lambda;
        if denom <= 0.0 {
            return;
        }
        // slope weights per snapshot, folded into per-factor scalars:
        // b = Σⱼ wⱼ·(F₋ⱼ − F₀) with wⱼ = −j/denom and
        // F₋ⱼ − F₀ = Σ_{i≥1} (−1)ⁱ·C(j,i)·Δⁱ
        for i in 1..=m {
            let sign = if i % 2 == 0 { 1.0f32 } else { -1.0 };
            let mut coef = 0.0f32;
            for j in i..=m {
                coef += -(j as f32) / denom * sign * binom(j, i);
            }
            Tensor::axpy(r * coef, history.factor(i), out);
        }
    }
}

/// Frequency-domain draft: per-channel DCT extrapolation over the tap
/// history with the high-frequency tail damped (Adaptive Spectral
/// Feature Forecasting-style; DESIGN.md §16).
///
/// The cached factors Δ⁰..Δᵐ reconstruct the last m+1 refresh snapshots
/// F₋ⱼ = Σᵢ (−1)ⁱ·C(j,i)·Δⁱ. Viewing them as a chronological signal
/// g₀..gₘ (gₘ = F₀, one sample per refresh), the draft takes its DCT-II,
/// damps coefficient n by `damp`ⁿ — trajectories of transformer features
/// are smooth across refreshes, so the high-frequency content is mostly
/// verification-failing noise — and evaluates the damped basis at the
/// fractional position p* = m + k/N past the window:
///
///   F̂(k) = (2/L)·(C₀/2 + Σ_{n≥1} dampⁿ·Cₙ·cos(πn(p*+½)/L)),  L = m+1
///
/// Because every snapshot is a fixed linear combination of the factors,
/// the whole transform collapses to scalar weights over Δ⁰..Δᵐ computed
/// once per call — the per-channel work is the same axpy sweep the
/// polynomial drafts do, with no per-call allocation. The weights sum
/// to exactly 1 at every horizon (DCT orthogonality), so constant
/// trajectories are predicted exactly; with no observed differences
/// (usable order 0) the draft degrades to reuse.
#[derive(Debug, Clone, Copy)]
pub struct SpectralDraft {
    /// Per-coefficient damping `damp` ∈ [0, 1] applied as dampⁿ to DCT
    /// coefficient n; 1 = undamped extrapolation, 0 keeps only the DC
    /// term (the prediction collapses to the window mean).
    damp: f32,
}

impl SpectralDraft {
    /// Draft with an explicit damping factor, clamped into [0, 1].
    pub fn new(damp: f32) -> SpectralDraft {
        SpectralDraft { damp: damp.clamp(0.0, 1.0) }
    }

    /// The high-frequency damping factor this instance extrapolates with.
    pub fn damp(&self) -> f32 {
        self.damp
    }

    /// Weight of chronological snapshot `p` (0 oldest, `m` newest) in the
    /// damped-DCT extrapolation to position `pstar` over a window of
    /// `m + 1` samples. Exposed to the crate so tests can check the
    /// collapsed axpy sweep against a direct scalar DCT oracle.
    pub(crate) fn snapshot_weight(&self, m: usize, p: usize, pstar: f32) -> f32 {
        let l = (m + 1) as f32;
        let mut w = 0.5f32;
        for n in 1..=m {
            let basis_p = (PI * n as f32 * (p as f32 + 0.5) / l).cos();
            let basis_star = (PI * n as f32 * (pstar + 0.5) / l).cos();
            w += self.damp.powi(n as i32) * basis_p * basis_star;
        }
        w * 2.0 / l
    }
}

impl Default for SpectralDraft {
    /// The registry default: damp = 0.7, a strong shrink of the tail.
    fn default() -> SpectralDraft {
        SpectralDraft::new(0.7)
    }
}

impl DraftStrategy for SpectralDraft {
    fn name(&self) -> &str {
        "spectral"
    }

    fn max_order(&self, configured: usize) -> usize {
        configured
    }

    fn predict_into(&self, history: &TapHistory<'_>, k: f32, out: &mut [f32]) {
        let m = history.usable_order().min(history.max_order());
        if m == 0 {
            out.copy_from_slice(history.factor(0));
            return;
        }
        let pstar = m as f32 + k / history.interval();
        out.fill(0.0);
        // Fold the snapshot weights into per-factor scalars: snapshot at
        // chronological position p is F₋(m−p) = Σᵢ (−1)ⁱ·C(m−p,i)·Δⁱ, and
        // C(j,i) = 0 for i > j keeps the sweep triangular.
        for i in 0..=m {
            let sign = if i % 2 == 0 { 1.0f32 } else { -1.0 };
            let mut v = 0.0f32;
            for p in 0..=(m - i) {
                v += self.snapshot_weight(m, p, pstar) * binom(m - p, i);
            }
            Tensor::axpy(sign * v, history.factor(i), out);
        }
    }
}

/// The process-wide default Taylor strategy (what non-SpeCa cache
/// policies such as TaylorSeer draft with).
pub fn taylor_default() -> &'static (dyn DraftStrategy + Send + Sync) {
    static TAYLOR: TaylorDraft = TaylorDraft;
    &TAYLOR
}

/// A cloneable, shard-shareable handle to one strategy instance.
///
/// This is what [`SpeCaConfig`](crate::coordinator::policy::SpeCaConfig)
/// carries per request: cloning is an `Arc` bump, so every shard worker
/// predicting for the same request family reads one shared instance —
/// the same sharing model as the execution backend.
#[derive(Clone)]
pub struct Draft(Arc<dyn DraftStrategy + Send + Sync>);

impl Draft {
    /// Wrap a strategy instance.
    pub fn new(strategy: Arc<dyn DraftStrategy + Send + Sync>) -> Draft {
        Draft(strategy)
    }

    /// Resolve a strategy by name through the global registry
    /// (case-insensitive; the error lists every valid name).
    pub fn named(name: &str) -> Result<Draft> {
        DraftRegistry::global().resolve(name)
    }

    /// The default draft: the paper's truncated Taylor series (the
    /// registry's shared instance, so it compares equal to
    /// `Draft::named("taylor")`).
    pub fn taylor() -> Draft {
        DraftRegistry::global().resolve("taylor").expect("taylor is a builtin")
    }

    /// The wrapped strategy's reporting name.
    pub fn name(&self) -> &str {
        self.0.name()
    }
}

impl std::ops::Deref for Draft {
    type Target = dyn DraftStrategy + Send + Sync;

    fn deref(&self) -> &Self::Target {
        &*self.0
    }
}

impl fmt::Debug for Draft {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Draft({})", self.0.name())
    }
}

impl PartialEq for Draft {
    /// Drafts compare by *instance identity* (the same shared strategy
    /// object), not by name — two `learned-linear` drafts with different
    /// ridge penalties are different drafts. Handles resolved from the
    /// same registry entry compare equal because they clone one `Arc`.
    fn eq(&self, other: &Draft) -> bool {
        Arc::ptr_eq(&self.0, &other.0)
    }
}

struct RegEntry {
    draft: Draft,
    blurb: String,
}

/// String-keyed draft-strategy registry — the one place `draft=<name>`
/// in policy descriptions, the per-request `draft` field on the wire
/// protocol and the `--draft` CLI flag all resolve through.
///
/// Lookups are case-insensitive and follow aliases; unknown names error
/// with the full list of valid strategies. [`DraftRegistry::global`]
/// holds the built-in five; build a custom registry with
/// [`DraftRegistry::empty`] + [`DraftRegistry::register`] to plug in
/// experimental drafts without touching the engine.
///
/// # Examples
///
/// ```
/// use speca::cache::draft::DraftRegistry;
///
/// let reg = DraftRegistry::global();
/// assert_eq!(reg.resolve("Taylor").unwrap().name(), "taylor");
/// // aliases resolve to their canonical strategy
/// assert_eq!(reg.resolve("adams").unwrap().name(), "adams-bashforth");
/// // unknown names list what would have worked
/// let err = reg.resolve("magic").unwrap_err().to_string();
/// assert!(err.contains("taylor") && err.contains("richardson"));
/// ```
pub struct DraftRegistry {
    entries: BTreeMap<String, RegEntry>,
    aliases: BTreeMap<String, String>,
}

impl DraftRegistry {
    /// A registry with no strategies (plugin construction).
    pub fn empty() -> DraftRegistry {
        DraftRegistry { entries: BTreeMap::new(), aliases: BTreeMap::new() }
    }

    /// A registry holding the six built-in strategies and their aliases.
    pub fn with_builtins() -> DraftRegistry {
        let mut reg = DraftRegistry::empty();
        reg.register(
            "order-0 feature reuse (FORA-style; ignores the horizon)",
            Arc::new(ReuseDraft),
        );
        reg.register(
            "2-point Adams-Bashforth linear multistep (order 1)",
            Arc::new(AdamsBashforthDraft),
        );
        reg.register(
            "truncated Taylor series at the configured order (TaylorSeer; default)",
            Arc::new(TaylorDraft),
        );
        reg.register(
            "Richardson extrapolation over spacings N and 2N (fixed order 2)",
            Arc::new(RichardsonDraft),
        );
        reg.register(
            "online per-channel ridge line fit over the tap history (SpecDiff-style)",
            Arc::new(LearnedLinearDraft::default()),
        );
        reg.register(
            "damped DCT extrapolation over the tap history (spectral forecasting)",
            Arc::new(SpectralDraft::default()),
        );
        reg.alias("adams", "adams-bashforth");
        reg.alias("ab", "adams-bashforth");
        reg.alias("taylorseer", "taylor");
        reg.alias("learned", "learned-linear");
        reg.alias("specdiff", "learned-linear");
        reg
    }

    /// Register a strategy under its own (lowercased) name with a short
    /// description for `--list-drafts`.
    pub fn register(&mut self, blurb: &str, strategy: Arc<dyn DraftStrategy + Send + Sync>) {
        let key = strategy.name().to_ascii_lowercase();
        self.entries.insert(key, RegEntry { draft: Draft(strategy), blurb: blurb.to_string() });
    }

    /// Register an alternate lookup name for a canonical strategy.
    pub fn alias(&mut self, alias: &str, canonical: &str) {
        debug_assert!(self.entries.contains_key(canonical), "alias to unknown '{canonical}'");
        self.aliases.insert(alias.to_ascii_lowercase(), canonical.to_ascii_lowercase());
    }

    /// Resolve a name or alias (case-insensitive) to a shared handle.
    pub fn resolve(&self, name: &str) -> Result<Draft> {
        let key = name.trim().to_ascii_lowercase();
        let canonical = self.aliases.get(&key).map(|s| s.as_str()).unwrap_or(&key);
        match self.entries.get(canonical) {
            Some(e) => Ok(e.draft.clone()),
            None => Err(anyhow!(
                "unknown draft strategy '{name}' (expected one of: {})",
                self.names().join(", ")
            )),
        }
    }

    /// Canonical strategy names, sorted.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(|s| s.as_str()).collect()
    }

    /// `(name, description)` pairs for every canonical strategy, sorted
    /// by name (`speca --list-drafts` output).
    pub fn list(&self) -> Vec<(&str, &str)> {
        self.entries.iter().map(|(k, e)| (k.as_str(), e.blurb.as_str())).collect()
    }

    /// The process-wide registry of built-in strategies.
    pub fn global() -> &'static DraftRegistry {
        static GLOBAL: OnceLock<DraftRegistry> = OnceLock::new();
        GLOBAL.get_or_init(DraftRegistry::with_builtins)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fabricated history: factors Δ⁰..Δᵐ with distinct contents.
    fn factors(m: usize, feat: usize) -> Vec<Vec<f32>> {
        (0..=m)
            .map(|i| (0..feat).map(|c| (i * 10 + c) as f32 * 0.25 - 1.0).collect())
            .collect()
    }

    #[test]
    fn registry_resolves_builtins_case_insensitively() {
        let reg = DraftRegistry::global();
        for (name, expect) in [
            ("reuse", "reuse"),
            ("REUSE", "reuse"),
            ("Adams-Bashforth", "adams-bashforth"),
            ("ab", "adams-bashforth"),
            ("taylor", "taylor"),
            ("TaylorSeer", "taylor"),
            ("richardson", "richardson"),
            ("Learned", "learned-linear"),
            ("specdiff", "learned-linear"),
            ("spectral", "spectral"),
            ("Spectral", "spectral"),
            (" taylor ", "taylor"),
        ] {
            assert_eq!(reg.resolve(name).unwrap().name(), expect, "{name}");
        }
        assert_eq!(reg.names().len(), 6);
        assert_eq!(reg.list().len(), 6);
    }

    #[test]
    fn registry_error_lists_names() {
        // The unknown-name error is built from the registry, never from a
        // hand-maintained list — every registered strategy must appear,
        // including ones added after the message was written.
        let err = DraftRegistry::global().resolve("warp").unwrap_err().to_string();
        for name in DraftRegistry::global().names() {
            assert!(err.contains(name), "error should list '{name}': {err}");
        }
        assert!(err.contains("spectral"), "registry must ship spectral: {err}");
    }

    #[test]
    fn richardson_matches_closed_form() {
        let f = factors(3, 4);
        let h = TapHistory::new(&f, 3, 5.0);
        let mut out = vec![0.0f32; 4];
        RichardsonDraft.predict_into(&h, 3.0, &mut out);
        let r = 3.0f32 / 5.0;
        for c in 0..4 {
            let expect = f[0][c] + r * f[1][c] + r * 0.5 * f[2][c];
            assert!((out[c] - expect).abs() < 1e-6, "channel {c}");
        }
    }

    #[test]
    fn richardson_degrades_with_short_history() {
        let f = factors(2, 3);
        let mut out = vec![0.0f32; 3];
        // usable 0 → reuse
        RichardsonDraft.predict_into(&TapHistory::new(&f, 0, 4.0), 2.0, &mut out);
        assert_eq!(out, f[0]);
        // usable 1 → Adams–Bashforth
        let mut ab = vec![0.0f32; 3];
        AdamsBashforthDraft.predict_into(&TapHistory::new(&f, 1, 4.0), 2.0, &mut ab);
        RichardsonDraft.predict_into(&TapHistory::new(&f, 1, 4.0), 2.0, &mut out);
        assert_eq!(out, ab);
    }

    #[test]
    fn learned_linear_exact_on_linear_trajectories() {
        // A linear feature F(t) = a + s·t sampled at refreshes N apart has
        // Δ¹ = s·N and Δⁱ = 0 for i ≥ 2; the λ=0 fit must extrapolate it
        // exactly for any usable order.
        let n = 4.0f32;
        let (a, s) = (2.0f32, -0.75f32);
        for m in 1..=3usize {
            let mut f = vec![vec![a; 1]; m + 1];
            f[1][0] = s * n;
            for fac in f.iter_mut().skip(2) {
                fac[0] = 0.0;
            }
            let h = TapHistory::new(&f, m, n);
            let mut out = vec![0.0f32];
            LearnedLinearDraft::new(0.0).predict_into(&h, 3.0, &mut out);
            let expect = a + s * 3.0;
            assert!((out[0] - expect).abs() < 1e-4, "m={m}: {} vs {expect}", out[0]);
        }
    }

    #[test]
    fn learned_linear_large_lambda_degrades_to_reuse() {
        let f = factors(2, 3);
        let h = TapHistory::new(&f, 2, 5.0);
        let mut out = vec![0.0f32; 3];
        LearnedLinearDraft::new(1e12).predict_into(&h, 4.0, &mut out);
        for c in 0..3 {
            assert!((out[c] - f[0][c]).abs() < 1e-4, "channel {c}");
        }
    }

    #[test]
    fn learned_linear_m1_equals_adams_bashforth_at_lambda_zero() {
        let f = factors(1, 4);
        let h = TapHistory::new(&f, 1, 3.0);
        let mut lin = vec![0.0f32; 4];
        let mut ab = vec![0.0f32; 4];
        LearnedLinearDraft::new(0.0).predict_into(&h, 2.0, &mut lin);
        AdamsBashforthDraft.predict_into(&h, 2.0, &mut ab);
        for c in 0..4 {
            assert!((lin[c] - ab[c]).abs() < 1e-5, "channel {c}");
        }
    }

    #[test]
    fn spectral_is_exact_on_constant_trajectories() {
        // All snapshots equal ⇒ Δ⁰ = a, Δ¹.. = 0; DCT orthogonality makes
        // the snapshot weights sum to exactly 1 at every horizon.
        for m in 1..=3usize {
            let mut f = vec![vec![0.0f32; 2]; m + 1];
            f[0] = vec![4.25, -1.5];
            let h = TapHistory::new(&f, m, 5.0);
            let mut out = vec![0.0f32; 2];
            for k in [1.0f32, 3.0, 12.0] {
                SpectralDraft::default().predict_into(&h, k, &mut out);
                for c in 0..2 {
                    assert!((out[c] - f[0][c]).abs() < 1e-5, "m={m} k={k} channel {c}");
                }
            }
        }
    }

    #[test]
    fn spectral_usable_order_zero_is_reuse() {
        let f = factors(2, 3);
        let h = TapHistory::new(&f, 0, 5.0);
        let mut out = vec![0.0f32; 3];
        SpectralDraft::default().predict_into(&h, 7.0, &mut out);
        assert_eq!(out, f[0]);
    }

    #[test]
    fn spectral_damp_is_clamped_and_reported() {
        assert_eq!(SpectralDraft::new(2.0).damp(), 1.0);
        assert_eq!(SpectralDraft::new(-1.0).damp(), 0.0);
        assert_eq!(SpectralDraft::default().damp(), 0.7);
        assert_eq!(SpectralDraft::default().name(), "spectral");
        assert_eq!(SpectralDraft::default().max_order(3), 3);
    }

    #[test]
    fn draft_handle_semantics() {
        let d = Draft::named("taylor").unwrap();
        assert_eq!(d.name(), "taylor");
        assert_eq!(format!("{d:?}"), "Draft(taylor)");
        assert_eq!(d, Draft::taylor());
        assert_ne!(d, Draft::named("reuse").unwrap());
        // Deref reaches the trait surface
        assert_eq!(d.max_order(4), 4);
        d.reset(); // no-op, must not panic
        assert_eq!(Draft::named("richardson").unwrap().max_order(0), 2);
        assert_eq!(Draft::named("reuse").unwrap().max_order(9), 0);
    }

    #[test]
    fn binom_small_values() {
        assert_eq!(binom(3, 0), 1.0);
        assert_eq!(binom(3, 1), 3.0);
        assert_eq!(binom(3, 2), 3.0);
        assert_eq!(binom(4, 2), 6.0);
    }
}
