//! TaylorSeer feature-factor cache (paper §3.3) — the per-request state the
//! draft model predicts from.
//!
//! Each request tracks one `TapCache` per tap point (block boundary).
//! A tap stores the rolling backward differences Δ⁰..Δᵐ of the feature at
//! successive *refresh* points (full computations), spaced nominally `N`
//! serve steps apart:
//!
//!   refresh:  Δ⁰ ← F_new,  Δⁱ ← Δⁱ⁻¹_new − Δⁱ⁻¹_old        (Eq. 3)
//!   predict:  F̂(k) = Σ_i Δⁱ · (k/N)ⁱ / i!                    (Eq. 2)
//!
//! The effective order is capped by the number of refreshes seen so far, so
//! predictions during warmup degrade gracefully (reuse → linear → ...).
//!
//! *How* a prediction is formed from the cached differences is pluggable:
//! the [`draft`] submodule defines the object-safe
//! [`DraftStrategy`](draft::DraftStrategy) trait, the six shipped
//! strategies, and the name-keyed [`DraftRegistry`](draft::DraftRegistry)
//! (DESIGN.md §10). The [`DraftKind`] enum is kept as the legacy reference
//! implementation of the original three drafts; `tests/draft_parity.rs`
//! asserts the trait impls are bitwise-identical to it.

pub mod draft;

pub use draft::{Draft, DraftRegistry, DraftStrategy, TapHistory};

use crate::cache::draft::eval_taylor_into;

/// Draft-model flavor (paper Table 7 ablation) — the legacy enum form of
/// the three original strategies, kept as the bitwise reference for the
/// trait-based [`draft`] subsystem (and for hot paths that want a `Copy`
/// selector). New code should resolve a [`Draft`] through the
/// [`DraftRegistry`] instead.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DraftKind {
    /// Direct feature reuse (order-0; what FORA-style caches do).
    Reuse,
    /// Two-point Adams–Bashforth linear multistep (order-1 extrapolation).
    AdamsBashforth,
    /// Truncated Taylor series of the configured order (TaylorSeer).
    Taylor,
}

impl DraftKind {
    /// Parse one of the three legacy names (case-insensitive). Strategy
    /// names beyond these resolve through [`DraftRegistry`], whose errors
    /// list every registered name.
    pub fn parse(s: &str) -> Option<DraftKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "reuse" => Some(DraftKind::Reuse),
            "adams" | "ab" | "adams-bashforth" => Some(DraftKind::AdamsBashforth),
            "taylor" | "taylorseer" => Some(DraftKind::Taylor),
            _ => None,
        }
    }

    /// Effective series order used for prediction.
    pub fn order(&self, configured: usize) -> usize {
        match self {
            DraftKind::Reuse => 0,
            DraftKind::AdamsBashforth => 1,
            DraftKind::Taylor => configured,
        }
    }
}

/// Rolling backward-difference cache for one tap point (block boundary).
///
/// # Examples
///
/// On a linear trajectory the order-1 prediction is exact for any
/// horizon, whichever way you ask for it:
///
/// ```
/// use speca::cache::{Draft, DraftKind, TapCache};
///
/// let mut cache = TapCache::new(2, 1, 4); // order 2, 1 channel, N = 4
/// for j in 0..3 {
///     cache.refresh(&[2.0 - 3.0 * (j as f32 * 4.0)]); // F(t) = 2 − 3t
/// }
/// let legacy = cache.predict(2.0, DraftKind::Taylor);
/// let mut out = vec![0.0];
/// cache.predict_with(&*Draft::named("taylor").unwrap(), 2.0, &mut out);
/// assert_eq!(legacy, out);
/// assert!((out[0] - (2.0 - 3.0 * 10.0)).abs() < 1e-4); // exact at t = 8 + 2
/// ```
#[derive(Debug, Clone)]
pub struct TapCache {
    /// factors[i] = Δⁱ F (raw backward differences), each of length `feat_len`
    factors: Vec<Vec<f32>>,
    /// refreshes observed so far (caps the usable order)
    updates: usize,
    /// nominal refresh spacing N used in the denominators
    interval: f32,
    /// rolling-update staging buffer (allocated once at construction, so
    /// steady-state refreshes never touch the allocator)
    scratch: Vec<f32>,
}

impl TapCache {
    /// Cache holding differences Δ⁰..Δ^order of a `feat_len`-channel
    /// feature refreshed nominally every `interval` serve steps.
    pub fn new(order: usize, feat_len: usize, interval: usize) -> TapCache {
        TapCache {
            factors: vec![vec![0.0; feat_len]; order + 1],
            updates: 0,
            interval: interval as f32,
            scratch: Vec::with_capacity(feat_len),
        }
    }

    /// Channels per factor.
    pub fn feat_len(&self) -> usize {
        self.factors[0].len()
    }

    /// Highest difference order allocated (Δ⁰..Δᵐ ⇒ m).
    pub fn max_order(&self) -> usize {
        self.factors.len() - 1
    }

    /// Highest difference order currently backed by data.
    pub fn usable_order(&self) -> usize {
        self.updates.saturating_sub(1).min(self.max_order())
    }

    /// Whether at least one refresh has populated the cache.
    pub fn ready(&self) -> bool {
        self.updates > 0
    }

    /// Resident bytes of the factor storage.
    pub fn bytes(&self) -> usize {
        self.factors.iter().map(|f| f.len() * 4).sum()
    }

    /// Rolling backward-difference update with a freshly computed feature
    /// (mirrors kernels/taylor.py::taylor_update → tested for parity).
    /// Allocation-free in steady state: the staging buffer is swapped
    /// through the factor levels, so only capacities move.
    pub fn refresh(&mut self, feat: &[f32]) {
        assert_eq!(feat.len(), self.feat_len());
        let m1 = self.factors.len();
        // scratch carries "new Δⁱ" into level i; after the swap it holds
        // the *old* Δⁱ and is rewritten to new Δⁱ⁺¹ = new Δⁱ − old Δⁱ
        self.scratch.clear();
        self.scratch.extend_from_slice(feat);
        for i in 0..m1 {
            std::mem::swap(&mut self.factors[i], &mut self.scratch);
            if i + 1 < m1 {
                for (o, n) in self.scratch.iter_mut().zip(self.factors[i].iter()) {
                    *o = *n - *o;
                }
            }
        }
        self.updates += 1;
    }

    /// Predict the feature k steps ahead of the last refresh (Eq. 2),
    /// truncated to `draft.order(configured)` and the usable order.
    pub fn predict(&self, k: f32, draft: DraftKind) -> Vec<f32> {
        let mut out = vec![0.0; self.feat_len()];
        self.predict_into(k, draft, &mut out);
        out
    }

    /// Predict into a caller buffer (hot-path variant, no allocation).
    pub fn predict_into(&self, k: f32, draft: DraftKind, out: &mut [f32]) {
        let order = draft.order(self.max_order()).min(self.usable_order());
        eval_taylor_into(&self.factors, order, k / self.interval, out);
    }

    /// Predict into a caller buffer through a trait-object draft strategy
    /// (what the engine dispatches; see [`draft`]).
    pub fn predict_with(&self, strategy: &dyn DraftStrategy, k: f32, out: &mut [f32]) {
        strategy.predict_into(&self.history(), k, out);
    }

    /// The read-only trajectory view draft strategies predict from.
    pub fn history(&self) -> TapHistory<'_> {
        TapHistory::new(&self.factors, self.usable_order(), self.interval)
    }

    /// The raw difference factors Δ⁰..Δᵐ.
    pub fn factors(&self) -> &[Vec<f32>] {
        &self.factors
    }

    /// Refreshes observed so far (the warmup counter capping
    /// [`Self::usable_order`]). Together with [`Self::factors`] and
    /// [`Self::interval`] this is the tap's complete serializable state —
    /// what a [`crate::coordinator::state::RequestCheckpoint`] extracts.
    pub fn updates(&self) -> usize {
        self.updates
    }

    /// Nominal refresh spacing N used in the Taylor denominators.
    pub fn interval(&self) -> f32 {
        self.interval
    }

    /// Rebuild a tap from previously extracted state (the inverse of
    /// [`Self::factors`] + [`Self::updates`] + [`Self::interval`]): the
    /// re-insertion half of the checkpoint contract. The scratch staging
    /// buffer is rebuilt empty — it is an intra-refresh temporary and
    /// carries no trajectory state, so a restored tap predicts and
    /// refreshes bitwise-identically to the original.
    pub fn from_parts(factors: Vec<Vec<f32>>, updates: usize, interval: f32) -> TapCache {
        assert!(!factors.is_empty(), "a tap stores at least Δ⁰");
        let feat_len = factors[0].len();
        assert!(factors.iter().all(|f| f.len() == feat_len), "factor lengths must agree");
        TapCache { factors, updates, interval, scratch: Vec::with_capacity(feat_len) }
    }
}

/// The per-request bundle of tap caches tracked by the SpeCa engine:
/// boundary v (verify-block input), boundary v+1 (its output), and the last
/// boundary L (head input) — plus optionally *all* boundaries for the
/// layer-correlation experiments (Fig. 6).
#[derive(Debug, Clone)]
pub struct FeatureCache {
    /// One [`TapCache`] per tapped boundary, in tap-layout order.
    pub taps: Vec<TapCache>,
    /// serve step of the last refresh (for computing k)
    pub last_refresh_step: Option<usize>,
}

impl FeatureCache {
    /// `n_taps` identically-shaped tap caches (see [`TapCache::new`]).
    pub fn new(n_taps: usize, order: usize, feat_len: usize, interval: usize) -> FeatureCache {
        FeatureCache {
            taps: (0..n_taps).map(|_| TapCache::new(order, feat_len, interval)).collect(),
            last_refresh_step: None,
        }
    }

    /// Refresh every tap with its freshly computed boundary feature.
    pub fn refresh(&mut self, step: usize, feats: &[&[f32]]) {
        assert_eq!(feats.len(), self.taps.len());
        self.refresh_iter(step, feats.iter().copied());
    }

    /// [`Self::refresh`] over an iterator of boundary slices — the
    /// engine's hot-path form, which avoids materializing a `Vec<&[f32]>`
    /// per refresh (DESIGN.md §11). The iterator must yield exactly one
    /// feature per tap: both under- and over-supply panic (the same
    /// exact-length contract as the slice form).
    pub fn refresh_iter<'a>(&mut self, step: usize, mut feats: impl Iterator<Item = &'a [f32]>) {
        for tap in self.taps.iter_mut() {
            let feat = feats.next().expect("refresh must cover every tap");
            tap.refresh(feat);
        }
        assert!(feats.next().is_none(), "refresh yielded more features than taps");
        self.last_refresh_step = Some(step);
    }

    /// Steps elapsed since the last refresh when serving step `step`.
    pub fn k_for_step(&self, step: usize) -> Option<f32> {
        self.last_refresh_step.map(|s| (step - s) as f32)
    }

    /// Whether every tap has observed at least one refresh.
    pub fn ready(&self) -> bool {
        self.last_refresh_step.is_some() && self.taps.iter().all(|t| t.ready())
    }

    /// Total resident bytes across taps.
    pub fn bytes(&self) -> usize {
        self.taps.iter().map(|t| t.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Python-oracle parity: same update algebra as taylor_update_ref.
    fn ref_update(factors: &[Vec<f32>], feat: &[f32]) -> Vec<Vec<f32>> {
        let m1 = factors.len();
        let mut out = vec![feat.to_vec()];
        for i in 1..m1 {
            let prev: Vec<f32> =
                out[i - 1].iter().zip(&factors[i - 1]).map(|(a, b)| a - b).collect();
            out.push(prev);
        }
        out
    }

    #[test]
    fn refresh_matches_reference_algebra() {
        let mut cache = TapCache::new(3, 4, 5);
        let mut reference = vec![vec![0.0f32; 4]; 4];
        for s in 0..6 {
            let feat: Vec<f32> = (0..4).map(|i| ((s * 7 + i * 3) % 11) as f32).collect();
            reference = ref_update(&reference, &feat);
            cache.refresh(&feat);
            for (a, b) in cache.factors().iter().zip(&reference) {
                assert_eq!(a, b, "step {s}");
            }
        }
    }

    #[test]
    fn exact_on_linear_trajectories() {
        // On a linear feature trajectory the order-1+ Taylor prediction is
        // exact for any horizon (Δ¹/N is the exact slope).
        let n = 4.0f32;
        let f = |t: f32| 2.0 - 3.0 * t;
        let mut cache = TapCache::new(2, 1, 4);
        for j in 0..3 {
            cache.refresh(&[f(j as f32 * n)]);
        }
        for k in 1..=6 {
            let pred = cache.predict(k as f32, DraftKind::Taylor);
            let expect = f(8.0 + k as f32);
            assert!((pred[0] - expect).abs() < 1e-4, "k={k}: {} vs {expect}", pred[0]);
        }
    }

    #[test]
    fn higher_order_reduces_error_on_smooth_curves() {
        // Paper Eq. 2 is a Taylor *approximation* (its backward differences
        // carry O(N) derivative bias), so degree-2 curves are not exact —
        // but error must shrink monotonically with draft order, which is
        // exactly the Table-7 ordering (reuse > Adams-Bashforth > Taylor).
        let f = |t: f32| 1.0 + 2.0 * t + t * t;
        let mut cache = TapCache::new(2, 1, 2);
        for j in 0..4 {
            cache.refresh(&[f(j as f32 * 2.0)]);
        }
        let truth = f(8.0);
        let reuse = cache.predict(2.0, DraftKind::Reuse)[0];
        let ab = cache.predict(2.0, DraftKind::AdamsBashforth)[0];
        let taylor = cache.predict(2.0, DraftKind::Taylor)[0];
        assert_eq!(reuse, f(6.0)); // pure reuse = last refresh value
        assert!((taylor - truth).abs() < (ab - truth).abs());
        assert!((ab - truth).abs() < (reuse - truth).abs());
        // order-2 error bound: |N·k·f''/2| + higher terms (Thm G.1 flavor)
        assert!((taylor - truth).abs() <= 2.0 * 2.0 * 2.0 / 2.0 + 1e-3);
    }

    #[test]
    fn refresh_reuses_factor_capacity() {
        // the rolling update recycles buffers through the scratch swap, so
        // factor capacities are fixed after construction (zero-alloc path)
        let mut cache = TapCache::new(2, 16, 5);
        cache.refresh(&vec![1.0; 16]);
        let caps: Vec<usize> = cache.factors().iter().map(|f| f.capacity()).collect();
        for s in 0..10 {
            cache.refresh(&vec![s as f32; 16]);
        }
        let after: Vec<usize> = cache.factors().iter().map(|f| f.capacity()).collect();
        assert_eq!(caps, after);
    }

    #[test]
    fn refresh_iter_matches_slice_refresh() {
        let f1 = vec![1.0f32; 4];
        let f2 = vec![2.0f32; 4];
        let mut a = FeatureCache::new(2, 2, 4, 5);
        let mut b = FeatureCache::new(2, 2, 4, 5);
        a.refresh(3, &[&f1, &f2]);
        b.refresh_iter(3, [f1.as_slice(), f2.as_slice()].into_iter());
        for (ta, tb) in a.taps.iter().zip(&b.taps) {
            assert_eq!(ta.factors(), tb.factors());
        }
        assert_eq!(a.last_refresh_step, b.last_refresh_step);
    }

    #[test]
    fn warmup_caps_order() {
        let mut cache = TapCache::new(3, 2, 5);
        assert!(!cache.ready());
        cache.refresh(&[1.0, 2.0]);
        assert_eq!(cache.usable_order(), 0);
        // with a single refresh, Taylor falls back to reuse
        assert_eq!(cache.predict(3.0, DraftKind::Taylor), vec![1.0, 2.0]);
        cache.refresh(&[2.0, 4.0]);
        assert_eq!(cache.usable_order(), 1);
    }

    #[test]
    fn predict_into_matches_predict() {
        let mut cache = TapCache::new(2, 8, 3);
        for s in 0..3 {
            let feat: Vec<f32> = (0..8).map(|i| (s * i) as f32 * 0.5).collect();
            cache.refresh(&feat);
        }
        let a = cache.predict(2.0, DraftKind::Taylor);
        let mut b = vec![0.0; 8];
        cache.predict_into(2.0, DraftKind::Taylor, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn case_insensitive_legacy_parse() {
        assert_eq!(DraftKind::parse("Taylor"), Some(DraftKind::Taylor));
        assert_eq!(DraftKind::parse("AB"), Some(DraftKind::AdamsBashforth));
        assert_eq!(DraftKind::parse(" REUSE "), Some(DraftKind::Reuse));
        assert_eq!(DraftKind::parse("richardson"), None); // trait-only strategy
        assert_eq!(DraftKind::parse("spectral"), None); // trait-only strategy
    }

    #[test]
    fn history_view_mirrors_cache() {
        let mut cache = TapCache::new(2, 4, 5);
        cache.refresh(&[1.0; 4]);
        cache.refresh(&[2.0; 4]);
        let h = cache.history();
        assert_eq!(h.max_order(), 2);
        assert_eq!(h.usable_order(), 1);
        assert_eq!(h.interval(), 5.0);
        assert_eq!(h.feat_len(), 4);
        assert_eq!(h.factor(0), cache.factors()[0].as_slice());
    }

    #[test]
    fn extracted_tap_state_reinserts_bitwise() {
        // the checkpoint contract: factors + updates + interval fully
        // determine future predicts AND future refreshes
        let mut orig = TapCache::new(2, 4, 5);
        orig.refresh(&[1.0, 2.0, 3.0, 4.0]);
        orig.refresh(&[2.0, 4.0, 6.0, 8.0]);
        let mut restored =
            TapCache::from_parts(orig.factors().to_vec(), orig.updates(), orig.interval());
        assert_eq!(restored.usable_order(), orig.usable_order());
        assert_eq!(
            restored.predict(3.0, DraftKind::Taylor),
            orig.predict(3.0, DraftKind::Taylor)
        );
        // continued refreshes stay in lockstep (scratch carries no state)
        orig.refresh(&[5.0, 1.0, 0.0, -2.0]);
        restored.refresh(&[5.0, 1.0, 0.0, -2.0]);
        assert_eq!(orig.factors(), restored.factors());
        assert_eq!(orig.updates(), restored.updates());
    }

    #[test]
    fn feature_cache_bookkeeping() {
        let mut fc = FeatureCache::new(3, 2, 4, 5);
        assert!(!fc.ready());
        let f1 = vec![1.0f32; 4];
        fc.refresh(10, &[&f1, &f1, &f1]);
        assert!(fc.ready());
        assert_eq!(fc.k_for_step(13), Some(3.0));
        assert!(fc.bytes() > 0);
    }
}
