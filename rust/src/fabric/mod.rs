//! Multi-process serving fabric: one front-door router process fanning
//! jobs out over TCP to N worker processes (DESIGN.md §15, ROADMAP
//! item 3).
//!
//! The shard pool scales to one process's cores; the fabric scales past
//! one process (and, with real addresses, past one box) while keeping
//! the client-facing surface exactly the wire protocol v2 the
//! single-process server speaks:
//!
//! * [`router`] — the front door: speaks protocol v2 to clients on one
//!   port, maintains per-worker sessions (handshake, heartbeats,
//!   weighted routing on the workers' EWMA work gauges) on another, and
//!   re-queues a dead worker's in-flight jobs to live peers from their
//!   spilled SPCK checkpoints so accepted jobs complete instead of
//!   aborting.
//! * [`worker`] — one of today's
//!   [`EngineShardPool`](crate::coordinator::EngineShardPool) processes
//!   joined to a router: executes jobs, answers heartbeats with its
//!   shard work
//!   gauges, and ships checkpoint images of everything in flight at
//!   each heartbeat boundary (the spill contract that makes failover
//!   lossless).
//! * [`metrics`] — the Prometheus-style text rendering behind
//!   `op:"metrics"` on both router and workers.
//!
//! ## Fabric session protocol (JSON lines, one object per line)
//!
//! A worker dials the router's fabric port and leads with a hello; the
//! router acks with the worker's session id. Every other line is tagged
//! by a `"fabric"` key (never `"op"`, so a fabric line can never be
//! mistaken for a client op and vice versa):
//!
//! ```text
//! worker → {"fabric":"hello","magic":"SPFB","version":1,"shards":2}
//! router → {"ok":true,"fabric":"hello","magic":"SPFB","version":1,"worker":0}
//!
//! router → {"fabric":"job","id":7,"req":{...client submit body, seed pinned...}}
//! router → {"fabric":"resume","id":7,"policy":"speca:N=5,...","step":12,
//!           "bytes":"<hex SPCK image>","return_latent":false}
//! router → {"fabric":"cancel","id":7}
//! router → {"fabric":"ping","seq":41}
//! router → {"fabric":"bye"}
//!
//! worker → {"fabric":"pong","seq":41,"loads":[1,0],"work_us":[1800,0],
//!           "ckpts":[{"id":7,"step":12,"policy":"...","bytes":"..."}],
//!           "stats":{...shard counters...},"completed":9}
//! worker → {"fabric":"done","id":7,"reply":{...terminal v2 status...}}
//! worker → {"fabric":"error","error":"unknown fabric op 'x'"}
//! ```
//!
//! A peer that opens the fabric port without the hello (a v1 client, a
//! v2 client, a mistyped port) gets a structured `{"ok":false,...}`
//! error naming the expected handshake, then the connection closes — no
//! hang, no silent drop. Version skew is rejected the same way. Client
//! connections have the mirror-image guard: `op:"hello"` on any serving
//! port (router or worker) answers with the protocol name + version so
//! load generators can fail fast on a mismatched peer.
//!
//! Checkpoints travel as hex SPCK images plus the policy's canonical
//! [`Policy::describe`](crate::coordinator::Policy::describe) string —
//! the codec deliberately serializes neither policy nor job metadata,
//! and the receiving worker re-resolves both from the wire description
//! (`RequestCheckpoint::from_bytes` + `parse_policy`). Resume is
//! bitwise-identical, so a failed-over job's result is exactly the
//! result the dead worker would have produced.

pub mod metrics;
pub mod router;
pub mod worker;

pub use router::{spawn_router, RouterConfig, RouterHandle};
pub use worker::{run_worker, spawn_worker, WorkerConfig, WorkerHandle};

use crate::util::json::Json;

/// Fabric handshake magic (the first line of every worker session).
pub const FABRIC_MAGIC: &str = "SPFB";
/// Fabric session protocol version.
pub const FABRIC_VERSION: u64 = 1;
/// Client-facing wire protocol name (`op:"hello"` exchange).
pub const WIRE_PROTO: &str = "speca";
/// Client-facing wire protocol version (the v2 job-lifecycle surface).
pub const WIRE_VERSION: u64 = 2;

/// Lowercase hex encoding of a byte image (SPCK checkpoints on the
/// fabric wire; no external base64 dependency).
pub fn hex_encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push(char::from_digit((b >> 4) as u32, 16).unwrap());
        s.push(char::from_digit((b & 0xf) as u32, 16).unwrap());
    }
    s
}

/// Inverse of [`hex_encode`]; errors on odd length or a non-hex digit.
pub fn hex_decode(s: &str) -> Result<Vec<u8>, String> {
    let raw = s.as_bytes();
    if raw.len() % 2 != 0 {
        return Err(format!("hex image has odd length {}", raw.len()));
    }
    let digit = |c: u8| -> Result<u8, String> {
        (c as char)
            .to_digit(16)
            .map(|d| d as u8)
            .ok_or_else(|| format!("hex image has non-hex byte 0x{c:02x}"))
    };
    let mut out = Vec::with_capacity(raw.len() / 2);
    for pair in raw.chunks_exact(2) {
        out.push((digit(pair[0])? << 4) | digit(pair[1])?);
    }
    Ok(out)
}

/// The worker side of the fabric handshake line.
pub(crate) fn worker_hello(shards: usize) -> String {
    Json::obj(vec![
        ("fabric", Json::str("hello")),
        ("magic", Json::str(FABRIC_MAGIC)),
        ("version", Json::Num(FABRIC_VERSION as f64)),
        ("shards", Json::Num(shards as f64)),
    ])
    .dump()
}

/// Validate a fabric hello line; returns the worker's shard count. The
/// error string is the structured reply body for rejected peers — it
/// names what the port expects, so a v1/v2 client that dialed the
/// fabric port by mistake learns why instead of hanging.
pub(crate) fn check_worker_hello(line: &str) -> Result<usize, String> {
    let j = Json::parse(line).map_err(|_| {
        format!(
            "fabric port expects a {FABRIC_MAGIC} hello as the first line \
             (got a non-JSON line); this is not a client serving port"
        )
    })?;
    let Some(kind) = j.get("fabric").and_then(|f| f.as_str()) else {
        return Err(format!(
            "fabric port expects a {FABRIC_MAGIC} hello as the first line \
             (got a client op?); connect clients to the router's serving \
             address instead"
        ));
    };
    if kind != "hello" {
        return Err(format!("fabric session must start with 'hello', got '{kind}'"));
    }
    let magic = j.get("magic").and_then(|m| m.as_str()).unwrap_or("");
    if magic != FABRIC_MAGIC {
        return Err(format!("bad fabric magic '{magic}' (expected '{FABRIC_MAGIC}')"));
    }
    let version = j.get("version").and_then(|v| v.as_u64()).unwrap_or(0);
    if version != FABRIC_VERSION {
        return Err(format!(
            "unsupported fabric version {version} (this router speaks {FABRIC_VERSION})"
        ));
    }
    Ok(j.get("shards").and_then(|s| s.as_usize()).unwrap_or(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_round_trips() {
        let img: Vec<u8> = (0u8..=255).collect();
        assert_eq!(hex_decode(&hex_encode(&img)).unwrap(), img);
        assert_eq!(hex_encode(&[]), "");
        assert_eq!(hex_decode("").unwrap(), Vec::<u8>::new());
        assert!(hex_decode("abc").is_err(), "odd length");
        assert!(hex_decode("zz").is_err(), "non-hex digit");
    }

    #[test]
    fn handshake_accepts_itself_and_rejects_strangers() {
        assert_eq!(check_worker_hello(&worker_hello(4)).unwrap(), 4);
        // a v2 client op on the fabric port is a structured error
        let err = check_worker_hello(r#"{"op":"submit","cond":1}"#).unwrap_err();
        assert!(err.contains("SPFB"), "{err}");
        // version skew is named explicitly
        let skew = r#"{"fabric":"hello","magic":"SPFB","version":9}"#;
        let err = check_worker_hello(skew).unwrap_err();
        assert!(err.contains("version 9"), "{err}");
        assert!(check_worker_hello("not json").is_err());
    }
}
