//! Prometheus-style text exposition for `op:"metrics"` (DESIGN.md §15).
//!
//! Both serving surfaces export the same families: a worker (or any
//! single-process server) renders its own [`JobManager`] gauges with
//! [`render_manager_metrics`]; the router renders fabric-wide state —
//! per-worker shard gauges plus the fabric counters — in
//! [`router`](crate::fabric::router). The reply travels as one JSON
//! line `{"ok":true,"metrics":"..."}` whose `metrics` string is
//! standard exposition text (`# HELP` / `# TYPE` / samples), so any
//! Prometheus parser can scrape it once unwrapped.

use crate::coordinator::job::JobManager;
use crate::util::alloc;

/// Incremental Prometheus exposition-text builder: `# HELP`/`# TYPE`
/// headers once per family, then one sample line per call.
#[derive(Default)]
pub struct PromText {
    out: String,
}

impl PromText {
    /// Empty document.
    pub fn new() -> PromText {
        PromText::default()
    }

    /// Start a metric family (`kind` is `gauge` or `counter`).
    pub fn family(&mut self, name: &str, kind: &str, help: &str) -> &mut Self {
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
        self
    }

    /// Append one unlabelled sample of the current family.
    pub fn sample(&mut self, name: &str, value: f64) -> &mut Self {
        self.out.push_str(&format!("{name} {value}\n"));
        self
    }

    /// Append one labelled sample (`labels` are `key`/`value` pairs;
    /// values here are always numeric indices, so no escaping needed).
    pub fn labelled(&mut self, name: &str, labels: &[(&str, String)], value: f64) -> &mut Self {
        let body =
            labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect::<Vec<_>>().join(",");
        self.out.push_str(&format!("{name}{{{body}}} {value}\n"));
        self
    }

    /// Finish: the exposition document.
    pub fn finish(self) -> String {
        self.out
    }
}

/// Render one process's serving metrics: per-shard in-flight and
/// expected-work gauges (dead shards report `_up 0` and drop their
/// gauge samples, mirroring the `null` convention of `op:"stats"`),
/// job lifecycle counters, checkpoint counters
/// (`parked`/`resumed`/`stolen`/`migrated`), draft acceptance (the
/// paper's α and γ), the service-time EWMA, and the allocator probes
/// (zero unless the binary installs the counting allocator).
pub fn render_manager_metrics(manager: &JobManager) -> String {
    let stats = manager.stats();
    let counts = manager.counts();
    let loads = manager.shard_loads();
    let work = manager.shard_work_us();
    let mut p = PromText::new();

    p.family("speca_shard_up", "gauge", "1 if the shard worker is alive");
    for (i, l) in loads.iter().enumerate() {
        let up = if *l == usize::MAX { 0.0 } else { 1.0 };
        p.labelled("speca_shard_up", &[("shard", i.to_string())], up);
    }
    p.family("speca_shard_inflight", "gauge", "requests admitted or queued on the shard");
    for (i, l) in loads.iter().enumerate() {
        if *l != usize::MAX {
            p.labelled("speca_shard_inflight", &[("shard", i.to_string())], *l as f64);
        }
    }
    p.family(
        "speca_shard_work_us",
        "gauge",
        "EWMA-decayed expected remaining work on the shard (microsecond units)",
    );
    for (i, (l, w)) in loads.iter().zip(&work).enumerate() {
        if *l != usize::MAX {
            p.labelled("speca_shard_work_us", &[("shard", i.to_string())], *w as f64);
        }
    }

    p.family("speca_jobs_submitted_total", "counter", "jobs submitted");
    p.sample("speca_jobs_submitted_total", counts.submitted as f64);
    p.family("speca_jobs_completed_total", "counter", "jobs completed");
    p.sample("speca_jobs_completed_total", counts.completed as f64);
    p.family("speca_jobs_rejected_total", "counter", "jobs shed by admission or deadline");
    p.sample("speca_jobs_rejected_total", counts.rejected as f64);
    p.family("speca_jobs_cancelled_total", "counter", "jobs dropped by cancel tokens");
    p.sample("speca_jobs_cancelled_total", counts.cancelled as f64);
    p.family("speca_jobs_aborted_total", "counter", "jobs abandoned by dead shards");
    p.sample("speca_jobs_aborted_total", counts.aborted as f64);
    p.family("speca_jobs_live", "gauge", "jobs currently in a non-terminal state");
    p.sample("speca_jobs_live", manager.live() as f64);

    p.family("speca_checkpoints_parked_total", "counter", "checkpoints parked at step boundaries");
    p.sample("speca_checkpoints_parked_total", stats.parked as f64);
    p.family("speca_checkpoints_resumed_total", "counter", "checkpoints resumed into a slot");
    p.sample("speca_checkpoints_resumed_total", stats.resumed as f64);
    p.family("speca_units_stolen_total", "counter", "units pulled from loaded peers while idle");
    p.sample("speca_units_stolen_total", stats.stolen as f64);
    p.family("speca_units_migrated_total", "counter", "units received from dying peers");
    p.sample("speca_units_migrated_total", stats.migrated as f64);

    p.family("speca_engine_ticks_total", "counter", "engine ticks executed");
    p.sample("speca_engine_ticks_total", stats.ticks as f64);
    p.family("speca_flops_total", "counter", "booked FLOPs across all requests");
    p.sample("speca_flops_total", stats.flops.total() as f64);
    p.family("speca_spec_steps_total", "counter", "steps served speculatively");
    p.sample("speca_spec_steps_total", stats.flops.n_spec_steps as f64);
    p.family("speca_spec_rejects_total", "counter", "speculative steps rejected by verification");
    p.sample("speca_spec_rejects_total", stats.flops.n_rejects as f64);
    p.family("speca_draft_alpha", "gauge", "fraction of steps served speculatively (paper alpha)");
    p.sample("speca_draft_alpha", stats.flops.acceptance_rate());
    p.family("speca_draft_gamma", "gauge", "verify-to-full cost ratio (paper gamma)");
    p.sample("speca_draft_gamma", stats.flops.gamma());

    p.family("speca_est_service_ms", "gauge", "EWMA of completed-job latency in ms");
    p.sample("speca_est_service_ms", manager.est_service_ms());

    p.family("speca_alloc_calls_total", "counter", "allocator calls (0 without counting allocator)");
    p.sample("speca_alloc_calls_total", alloc::allocations() as f64);
    p.family("speca_dealloc_calls_total", "counter", "deallocations (0 without counting allocator)");
    p.sample("speca_dealloc_calls_total", alloc::deallocations() as f64);
    p.family("speca_alloc_bytes_total", "counter", "bytes allocated (0 without counting allocator)");
    p.sample("speca_alloc_bytes_total", alloc::allocated_bytes() as f64);

    p.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exposition_text_shape() {
        let mut p = PromText::new();
        p.family("x_total", "counter", "help text");
        p.sample("x_total", 3.0);
        p.labelled("x_total", &[("shard", "1".to_string())], 4.5);
        let text = p.finish();
        assert!(text.contains("# HELP x_total help text\n"));
        assert!(text.contains("# TYPE x_total counter\n"));
        assert!(text.contains("\nx_total 3\n"));
        assert!(text.contains("x_total{shard=\"1\"} 4.5\n"));
    }
}
