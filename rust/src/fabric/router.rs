//! The router side of the serving fabric: the front door clients dial.
//!
//! One process, two listeners. The **client port** speaks wire protocol
//! v2 exactly like a single-process server — submit/poll/wait/cancel,
//! the v1 `generate` shim, `stats`, `metrics`, `hello`, `shutdown` —
//! so existing clients and load generators work unchanged against a
//! fabric. The **fabric port** speaks the SPFB session protocol to
//! workers (see [`crate::fabric`] for the line grammar).
//!
//! Routing is work-weighted: each heartbeat reply carries the worker's
//! per-shard EWMA work gauges (the PR 5 cost model, summed), and a
//! submit goes to the live worker with the least expected work per
//! shard, plus a small optimistic booking per un-acknowledged
//! assignment so a burst between heartbeats doesn't pile onto one
//! worker.
//!
//! Failover (the no-lost-accepted-jobs contract, DESIGN.md §15): when
//! a worker's connection drops or it misses `miss_limit` consecutive
//! heartbeats, every non-terminal job it owned is re-queued to live
//! peers in ascending fabric-id order — from its last spilled SPCK
//! checkpoint when one exists (resumed bitwise-identically
//! mid-flight), else re-submitted from scratch under the same pinned
//! seed (identical result, recomputed). Only when no live peer remains
//! does a job abort, with a structured error.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::fabric::metrics::PromText;
use crate::fabric::{check_worker_hello, FABRIC_MAGIC, FABRIC_VERSION, WIRE_PROTO, WIRE_VERSION};
use crate::server::error_json;
use crate::util::alloc;
use crate::util::json::Json;

/// Optimistic per-assignment booking (µ-units) counted against a worker
/// until its next heartbeat reply refreshes the real gauges — one
/// nominal request, matching the pool's unit weight.
const ROUTER_BOOK_US: u64 = 1000;

/// Fabric router configuration.
pub struct RouterConfig {
    /// Client serving address (wire protocol v2; port 0 picks a port).
    pub addr: String,
    /// Fabric address workers join (`--workers-addr`).
    pub workers_addr: String,
    /// Maximum fabric jobs in a non-terminal state.
    pub max_queue: usize,
    /// Heartbeat cadence in milliseconds (clamped to ≥ 10).
    pub heartbeat_ms: u64,
    /// Consecutive unanswered heartbeats before a worker is declared
    /// dead and its jobs fail over (clamped to ≥ 1).
    pub miss_limit: u32,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            addr: "127.0.0.1:7433".into(),
            workers_addr: "127.0.0.1:7434".into(),
            max_queue: 4096,
            heartbeat_ms: 250,
            miss_limit: 3,
        }
    }
}

/// One worker session (index-stable: dead workers keep their slot and
/// report as `null`, mirroring the pool's dead-shard convention).
struct WorkerSession {
    alive: bool,
    writer: Arc<Mutex<TcpStream>>,
    shards: usize,
    /// Summed per-shard expected-work gauge from the last pong.
    work_us: u64,
    /// Summed per-shard in-flight count from the last pong.
    inflight: u64,
    /// Optimistic booking since the last pong.
    booked_us: u64,
    /// Consecutive heartbeats without a reply.
    missed: u32,
    /// A ping is outstanding (cleared by any pong).
    outstanding: bool,
    /// Last pong's `op:"stats"` body (the per-worker breakdown).
    stats: Json,
    /// Jobs completed on the worker (its own counter, from pongs).
    completed: u64,
}

/// A spilled checkpoint held for failover.
struct Ckpt {
    policy: String,
    step: u64,
    bytes: String,
}

/// One accepted fabric job.
struct FabricJob {
    owner: usize,
    /// The submit body (seed pinned) — enough to re-run from scratch.
    req: Json,
    return_latent: bool,
    /// Latest spilled image, if any heartbeat captured one.
    ckpt: Option<Ckpt>,
    /// Terminal reply line (`job`/`id` rewritten to the fabric id);
    /// `None` while in flight.
    reply: Option<String>,
    cancelled: bool,
}

struct FabricState {
    workers: Vec<WorkerSession>,
    jobs: HashMap<u64, FabricJob>,
    live_jobs: usize,
    seq: u64,
}

/// Shared router state: sessions, the job ledger, and the fabric
/// counters exported by `op:"metrics"`.
struct Fabric {
    state: Mutex<FabricState>,
    cv: Condvar,
    accepting: AtomicBool,
    running: AtomicBool,
    next_fid: AtomicU64,
    max_queue: usize,
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
    cancelled: AtomicU64,
    aborted: AtomicU64,
    heartbeats_missed: AtomicU64,
    failovers: AtomicU64,
    requeued: AtomicU64,
    shutdown: Mutex<Sender<()>>,
}

fn write_line(writer: &Mutex<TcpStream>, line: &str) -> bool {
    let mut w = writer.lock().unwrap();
    w.write_all(line.as_bytes()).is_ok() && w.write_all(b"\n").is_ok()
}

fn job_line(fid: u64, job: &FabricJob) -> String {
    Json::obj(vec![
        ("fabric", Json::str("job")),
        ("id", Json::Num(fid as f64)),
        ("req", job.req.clone()),
    ])
    .dump()
}

fn resume_line(fid: u64, job: &FabricJob, c: &Ckpt) -> String {
    Json::obj(vec![
        ("fabric", Json::str("resume")),
        ("id", Json::Num(fid as f64)),
        ("policy", Json::str(&c.policy)),
        ("step", Json::Num(c.step as f64)),
        ("bytes", Json::str(&c.bytes)),
        ("return_latent", Json::Bool(job.return_latent)),
    ])
    .dump()
}

/// Least expected work per shard among live workers.
fn pick_worker(g: &FabricState) -> Option<usize> {
    g.workers
        .iter()
        .enumerate()
        .filter(|(_, s)| s.alive)
        .min_by(|(_, a), (_, b)| {
            let wa = (a.work_us + a.booked_us) as f64 / a.shards.max(1) as f64;
            let wb = (b.work_us + b.booked_us) as f64 / b.shards.max(1) as f64;
            wa.total_cmp(&wb)
        })
        .map(|(i, _)| i)
}

impl Fabric {
    /// Bump the job counter matching a terminal reply's `state` label.
    fn classify(&self, label: &str) {
        match label {
            "completed" => &self.completed,
            "rejected" => &self.rejected,
            "cancelled" => &self.cancelled,
            _ => &self.aborted,
        }
        .fetch_add(1, Ordering::SeqCst);
    }

    /// Record a job's terminal reply (idempotent — the first terminal
    /// verdict wins; a stale duplicate from a slow ex-owner is
    /// dropped), rewriting the id fields to the fabric id and waking
    /// blocked waits. Caller holds the state lock.
    fn finish_job(&self, g: &mut FabricState, fid: u64, mut reply: Json) {
        let Some(job) = g.jobs.get_mut(&fid) else { return };
        if job.reply.is_some() {
            return;
        }
        if let Json::Obj(m) = &mut reply {
            m.insert("job".into(), Json::Num(fid as f64));
            if m.contains_key("id") {
                m.insert("id".into(), Json::Num(fid as f64));
            }
        }
        let label = reply.get("state").and_then(|s| s.as_str()).unwrap_or("aborted").to_string();
        self.classify(&label);
        job.reply = Some(reply.dump());
        g.live_jobs -= 1;
        self.cv.notify_all();
    }

    /// Declare worker `idx` dead and fail its jobs over: every
    /// non-terminal job it owned is re-queued to a live peer in
    /// ascending fabric-id order — preferring its spilled checkpoint,
    /// else a from-scratch re-submit of the pinned-seed body — and
    /// aborts only when no live peer remains. Idempotent; a no-op
    /// during teardown (a drained worker leaving is not a failure).
    fn mark_dead(self: &Arc<Self>, idx: usize) {
        let mut sends = Vec::new();
        {
            let mut g = self.state.lock().unwrap();
            let Some(s) = g.workers.get_mut(idx) else { return };
            if !s.alive {
                return;
            }
            s.alive = false;
            // silence the session so no late line races the takeover
            let dead_writer = s.writer.clone();
            let _ = dead_writer.lock().unwrap().shutdown(Shutdown::Both);
            if !self.running.load(Ordering::SeqCst) {
                return;
            }
            self.failovers.fetch_add(1, Ordering::SeqCst);
            let mut orphans: Vec<u64> = g
                .jobs
                .iter()
                .filter(|(_, j)| j.owner == idx && j.reply.is_none())
                .map(|(f, _)| *f)
                .collect();
            orphans.sort_unstable();
            for fid in orphans {
                if g.jobs[&fid].cancelled {
                    // its forwarded cancel died with the worker —
                    // finish the cancellation here
                    let reply = Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        ("state", Json::str("cancelled")),
                        ("error", Json::str("cancelled by client")),
                    ]);
                    self.finish_job(&mut g, fid, reply);
                    continue;
                }
                match pick_worker(&g) {
                    None => {
                        let reply = Json::obj(vec![
                            ("ok", Json::Bool(false)),
                            ("state", Json::str("aborted")),
                            (
                                "error",
                                Json::str(&format!(
                                    "worker {idx} died with no live peers to adopt the job"
                                )),
                            ),
                        ]);
                        self.finish_job(&mut g, fid, reply);
                    }
                    Some(t) => {
                        let line = {
                            let job = g.jobs.get_mut(&fid).unwrap();
                            job.owner = t;
                            match &job.ckpt {
                                Some(c) => resume_line(fid, job, c),
                                None => job_line(fid, job),
                            }
                        };
                        g.workers[t].booked_us += ROUTER_BOOK_US;
                        self.requeued.fetch_add(1, Ordering::SeqCst);
                        sends.push((t, g.workers[t].writer.clone(), line));
                    }
                }
            }
        }
        // writes happen outside the state lock; a failed write means
        // the adopter is dead too — recurse (bounded by worker count)
        for (t, w, line) in sends {
            if !write_line(&w, &line) {
                self.mark_dead(t);
            }
        }
    }

    /// Fold a heartbeat reply into the session gauges and stash any
    /// spilled checkpoints for jobs this worker still owns.
    fn note_pong(&self, idx: usize, msg: &Json) {
        let sum = |key: &str| -> u64 {
            msg.get(key)
                .and_then(|a| a.as_arr())
                .map(|a| a.iter().filter_map(|v| v.as_u64()).sum())
                .unwrap_or(0)
        };
        let mut g = self.state.lock().unwrap();
        if let Some(s) = g.workers.get_mut(idx) {
            if s.alive {
                s.outstanding = false;
                s.missed = 0;
                s.booked_us = 0;
                s.inflight = sum("loads");
                s.work_us = sum("work_us");
                if let Some(c) = msg.get("completed").and_then(|c| c.as_u64()) {
                    s.completed = c;
                }
                if let Some(st) = msg.get("stats") {
                    s.stats = st.clone();
                }
            }
        }
        if let Some(arr) = msg.get("ckpts").and_then(|c| c.as_arr()) {
            for c in arr {
                let (Some(fid), Some(policy), Some(hex)) = (
                    c.get("id").and_then(|i| i.as_u64()),
                    c.get("policy").and_then(|p| p.as_str()),
                    c.get("bytes").and_then(|b| b.as_str()),
                ) else {
                    continue;
                };
                let step = c.get("step").and_then(|s| s.as_u64()).unwrap_or(0);
                if let Some(job) = g.jobs.get_mut(&fid) {
                    if job.owner == idx && job.reply.is_none() {
                        job.ckpt =
                            Some(Ckpt { policy: policy.to_string(), step, bytes: hex.to_string() });
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Worker-facing side: handshake, per-session reader, heartbeats
// ---------------------------------------------------------------------------

/// Serve one fabric connection: handshake (structured rejection for
/// anything that isn't a well-formed SPFB hello — a v1/v2 client on the
/// wrong port learns why instead of hanging), then fold the session's
/// pong/done/failed stream into router state until EOF.
fn serve_fabric_conn(fabric: &Arc<Fabric>, stream: TcpStream) {
    let Ok(mut writer) = stream.try_clone() else { return };
    let mut reader = BufReader::new(stream);
    let mut hello = String::new();
    if reader.read_line(&mut hello).unwrap_or(0) == 0 {
        return;
    }
    let shards = match check_worker_hello(hello.trim()) {
        Err(e) => {
            let _ = writer.write_all(error_json(&e).as_bytes());
            let _ = writer.write_all(b"\n");
            return;
        }
        Ok(s) => s,
    };
    let Ok(session_writer) = writer.try_clone() else { return };
    let idx = {
        let mut g = fabric.state.lock().unwrap();
        g.workers.push(WorkerSession {
            alive: true,
            writer: Arc::new(Mutex::new(session_writer)),
            shards,
            work_us: 0,
            inflight: 0,
            booked_us: 0,
            missed: 0,
            outstanding: false,
            stats: Json::Null,
            completed: 0,
        });
        g.workers.len() - 1
    };
    let ack = Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("fabric", Json::str("hello")),
        ("magic", Json::str(FABRIC_MAGIC)),
        ("version", Json::Num(FABRIC_VERSION as f64)),
        ("worker", Json::Num(idx as f64)),
    ])
    .dump();
    if writer.write_all(ack.as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
        fabric.mark_dead(idx);
        return;
    }
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let Ok(msg) = Json::parse(&line) else { continue };
        match msg.get("fabric").and_then(|k| k.as_str()).unwrap_or("") {
            "pong" => fabric.note_pong(idx, &msg),
            "done" => {
                let Json::Obj(mut m) = msg else { continue };
                let fid = m.get("id").and_then(|i| i.as_u64());
                let reply = m.remove("reply");
                if let (Some(fid), Some(reply)) = (fid, reply) {
                    let mut g = fabric.state.lock().unwrap();
                    // a done from a worker the job failed away from is
                    // stale — the current owner's verdict is canonical
                    if g.jobs.get(&fid).map(|j| j.owner == idx).unwrap_or(false) {
                        fabric.finish_job(&mut g, fid, reply);
                    }
                }
            }
            "failed" => {
                let fid = msg.get("id").and_then(|i| i.as_u64());
                let err = msg.get("error").and_then(|e| e.as_str()).unwrap_or("failed on worker");
                if let Some(fid) = fid {
                    let reply = Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        ("state", Json::str("aborted")),
                        ("error", Json::str(err)),
                    ]);
                    let mut g = fabric.state.lock().unwrap();
                    if g.jobs.get(&fid).map(|j| j.owner == idx).unwrap_or(false) {
                        fabric.finish_job(&mut g, fid, reply);
                    }
                }
            }
            "error" => {
                let err = msg.get("error").and_then(|e| e.as_str()).unwrap_or("?");
                eprintln!("speca: fabric worker {idx} reported: {err}");
            }
            _ => {}
        }
    }
    fabric.mark_dead(idx);
}

/// Heartbeat pacemaker: every period, ping each live worker; a worker
/// whose previous ping is still unanswered accrues a miss (the
/// `heartbeats_missed` counter), and `miss_limit` consecutive misses
/// declare it dead.
fn heartbeat_loop(fabric: &Arc<Fabric>, period: Duration, miss_limit: u32) {
    while fabric.running.load(Ordering::SeqCst) {
        thread::sleep(period);
        let mut pings = Vec::new();
        let mut dead = Vec::new();
        {
            let mut g = fabric.state.lock().unwrap();
            g.seq += 1;
            let seq = g.seq;
            for (i, s) in g.workers.iter_mut().enumerate() {
                if !s.alive {
                    continue;
                }
                if s.outstanding {
                    s.missed += 1;
                    fabric.heartbeats_missed.fetch_add(1, Ordering::SeqCst);
                    if s.missed >= miss_limit {
                        dead.push(i);
                        continue;
                    }
                }
                s.outstanding = true;
                let line = Json::obj(vec![
                    ("fabric", Json::str("ping")),
                    ("seq", Json::Num(seq as f64)),
                ])
                .dump();
                pings.push((i, s.writer.clone(), line));
            }
        }
        for i in dead {
            fabric.mark_dead(i);
        }
        for (i, w, line) in pings {
            if !write_line(&w, &line) {
                fabric.mark_dead(i);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Client-facing side: wire protocol v2 over the fabric
// ---------------------------------------------------------------------------

/// Accept a submit body: pin the seed (failover re-execution must be
/// deterministic, so a client that names no seed gets the fabric id —
/// the same default a single-process server applies), pick the least
/// loaded live worker, ledger the job, forward it. Returns the ack
/// line plus the fabric id when the job was accepted.
fn submit_inner(fabric: &Arc<Fabric>, req: &Json) -> (String, Option<u64>) {
    if !fabric.accepting.load(Ordering::SeqCst) {
        return (error_json("server is shutting down"), None);
    }
    let Some(body) = req.as_obj() else {
        return (error_json("submit body must be a JSON object"), None);
    };
    let fid = fabric.next_fid.fetch_add(1, Ordering::SeqCst);
    fabric.submitted.fetch_add(1, Ordering::SeqCst);
    let mut body = body.clone();
    body.remove("op");
    body.entry("seed".to_string()).or_insert(Json::Num(fid as f64));
    let return_latent = body.get("return_latent").and_then(|b| b.as_bool()).unwrap_or(false);
    let verdict = |ok: bool, state: &str, error: &str| {
        Json::obj(vec![
            ("ok", Json::Bool(ok)),
            ("job", Json::Num(fid as f64)),
            ("state", Json::str(state)),
            ("error", Json::str(error)),
        ])
        .dump()
    };
    let (target, writer, line) = {
        let mut g = fabric.state.lock().unwrap();
        if g.live_jobs >= fabric.max_queue {
            fabric.rejected.fetch_add(1, Ordering::SeqCst);
            return (verdict(false, "rejected", "queue full"), None);
        }
        let Some(t) = pick_worker(&g) else {
            fabric.aborted.fetch_add(1, Ordering::SeqCst);
            return (verdict(false, "aborted", "no live workers joined to this router"), None);
        };
        let job = FabricJob {
            owner: t,
            req: Json::Obj(body),
            return_latent,
            ckpt: None,
            reply: None,
            cancelled: false,
        };
        let line = job_line(fid, &job);
        g.jobs.insert(fid, job);
        g.live_jobs += 1;
        g.workers[t].booked_us += ROUTER_BOOK_US;
        (t, g.workers[t].writer.clone(), line)
    };
    if !write_line(&writer, &line) {
        // the owner just died: failover re-queues this job too
        fabric.mark_dead(target);
    }
    let ack = Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("job", Json::Num(fid as f64)),
        ("state", Json::str("queued")),
    ])
    .dump();
    (ack, Some(fid))
}

/// Non-terminal status line for a job currently owned by `owner`.
fn inflight_json(fid: u64, owner: usize) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("job", Json::Num(fid as f64)),
        ("state", Json::str("admitted")),
        ("worker", Json::Num(owner as f64)),
    ])
}

fn fid_of(req: &Json) -> Result<u64, String> {
    req.get("job").and_then(|j| j.as_u64()).ok_or_else(|| "missing numeric 'job' field".into())
}

fn handle_poll(fabric: &Arc<Fabric>, req: &Json) -> String {
    let fid = match fid_of(req) {
        Ok(f) => f,
        Err(e) => return error_json(&e),
    };
    let g = fabric.state.lock().unwrap();
    match g.jobs.get(&fid) {
        None => error_json(&format!("unknown job {fid}")),
        Some(j) => match &j.reply {
            Some(r) => r.clone(),
            None => inflight_json(fid, j.owner).dump(),
        },
    }
}

/// `op:"wait"`: park on the condvar until the job's terminal reply
/// lands (consuming the ledger entry, like a server-side wait) or the
/// timeout passes.
fn handle_wait(fabric: &Arc<Fabric>, req: &Json) -> String {
    let fid = match fid_of(req) {
        Ok(f) => f,
        Err(e) => return error_json(&e),
    };
    let deadline = req
        .get("timeout_ms")
        .and_then(|t| t.as_f64())
        .map(|ms| Instant::now() + Duration::from_millis(ms.max(0.0) as u64));
    let mut g = fabric.state.lock().unwrap();
    loop {
        let owner = match g.jobs.get(&fid) {
            None => return error_json(&format!("unknown job {fid}")),
            Some(j) if j.reply.is_some() => {
                let job = g.jobs.remove(&fid).unwrap();
                return job.reply.unwrap();
            }
            Some(j) => j.owner,
        };
        match deadline {
            None => g = fabric.cv.wait(g).unwrap(),
            Some(dl) => {
                let now = Instant::now();
                if now >= dl {
                    let mut j = inflight_json(fid, owner);
                    if let Json::Obj(m) = &mut j {
                        m.insert("timed_out".into(), Json::Bool(true));
                    }
                    return j.dump();
                }
                let (g2, _) = fabric.cv.wait_timeout(g, dl - now).unwrap();
                g = g2;
            }
        }
    }
}

fn handle_cancel(fabric: &Arc<Fabric>, req: &Json) -> String {
    if req.get("job").is_none() && req.get("group").is_some() {
        return error_json("group cancel is not supported by the fabric router (cancel by job)");
    }
    let fid = match fid_of(req) {
        Ok(f) => f,
        Err(e) => return error_json(&e),
    };
    let forward = {
        let mut g = fabric.state.lock().unwrap();
        let Some(j) = g.jobs.get_mut(&fid) else {
            return error_json(&format!("unknown job {fid}"));
        };
        if j.reply.is_some() {
            None
        } else {
            j.cancelled = true;
            let owner = j.owner;
            let line = Json::obj(vec![
                ("fabric", Json::str("cancel")),
                ("id", Json::Num(fid as f64)),
            ])
            .dump();
            Some((owner, g.workers[owner].writer.clone(), line))
        }
    };
    if let Some((owner, w, line)) = forward {
        if !write_line(&w, &line) {
            fabric.mark_dead(owner);
        }
    }
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("job", Json::Num(fid as f64)),
        ("state", Json::str("cancelling")),
    ])
    .dump()
}

/// Aggregated `op:"stats"`: the per-worker breakdown (each live
/// worker's own stats body from its last heartbeat; dead workers are
/// `null`, like dead shards) plus fabric-wide totals and counters.
fn handle_stats(fabric: &Arc<Fabric>) -> String {
    let g = fabric.state.lock().unwrap();
    let live = g.workers.iter().filter(|s| s.alive).count();
    let breakdown = Json::Arr(
        g.workers
            .iter()
            .map(|s| if s.alive { s.stats.clone() } else { Json::Null })
            .collect(),
    );
    let completed: u64 = g.workers.iter().filter(|s| s.alive).map(|s| s.completed).sum();
    let inflight: u64 = g.workers.iter().filter(|s| s.alive).map(|s| s.inflight).sum();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("role", Json::str("router")),
        ("workers", breakdown),
        ("workers_total", Json::Num(g.workers.len() as f64)),
        ("workers_live", Json::Num(live as f64)),
        ("completed", Json::Num(completed as f64)),
        ("inflight", Json::Num(inflight as f64)),
        ("failovers", Json::Num(fabric.failovers.load(Ordering::SeqCst) as f64)),
        ("requeued_jobs", Json::Num(fabric.requeued.load(Ordering::SeqCst) as f64)),
        (
            "heartbeats_missed",
            Json::Num(fabric.heartbeats_missed.load(Ordering::SeqCst) as f64),
        ),
        (
            "jobs",
            Json::obj(vec![
                ("submitted", Json::Num(fabric.submitted.load(Ordering::SeqCst) as f64)),
                ("completed", Json::Num(fabric.completed.load(Ordering::SeqCst) as f64)),
                ("rejected", Json::Num(fabric.rejected.load(Ordering::SeqCst) as f64)),
                ("cancelled", Json::Num(fabric.cancelled.load(Ordering::SeqCst) as f64)),
                ("aborted", Json::Num(fabric.aborted.load(Ordering::SeqCst) as f64)),
                ("live", Json::Num(g.live_jobs as f64)),
            ]),
        ),
    ])
    .dump()
}

/// Router `op:"metrics"`: fabric counters, per-worker gauges (plus the
/// per-shard breakdown each worker reported in its last heartbeat),
/// and this process's allocator probes.
fn handle_metrics(fabric: &Arc<Fabric>) -> String {
    let mut p = PromText::new();
    {
        let g = fabric.state.lock().unwrap();
        let live = g.workers.iter().filter(|s| s.alive).count();
        p.family("speca_workers_total", "gauge", "fabric workers ever joined");
        p.sample("speca_workers_total", g.workers.len() as f64);
        p.family("speca_workers_live", "gauge", "fabric workers currently live");
        p.sample("speca_workers_live", live as f64);
        p.family("speca_worker_up", "gauge", "1 if the worker session is live");
        for (i, s) in g.workers.iter().enumerate() {
            let up = if s.alive { 1.0 } else { 0.0 };
            p.labelled("speca_worker_up", &[("worker", i.to_string())], up);
        }
        p.family("speca_worker_inflight", "gauge", "jobs in flight on the worker (last pong)");
        for (i, s) in g.workers.iter().enumerate().filter(|(_, s)| s.alive) {
            p.labelled("speca_worker_inflight", &[("worker", i.to_string())], s.inflight as f64);
        }
        p.family("speca_worker_work_us", "gauge", "expected remaining work (last pong, µ-units)");
        for (i, s) in g.workers.iter().enumerate().filter(|(_, s)| s.alive) {
            p.labelled("speca_worker_work_us", &[("worker", i.to_string())], s.work_us as f64);
        }
        p.family(
            "speca_worker_shard_inflight",
            "gauge",
            "per-shard in-flight on the worker (last pong)",
        );
        for (i, s) in g.workers.iter().enumerate().filter(|(_, s)| s.alive) {
            if let Some(loads) = s.stats.get("shard_loads").and_then(|l| l.as_arr()) {
                for (shard, l) in loads.iter().enumerate() {
                    if let Some(v) = l.as_f64() {
                        let labels =
                            [("worker", i.to_string()), ("shard", shard.to_string())];
                        p.labelled("speca_worker_shard_inflight", &labels, v);
                    }
                }
            }
        }
        p.family("speca_worker_draft_alpha", "gauge", "worker speculative acceptance (alpha)");
        for (i, s) in g.workers.iter().enumerate().filter(|(_, s)| s.alive) {
            if let Some(a) = s.stats.get("alpha").and_then(|a| a.as_f64()) {
                p.labelled("speca_worker_draft_alpha", &[("worker", i.to_string())], a);
            }
        }
        p.family("speca_router_jobs_live", "gauge", "fabric jobs in a non-terminal state");
        p.sample("speca_router_jobs_live", g.live_jobs as f64);
    }
    let counters: [(&str, &AtomicU64, &str); 8] = [
        ("speca_router_jobs_submitted_total", &fabric.submitted, "jobs accepted by the router"),
        ("speca_router_jobs_completed_total", &fabric.completed, "jobs finished normally"),
        ("speca_router_jobs_rejected_total", &fabric.rejected, "jobs shed by admission"),
        ("speca_router_jobs_cancelled_total", &fabric.cancelled, "jobs cancelled"),
        ("speca_router_jobs_aborted_total", &fabric.aborted, "jobs lost (no live peers)"),
        ("speca_heartbeats_missed_total", &fabric.heartbeats_missed, "unanswered heartbeats"),
        ("speca_failovers_total", &fabric.failovers, "workers declared dead with failover"),
        ("speca_requeued_jobs_total", &fabric.requeued, "jobs re-queued off dead workers"),
    ];
    for (name, c, help) in counters {
        p.family(name, "counter", help);
        p.sample(name, c.load(Ordering::SeqCst) as f64);
    }
    p.family("speca_alloc_calls_total", "counter", "allocator calls (0 without counting allocator)");
    p.sample("speca_alloc_calls_total", alloc::allocations() as f64);
    p.family("speca_dealloc_calls_total", "counter", "deallocations (0 without counting allocator)");
    p.sample("speca_dealloc_calls_total", alloc::deallocations() as f64);
    p.family("speca_alloc_bytes_total", "counter", "bytes allocated (0 without counting allocator)");
    p.sample("speca_alloc_bytes_total", alloc::allocated_bytes() as f64);
    Json::obj(vec![("ok", Json::Bool(true)), ("metrics", Json::str(&p.finish()))]).dump()
}

fn handle_hello(fabric: &Arc<Fabric>, req: &Json) -> String {
    let proto = req.get("proto").and_then(|p| p.as_str()).unwrap_or(WIRE_PROTO);
    if proto != WIRE_PROTO {
        return error_json(&format!(
            "unknown protocol '{proto}' (this port speaks '{WIRE_PROTO}' v{WIRE_VERSION})"
        ));
    }
    let version = req.get("version").and_then(|v| v.as_u64()).unwrap_or(WIRE_VERSION);
    if version != WIRE_VERSION {
        return error_json(&format!(
            "unsupported protocol version {version} (this port speaks v{WIRE_VERSION})"
        ));
    }
    let live = fabric.state.lock().unwrap().workers.iter().filter(|s| s.alive).count();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("proto", Json::str(WIRE_PROTO)),
        ("version", Json::Num(WIRE_VERSION as f64)),
        ("role", Json::str("router")),
        ("workers_live", Json::Num(live as f64)),
    ])
    .dump()
}

/// One client connection: the v2 op surface, terminated by EOF.
fn serve_client_conn(fabric: &Arc<Fabric>, stream: TcpStream) {
    let Ok(mut writer) = stream.try_clone() else { return };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply_line = match Json::parse(&line) {
            Err(e) => error_json(&e.to_string()),
            Ok(req) => {
                let op = req.get("op").and_then(|o| o.as_str()).unwrap_or("generate");
                match op {
                    "shutdown" => {
                        fabric.accepting.store(false, Ordering::SeqCst);
                        let _ = fabric.shutdown.lock().unwrap().send(());
                        Json::obj(vec![("ok", Json::Bool(true))]).dump()
                    }
                    "hello" => handle_hello(fabric, &req),
                    "stats" => handle_stats(fabric),
                    "metrics" => handle_metrics(fabric),
                    "submit" => submit_inner(fabric, &req).0,
                    "poll" => handle_poll(fabric, &req),
                    "wait" => handle_wait(fabric, &req),
                    "cancel" => handle_cancel(fabric, &req),
                    // v1 shim, fabric edition: submit + consuming wait
                    "generate" => match submit_inner(fabric, &req) {
                        (ack, None) => ack,
                        (_, Some(fid)) => {
                            let body = Json::obj(vec![("job", Json::Num(fid as f64))]);
                            handle_wait(fabric, &body)
                        }
                    },
                    other => error_json(&format!("unknown op '{other}'")),
                }
            }
        };
        if writer.write_all(reply_line.as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
            break;
        }
    }
}

// ---------------------------------------------------------------------------
// Lifecycle
// ---------------------------------------------------------------------------

/// A running fabric router. Obtained from [`spawn_router`]; call
/// [`RouterHandle::join`] to block until an `op:"shutdown"` arrives and
/// tear the fabric down.
pub struct RouterHandle {
    fabric: Arc<Fabric>,
    addr: SocketAddr,
    workers_addr: SocketAddr,
    shutdown_rx: Receiver<()>,
    acceptors: Vec<JoinHandle<()>>,
    heartbeat: JoinHandle<()>,
}

impl RouterHandle {
    /// The client serving address the router bound.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The fabric address workers join.
    pub fn workers_addr(&self) -> SocketAddr {
        self.workers_addr
    }

    /// Live worker sessions right now (spin on this after spawning
    /// workers so a bench doesn't race the joins).
    pub fn workers_live(&self) -> usize {
        self.fabric.state.lock().unwrap().workers.iter().filter(|s| s.alive).count()
    }

    /// Workers declared dead with failover, so far.
    pub fn failovers(&self) -> u64 {
        self.fabric.failovers.load(Ordering::SeqCst)
    }

    /// Jobs re-queued off dead workers, so far.
    pub fn requeued_jobs(&self) -> u64 {
        self.fabric.requeued.load(Ordering::SeqCst)
    }

    /// Block until a client `op:"shutdown"` arrives, then tear down:
    /// stop accepting, stop the pacemaker, say `bye` to live workers
    /// (they drain their pools and exit), close everything.
    pub fn join(self) -> Result<()> {
        let _ = self.shutdown_rx.recv();
        self.fabric.accepting.store(false, Ordering::SeqCst);
        self.fabric.running.store(false, Ordering::SeqCst);
        // wake both accept loops so they observe the cleared flag
        let _ = TcpStream::connect(self.addr);
        let _ = TcpStream::connect(self.workers_addr);
        for h in self.acceptors {
            let _ = h.join();
        }
        let _ = self.heartbeat.join();
        let byes: Vec<_> = {
            let g = self.fabric.state.lock().unwrap();
            g.workers.iter().filter(|s| s.alive).map(|s| s.writer.clone()).collect()
        };
        let bye = Json::obj(vec![("fabric", Json::str("bye"))]).dump();
        for w in byes {
            let _ = write_line(&w, &bye);
            let _ = w.lock().unwrap().shutdown(Shutdown::Both);
        }
        Ok(())
    }
}

/// Spawn a fabric router: bind the client and fabric listeners, start
/// the acceptors and the heartbeat pacemaker. Returns immediately;
/// workers join (and leave) at any time.
pub fn spawn_router(cfg: &RouterConfig) -> Result<RouterHandle> {
    let client_listener = TcpListener::bind(&cfg.addr)?;
    let fabric_listener = TcpListener::bind(&cfg.workers_addr)?;
    let addr = client_listener.local_addr()?;
    let workers_addr = fabric_listener.local_addr()?;
    let (shutdown_tx, shutdown_rx) = channel::<()>();
    let fabric = Arc::new(Fabric {
        state: Mutex::new(FabricState {
            workers: Vec::new(),
            jobs: HashMap::new(),
            live_jobs: 0,
            seq: 0,
        }),
        cv: Condvar::new(),
        accepting: AtomicBool::new(true),
        running: AtomicBool::new(true),
        next_fid: AtomicU64::new(0),
        max_queue: cfg.max_queue.max(1),
        submitted: AtomicU64::new(0),
        completed: AtomicU64::new(0),
        rejected: AtomicU64::new(0),
        cancelled: AtomicU64::new(0),
        aborted: AtomicU64::new(0),
        heartbeats_missed: AtomicU64::new(0),
        failovers: AtomicU64::new(0),
        requeued: AtomicU64::new(0),
        shutdown: Mutex::new(shutdown_tx),
    });
    let fab_acceptor = {
        let fabric = fabric.clone();
        thread::Builder::new()
            .name("speca-fabric-acceptor".into())
            .spawn(move || {
                for stream in fabric_listener.incoming() {
                    if !fabric.accepting.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { break };
                    let fabric = fabric.clone();
                    thread::spawn(move || serve_fabric_conn(&fabric, stream));
                }
            })
            .expect("spawning fabric acceptor")
    };
    let client_acceptor = {
        let fabric = fabric.clone();
        thread::Builder::new()
            .name("speca-router-acceptor".into())
            .spawn(move || {
                for stream in client_listener.incoming() {
                    if !fabric.accepting.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { break };
                    let fabric = fabric.clone();
                    thread::spawn(move || serve_client_conn(&fabric, stream));
                }
            })
            .expect("spawning router client acceptor")
    };
    let heartbeat = {
        let fabric = fabric.clone();
        let period = Duration::from_millis(cfg.heartbeat_ms.max(10));
        let miss_limit = cfg.miss_limit.max(1);
        thread::Builder::new()
            .name("speca-fabric-heartbeat".into())
            .spawn(move || heartbeat_loop(&fabric, period, miss_limit))
            .expect("spawning fabric heartbeat")
    };
    eprintln!("speca: fabric router serving clients on {addr}, workers on {workers_addr}");
    Ok(RouterHandle {
        fabric,
        addr,
        workers_addr,
        shutdown_rx,
        acceptors: vec![fab_acceptor, client_acceptor],
        heartbeat,
    })
}
