//! The worker side of the serving fabric: one
//! [`EngineShardPool`](crate::coordinator::EngineShardPool) process
//! joined to a router.
//!
//! A worker dials the router's fabric port, completes the SPFB
//! handshake, then serves the fabric session from one loop:
//!
//! * `job` — a client submit body the router forwarded (seed already
//!   pinned): submitted through the exact server-side submit path, with
//!   a detached waiter thread shipping the terminal reply back as a
//!   `done` line the moment the job finishes.
//! * `resume` — a spilled SPCK checkpoint from a dead peer: decoded,
//!   its policy re-resolved from the canonical description, and resumed
//!   via [`JobManager::submit_checkpoint`] — bitwise-identical to the
//!   run the dead worker would have finished.
//! * `ping` — answered with a `pong` carrying the shard load/work
//!   gauges (weighted routing), the full `op:"stats"` body, and a
//!   checkpoint image of everything in flight
//!   ([`JobManager::spill`]) — the spill contract that makes router-side
//!   failover lossless.
//! * `cancel` / `bye` / anything else — forwarded cancels, graceful
//!   drain, structured errors.
//!
//! The worker also runs the standard client listener on its own port
//! (`op:"stats"`, `op:"metrics"`, direct submits), so a fabric worker
//! is a strict superset of a single-process server.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};

use anyhow::{anyhow, bail, Result};

use crate::cache::Draft;
use crate::coordinator::job::{JobManager, JobStatus};
use crate::coordinator::state::RequestCheckpoint;
use crate::coordinator::{EngineConfig, JobMeta, PoolConfig, RouterPolicy};
use crate::fabric::{hex_decode, hex_encode, worker_hello};
use crate::runtime::ModelBackend;
use crate::server::{spawn_client_listener, stats_pairs, status_json, submit_from_json, ConnCtx};
use crate::util::json::Json;
use crate::workload::parse_policy;

/// Fabric worker configuration.
pub struct WorkerConfig {
    /// Router fabric address to join (`speca serve --fabric-worker
    /// --join <addr>`).
    pub join: String,
    /// Local client serving address (port 0 picks a free port).
    pub addr: String,
    /// Maximum jobs in a non-terminal state on this worker.
    pub max_queue: usize,
    /// Engine worker threads (shards) in this process.
    pub shards: usize,
    /// How submissions spread over this worker's shards.
    pub router: RouterPolicy,
    /// Default draft strategy for SpeCa requests that name none.
    pub default_draft: Option<Draft>,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            join: "127.0.0.1:7434".into(),
            addr: "127.0.0.1:0".into(),
            max_queue: 1024,
            shards: 1,
            router: RouterPolicy::LeastLoaded,
            default_draft: None,
        }
    }
}

/// A running fabric worker: the shard pool, its fabric session, and its
/// client listener. Obtained from [`spawn_worker`]; end it with
/// [`WorkerHandle::join`] (graceful drain) or [`WorkerHandle::kill`]
/// (abrupt death, for failover tests).
pub struct WorkerHandle {
    manager: Arc<JobManager>,
    fabric: TcpStream,
    accepting: Arc<AtomicBool>,
    client_addr: SocketAddr,
    loop_handle: JoinHandle<()>,
    listener_handle: JoinHandle<()>,
}

impl WorkerHandle {
    /// The client serving address this worker bound (useful with
    /// `addr: "127.0.0.1:0"`).
    pub fn client_addr(&self) -> SocketAddr {
        self.client_addr
    }

    /// The worker's job manager (direct submits, stats in tests).
    pub fn manager(&self) -> &Arc<JobManager> {
        &self.manager
    }

    /// Simulate abrupt process death: the fabric socket dies **first**
    /// (so no post-death message can reach the router — exactly what a
    /// crash looks like from the other end), then the pool abandons its
    /// in-flight work. Recovery of that work is the router's job, from
    /// the checkpoints this worker spilled on earlier heartbeats.
    pub fn kill(self) {
        let _ = self.fabric.shutdown(Shutdown::Both);
        self.accepting.store(false, Ordering::SeqCst);
        let _ = self.loop_handle.join();
        // wake the client listener so it observes the cleared flag
        let _ = TcpStream::connect(self.client_addr);
        let _ = self.listener_handle.join();
        let _ = self.manager.shutdown(false);
    }

    /// Wait for the fabric session to end (router `bye` or disconnect),
    /// then drain the pool. Returns jobs completed by this worker.
    pub fn join(self) -> Result<u64> {
        let _ = self.loop_handle.join();
        self.accepting.store(false, Ordering::SeqCst);
        let _ = TcpStream::connect(self.client_addr);
        let _ = self.listener_handle.join();
        let out = self.manager.shutdown(true)?;
        Ok(out.counts.completed)
    }
}

/// Spawn a fabric worker: build the shard pool, join the router at
/// `cfg.join` (SPFB handshake), start the client listener and the
/// fabric session loop. Errors if the router is unreachable or rejects
/// the handshake.
pub fn spawn_worker(
    model: Arc<dyn ModelBackend + Send + Sync>,
    engine_cfg: EngineConfig,
    cfg: &WorkerConfig,
) -> Result<WorkerHandle> {
    let (depth, steps, full_flops) = {
        let entry = model.entry();
        (
            entry.config.depth,
            entry.config.serve_steps,
            entry.flops.full_step.get(&1).copied().unwrap_or(0),
        )
    };
    let shards = cfg.shards.max(1);
    let manager = Arc::new(JobManager::new(
        model,
        PoolConfig { shards, router: cfg.router, engine: engine_cfg, steal: true },
        cfg.max_queue,
    ));

    // fabric session: dial, hello, check the ack before serving anything
    let stream = TcpStream::connect(&cfg.join)
        .map_err(|e| anyhow!("connecting to router fabric port {}: {e}", cfg.join))?;
    let fabric = stream.try_clone()?;
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let mut reader = BufReader::new(stream);
    {
        let mut w = writer.lock().unwrap();
        w.write_all(worker_hello(shards).as_bytes())?;
        w.write_all(b"\n")?;
    }
    let mut ack = String::new();
    if reader.read_line(&mut ack)? == 0 {
        bail!("router at {} closed the connection during the fabric handshake", cfg.join);
    }
    let j = Json::parse(ack.trim()).map_err(|e| anyhow!("bad fabric handshake ack: {e}"))?;
    if !j.get("ok").and_then(|o| o.as_bool()).unwrap_or(false) {
        let why = j.get("error").and_then(|e| e.as_str()).unwrap_or("no reason given");
        bail!("router at {} rejected the fabric handshake: {why}", cfg.join);
    }

    // client listener: the same protocol-v2 surface as a standalone
    // server, on this worker's own port
    let listener = TcpListener::bind(&cfg.addr)?;
    let client_addr = listener.local_addr()?;
    let accepting = Arc::new(AtomicBool::new(true));
    let (shutdown_tx, shutdown_rx) = channel::<()>();
    let ctx = ConnCtx {
        manager: manager.clone(),
        accepting: accepting.clone(),
        shutdown: shutdown_tx,
        depth,
        steps,
        full_flops,
        default_draft: cfg.default_draft.clone(),
        role: "worker",
    };
    let listener_handle = spawn_client_listener(listener, ctx.clone());
    // a client op:"shutdown" on the worker port ends the fabric session
    // too: closing the socket EOFs the session loop, which drains
    {
        let f = fabric.try_clone()?;
        let accepting = accepting.clone();
        thread::Builder::new()
            .name("speca-worker-shutdown".into())
            .spawn(move || {
                if shutdown_rx.recv().is_ok() {
                    accepting.store(false, Ordering::SeqCst);
                    let _ = f.shutdown(Shutdown::Both);
                }
            })
            .expect("spawning worker shutdown watcher");
    }

    let loop_handle = {
        let ctx = ctx.clone();
        thread::Builder::new()
            .name("speca-fabric-worker".into())
            .spawn(move || {
                worker_loop(&ctx, reader, &writer);
                // session over: stop accepting clients and wake the
                // listener so join/kill never blocks on accept
                ctx.accepting.store(false, Ordering::SeqCst);
                let _ = TcpStream::connect(client_addr);
            })
            .expect("spawning fabric worker loop")
    };
    eprintln!(
        "speca: fabric worker serving on {client_addr} ({shards} shard(s)), joined router at {}",
        cfg.join
    );
    Ok(WorkerHandle { manager, fabric, accepting, client_addr, loop_handle, listener_handle })
}

/// Run a fabric worker to completion on the current thread: join the
/// router, serve until the session ends, drain. Returns jobs completed.
pub fn run_worker(
    model: Arc<dyn ModelBackend + Send + Sync>,
    engine_cfg: EngineConfig,
    cfg: &WorkerConfig,
) -> Result<u64> {
    spawn_worker(model, engine_cfg, cfg)?.join()
}

/// Write one JSON line to the shared fabric writer; returns whether the
/// write stuck (a dead socket is the caller's cue that the session is
/// over — replies are best-effort after that).
fn send_line(writer: &Mutex<TcpStream>, line: &str) -> bool {
    let mut w = writer.lock().unwrap();
    w.write_all(line.as_bytes()).is_ok() && w.write_all(b"\n").is_ok()
}

fn fabric_error(msg: &str) -> String {
    Json::obj(vec![("fabric", Json::str("error")), ("error", Json::str(msg))]).dump()
}

fn fabric_failed(fid: u64, msg: &str) -> String {
    Json::obj(vec![
        ("fabric", Json::str("failed")),
        ("id", Json::Num(fid as f64)),
        ("error", Json::str(msg)),
    ])
    .dump()
}

/// Detached waiter: block until the local job is terminal, render the
/// protocol-v2 reply under the *fabric* id, ship it as a `done` line.
/// The consuming wait frees the local record, exactly like a client
/// `op:"wait"` would.
fn spawn_done_waiter(ctx: &ConnCtx, writer: &Arc<Mutex<TcpStream>>, fid: u64, local: u64) {
    let ctx = ctx.clone();
    let writer = writer.clone();
    thread::Builder::new()
        .name(format!("speca-fabric-done-{fid}"))
        .spawn(move || {
            let Some((status, rl)) = ctx.manager.wait(local, None, true) else { return };
            let line = Json::obj(vec![
                ("fabric", Json::str("done")),
                ("id", Json::Num(fid as f64)),
                ("reply", status_json(&ctx, fid, &status, rl)),
            ])
            .dump();
            send_line(&writer, &line);
        })
        .expect("spawning fabric done waiter");
}

/// Track a freshly submitted fabric job: terminal-at-submission jobs
/// answer immediately (there will never be a consuming wait), live ones
/// get id-map entries and a done waiter.
#[allow(clippy::too_many_arguments)]
fn track_submission(
    ctx: &ConnCtx,
    writer: &Arc<Mutex<TcpStream>>,
    local_of: &mut HashMap<u64, u64>,
    fid_of: &mut HashMap<u64, u64>,
    fid: u64,
    local: u64,
    status: &JobStatus,
) {
    if matches!(status, JobStatus::Rejected { .. } | JobStatus::Aborted { .. }) {
        let line = Json::obj(vec![
            ("fabric", Json::str("done")),
            ("id", Json::Num(fid as f64)),
            ("reply", status_json(ctx, fid, status, false)),
        ])
        .dump();
        ctx.manager.forget(local);
        send_line(writer, &line);
    } else {
        local_of.insert(fid, local);
        fid_of.insert(local, fid);
        spawn_done_waiter(ctx, writer, fid, local);
    }
}

/// The pong body for heartbeat `seq`: shard gauges (dead shards are
/// `null`, like `op:"stats"`), the stats body, and the spilled
/// checkpoint images of everything in flight, tagged by fabric id.
/// Locally submitted jobs (direct client connections to this worker)
/// have no fabric id and are omitted — the router never owned them.
fn pong_line(ctx: &ConnCtx, fid_of: &HashMap<u64, u64>, seq: u64) -> String {
    let loads = ctx.manager.shard_loads();
    let work = ctx.manager.shard_work_us();
    let load_arr = Json::Arr(
        loads
            .iter()
            .map(|l| if *l == usize::MAX { Json::Null } else { Json::Num(*l as f64) })
            .collect(),
    );
    let work_arr = Json::Arr(
        loads
            .iter()
            .zip(&work)
            .map(|(l, w)| if *l == usize::MAX { Json::Null } else { Json::Num(*w as f64) })
            .collect(),
    );
    let ckpts = Json::Arr(
        ctx.manager
            .spill()
            .iter()
            .filter_map(|s| {
                fid_of.get(&s.id).map(|fid| {
                    Json::obj(vec![
                        ("id", Json::Num(*fid as f64)),
                        ("step", Json::Num(s.step as f64)),
                        ("policy", Json::str(&s.policy)),
                        ("bytes", Json::str(&hex_encode(&s.bytes))),
                    ])
                })
            })
            .collect(),
    );
    Json::obj(vec![
        ("fabric", Json::str("pong")),
        ("seq", Json::Num(seq as f64)),
        ("loads", load_arr),
        ("work_us", work_arr),
        ("ckpts", ckpts),
        ("completed", Json::Num(ctx.manager.counts().completed as f64)),
        ("stats", Json::obj(stats_pairs(&ctx.manager))),
    ])
    .dump()
}

/// The fabric session loop: one message per line until `bye` or EOF.
fn worker_loop(ctx: &ConnCtx, reader: BufReader<TcpStream>, writer: &Arc<Mutex<TcpStream>>) {
    // fabric id ↔ local job id, pruned on each ping (a consumed local
    // record will never spill again)
    let mut local_of: HashMap<u64, u64> = HashMap::new();
    let mut fid_of: HashMap<u64, u64> = HashMap::new();
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let msg = match Json::parse(&line) {
            Ok(m) => m,
            Err(e) => {
                send_line(writer, &fabric_error(&format!("bad fabric line: {e}")));
                continue;
            }
        };
        let kind = msg.get("fabric").and_then(|k| k.as_str()).unwrap_or("");
        match kind {
            "job" => {
                let (Some(fid), Some(req)) =
                    (msg.get("id").and_then(|i| i.as_u64()), msg.get("req"))
                else {
                    send_line(writer, &fabric_error("'job' needs numeric 'id' and 'req'"));
                    continue;
                };
                match submit_from_json(ctx, req) {
                    Err(e) => {
                        send_line(writer, &fabric_failed(fid, &format!("{e}")));
                    }
                    Ok(handle) => {
                        let local = handle.id().0;
                        let status = handle.poll();
                        track_submission(
                            ctx,
                            writer,
                            &mut local_of,
                            &mut fid_of,
                            fid,
                            local,
                            &status,
                        );
                    }
                }
            }
            "resume" => {
                let (Some(fid), Some(desc), Some(hex)) = (
                    msg.get("id").and_then(|i| i.as_u64()),
                    msg.get("policy").and_then(|p| p.as_str()),
                    msg.get("bytes").and_then(|b| b.as_str()),
                ) else {
                    send_line(
                        writer,
                        &fabric_error("'resume' needs numeric 'id', 'policy' and 'bytes'"),
                    );
                    continue;
                };
                let rl = msg.get("return_latent").and_then(|b| b.as_bool()).unwrap_or(false);
                let ckpt = hex_decode(hex)
                    .and_then(|bytes| {
                        let policy = parse_policy(desc, ctx.depth).map_err(|e| format!("{e}"))?;
                        RequestCheckpoint::from_bytes(&bytes, policy, JobMeta::default())
                    })
                    .map_err(|e| format!("decoding spilled checkpoint: {e}"));
                match ckpt {
                    Err(e) => {
                        send_line(writer, &fabric_failed(fid, &e));
                    }
                    Ok(ckpt) => {
                        let handle = ctx.manager.submit_checkpoint(Box::new(ckpt), rl);
                        let local = handle.id().0;
                        let status = handle.poll();
                        track_submission(
                            ctx,
                            writer,
                            &mut local_of,
                            &mut fid_of,
                            fid,
                            local,
                            &status,
                        );
                    }
                }
            }
            "cancel" => {
                if let Some(local) =
                    msg.get("id").and_then(|i| i.as_u64()).and_then(|f| local_of.get(&f))
                {
                    ctx.manager.cancel(*local);
                }
            }
            "ping" => {
                let seq = msg.get("seq").and_then(|s| s.as_u64()).unwrap_or(0);
                fid_of.retain(|local, _| ctx.manager.poll(*local).is_some());
                local_of.retain(|_, local| ctx.manager.poll(*local).is_some());
                send_line(writer, &pong_line(ctx, &fid_of, seq));
            }
            "bye" => break,
            other => {
                send_line(writer, &fabric_error(&format!("unknown fabric message '{other}'")));
            }
        }
    }
}
