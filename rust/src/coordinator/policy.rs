//! Acceleration policies: the paper's SpeCa plus every baseline in the
//! evaluation tables (full compute, DDIM step reduction, FORA, TeaCache,
//! ToCa/DuCa token-reuse simulations, TaylorSeer).
//!
//! A policy decides, per request per serve step, one of
//!   * `Full`   — complete forward pass (refreshes the feature cache)
//!   * `Spec`   — draft-predict features; SpeCa additionally verifies and
//!                may *reject*, falling back to a full pass the same step
//!   * `Skip`   — reuse the previous ε̂ verbatim (FORA/TeaCache-style)
//!   * `Blend`  — recompute but reuse a token fraction (ToCa/DuCa-sim)
//!
//! SpeCa's acceptance test (paper §3.4): e = ‖F̂−F‖/(‖F‖+ε) against the
//! adaptive threshold τ_t = τ0·β^((T−t)/T).

use crate::cache::Draft;

/// Error metric for verification (paper Appendix E ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorMetric {
    /// Relative L2 (the paper's default: ‖F̂−F‖₂/(‖F‖₂+ε)).
    L2,
    /// Relative L1.
    L1,
    /// Relative L∞ (max-abs ratio).
    Linf,
    /// Cosine distance 1 − cos(F̂, F).
    Cosine,
}

impl ErrorMetric {
    /// Parse a metric name (`l2`, `l1`, `linf`, `cos`/`cosine`).
    pub fn parse(s: &str) -> Option<ErrorMetric> {
        match s {
            "l2" => Some(ErrorMetric::L2),
            "l1" => Some(ErrorMetric::L1),
            "linf" => Some(ErrorMetric::Linf),
            "cos" | "cosine" => Some(ErrorMetric::Cosine),
            _ => None,
        }
    }

    /// Canonical wire label — the inverse of [`Self::parse`]:
    /// `parse(m.label()) == Some(m)` for every metric.
    pub fn label(&self) -> &'static str {
        match self {
            ErrorMetric::L2 => "l2",
            ErrorMetric::L1 => "l1",
            ErrorMetric::Linf => "linf",
            ErrorMetric::Cosine => "cos",
        }
    }

    /// Relative error between prediction and ground truth, single pass.
    pub fn eval(&self, pred: &[f32], actual: &[f32]) -> f64 {
        const EPS: f64 = 1e-8;
        debug_assert_eq!(pred.len(), actual.len());
        match self {
            ErrorMetric::L2 => {
                let mut dd = 0.0f64;
                let mut aa = 0.0f64;
                for (p, a) in pred.iter().zip(actual) {
                    let d = (*p - *a) as f64;
                    dd += d * d;
                    aa += (*a as f64) * (*a as f64);
                }
                dd.sqrt() / (aa.sqrt() + EPS)
            }
            ErrorMetric::L1 => {
                let mut dd = 0.0f64;
                let mut aa = 0.0f64;
                for (p, a) in pred.iter().zip(actual) {
                    dd += ((*p - *a) as f64).abs();
                    aa += (*a as f64).abs();
                }
                dd / (aa + EPS)
            }
            ErrorMetric::Linf => {
                let mut dd = 0.0f64;
                let mut aa = 0.0f64;
                for (p, a) in pred.iter().zip(actual) {
                    dd = dd.max(((*p - *a) as f64).abs());
                    aa = aa.max((*a as f64).abs());
                }
                dd / (aa + EPS)
            }
            ErrorMetric::Cosine => {
                let mut pa = 0.0f64;
                let mut pp = 0.0f64;
                let mut aa = 0.0f64;
                for (p, a) in pred.iter().zip(actual) {
                    pa += (*p as f64) * (*a as f64);
                    pp += (*p as f64) * (*p as f64);
                    aa += (*a as f64) * (*a as f64);
                }
                1.0 - pa / (pp.sqrt() * aa.sqrt() + EPS)
            }
        }
    }
}

/// SpeCa hyper-parameters (paper §3.4, Tables 4-8).
#[derive(Debug, Clone)]
pub struct SpeCaConfig {
    /// forced refresh period N (max speculative run length)
    pub interval: usize,
    /// Taylor order m
    pub order: usize,
    /// base threshold τ0
    pub tau0: f64,
    /// decay β ∈ (0, 1]
    pub beta: f64,
    /// verification layer v (block index; default depth−1 = last)
    pub verify_layer: usize,
    /// draft strategy shared across shards (DESIGN.md §10; resolve by
    /// name through [`crate::cache::DraftRegistry`])
    pub draft: Draft,
    /// relative-error metric the acceptance test evaluates
    pub metric: ErrorMetric,
    /// total rel-error budget for sample-adaptive allocation (`None` =
    /// static policy; `Some(b)` attaches a per-request
    /// [`AdaptiveController`](crate::coordinator::adaptive::AdaptiveController))
    pub adaptive: Option<f64>,
    /// Lookahead cap k (policy key `lookahead=<k>`, wire `"lookahead"`):
    /// how many future steps one verification may cover. 1 (the
    /// default) verifies every speculative step — byte-for-byte today's
    /// behavior; k ≥ 2 lets the engine draft a run of up to k steps and
    /// accept the longest verified prefix at the next verify point
    /// (DESIGN.md §16). Sample-adaptive requests treat this as the
    /// *ceiling* of the controller's k-ladder; static requests run at
    /// exactly k.
    pub lookahead: usize,
}

impl SpeCaConfig {
    /// The paper's default hyper-parameters with the verify layer pinned
    /// to the last block of a `depth`-block model.
    pub fn default_for_depth(depth: usize) -> SpeCaConfig {
        SpeCaConfig {
            interval: 5,
            order: 2,
            tau0: 0.3,
            beta: 0.05,
            verify_layer: depth - 1,
            draft: Draft::taylor(),
            metric: ErrorMetric::L2,
            adaptive: None,
            lookahead: 1,
        }
    }

    /// Adaptive threshold at serve step i of T (paper: τ_t = τ0·β^((T−t)/T);
    /// serve step i runs t = T−i, so the exponent is i/T — loose early,
    /// strict near the data end).
    pub fn tau_at(&self, step: usize, total: usize) -> f64 {
        self.tau0 * self.beta.powf(step as f64 / total as f64)
    }
}

/// Per-request acceleration policy.
#[derive(Debug, Clone)]
pub enum Policy {
    /// every step fully computed (the quality reference)
    Full,
    /// DDIM/RF with only `keep` of the schedule's steps (uniform subsample)
    StepReduction { keep: usize },
    /// FORA: full pass every N steps, reuse ε̂ in between
    Fora { interval: usize },
    /// TeaCache: reuse ε̂ until the accumulated timestep-embedding drift
    /// exceeds `threshold`, then refresh
    TeaCache { threshold: f64 },
    /// ToCa-sim: full pass every N steps; between them recompute but keep a
    /// `reuse_frac` token subset cached (cost ≈ (1−R)·C booked)
    TocaSim { interval: usize, reuse_frac: f64 },
    /// DuCa-sim: like ToCa but alternating full-reuse and partial steps
    DucaSim { interval: usize, reuse_frac: f64 },
    /// TaylorSeer: draft predictions on a fixed interval, never verified
    TaylorSeer { interval: usize, order: usize },
    /// SpeCa: forecast-then-verify (the paper's contribution)
    SpeCa(SpeCaConfig),
}

/// What the engine should do for a request at the current step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Plan {
    /// complete forward pass (refreshes the feature cache)
    Full,
    /// draft-predict (SpeCa additionally verifies and may reject)
    Spec,
    /// reuse the previous ε̂ verbatim
    Skip,
    /// recompute but reuse a token fraction (ToCa/DuCa-sim)
    Blend,
    /// step-reduction: this schedule step is skipped entirely (the sampler
    /// jumps across it; no model call, no ε̂ reuse)
    Elide,
}

impl Policy {
    /// Reporting label of the policy family.
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Full => "full",
            Policy::StepReduction { .. } => "step-reduction",
            Policy::Fora { .. } => "fora",
            Policy::TeaCache { .. } => "teacache",
            Policy::TocaSim { .. } => "toca-sim",
            Policy::DucaSim { .. } => "duca-sim",
            Policy::TaylorSeer { .. } => "taylorseer",
            Policy::SpeCa(_) => "speca",
        }
    }

    /// Does this policy use the TaylorSeer feature cache?
    pub fn uses_cache(&self) -> bool {
        matches!(self, Policy::TaylorSeer { .. } | Policy::SpeCa(_))
    }

    /// Configured prediction order (0 for policies without a draft).
    pub fn order(&self) -> usize {
        match self {
            Policy::TaylorSeer { order, .. } => *order,
            Policy::SpeCa(c) => c.order,
            _ => 0,
        }
    }

    /// Name of the draft strategy this policy predicts with (`-` for
    /// policies that never draft) — the per-request reporting axis of
    /// the draft-comparison experiments.
    pub fn draft_name(&self) -> &str {
        match self {
            Policy::SpeCa(c) => c.draft.name(),
            Policy::TaylorSeer { .. } => "taylor",
            _ => "-",
        }
    }

    /// Canonical wire description — the inverse of
    /// [`parse_policy`](crate::workload::parse_policy): parsing the
    /// returned string (at the same model depth) reconstructs this
    /// policy exactly. This is how a policy travels between fabric
    /// processes: the SPCK checkpoint codec deliberately does not
    /// serialize the policy (see
    /// [`RequestCheckpoint`](crate::coordinator::state::RequestCheckpoint)),
    /// so the router ships this string alongside the checkpoint bytes
    /// and the receiving worker re-resolves it. Rust's shortest
    /// round-trip `{}` float formatting keeps the f64 fields exact.
    pub fn describe(&self) -> String {
        match self {
            Policy::Full => "full".to_string(),
            Policy::StepReduction { keep } => format!("steps:keep={keep}"),
            Policy::Fora { interval } => format!("fora:N={interval}"),
            Policy::TeaCache { threshold } => format!("teacache:l={threshold}"),
            Policy::TocaSim { interval, reuse_frac } => {
                format!("toca:N={interval},R={reuse_frac}")
            }
            Policy::DucaSim { interval, reuse_frac } => {
                format!("duca:N={interval},R={reuse_frac}")
            }
            Policy::TaylorSeer { interval, order } => {
                format!("taylorseer:N={interval},O={order}")
            }
            Policy::SpeCa(c) => {
                let mut s = format!(
                    "speca:N={},O={},tau0={},beta={},layer={},draft={},metric={}",
                    c.interval,
                    c.order,
                    c.tau0,
                    c.beta,
                    c.verify_layer,
                    c.draft.name(),
                    c.metric.label()
                );
                if let Some(b) = c.adaptive {
                    s.push_str(&format!(",adaptive={b}"));
                }
                if c.lookahead > 1 {
                    s.push_str(&format!(",lookahead={}", c.lookahead));
                }
                s
            }
        }
    }

    /// Nominal refresh interval N (1 for policies without one).
    pub fn interval(&self) -> usize {
        match self {
            Policy::Fora { interval }
            | Policy::TocaSim { interval, .. }
            | Policy::DucaSim { interval, .. }
            | Policy::TaylorSeer { interval, .. } => *interval,
            Policy::SpeCa(c) => c.interval,
            _ => 1,
        }
    }

    /// Plan the action for serve step `step`, given steps-since-refresh
    /// (`since_full`, 0 ⇒ the refresh happened this step boundary) and the
    /// TeaCache drift accumulator.
    pub fn plan(
        &self,
        step: usize,
        total_steps: usize,
        since_full: usize,
        tea_accum: f64,
    ) -> Plan {
        match self {
            Policy::Full => Plan::Full,
            Policy::StepReduction { keep } => {
                // uniformly keep `keep` of `total_steps` (always step 0)
                let keep = (*keep).clamp(1, total_steps);
                let prev = step.saturating_sub(1) * keep / total_steps;
                let cur = step * keep / total_steps;
                if step == 0 || cur != prev {
                    Plan::Full
                } else {
                    Plan::Elide
                }
            }
            Policy::Fora { interval } => {
                if step % (*interval).max(1) == 0 {
                    Plan::Full
                } else {
                    Plan::Skip
                }
            }
            Policy::TeaCache { threshold } => {
                if step == 0 || tea_accum > *threshold {
                    Plan::Full
                } else {
                    Plan::Skip
                }
            }
            Policy::TocaSim { interval, .. } => {
                if step % (*interval).max(1) == 0 {
                    Plan::Full
                } else {
                    Plan::Blend
                }
            }
            Policy::DucaSim { interval, .. } => {
                let i = (*interval).max(1);
                if step % i == 0 {
                    Plan::Full
                } else if (step % i) % 2 == 1 {
                    Plan::Blend
                } else {
                    Plan::Skip
                }
            }
            Policy::TaylorSeer { interval, .. } | Policy::SpeCa(SpeCaConfig { interval, .. }) => {
                // Refresh every `interval` steps. TaylorSeer seeds its
                // differences at successive refresh points (spacing N); the
                // usable prediction order ramps up as refreshes accumulate,
                // so no special warmup phase is needed.
                if step == 0 || since_full >= (*interval).max(1) {
                    Plan::Full
                } else {
                    Plan::Spec
                }
            }
        }
    }

    /// Token-reuse fraction R of the blend-simulation policies (0 elsewhere).
    pub fn reuse_frac(&self) -> f64 {
        match self {
            Policy::TocaSim { reuse_frac, .. } | Policy::DucaSim { reuse_frac, .. } => *reuse_frac,
            _ => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_l2_matches_definition() {
        let pred = vec![1.1f32, 2.0, 2.9];
        let actual = vec![1.0f32, 2.0, 3.0];
        let e = ErrorMetric::L2.eval(&pred, &actual);
        let num = (0.01f64 + 0.0 + 0.01).sqrt();
        let den = (1.0f64 + 4.0 + 9.0).sqrt();
        // inputs are f32 so the differences carry f32 rounding
        assert!((e - num / den).abs() < 1e-7, "{e}");
    }

    #[test]
    fn metric_zero_on_equal() {
        let a = vec![0.5f32, -1.0, 2.0];
        for m in [ErrorMetric::L2, ErrorMetric::L1, ErrorMetric::Linf, ErrorMetric::Cosine] {
            assert!(m.eval(&a, &a) < 1e-7, "{m:?}");
        }
    }

    #[test]
    fn cosine_orthogonal_is_one() {
        let e = ErrorMetric::Cosine.eval(&[1.0, 0.0], &[0.0, 1.0]);
        assert!((e - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tau_decays_monotonically() {
        let c = SpeCaConfig { beta: 0.05, tau0: 0.3, ..SpeCaConfig::default_for_depth(8) };
        let total = 50;
        let mut last = f64::INFINITY;
        for i in 0..total {
            let t = c.tau_at(i, total);
            assert!(t <= last);
            last = t;
        }
        assert!((c.tau_at(0, total) - 0.3).abs() < 1e-12);
        // endpoint approaches τ0·β
        assert!(c.tau_at(total, total) - 0.3 * 0.05 < 1e-12);
    }

    #[test]
    fn fora_period() {
        let p = Policy::Fora { interval: 5 };
        let plans: Vec<Plan> = (0..11).map(|i| p.plan(i, 50, 0, 0.0)).collect();
        assert_eq!(plans[0], Plan::Full);
        assert_eq!(plans[5], Plan::Full);
        assert_eq!(plans[10], Plan::Full);
        assert!(plans[1..5].iter().all(|p| *p == Plan::Skip));
    }

    #[test]
    fn step_reduction_keeps_exactly_k() {
        for keep in [5, 10, 25, 50] {
            let p = Policy::StepReduction { keep };
            let n = (0..50).filter(|i| p.plan(*i, 50, 0, 0.0) == Plan::Full).count();
            assert_eq!(n, keep, "keep={keep}");
        }
    }

    #[test]
    fn speca_respects_interval_and_refresh() {
        let p = Policy::SpeCa(SpeCaConfig::default_for_depth(8));
        assert_eq!(p.plan(0, 50, 0, 0.0), Plan::Full);
        assert_eq!(p.plan(3, 50, 2, 0.0), Plan::Spec);
        assert_eq!(p.plan(7, 50, 5, 0.0), Plan::Full); // forced refresh at N=5
    }

    #[test]
    fn teacache_triggers_on_accum() {
        let p = Policy::TeaCache { threshold: 0.5 };
        assert_eq!(p.plan(0, 50, 0, 0.0), Plan::Full);
        assert_eq!(p.plan(3, 50, 3, 0.3), Plan::Skip);
        assert_eq!(p.plan(4, 50, 4, 0.6), Plan::Full);
    }

    #[test]
    fn duca_alternates() {
        let p = Policy::DucaSim { interval: 4, reuse_frac: 0.9 };
        assert_eq!(p.plan(0, 50, 0, 0.0), Plan::Full);
        assert_eq!(p.plan(1, 50, 1, 0.0), Plan::Blend);
        assert_eq!(p.plan(2, 50, 2, 0.0), Plan::Skip);
        assert_eq!(p.plan(3, 50, 3, 0.0), Plan::Blend);
        assert_eq!(p.plan(4, 50, 0, 0.0), Plan::Full);
    }
}
