//! Sample-adaptive computation allocation (paper §sample-adaptive
//! allocation; DESIGN.md §14).
//!
//! SpeCa's third contribution: instead of one static draft policy per
//! request, a per-request [`AdaptiveController`] owns a total rel-error
//! **budget** and, at every verify boundary, re-decides how aggressively
//! the request may speculate. The controller reads exactly the signals
//! the engine already produces — the measured verify error `e`, the
//! accept/reject outcome, and the acceptance history — and adapts three
//! knobs:
//!
//! * **accept threshold** — the per-step allowance is the remaining
//!   budget spread over the remaining schedule steps, further scaled by
//!   a tighten/loosen multiplier driven by streaks;
//! * **draft strategy / order** — a *ladder* of strategies resolved
//!   through the shared [`DraftRegistry`](crate::cache::DraftRegistry)
//!   (configured draft → `adams-bashforth` → `reuse`); rejection streaks
//!   step down to cheaper, lower-order, more conservative drafts,
//!   sustained acceptance climbs back up — mid-request draft switching
//!   with zero engine-loop allocations;
//! * **dense fallback** — off the bottom of the ladder (or when the
//!   budget is exhausted) the controller routes every step to a full
//!   forward pass. Streak-triggered fallback is probational: after
//!   [`DENSE_PROBATION`] dense steps the controller retries speculation
//!   at the most conservative rung. Budget-exhausted fallback is final.
//!
//! The controller's mutable state is a `Copy` scalar block
//! ([`AdaptiveSnap`]) so the engine's tick-snapshot/rollback crash
//! protocol covers it like any other per-request counter, and it
//! serializes into the SPCK v2 checkpoint appendix
//! ([`CtlCheckpoint`]) so parked / stolen / migrated requests resume
//! with bitwise-identical controller decisions (DESIGN.md §13).

use crate::cache::{Draft, DraftRegistry, DraftStrategy};

/// Consecutive rejections before the controller tightens one notch.
pub const TIGHTEN_AFTER: u32 = 2;
/// Consecutive acceptances before the controller loosens one notch.
pub const LOOSEN_AFTER: u32 = 3;
/// Dense steps served before a streak-triggered fallback retries
/// speculation (budget-exhausted fallback never retries).
pub const DENSE_PROBATION: u32 = 3;
/// Floor of the tighten/loosen threshold multiplier.
pub const TAU_SCALE_MIN: f64 = 0.25;

/// The controller's mutable scalar state.
///
/// `Copy` on purpose: the engine snapshots it per tick next to the other
/// per-request counters and restores it wholesale when a tick fails
/// mid-flight, so a crashed tick cannot leave a half-applied adaptation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveSnap {
    /// Remaining rel-error budget (total minus every accepted step's
    /// measured verify error). `<= 0` latches dense fallback for good.
    pub budget_left: f64,
    /// Tighten/loosen multiplier on the per-step allowance, in
    /// `[TAU_SCALE_MIN, 1]`.
    pub tau_scale: f64,
    /// Consecutive accepted verifications since the last rejection.
    pub accept_streak: u32,
    /// Consecutive rejected verifications since the last acceptance.
    pub reject_streak: u32,
    /// Current ladder rung (0 = configured draft, deeper = cheaper).
    pub rung: u32,
    /// Streak-triggered dense fallback latch (probational).
    pub dense: bool,
    /// Dense steps served since the fallback latched.
    pub probation: u32,
    /// Lifetime count of controller-forced dense steps (reporting).
    pub dense_steps: u64,
}

/// Serializable controller image carried by [`RequestCheckpoint`]
/// (SPCK v2 appendix; see DESIGN.md §14 for the compatibility rules).
///
/// [`RequestCheckpoint`]: crate::coordinator::state::RequestCheckpoint
#[derive(Debug, Clone, PartialEq)]
pub struct CtlCheckpoint {
    /// Total budget the request was admitted with.
    pub total: f64,
    /// Scalar state at the park boundary.
    pub snap: AdaptiveSnap,
    /// Registry name of the draft rung in use at the park boundary —
    /// resolved back through [`DraftRegistry`] on resume, so a decoded
    /// checkpoint keeps speculating with the same strategy.
    pub draft: String,
}

/// Per-request sample-adaptive controller (see the module docs).
///
/// One instance per in-flight request, owned by the request's
/// [`ReqState`](crate::coordinator::state::ReqState) — never shared
/// through the registry, so per-request adaptation never leaks across
/// requests (the `DraftStrategy` statelessness contract).
#[derive(Debug, Clone)]
pub struct AdaptiveController {
    total: f64,
    /// Strategy ladder, most aggressive first. Built once at admission
    /// from the configured draft plus the registry's conservative rungs;
    /// the hot loop only indexes it.
    ladder: Vec<Draft>,
    snap: AdaptiveSnap,
}

/// Conservative rungs appended below the configured draft, in tightening
/// order. Both are registry builtins, so resolution cannot fail.
const FALLBACK_RUNGS: [&str; 2] = ["adams-bashforth", "reuse"];

fn build_ladder(configured: &Draft) -> Vec<Draft> {
    let mut ladder = vec![configured.clone()];
    for name in FALLBACK_RUNGS {
        if ladder.iter().all(|d| d.name() != name) {
            let d = DraftRegistry::global()
                .resolve(name)
                .expect("builtin fallback draft must be registered");
            ladder.push(d);
        }
    }
    ladder
}

impl AdaptiveController {
    /// Fresh controller for a request admitted with `budget` total
    /// rel-error tolerance, speculating with `configured` at rung 0.
    pub fn new(budget: f64, configured: &Draft) -> AdaptiveController {
        AdaptiveController {
            total: budget,
            ladder: build_ladder(configured),
            snap: AdaptiveSnap {
                budget_left: budget,
                tau_scale: 1.0,
                accept_streak: 0,
                reject_streak: 0,
                rung: 0,
                dense: false,
                probation: 0,
                dense_steps: 0,
            },
        }
    }

    /// Rebuild a controller from a checkpoint image. The rung is
    /// recovered by matching the serialized draft name against the
    /// ladder rebuilt from the (re-attached) policy; an unknown name
    /// lands on the most conservative rung rather than failing resume.
    pub fn from_checkpoint(c: &CtlCheckpoint, configured: &Draft) -> AdaptiveController {
        let ladder = build_ladder(configured);
        let rung = ladder
            .iter()
            .position(|d| d.name() == c.draft)
            .unwrap_or(ladder.len() - 1) as u32;
        let mut snap = c.snap;
        snap.rung = rung;
        AdaptiveController { total: c.total, ladder, snap }
    }

    /// Serializable image of this controller (park-time counterpart of
    /// [`AdaptiveController::from_checkpoint`]).
    pub fn checkpoint(&self) -> CtlCheckpoint {
        CtlCheckpoint {
            total: self.total,
            snap: self.snap,
            draft: self.current_draft().name().to_string(),
        }
    }

    /// Total budget the request was admitted with.
    pub fn total_budget(&self) -> f64 {
        self.total
    }

    /// Current scalar state (the engine's tick snapshot reads this).
    pub fn snap(&self) -> AdaptiveSnap {
        self.snap
    }

    /// Restore scalar state wholesale (tick rollback).
    pub fn restore(&mut self, snap: AdaptiveSnap) {
        self.snap = snap;
    }

    /// Must the next step run dense? True while the streak fallback is
    /// latched or once the budget is spent.
    pub fn wants_dense(&self) -> bool {
        self.snap.dense || self.snap.budget_left <= 0.0
    }

    /// The draft rung currently in use.
    pub fn current_draft(&self) -> &Draft {
        &self.ladder[self.snap.rung as usize]
    }

    /// Strategy + effective order for the speculative phase, replacing
    /// the static `policy.draft` lookup (no allocation; `configured` is
    /// the policy's order knob).
    pub fn strategy(&self, configured_order: usize) -> (&dyn DraftStrategy, usize) {
        let d = self.current_draft();
        (&**d, d.max_order(configured_order))
    }

    /// Accept threshold at a verify boundary: the remaining budget
    /// spread over the remaining steps, clamped by the schedule's τ_t
    /// and scaled by the streak multiplier.
    pub fn threshold(&self, base_tau: f64, steps_left: usize) -> f64 {
        let allowance = self.snap.budget_left / steps_left.max(1) as f64;
        base_tau.min(allowance).max(0.0) * self.snap.tau_scale
    }

    /// Observe an accepted verification with measured error `e` (spends
    /// budget; sustained acceptance loosens).
    pub fn on_accept(&mut self, e: f64) {
        self.snap.budget_left -= e;
        self.snap.reject_streak = 0;
        self.snap.accept_streak += 1;
        if self.snap.accept_streak >= LOOSEN_AFTER {
            self.snap.accept_streak = 0;
            self.snap.tau_scale = (self.snap.tau_scale * 2.0).min(1.0);
            self.snap.rung = self.snap.rung.saturating_sub(1);
        }
    }

    /// Observe a rejected verification (tightens on streaks; off the
    /// bottom rung, latches the dense fallback).
    pub fn on_reject(&mut self) {
        self.snap.accept_streak = 0;
        self.snap.reject_streak += 1;
        if self.snap.reject_streak >= TIGHTEN_AFTER {
            self.snap.reject_streak = 0;
            self.snap.tau_scale = (self.snap.tau_scale * 0.5).max(TAU_SCALE_MIN);
            if (self.snap.rung as usize) + 1 < self.ladder.len() {
                self.snap.rung += 1;
            } else {
                self.snap.dense = true;
                self.snap.probation = 0;
            }
        }
    }

    /// Observe one controller-forced dense step. Probational fallbacks
    /// retry speculation after [`DENSE_PROBATION`] steps; budget-spent
    /// fallbacks stay dense to the end of the schedule.
    pub fn on_dense_step(&mut self) {
        self.snap.dense_steps += 1;
        if self.snap.dense && self.snap.budget_left > 0.0 {
            self.snap.probation += 1;
            if self.snap.probation >= DENSE_PROBATION {
                self.snap.dense = false;
                self.snap.probation = 0;
                self.snap.accept_streak = 0;
                self.snap.reject_streak = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl(budget: f64) -> AdaptiveController {
        AdaptiveController::new(budget, &Draft::taylor())
    }

    #[test]
    fn ladder_is_configured_then_conservative_rungs() {
        let c = ctl(1.0);
        let names: Vec<&str> = c.ladder.iter().map(|d| d.name()).collect();
        assert_eq!(names, vec!["taylor", "adams-bashforth", "reuse"]);
        // a configured draft that *is* a fallback rung is not duplicated
        let c = AdaptiveController::new(1.0, &Draft::named("reuse").unwrap());
        let names: Vec<&str> = c.ladder.iter().map(|d| d.name()).collect();
        assert_eq!(names, vec!["reuse", "adams-bashforth"]);
    }

    #[test]
    fn tighten_steps_down_the_ladder_then_latches_dense() {
        // step-by-step: every TIGHTEN_AFTER consecutive rejects costs one
        // rung and halves the scale; off the bottom rung the dense
        // fallback latches
        let mut c = ctl(10.0);
        assert_eq!(c.current_draft().name(), "taylor");
        c.on_reject();
        assert_eq!(c.snap.rung, 0, "one reject must not tighten yet");
        c.on_reject();
        assert_eq!(c.current_draft().name(), "adams-bashforth");
        assert_eq!(c.snap.tau_scale, 0.5);
        c.on_reject();
        c.on_reject();
        assert_eq!(c.current_draft().name(), "reuse");
        assert_eq!(c.snap.tau_scale, 0.25);
        assert!(!c.wants_dense());
        c.on_reject();
        c.on_reject();
        assert!(c.wants_dense(), "bottom-rung tighten must latch dense");
        assert_eq!(c.snap.tau_scale, TAU_SCALE_MIN, "scale floor holds");
    }

    #[test]
    fn loosen_climbs_back_up() {
        let mut c = ctl(10.0);
        for _ in 0..4 {
            c.on_reject();
        }
        assert_eq!(c.snap.rung, 2);
        // an isolated accept resets the reject streak but does not loosen
        c.on_accept(0.01);
        assert_eq!(c.snap.rung, 2);
        for _ in 0..2 {
            c.on_accept(0.01);
        }
        assert_eq!(c.snap.rung, 1, "LOOSEN_AFTER accepts climb one rung");
        assert_eq!(c.snap.tau_scale, 0.5);
        for _ in 0..LOOSEN_AFTER {
            c.on_accept(0.01);
        }
        assert_eq!(c.snap.rung, 0);
        assert_eq!(c.snap.tau_scale, 1.0, "scale is capped at 1");
    }

    #[test]
    fn probation_exits_streak_fallback_but_not_budget_exhaustion() {
        let mut c = ctl(10.0);
        for _ in 0..6 {
            c.on_reject();
        }
        assert!(c.wants_dense());
        for _ in 0..DENSE_PROBATION {
            assert!(c.wants_dense());
            c.on_dense_step();
        }
        assert!(!c.wants_dense(), "probation must retry speculation");
        assert_eq!(c.current_draft().name(), "reuse", "retry starts conservative");
        assert_eq!(c.snap.dense_steps, u64::from(DENSE_PROBATION));

        // budget exhaustion is final: dense steps never un-latch it
        let mut c = ctl(0.05);
        c.on_accept(0.1);
        assert!(c.snap.budget_left <= 0.0);
        assert!(c.wants_dense());
        for _ in 0..10 {
            c.on_dense_step();
        }
        assert!(c.wants_dense(), "spent budget must stay dense");
    }

    #[test]
    fn threshold_spreads_remaining_budget() {
        let c = ctl(1.0);
        // 10 steps left: allowance 0.1 clamps a loose schedule τ
        assert!((c.threshold(0.5, 10) - 0.1).abs() < 1e-12);
        // a strict schedule τ clamps the allowance
        assert!((c.threshold(0.02, 10) - 0.02).abs() < 1e-12);
        let mut c = ctl(1.0);
        c.on_reject();
        c.on_reject();
        assert!((c.threshold(0.5, 10) - 0.05).abs() < 1e-12, "tighten halves it");
        let mut c = ctl(0.5);
        c.on_accept(0.6);
        assert_eq!(c.threshold(0.5, 10), 0.0, "overdrawn budget yields 0");
    }

    #[test]
    fn snapshot_restore_is_total() {
        let mut c = ctl(2.0);
        let before = c.snap();
        c.on_accept(0.3);
        c.on_reject();
        c.on_reject();
        c.on_dense_step();
        assert_ne!(c.snap(), before);
        c.restore(before);
        assert_eq!(c.snap(), before);
        assert_eq!(c.current_draft().name(), "taylor");
    }

    #[test]
    fn checkpoint_round_trips_rung_by_draft_name() {
        let mut c = ctl(3.0);
        c.on_accept(0.25);
        for _ in 0..2 {
            c.on_reject();
        }
        let img = c.checkpoint();
        assert_eq!(img.draft, "adams-bashforth");
        let back = AdaptiveController::from_checkpoint(&img, &Draft::taylor());
        assert_eq!(back.snap(), c.snap());
        assert_eq!(back.total_budget(), 3.0);
        assert_eq!(back.current_draft().name(), "adams-bashforth");
        // an unknown serialized name degrades to the deepest rung
        let mut img2 = img.clone();
        img2.draft = "no-such-draft".into();
        let back = AdaptiveController::from_checkpoint(&img2, &Draft::taylor());
        assert_eq!(back.current_draft().name(), "reuse");
    }
}
