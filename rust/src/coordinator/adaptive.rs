//! Sample-adaptive computation allocation (paper §sample-adaptive
//! allocation; DESIGN.md §14).
//!
//! SpeCa's third contribution: instead of one static draft policy per
//! request, a per-request [`AdaptiveController`] owns a total rel-error
//! **budget** and, at every verify boundary, re-decides how aggressively
//! the request may speculate. The controller reads exactly the signals
//! the engine already produces — the measured verify error `e`, the
//! accept/reject outcome, and the acceptance history — and adapts three
//! knobs:
//!
//! * **accept threshold** — the per-step allowance is the remaining
//!   budget spread over the remaining schedule steps, further scaled by
//!   a tighten/loosen multiplier driven by streaks;
//! * **draft strategy / order** — a *ladder* of strategies resolved
//!   through the shared [`DraftRegistry`](crate::cache::DraftRegistry)
//!   (configured draft → `adams-bashforth` → `reuse`); rejection streaks
//!   step down to cheaper, lower-order, more conservative drafts,
//!   sustained acceptance climbs back up — mid-request draft switching
//!   with zero engine-loop allocations;
//! * **dense fallback** — off the bottom of the ladder (or when the
//!   budget is exhausted) the controller routes every step to a full
//!   forward pass. Streak-triggered fallback is probational: after
//!   [`DENSE_PROBATION`] dense steps the controller retries speculation
//!   at the most conservative rung. Budget-exhausted fallback is final;
//! * **lookahead k-ladder** (DESIGN.md §16) — when the policy enables
//!   lookahead-k speculation (`lookahead=<cap>` with cap ≥ 2), the
//!   controller also owns the current run length k ∈ [1, cap]: every
//!   [`LOOK_GROW_AFTER`] consecutive accepted verifications grow k by
//!   one toward the policy cap, and any rejection halves it (integer,
//!   floor 1). Static (non-adaptive) requests run at the cap directly.
//!
//! The controller's mutable state is a `Copy` scalar block
//! ([`AdaptiveSnap`]) so the engine's tick-snapshot/rollback crash
//! protocol covers it like any other per-request counter, and it
//! serializes into the SPCK v2 checkpoint appendix
//! ([`CtlCheckpoint`]) so parked / stolen / migrated requests resume
//! with bitwise-identical controller decisions (DESIGN.md §13).

use crate::cache::{Draft, DraftRegistry, DraftStrategy};

/// Consecutive rejections before the controller tightens one notch.
pub const TIGHTEN_AFTER: u32 = 2;
/// Consecutive acceptances before the controller loosens one notch.
pub const LOOSEN_AFTER: u32 = 3;
/// Dense steps served before a streak-triggered fallback retries
/// speculation (budget-exhausted fallback never retries).
pub const DENSE_PROBATION: u32 = 3;
/// Floor of the tighten/loosen threshold multiplier.
pub const TAU_SCALE_MIN: f64 = 0.25;
/// Consecutive accepted verifications before the lookahead run length
/// grows one step toward the policy cap (DESIGN.md §16).
pub const LOOK_GROW_AFTER: u32 = 2;

/// The controller's mutable scalar state.
///
/// `Copy` on purpose: the engine snapshots it per tick next to the other
/// per-request counters and restores it wholesale when a tick fails
/// mid-flight, so a crashed tick cannot leave a half-applied adaptation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveSnap {
    /// Remaining rel-error budget (total minus every accepted step's
    /// measured verify error). `<= 0` latches dense fallback for good.
    pub budget_left: f64,
    /// Tighten/loosen multiplier on the per-step allowance, in
    /// `[TAU_SCALE_MIN, 1]`.
    pub tau_scale: f64,
    /// Consecutive accepted verifications since the last rejection.
    pub accept_streak: u32,
    /// Consecutive rejected verifications since the last acceptance.
    pub reject_streak: u32,
    /// Current ladder rung (0 = configured draft, deeper = cheaper).
    pub rung: u32,
    /// Streak-triggered dense fallback latch (probational).
    pub dense: bool,
    /// Dense steps served since the fallback latched.
    pub probation: u32,
    /// Lifetime count of controller-forced dense steps (reporting).
    pub dense_steps: u64,
    /// Current lookahead run length k (k-ladder position, ≥ 1; clamped
    /// to the policy cap when read through
    /// [`AdaptiveController::lookahead`]).
    pub look: u32,
    /// Consecutive accepted verifications since k last changed.
    pub look_streak: u32,
}

impl AdaptiveSnap {
    /// Accept threshold given this scalar state: the remaining budget
    /// spread over the remaining steps, clamped by the schedule's τ and
    /// scaled by the streak multiplier. Exposed on the snapshot (not
    /// just the controller) because the engine's lookahead audit
    /// re-evaluates intermediate steps against the controller state *at
    /// run time* — i.e. against the tick snapshot taken before the
    /// verify outcome mutated the live controller (DESIGN.md §16).
    pub fn threshold(&self, base_tau: f64, steps_left: usize) -> f64 {
        let allowance = self.budget_left / steps_left.max(1) as f64;
        base_tau.min(allowance).max(0.0) * self.tau_scale
    }
}

/// Serializable controller image carried by [`RequestCheckpoint`]
/// (SPCK v2 appendix, extended with the k-ladder fields in v3 — v2
/// images decode with `look = 1`; see DESIGN.md §14/§16 for the
/// compatibility rules).
///
/// [`RequestCheckpoint`]: crate::coordinator::state::RequestCheckpoint
#[derive(Debug, Clone, PartialEq)]
pub struct CtlCheckpoint {
    /// Total budget the request was admitted with.
    pub total: f64,
    /// Scalar state at the park boundary.
    pub snap: AdaptiveSnap,
    /// Registry name of the draft rung in use at the park boundary —
    /// resolved back through [`DraftRegistry`] on resume, so a decoded
    /// checkpoint keeps speculating with the same strategy.
    pub draft: String,
}

/// Per-request sample-adaptive controller (see the module docs).
///
/// One instance per in-flight request, owned by the request's
/// [`ReqState`](crate::coordinator::state::ReqState) — never shared
/// through the registry, so per-request adaptation never leaks across
/// requests (the `DraftStrategy` statelessness contract).
#[derive(Debug, Clone)]
pub struct AdaptiveController {
    total: f64,
    /// Strategy ladder, most aggressive first. Built once at admission
    /// from the configured draft plus the registry's conservative rungs;
    /// the hot loop only indexes it.
    ladder: Vec<Draft>,
    /// Policy cap on the lookahead run length (the `lookahead=<k>` key;
    /// 1 disables lookahead speculation entirely).
    look_cap: u32,
    snap: AdaptiveSnap,
}

/// Conservative rungs appended below the configured draft, in tightening
/// order. Both are registry builtins, so resolution cannot fail.
const FALLBACK_RUNGS: [&str; 2] = ["adams-bashforth", "reuse"];

fn build_ladder(configured: &Draft) -> Vec<Draft> {
    let mut ladder = vec![configured.clone()];
    for name in FALLBACK_RUNGS {
        if ladder.iter().all(|d| d.name() != name) {
            let d = DraftRegistry::global()
                .resolve(name)
                .expect("builtin fallback draft must be registered");
            ladder.push(d);
        }
    }
    ladder
}

impl AdaptiveController {
    /// Fresh controller for a request admitted with `budget` total
    /// rel-error tolerance, speculating with `configured` at rung 0.
    /// `look_cap` is the policy's lookahead ceiling (clamped to ≥ 1); the
    /// k-ladder starts at 1 and grows toward it on sustained acceptance.
    pub fn new(budget: f64, configured: &Draft, look_cap: usize) -> AdaptiveController {
        AdaptiveController {
            total: budget,
            ladder: build_ladder(configured),
            look_cap: look_cap.max(1).min(u32::MAX as usize) as u32,
            snap: AdaptiveSnap {
                budget_left: budget,
                tau_scale: 1.0,
                accept_streak: 0,
                reject_streak: 0,
                rung: 0,
                dense: false,
                probation: 0,
                dense_steps: 0,
                look: 1,
                look_streak: 0,
            },
        }
    }

    /// Rebuild a controller from a checkpoint image. The rung is
    /// recovered by matching the serialized draft name against the
    /// ladder rebuilt from the (re-attached) policy; an unknown name
    /// lands on the most conservative rung rather than failing resume.
    /// The k-ladder position is clamped into the re-attached policy's
    /// `[1, look_cap]` so a cap change across park/resume cannot leave a
    /// run length the policy forbids.
    pub fn from_checkpoint(
        c: &CtlCheckpoint,
        configured: &Draft,
        look_cap: usize,
    ) -> AdaptiveController {
        let ladder = build_ladder(configured);
        let rung = ladder
            .iter()
            .position(|d| d.name() == c.draft)
            .unwrap_or(ladder.len() - 1) as u32;
        let look_cap = look_cap.max(1).min(u32::MAX as usize) as u32;
        let mut snap = c.snap;
        snap.rung = rung;
        snap.look = snap.look.clamp(1, look_cap);
        AdaptiveController { total: c.total, ladder, look_cap, snap }
    }

    /// Serializable image of this controller (park-time counterpart of
    /// [`AdaptiveController::from_checkpoint`]).
    pub fn checkpoint(&self) -> CtlCheckpoint {
        CtlCheckpoint {
            total: self.total,
            snap: self.snap,
            draft: self.current_draft().name().to_string(),
        }
    }

    /// Total budget the request was admitted with.
    pub fn total_budget(&self) -> f64 {
        self.total
    }

    /// Current scalar state (the engine's tick snapshot reads this).
    pub fn snap(&self) -> AdaptiveSnap {
        self.snap
    }

    /// Restore scalar state wholesale (tick rollback).
    pub fn restore(&mut self, snap: AdaptiveSnap) {
        self.snap = snap;
    }

    /// Must the next step run dense? True while the streak fallback is
    /// latched or once the budget is spent.
    pub fn wants_dense(&self) -> bool {
        self.snap.dense || self.snap.budget_left <= 0.0
    }

    /// The draft rung currently in use.
    pub fn current_draft(&self) -> &Draft {
        &self.ladder[self.snap.rung as usize]
    }

    /// Strategy + effective order for the speculative phase, replacing
    /// the static `policy.draft` lookup (no allocation; `configured` is
    /// the policy's order knob).
    pub fn strategy(&self, configured_order: usize) -> (&dyn DraftStrategy, usize) {
        let d = self.current_draft();
        (&**d, d.max_order(configured_order))
    }

    /// Accept threshold at a verify boundary: the remaining budget
    /// spread over the remaining steps, clamped by the schedule's τ_t
    /// and scaled by the streak multiplier.
    pub fn threshold(&self, base_tau: f64, steps_left: usize) -> f64 {
        self.snap.threshold(base_tau, steps_left)
    }

    /// Current lookahead run length k — the k-ladder position clamped
    /// into the policy's `[1, cap]`. The engine drafts runs of this
    /// length between verify points.
    ///
    /// # Examples
    ///
    /// The ladder grows one step per [`LOOK_GROW_AFTER`] consecutive
    /// accepted verifications, never past the cap, and any rejection
    /// halves it (integer division, floor 1):
    ///
    /// ```
    /// use speca::cache::Draft;
    /// use speca::coordinator::AdaptiveController;
    ///
    /// let mut c = AdaptiveController::new(10.0, &Draft::taylor(), 4);
    /// assert_eq!(c.lookahead(), 1); // adaptive requests start cautious
    /// c.on_accept(0.01);
    /// c.on_accept(0.01);
    /// assert_eq!(c.lookahead(), 2); // LOOK_GROW_AFTER accepts grow k
    /// c.on_accept(0.01);
    /// c.on_accept(0.01);
    /// assert_eq!(c.lookahead(), 3);
    /// c.on_reject();
    /// assert_eq!(c.lookahead(), 1); // a rejected prefix halves k: 3 → 1
    /// for _ in 0..8 {
    ///     c.on_accept(0.01);
    /// }
    /// assert_eq!(c.lookahead(), 4, "growth saturates at the policy cap");
    /// ```
    pub fn lookahead(&self) -> usize {
        self.snap.look.clamp(1, self.look_cap) as usize
    }

    /// The policy's lookahead ceiling this controller was admitted with.
    pub fn lookahead_cap(&self) -> usize {
        self.look_cap as usize
    }

    /// Spend budget for the accepted prefix of a partially rejected
    /// lookahead run (the audit's realized error at the last kept step).
    /// Unlike [`AdaptiveController::on_accept`] this moves no streaks:
    /// the run's verify outcome was a rejection and
    /// [`AdaptiveController::on_reject`] has already recorded it.
    pub fn spend(&mut self, e: f64) {
        self.snap.budget_left -= e;
    }

    /// Observe an accepted verification with measured error `e` (spends
    /// budget; sustained acceptance loosens the threshold and grows the
    /// lookahead run length toward the policy cap).
    pub fn on_accept(&mut self, e: f64) {
        self.snap.budget_left -= e;
        self.snap.reject_streak = 0;
        self.snap.accept_streak += 1;
        if self.snap.accept_streak >= LOOSEN_AFTER {
            self.snap.accept_streak = 0;
            self.snap.tau_scale = (self.snap.tau_scale * 2.0).min(1.0);
            self.snap.rung = self.snap.rung.saturating_sub(1);
        }
        self.snap.look_streak += 1;
        if self.snap.look_streak >= LOOK_GROW_AFTER {
            self.snap.look_streak = 0;
            self.snap.look = (self.snap.look + 1).min(self.look_cap);
        }
    }

    /// Observe a rejected verification (tightens on streaks; off the
    /// bottom rung, latches the dense fallback; always halves the
    /// lookahead run length — a rejected prefix means the draft
    /// overreached its horizon).
    pub fn on_reject(&mut self) {
        self.snap.accept_streak = 0;
        self.snap.reject_streak += 1;
        if self.snap.reject_streak >= TIGHTEN_AFTER {
            self.snap.reject_streak = 0;
            self.snap.tau_scale = (self.snap.tau_scale * 0.5).max(TAU_SCALE_MIN);
            if (self.snap.rung as usize) + 1 < self.ladder.len() {
                self.snap.rung += 1;
            } else {
                self.snap.dense = true;
                self.snap.probation = 0;
            }
        }
        self.snap.look_streak = 0;
        self.snap.look = (self.snap.look / 2).max(1);
    }

    /// Observe one controller-forced dense step. Probational fallbacks
    /// retry speculation after [`DENSE_PROBATION`] steps; budget-spent
    /// fallbacks stay dense to the end of the schedule.
    pub fn on_dense_step(&mut self) {
        self.snap.dense_steps += 1;
        if self.snap.dense && self.snap.budget_left > 0.0 {
            self.snap.probation += 1;
            if self.snap.probation >= DENSE_PROBATION {
                self.snap.dense = false;
                self.snap.probation = 0;
                self.snap.accept_streak = 0;
                self.snap.reject_streak = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl(budget: f64) -> AdaptiveController {
        AdaptiveController::new(budget, &Draft::taylor(), 1)
    }

    #[test]
    fn ladder_is_configured_then_conservative_rungs() {
        let c = ctl(1.0);
        let names: Vec<&str> = c.ladder.iter().map(|d| d.name()).collect();
        assert_eq!(names, vec!["taylor", "adams-bashforth", "reuse"]);
        // a configured draft that *is* a fallback rung is not duplicated
        let c = AdaptiveController::new(1.0, &Draft::named("reuse").unwrap(), 1);
        let names: Vec<&str> = c.ladder.iter().map(|d| d.name()).collect();
        assert_eq!(names, vec!["reuse", "adams-bashforth"]);
    }

    #[test]
    fn tighten_steps_down_the_ladder_then_latches_dense() {
        // step-by-step: every TIGHTEN_AFTER consecutive rejects costs one
        // rung and halves the scale; off the bottom rung the dense
        // fallback latches
        let mut c = ctl(10.0);
        assert_eq!(c.current_draft().name(), "taylor");
        c.on_reject();
        assert_eq!(c.snap.rung, 0, "one reject must not tighten yet");
        c.on_reject();
        assert_eq!(c.current_draft().name(), "adams-bashforth");
        assert_eq!(c.snap.tau_scale, 0.5);
        c.on_reject();
        c.on_reject();
        assert_eq!(c.current_draft().name(), "reuse");
        assert_eq!(c.snap.tau_scale, 0.25);
        assert!(!c.wants_dense());
        c.on_reject();
        c.on_reject();
        assert!(c.wants_dense(), "bottom-rung tighten must latch dense");
        assert_eq!(c.snap.tau_scale, TAU_SCALE_MIN, "scale floor holds");
    }

    #[test]
    fn loosen_climbs_back_up() {
        let mut c = ctl(10.0);
        for _ in 0..4 {
            c.on_reject();
        }
        assert_eq!(c.snap.rung, 2);
        // an isolated accept resets the reject streak but does not loosen
        c.on_accept(0.01);
        assert_eq!(c.snap.rung, 2);
        for _ in 0..2 {
            c.on_accept(0.01);
        }
        assert_eq!(c.snap.rung, 1, "LOOSEN_AFTER accepts climb one rung");
        assert_eq!(c.snap.tau_scale, 0.5);
        for _ in 0..LOOSEN_AFTER {
            c.on_accept(0.01);
        }
        assert_eq!(c.snap.rung, 0);
        assert_eq!(c.snap.tau_scale, 1.0, "scale is capped at 1");
    }

    #[test]
    fn probation_exits_streak_fallback_but_not_budget_exhaustion() {
        let mut c = ctl(10.0);
        for _ in 0..6 {
            c.on_reject();
        }
        assert!(c.wants_dense());
        for _ in 0..DENSE_PROBATION {
            assert!(c.wants_dense());
            c.on_dense_step();
        }
        assert!(!c.wants_dense(), "probation must retry speculation");
        assert_eq!(c.current_draft().name(), "reuse", "retry starts conservative");
        assert_eq!(c.snap.dense_steps, u64::from(DENSE_PROBATION));

        // budget exhaustion is final: dense steps never un-latch it
        let mut c = ctl(0.05);
        c.on_accept(0.1);
        assert!(c.snap.budget_left <= 0.0);
        assert!(c.wants_dense());
        for _ in 0..10 {
            c.on_dense_step();
        }
        assert!(c.wants_dense(), "spent budget must stay dense");
    }

    #[test]
    fn threshold_spreads_remaining_budget() {
        let c = ctl(1.0);
        // 10 steps left: allowance 0.1 clamps a loose schedule τ
        assert!((c.threshold(0.5, 10) - 0.1).abs() < 1e-12);
        // a strict schedule τ clamps the allowance
        assert!((c.threshold(0.02, 10) - 0.02).abs() < 1e-12);
        let mut c = ctl(1.0);
        c.on_reject();
        c.on_reject();
        assert!((c.threshold(0.5, 10) - 0.05).abs() < 1e-12, "tighten halves it");
        let mut c = ctl(0.5);
        c.on_accept(0.6);
        assert_eq!(c.threshold(0.5, 10), 0.0, "overdrawn budget yields 0");
    }

    #[test]
    fn snapshot_restore_is_total() {
        let mut c = ctl(2.0);
        let before = c.snap();
        c.on_accept(0.3);
        c.on_reject();
        c.on_reject();
        c.on_dense_step();
        assert_ne!(c.snap(), before);
        c.restore(before);
        assert_eq!(c.snap(), before);
        assert_eq!(c.current_draft().name(), "taylor");
    }

    #[test]
    fn checkpoint_round_trips_rung_by_draft_name() {
        let mut c = ctl(3.0);
        c.on_accept(0.25);
        for _ in 0..2 {
            c.on_reject();
        }
        let img = c.checkpoint();
        assert_eq!(img.draft, "adams-bashforth");
        let back = AdaptiveController::from_checkpoint(&img, &Draft::taylor(), 1);
        assert_eq!(back.snap(), c.snap());
        assert_eq!(back.total_budget(), 3.0);
        assert_eq!(back.current_draft().name(), "adams-bashforth");
        // an unknown serialized name degrades to the deepest rung
        let mut img2 = img.clone();
        img2.draft = "no-such-draft".into();
        let back = AdaptiveController::from_checkpoint(&img2, &Draft::taylor(), 1);
        assert_eq!(back.current_draft().name(), "reuse");
    }

    #[test]
    fn k_ladder_grows_on_streaks_and_halves_on_rejection() {
        let mut c = AdaptiveController::new(10.0, &Draft::taylor(), 8);
        assert_eq!(c.lookahead(), 1);
        assert_eq!(c.lookahead_cap(), 8);
        // LOOK_GROW_AFTER accepts per step; climb to 4
        for _ in 0..(3 * LOOK_GROW_AFTER) {
            c.on_accept(0.001);
        }
        assert_eq!(c.lookahead(), 4);
        c.on_reject();
        assert_eq!(c.lookahead(), 2, "rejection halves k");
        c.on_reject();
        c.on_reject();
        assert_eq!(c.lookahead(), 1, "k never drops below 1");
        // growth saturates at the cap
        for _ in 0..100 {
            c.on_accept(0.001);
        }
        assert_eq!(c.lookahead(), 8);
    }

    #[test]
    fn k_ladder_is_inert_at_cap_one() {
        // lookahead=1 policies (the default) must see today's behavior:
        // whatever the streaks do, the effective k stays 1
        let mut c = ctl(10.0);
        for _ in 0..10 {
            c.on_accept(0.001);
        }
        assert_eq!(c.lookahead(), 1);
        c.on_reject();
        assert_eq!(c.lookahead(), 1);
    }

    #[test]
    fn spend_moves_budget_but_no_streaks() {
        let mut c = AdaptiveController::new(1.0, &Draft::taylor(), 4);
        c.on_accept(0.1);
        let before = c.snap();
        c.spend(0.25);
        let after = c.snap();
        assert!((after.budget_left - (before.budget_left - 0.25)).abs() < 1e-12);
        assert_eq!(
            AdaptiveSnap { budget_left: before.budget_left, ..after },
            before,
            "spend must touch nothing but the budget"
        );
    }

    #[test]
    fn k_ladder_checkpoint_clamps_to_reattached_cap() {
        let mut c = AdaptiveController::new(10.0, &Draft::taylor(), 8);
        for _ in 0..(3 * LOOK_GROW_AFTER) {
            c.on_accept(0.001);
        }
        assert_eq!(c.lookahead(), 4);
        let img = c.checkpoint();
        // same cap: bitwise ladder state
        let back = AdaptiveController::from_checkpoint(&img, &Draft::taylor(), 8);
        assert_eq!(back.snap(), c.snap());
        assert_eq!(back.lookahead(), 4);
        // a smaller re-attached cap clamps the run length
        let back = AdaptiveController::from_checkpoint(&img, &Draft::taylor(), 2);
        assert_eq!(back.lookahead(), 2);
    }

    #[test]
    fn snap_threshold_matches_controller_threshold() {
        let mut c = ctl(1.0);
        c.on_accept(0.3);
        c.on_reject();
        c.on_reject();
        for (base, left) in [(0.5, 10), (0.02, 10), (0.5, 1), (1.0, 0)] {
            assert_eq!(c.threshold(base, left), c.snap().threshold(base, left));
        }
    }
}
