//! Per-request serving state: the latent being denoised, the TaylorSeer
//! feature cache, policy-specific accumulators and the statistics that feed
//! the sample-adaptive analysis (paper §4.3 / Table 2).

use std::time::Instant;

use crate::cache::FeatureCache;
use crate::coordinator::job::JobMeta;
use crate::coordinator::policy::Policy;
use crate::metrics::flops::FlopsCounter;

/// A generation request as submitted to the router.
#[derive(Debug, Clone)]
pub struct RequestSpec {
    /// Request id (unique within one engine/pool run).
    pub id: u64,
    /// class label (dit-sim) or prompt id (flux-sim / video-sim)
    pub cond: i32,
    /// Seed of the initial latent noise.
    pub seed: u64,
    /// Acceleration policy driving this request (carries the draft
    /// strategy for SpeCa — an `Arc` clone, shared across shards).
    pub policy: Policy,
    /// record the last-boundary feature every step (Fig. 9 trajectories)
    pub record_traj: bool,
    /// Job-lifecycle metadata: priority class, absolute deadline and
    /// the shared cancel token (`Default` = the old fire-and-forget
    /// semantics — normal priority, no deadline, never cancelled).
    pub meta: JobMeta,
}

/// Outcome statistics for one request.
#[derive(Debug, Clone, Default)]
pub struct RequestStats {
    /// Serve steps that ran the complete forward pass.
    pub full_steps: usize,
    /// Speculative steps served from draft predictions.
    pub spec_steps: usize,
    /// Steps that reused the previous ε̂ verbatim.
    pub skip_steps: usize,
    /// Token-blend (ToCa/DuCa-sim) steps.
    pub blend_steps: usize,
    /// Schedule steps jumped entirely (step reduction).
    pub elided_steps: usize,
    /// SpeCa verifications that failed and fell back to a full pass.
    pub rejects: usize,
    /// End-to-end request latency.
    pub latency_ms: f64,
    /// Booked analytic cost of everything this request dispatched.
    pub flops: FlopsCounter,
    /// verification errors observed on speculative steps (step, e, tau)
    pub verify_trace: Vec<(usize, f64, f64)>,
}

impl RequestStats {
    /// Per-sample FLOPs acceleration vs full computation of all steps.
    pub fn speedup(&self, full_step_flops: u64, total_steps: usize) -> f64 {
        if self.flops.total() == 0 {
            return total_steps as f64
                / (self.full_steps + self.spec_steps).max(1) as f64;
        }
        (total_steps as u64 * full_step_flops) as f64 / self.flops.total() as f64
    }
}

/// Live state of one in-flight request.
pub struct ReqState {
    /// The submitted request.
    pub spec: RequestSpec,
    /// current latent x_t (flat)
    pub x: Vec<f32>,
    /// next serve step to execute (0 = noisiest)
    pub step: usize,
    /// steps since the last full computation (0 right after one)
    pub since_full: usize,
    /// TaylorSeer factor cache over the configured tap boundaries
    pub cache: FeatureCache,
    /// boundary indices the cache taps (sorted, deduped)
    pub tap_boundaries: Vec<usize>,
    /// last model output ε̂ (reused by Skip policies)
    pub last_eps: Vec<f32>,
    /// cached last-boundary feature for Blend policies
    pub blend_feat: Vec<f32>,
    /// TeaCache drift accumulator + embedding at the last refresh
    pub tea_accum: f64,
    /// Timestep embedding at the last TeaCache refresh.
    pub tea_last_temb: Vec<f32>,
    /// Running outcome statistics.
    pub stats: RequestStats,
    /// Recorded last-boundary features (when `spec.record_traj`).
    pub traj: Vec<Vec<f32>>,
    /// Admission time (latency measurement).
    pub started: Instant,
    /// scratch: draft predictions for the current speculative step
    pub pred_vin: Vec<f32>,
    /// scratch: predicted verify-block output.
    pub pred_vout: Vec<f32>,
    /// scratch: predicted head input.
    pub pred_last: Vec<f32>,
}

impl ReqState {
    /// Tap layout for a verify layer v over `depth` blocks:
    /// boundaries [v, v+1, depth] (deduped — v+1 == depth when v is last).
    pub fn tap_layout(verify_layer: usize, depth: usize) -> Vec<usize> {
        let mut taps = vec![verify_layer, verify_layer + 1, depth];
        taps.sort_unstable();
        taps.dedup();
        taps
    }

    /// Fresh per-request state: tap layout from the policy's verify
    /// layer, cache order sized by the draft strategy
    /// ([`DraftStrategy::max_order`](crate::cache::DraftStrategy::max_order)
    /// of the configured order), scratch buffers preallocated.
    pub fn new(
        spec: RequestSpec,
        x: Vec<f32>,
        depth: usize,
        feat_len: usize,
    ) -> ReqState {
        let verify_layer = match &spec.policy {
            Policy::SpeCa(c) => c.verify_layer,
            _ => depth - 1,
        };
        let taps = Self::tap_layout(verify_layer.min(depth - 1), depth);
        let order = match &spec.policy {
            Policy::SpeCa(c) => c.draft.max_order(c.order),
            _ => spec.policy.order(),
        };
        let interval = spec.policy.interval();
        let cache = FeatureCache::new(taps.len(), order, feat_len, interval.max(1));
        ReqState {
            spec,
            x,
            step: 0,
            since_full: 0,
            cache,
            tap_boundaries: taps,
            last_eps: Vec::new(),
            blend_feat: Vec::new(),
            tea_accum: 0.0,
            tea_last_temb: Vec::new(),
            stats: RequestStats::default(),
            traj: Vec::new(),
            started: Instant::now(),
            pred_vin: vec![0.0; feat_len],
            pred_vout: vec![0.0; feat_len],
            pred_last: vec![0.0; feat_len],
        }
    }

    /// Cache tap index of a boundary.
    pub fn tap_of(&self, boundary: usize) -> usize {
        self.tap_boundaries
            .iter()
            .position(|b| *b == boundary)
            .unwrap_or_else(|| panic!("boundary {boundary} not tapped ({:?})", self.tap_boundaries))
    }
}

/// Completed generation.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Request id (matches [`RequestSpec::id`]).
    pub id: u64,
    /// Conditioning class/prompt id.
    pub cond: i32,
    /// Policy family label ([`Policy::name`]).
    pub policy_name: String,
    /// Draft strategy the request predicted with ([`Policy::draft_name`];
    /// `-` for policies that never draft). Labels the verify trace so
    /// acceptance-rate-per-draft is a reportable axis.
    pub draft_name: String,
    /// final denoised latent x0
    pub latent: Vec<f32>,
    /// Outcome statistics (incl. the verify trace).
    pub stats: RequestStats,
    /// Recorded feature trajectory (empty unless requested).
    pub traj: Vec<Vec<f32>>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::SpeCaConfig;

    fn spec(policy: Policy) -> RequestSpec {
        let meta = JobMeta::default();
        RequestSpec { id: 1, cond: 0, seed: 42, policy, record_traj: false, meta }
    }

    #[test]
    fn tap_layout_last_layer() {
        // v = depth-1: boundaries v, v+1==depth — two taps
        assert_eq!(ReqState::tap_layout(7, 8), vec![7, 8]);
        // v interior: three taps
        assert_eq!(ReqState::tap_layout(3, 8), vec![3, 4, 8]);
        assert_eq!(ReqState::tap_layout(0, 8), vec![0, 1, 8]);
    }

    #[test]
    fn state_wiring() {
        let mut cfg = SpeCaConfig::default_for_depth(8);
        cfg.verify_layer = 3;
        let st = ReqState::new(spec(Policy::SpeCa(cfg)), vec![0.0; 16], 8, 32);
        assert_eq!(st.tap_boundaries, vec![3, 4, 8]);
        assert_eq!(st.tap_of(4), 1);
        assert_eq!(st.cache.taps.len(), 3);
        assert_eq!(st.cache.taps[0].feat_len(), 32);
    }

    #[test]
    fn non_cache_policy_defaults_to_last_layer() {
        let st = ReqState::new(spec(Policy::Full), vec![0.0; 16], 8, 32);
        assert_eq!(st.tap_boundaries, vec![7, 8]);
    }

    #[test]
    fn stats_speedup_fallback() {
        let mut s = RequestStats::default();
        s.full_steps = 10;
        assert!((s.speedup(100, 50) - 5.0).abs() < 1e-12);
    }
}
