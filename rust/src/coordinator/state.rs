//! Per-request serving state: the latent being denoised, the TaylorSeer
//! feature cache, policy-specific accumulators and the statistics that feed
//! the sample-adaptive analysis (paper §4.3 / Table 2).

use std::time::Instant;

use crate::cache::FeatureCache;
use crate::coordinator::adaptive::{AdaptiveController, AdaptiveSnap, CtlCheckpoint};
use crate::coordinator::job::JobMeta;
use crate::coordinator::policy::Policy;
use crate::metrics::flops::FlopsCounter;

/// A generation request as submitted to the router.
#[derive(Debug, Clone)]
pub struct RequestSpec {
    /// Request id (unique within one engine/pool run).
    pub id: u64,
    /// class label (dit-sim) or prompt id (flux-sim / video-sim)
    pub cond: i32,
    /// Seed of the initial latent noise.
    pub seed: u64,
    /// Acceleration policy driving this request (carries the draft
    /// strategy for SpeCa — an `Arc` clone, shared across shards).
    pub policy: Policy,
    /// record the last-boundary feature every step (Fig. 9 trajectories)
    pub record_traj: bool,
    /// Job-lifecycle metadata: priority class, absolute deadline and
    /// the shared cancel token (`Default` = the old fire-and-forget
    /// semantics — normal priority, no deadline, never cancelled).
    pub meta: JobMeta,
}

/// Outcome statistics for one request.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RequestStats {
    /// Serve steps that ran the complete forward pass.
    pub full_steps: usize,
    /// Speculative steps served from draft predictions.
    pub spec_steps: usize,
    /// Steps that reused the previous ε̂ verbatim.
    pub skip_steps: usize,
    /// Token-blend (ToCa/DuCa-sim) steps.
    pub blend_steps: usize,
    /// Schedule steps jumped entirely (step reduction).
    pub elided_steps: usize,
    /// SpeCa verifications that failed and fell back to a full pass.
    pub rejects: usize,
    /// End-to-end request latency.
    pub latency_ms: f64,
    /// Booked analytic cost of everything this request dispatched.
    pub flops: FlopsCounter,
    /// verification errors observed on speculative steps (step, e, tau)
    pub verify_trace: Vec<(usize, f64, f64)>,
    /// Accepted-prefix-length histogram over lookahead verify events
    /// (DESIGN.md §16): bucket j counts events that ratified exactly j
    /// speculated steps — j = 0 is a rejected verify point with nothing
    /// kept, the top bucket is a fully accepted run. Sized `cap + 1` at
    /// admission; at the default `lookahead=1` only buckets 0/1 move
    /// (plain reject/accept counts). Empty in a default-constructed
    /// stats block.
    pub prefix_hist: Vec<u64>,
}

impl RequestStats {
    /// Per-sample FLOPs acceleration vs full computation of all steps.
    pub fn speedup(&self, full_step_flops: u64, total_steps: usize) -> f64 {
        if self.flops.total() == 0 {
            return total_steps as f64
                / (self.full_steps + self.spec_steps).max(1) as f64;
        }
        (total_steps as u64 * full_step_flops) as f64 / self.flops.total() as f64
    }
}

/// Rollback point for one intermediate step of a lookahead-k run
/// (DESIGN.md §16): everything the engine must restore to put the
/// request back at the boundary *before* that step executed, plus the
/// draft predictions the step was served from (so the verify-point
/// audit can re-score it in one batched dispatch). Captured into
/// preallocated slots at plan time — steady-state speculation touches
/// the allocator no more than the rest of the tick.
#[derive(Debug, Clone, PartialEq)]
pub struct LookSnap {
    /// Serve step this snapshot guards (the step executed from it).
    pub step: usize,
    /// `since_full` at the boundary.
    pub since_full: usize,
    /// TeaCache drift accumulator at the boundary.
    pub tea_accum: f64,
    /// `stats.spec_steps` at the boundary.
    pub spec_steps: usize,
    /// `traj.len()` at the boundary (rollback truncates to it).
    pub traj_len: usize,
    /// Latent x_t at the boundary.
    pub x: Vec<f32>,
    /// Last model output ε̂ at the boundary.
    pub last_eps: Vec<f32>,
    /// Draft-predicted verify-block input this step was served from.
    pub pred_vin: Vec<f32>,
    /// Draft-predicted verify-block output (the audit's yardstick).
    pub pred_vout: Vec<f32>,
}

impl LookSnap {
    /// An empty slot with capacities presized for a `latent`-channel
    /// latent and `feat_len`-channel features (zero-alloc refills).
    pub fn sized(latent: usize, feat_len: usize) -> LookSnap {
        LookSnap {
            step: 0,
            since_full: 0,
            tea_accum: 0.0,
            spec_steps: 0,
            traj_len: 0,
            x: Vec::with_capacity(latent),
            last_eps: Vec::with_capacity(latent),
            pred_vin: Vec::with_capacity(feat_len),
            pred_vout: Vec::with_capacity(feat_len),
        }
    }
}

/// Live state of one in-flight request.
pub struct ReqState {
    /// The submitted request.
    pub spec: RequestSpec,
    /// current latent x_t (flat)
    pub x: Vec<f32>,
    /// next serve step to execute (0 = noisiest)
    pub step: usize,
    /// steps since the last full computation (0 right after one)
    pub since_full: usize,
    /// TaylorSeer factor cache over the configured tap boundaries
    pub cache: FeatureCache,
    /// boundary indices the cache taps (sorted, deduped)
    pub tap_boundaries: Vec<usize>,
    /// last model output ε̂ (reused by Skip policies)
    pub last_eps: Vec<f32>,
    /// cached last-boundary feature for Blend policies
    pub blend_feat: Vec<f32>,
    /// TeaCache drift accumulator + embedding at the last refresh
    pub tea_accum: f64,
    /// Timestep embedding at the last TeaCache refresh.
    pub tea_last_temb: Vec<f32>,
    /// Running outcome statistics.
    pub stats: RequestStats,
    /// Recorded last-boundary features (when `spec.record_traj`).
    pub traj: Vec<Vec<f32>>,
    /// Start of the *current residency* (latency measurement); park
    /// folds the elapsed span into [`Self::prior_ms`].
    pub started: Instant,
    /// Active milliseconds accumulated over previous residencies (zero
    /// unless the request was parked and resumed at least once).
    pub prior_ms: f64,
    /// Sample-adaptive controller (`Some` iff the policy carries an
    /// `adaptive=` budget; DESIGN.md §14).
    pub ctl: Option<AdaptiveController>,
    /// scratch: draft predictions for the current speculative step
    pub pred_vin: Vec<f32>,
    /// scratch: predicted verify-block output.
    pub pred_vout: Vec<f32>,
    /// scratch: predicted head input.
    pub pred_last: Vec<f32>,
    /// Unverified intermediate steps of the current lookahead run (0 at
    /// every verify boundary; only ever > 0 under `lookahead >= 2`).
    pub spec_run: usize,
    /// Preallocated rollback slots for the run's intermediate steps
    /// (`cap − 1` of them; the first [`Self::spec_run`] are live).
    pub look_snaps: Vec<LookSnap>,
}

impl ReqState {
    /// Tap layout for a verify layer v over `depth` blocks:
    /// boundaries [v, v+1, depth] (deduped — v+1 == depth when v is last).
    pub fn tap_layout(verify_layer: usize, depth: usize) -> Vec<usize> {
        let mut taps = vec![verify_layer, verify_layer + 1, depth];
        taps.sort_unstable();
        taps.dedup();
        taps
    }

    /// Fresh per-request state: tap layout from the policy's verify
    /// layer, cache order sized by the draft strategy
    /// ([`DraftStrategy::max_order`](crate::cache::DraftStrategy::max_order)
    /// of the configured order), scratch buffers preallocated.
    pub fn new(
        spec: RequestSpec,
        x: Vec<f32>,
        depth: usize,
        feat_len: usize,
    ) -> ReqState {
        let verify_layer = match &spec.policy {
            Policy::SpeCa(c) => c.verify_layer,
            _ => depth - 1,
        };
        let taps = Self::tap_layout(verify_layer.min(depth - 1), depth);
        let order = match &spec.policy {
            Policy::SpeCa(c) => c.draft.max_order(c.order),
            _ => spec.policy.order(),
        };
        let interval = spec.policy.interval();
        let cache = FeatureCache::new(taps.len(), order, feat_len, interval.max(1));
        let ctl = match &spec.policy {
            Policy::SpeCa(c) => {
                c.adaptive.map(|b| AdaptiveController::new(b, &c.draft, c.lookahead))
            }
            _ => None,
        };
        let look_cap = Self::look_cap_of(&spec.policy);
        let latent = x.len();
        ReqState {
            spec,
            x,
            step: 0,
            since_full: 0,
            cache,
            tap_boundaries: taps,
            last_eps: Vec::new(),
            blend_feat: Vec::new(),
            tea_accum: 0.0,
            tea_last_temb: Vec::new(),
            stats: RequestStats {
                prefix_hist: vec![0; look_cap + 1],
                ..RequestStats::default()
            },
            traj: Vec::new(),
            started: Instant::now(),
            prior_ms: 0.0,
            ctl,
            pred_vin: vec![0.0; feat_len],
            pred_vout: vec![0.0; feat_len],
            pred_last: vec![0.0; feat_len],
            spec_run: 0,
            look_snaps: (0..look_cap - 1).map(|_| LookSnap::sized(latent, feat_len)).collect(),
        }
    }

    /// The policy's lookahead cap (1 for non-SpeCa policies): how many
    /// steps one verification may ratify, sizing the rollback slots and
    /// the accepted-prefix histogram.
    pub fn look_cap_of(policy: &Policy) -> usize {
        match policy {
            Policy::SpeCa(c) => c.lookahead.max(1),
            _ => 1,
        }
    }

    /// Capture the boundary *before* the next intermediate step of a
    /// lookahead run into the next preallocated slot and open that step
    /// (engine plan phase; DESIGN.md §16). The slot's prediction fields
    /// are filled later by [`Self::stash_look_preds`].
    pub fn push_look_snap(&mut self) {
        let i = self.spec_run;
        if i >= self.look_snaps.len() {
            // only reachable when a checkpoint was re-attached to a
            // policy with a larger cap — grow rather than corrupt
            self.look_snaps.push(LookSnap::sized(self.x.len(), self.pred_vin.len()));
        }
        let s = &mut self.look_snaps[i];
        s.step = self.step;
        s.since_full = self.since_full;
        s.tea_accum = self.tea_accum;
        s.spec_steps = self.stats.spec_steps;
        s.traj_len = self.traj.len();
        s.x.clear();
        s.x.extend_from_slice(&self.x);
        s.last_eps.clear();
        s.last_eps.extend_from_slice(&self.last_eps);
        self.spec_run = i + 1;
    }

    /// Record the draft predictions the just-opened intermediate step is
    /// being served from (engine predict phase) so the verify-point
    /// audit can re-score the step without re-drafting.
    pub fn stash_look_preds(&mut self) {
        let i = self.spec_run.checked_sub(1).expect("no open lookahead step");
        let s = &mut self.look_snaps[i];
        s.pred_vin.clear();
        s.pred_vin.extend_from_slice(&self.pred_vin);
        s.pred_vout.clear();
        s.pred_vout.extend_from_slice(&self.pred_vout);
    }

    /// Cache tap index of a boundary.
    pub fn tap_of(&self, boundary: usize) -> usize {
        self.tap_boundaries
            .iter()
            .position(|b| *b == boundary)
            .unwrap_or_else(|| panic!("boundary {boundary} not tapped ({:?})", self.tap_boundaries))
    }

    /// Park this request at its current step boundary, lifting every
    /// piece of cross-step state into a shard-independent
    /// [`RequestCheckpoint`]. The pred_* scratch buffers are dropped —
    /// they are intra-tick temporaries rewritten before every use — and
    /// the elapsed residency is folded into `prior_ms` so end-to-end
    /// latency survives the migration.
    pub fn park(self) -> RequestCheckpoint {
        let feat_len = self.pred_vin.len();
        let mut look = self.look_snaps;
        look.truncate(self.spec_run); // only the live run slots travel
        RequestCheckpoint {
            spec: self.spec,
            x: self.x,
            step: self.step,
            since_full: self.since_full,
            cache: self.cache,
            tap_boundaries: self.tap_boundaries,
            last_eps: self.last_eps,
            blend_feat: self.blend_feat,
            tea_accum: self.tea_accum,
            tea_last_temb: self.tea_last_temb,
            stats: self.stats,
            traj: self.traj,
            prior_ms: self.prior_ms + self.started.elapsed().as_secs_f64() * 1e3,
            ctl: self.ctl.map(|c| c.checkpoint()),
            feat_len,
            look,
        }
    }

    /// Resume a parked request: the inverse of [`Self::park`]. Scratch
    /// prediction buffers are rebuilt zeroed (they carry no trajectory
    /// state), and the residency clock restarts now. Everything the
    /// forward pass reads — latent, tap factors, schedule position,
    /// policy accumulators — comes back exactly as parked, which is why
    /// resume on any shard over the same batch-invariant backend is
    /// bitwise-identical to never having parked.
    pub fn resume(ckpt: RequestCheckpoint) -> ReqState {
        // the controller image travels by value + registry name; the
        // ladder is rebuilt from the re-attached policy so resumed
        // requests keep making identical adaptive decisions
        let ctl = match (&ckpt.ctl, &ckpt.spec.policy) {
            (Some(img), Policy::SpeCa(c)) => {
                Some(AdaptiveController::from_checkpoint(img, &c.draft, c.lookahead))
            }
            _ => None,
        };
        // re-open the parked lookahead run in the first slots and top the
        // pool back up to the (re-attached) policy cap
        let look_cap = Self::look_cap_of(&ckpt.spec.policy);
        let latent = ckpt.x.len();
        let mut look_snaps = ckpt.look;
        let spec_run = look_snaps.len();
        while look_snaps.len() + 1 < look_cap {
            look_snaps.push(LookSnap::sized(latent, ckpt.feat_len));
        }
        ReqState {
            spec: ckpt.spec,
            x: ckpt.x,
            step: ckpt.step,
            since_full: ckpt.since_full,
            cache: ckpt.cache,
            tap_boundaries: ckpt.tap_boundaries,
            last_eps: ckpt.last_eps,
            blend_feat: ckpt.blend_feat,
            tea_accum: ckpt.tea_accum,
            tea_last_temb: ckpt.tea_last_temb,
            stats: ckpt.stats,
            traj: ckpt.traj,
            started: Instant::now(),
            prior_ms: ckpt.prior_ms,
            ctl,
            pred_vin: vec![0.0; ckpt.feat_len],
            pred_vout: vec![0.0; ckpt.feat_len],
            pred_last: vec![0.0; ckpt.feat_len],
            spec_run,
            look_snaps,
        }
    }
}

/// The complete cross-step state of one in-flight request, parked at a
/// serve-step boundary (DESIGN.md §13). Shard-independent by
/// construction: drafts are stateless, the per-request RNG is fully
/// consumed at admission (the initial latent), and the backend is
/// batch-invariant — so nothing a shard holds outside this struct
/// affects the remaining steps, and any shard can resume it
/// bitwise-identically.
///
/// `policy` (inside `spec`) and `meta` travel in-memory as part of the
/// struct; the byte codec ([`Self::to_bytes`]/[`Self::from_bytes`])
/// covers everything *numeric* and re-attaches policy + meta at decode,
/// since trait-object drafts and shared cancel tokens have no canonical
/// byte form (ROADMAP item 3's inter-node fabric re-resolves them from
/// the wire description instead).
#[derive(Debug, Clone)]
pub struct RequestCheckpoint {
    /// The submitted request (id, cond, seed, policy, meta).
    pub spec: RequestSpec,
    /// Latent x_t at the park boundary.
    pub x: Vec<f32>,
    /// Next serve step to execute.
    pub step: usize,
    /// Steps since the last full computation.
    pub since_full: usize,
    /// TaylorSeer factor cache (extracted whole; see
    /// [`crate::cache::TapCache::from_parts`] for the byte-level form).
    pub cache: FeatureCache,
    /// Tapped boundary indices.
    pub tap_boundaries: Vec<usize>,
    /// Last model output ε̂ (Skip policies).
    pub last_eps: Vec<f32>,
    /// Cached last-boundary feature (Blend policies).
    pub blend_feat: Vec<f32>,
    /// TeaCache drift accumulator.
    pub tea_accum: f64,
    /// Timestep embedding at the last TeaCache refresh.
    pub tea_last_temb: Vec<f32>,
    /// Statistics accumulated so far (incl. FLOPs + verify trace).
    pub stats: RequestStats,
    /// Recorded feature trajectory so far.
    pub traj: Vec<Vec<f32>>,
    /// Active milliseconds accumulated before this park.
    pub prior_ms: f64,
    /// Sample-adaptive controller image (SPCK v2 appendix; `None` for
    /// static-policy requests and every v1 image).
    pub ctl: Option<CtlCheckpoint>,
    /// Channels of the pred_* scratch buffers to rebuild on resume.
    pub feat_len: usize,
    /// Live lookahead-run snapshots at the park boundary (SPCK v3
    /// appendix; empty at every verify boundary, for `lookahead=1`
    /// requests, and for every v1/v2 image). A request may park *inside*
    /// a speculative run — resume reopens the run exactly where it was
    /// (DESIGN.md §16).
    pub look: Vec<LookSnap>,
}

/// Byte-codec magic ("SPCK") + version for [`RequestCheckpoint::to_bytes`].
/// v2 appends the sample-adaptive controller image after the v1 layout;
/// v3 extends the controller image with the k-ladder fields and appends
/// the lookahead state (accepted-prefix histogram + flag-worded
/// in-flight-run snapshots; DESIGN.md §16).
/// [`RequestCheckpoint::from_bytes`] still accepts v1/v2 (controller
/// and/or lookahead state absent → defaults).
const CKPT_MAGIC: u32 = 0x5350_434b;
const CKPT_VERSION: u32 = 3;
const CKPT_MIN_VERSION: u32 = 1;

struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32s(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for x in v {
            self.f32(*x);
        }
    }
    fn string(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

struct ByteReader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> ByteReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self.at.checked_add(n).ok_or("checkpoint length overflow")?;
        if end > self.buf.len() {
            return Err(format!("checkpoint truncated at byte {}", self.at));
        }
        let s = &self.buf[self.at..end];
        self.at = end;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> Result<i64, String> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32, String> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn len(&mut self) -> Result<usize, String> {
        let n = self.u64()?;
        // cap any decoded length by the bytes actually remaining so a
        // corrupt header cannot force a huge allocation
        if n > (self.buf.len() - self.at) as u64 {
            return Err(format!("checkpoint length field {n} exceeds remaining bytes"));
        }
        Ok(n as usize)
    }
    fn f32s(&mut self) -> Result<Vec<f32>, String> {
        let n = self.u64()? as usize;
        if n.checked_mul(4).is_none_or(|b| b > self.buf.len() - self.at) {
            return Err("checkpoint f32 run exceeds remaining bytes".into());
        }
        (0..n).map(|_| self.f32()).collect()
    }
    /// Strict boolean: only 0/1 are valid, so every decodable image
    /// re-encodes bitwise-identically (the codec stays canonical).
    fn bool32(&mut self) -> Result<bool, String> {
        match self.u32()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(format!("checkpoint flag has non-boolean value {v}")),
        }
    }
    fn string(&mut self) -> Result<String, String> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| "checkpoint string is not utf-8".into())
    }
}

impl RequestCheckpoint {
    /// Serialize every numeric field to a little-endian byte image —
    /// the wire form a multi-process fabric would ship between nodes.
    /// f32/f64 bit patterns are preserved exactly, so decode → resume
    /// is as bitwise as the in-memory path. Policy and job metadata are
    /// NOT encoded (see the type-level docs); [`Self::from_bytes`]
    /// re-attaches them.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter { buf: Vec::with_capacity(64 + self.x.len() * 4) };
        w.u32(CKPT_MAGIC);
        w.u32(CKPT_VERSION);
        w.u64(self.spec.id);
        w.i64(self.spec.cond as i64);
        w.u64(self.spec.seed);
        w.u32(self.spec.record_traj as u32);
        w.u64(self.feat_len as u64);
        w.u64(self.step as u64);
        w.u64(self.since_full as u64);
        w.f64(self.tea_accum);
        w.f64(self.prior_ms);
        w.f32s(&self.x);
        w.f32s(&self.last_eps);
        w.f32s(&self.blend_feat);
        w.f32s(&self.tea_last_temb);
        w.u64(self.tap_boundaries.len() as u64);
        for b in &self.tap_boundaries {
            w.u64(*b as u64);
        }
        // feature cache: refresh step (u64::MAX = never), then each tap's
        // full serializable state (factors, warmup counter, interval)
        w.u64(self.cache.last_refresh_step.map_or(u64::MAX, |s| s as u64));
        w.u64(self.cache.taps.len() as u64);
        for tap in &self.cache.taps {
            w.u64(tap.updates() as u64);
            w.f32(tap.interval());
            w.u64(tap.factors().len() as u64);
            for f in tap.factors() {
                w.f32s(f);
            }
        }
        // stats
        w.u64(self.stats.full_steps as u64);
        w.u64(self.stats.spec_steps as u64);
        w.u64(self.stats.skip_steps as u64);
        w.u64(self.stats.blend_steps as u64);
        w.u64(self.stats.elided_steps as u64);
        w.u64(self.stats.rejects as u64);
        w.f64(self.stats.latency_ms);
        let fl = &self.stats.flops;
        for v in [
            fl.full,
            fl.verify,
            fl.head,
            fl.predict,
            fl.other,
            fl.n_full_steps,
            fl.n_spec_steps,
            fl.n_rejects,
        ] {
            w.u64(v);
        }
        w.u64(self.stats.verify_trace.len() as u64);
        for (s, e, t) in &self.stats.verify_trace {
            w.u64(*s as u64);
            w.f64(*e);
            w.f64(*t);
        }
        w.u64(self.traj.len() as u64);
        for t in &self.traj {
            w.f32s(t);
        }
        // v2 appendix: sample-adaptive controller image (flag 0 keeps
        // static-policy images one word longer than v1, nothing more).
        // v3 widens it with the k-ladder fields, between dense_steps and
        // the draft name.
        match &self.ctl {
            None => w.u32(0),
            Some(c) => {
                w.u32(1);
                w.f64(c.total);
                w.f64(c.snap.budget_left);
                w.f64(c.snap.tau_scale);
                w.u32(c.snap.accept_streak);
                w.u32(c.snap.reject_streak);
                w.u32(c.snap.rung);
                w.u32(c.snap.dense as u32);
                w.u32(c.snap.probation);
                w.u64(c.snap.dense_steps);
                w.u32(c.snap.look);
                w.u32(c.snap.look_streak);
                w.string(&c.draft);
            }
        }
        // v3 appendix: accepted-prefix histogram, then a flag word for
        // the in-flight lookahead run (1 iff parked mid-speculation)
        w.u64(self.stats.prefix_hist.len() as u64);
        for h in &self.stats.prefix_hist {
            w.u64(*h);
        }
        if self.look.is_empty() {
            w.u32(0);
        } else {
            w.u32(1);
            w.u64(self.look.len() as u64);
            for s in &self.look {
                w.u64(s.step as u64);
                w.u64(s.since_full as u64);
                w.f64(s.tea_accum);
                w.u64(s.spec_steps as u64);
                w.u64(s.traj_len as u64);
                w.f32s(&s.x);
                w.f32s(&s.last_eps);
                w.f32s(&s.pred_vin);
                w.f32s(&s.pred_vout);
            }
        }
        w.buf
    }

    /// Decode a [`Self::to_bytes`] image, re-attaching the policy and
    /// job metadata (which have no canonical byte form). Errors on a
    /// wrong magic/version or a truncated/corrupt buffer.
    pub fn from_bytes(bytes: &[u8], policy: Policy, meta: JobMeta) -> Result<Self, String> {
        use crate::cache::TapCache;
        let mut r = ByteReader { buf: bytes, at: 0 };
        if r.u32()? != CKPT_MAGIC {
            return Err("not a checkpoint image (bad magic)".into());
        }
        let v = r.u32()?;
        if !(CKPT_MIN_VERSION..=CKPT_VERSION).contains(&v) {
            return Err(format!("unsupported checkpoint version {v}"));
        }
        let id = r.u64()?;
        let cond = i32::try_from(r.i64()?)
            .map_err(|_| "checkpoint cond id exceeds i32 range".to_string())?;
        let seed = r.u64()?;
        let record_traj = r.bool32()?;
        let feat_len = r.u64()? as usize;
        let step = r.u64()? as usize;
        let since_full = r.u64()? as usize;
        let tea_accum = r.f64()?;
        let prior_ms = r.f64()?;
        let x = r.f32s()?;
        let last_eps = r.f32s()?;
        let blend_feat = r.f32s()?;
        let tea_last_temb = r.f32s()?;
        let n_taps_b = r.len()?;
        let tap_boundaries =
            (0..n_taps_b).map(|_| r.u64().map(|v| v as usize)).collect::<Result<Vec<_>, _>>()?;
        let refresh = r.u64()?;
        let last_refresh_step = if refresh == u64::MAX { None } else { Some(refresh as usize) };
        let n_taps = r.len()?;
        let mut taps = Vec::with_capacity(n_taps);
        for _ in 0..n_taps {
            let updates = r.u64()? as usize;
            let interval = r.f32()?;
            let n_factors = r.len()?;
            let factors = (0..n_factors).map(|_| r.f32s()).collect::<Result<Vec<_>, _>>()?;
            // `TapCache::from_parts` asserts these invariants (legit
            // images always satisfy them) — turn corrupt counts into a
            // decode error instead of a panic
            if factors.is_empty() || factors.iter().any(|f| f.len() != factors[0].len()) {
                return Err("checkpoint tap factors are empty or ragged".to_string());
            }
            taps.push(TapCache::from_parts(factors, updates, interval));
        }
        let cache = FeatureCache { taps, last_refresh_step };
        let mut stats = RequestStats {
            full_steps: r.u64()? as usize,
            spec_steps: r.u64()? as usize,
            skip_steps: r.u64()? as usize,
            blend_steps: r.u64()? as usize,
            elided_steps: r.u64()? as usize,
            rejects: r.u64()? as usize,
            latency_ms: r.f64()?,
            ..RequestStats::default()
        };
        stats.flops = FlopsCounter {
            full: r.u64()?,
            verify: r.u64()?,
            head: r.u64()?,
            predict: r.u64()?,
            other: r.u64()?,
            n_full_steps: r.u64()?,
            n_spec_steps: r.u64()?,
            n_rejects: r.u64()?,
        };
        let n_trace = r.len()?;
        stats.verify_trace = (0..n_trace)
            .map(|_| Ok::<_, String>((r.u64()? as usize, r.f64()?, r.f64()?)))
            .collect::<Result<Vec<_>, _>>()?;
        let n_traj = r.len()?;
        let traj = (0..n_traj).map(|_| r.f32s()).collect::<Result<Vec<_>, _>>()?;
        let ctl = if v >= 2 {
            if r.bool32()? {
                let total = r.f64()?;
                let budget_left = r.f64()?;
                let tau_scale = r.f64()?;
                let accept_streak = r.u32()?;
                let reject_streak = r.u32()?;
                let rung = r.u32()?;
                let dense = r.bool32()?;
                let probation = r.u32()?;
                let dense_steps = r.u64()?;
                // v3 widened the controller image with the k-ladder; v2
                // images resume at the conservative ladder start
                let (look, look_streak) = if v >= 3 { (r.u32()?, r.u32()?) } else { (1, 0) };
                let draft = r.string()?;
                Some(CtlCheckpoint {
                    total,
                    snap: AdaptiveSnap {
                        budget_left,
                        tau_scale,
                        accept_streak,
                        reject_streak,
                        rung,
                        dense,
                        probation,
                        dense_steps,
                        look,
                        look_streak,
                    },
                    draft,
                })
            } else {
                None
            }
        } else {
            None
        };
        // v3 appendix: accepted-prefix histogram + in-flight run; older
        // images upgrade to an all-zero histogram sized by the
        // re-attached policy's cap and an empty run
        let look = if v >= 3 {
            let n_hist = r.len()?;
            stats.prefix_hist =
                (0..n_hist).map(|_| r.u64()).collect::<Result<Vec<_>, _>>()?;
            if r.bool32()? {
                let n_look = r.len()?;
                if n_look == 0 {
                    // the encoder spells an empty run as flag 0 — keep
                    // every decodable image canonically re-encodable
                    return Err("checkpoint lookahead run flagged present but empty".into());
                }
                (0..n_look)
                    .map(|_| {
                        Ok::<_, String>(LookSnap {
                            step: r.u64()? as usize,
                            since_full: r.u64()? as usize,
                            tea_accum: r.f64()?,
                            spec_steps: r.u64()? as usize,
                            traj_len: r.u64()? as usize,
                            x: r.f32s()?,
                            last_eps: r.f32s()?,
                            pred_vin: r.f32s()?,
                            pred_vout: r.f32s()?,
                        })
                    })
                    .collect::<Result<Vec<_>, _>>()?
            } else {
                Vec::new()
            }
        } else {
            stats.prefix_hist = vec![0; ReqState::look_cap_of(&policy) + 1];
            Vec::new()
        };
        // a decodable image must be exactly one encoded checkpoint —
        // trailing garbage would silently vanish on re-encode otherwise
        if r.at != bytes.len() {
            return Err(format!("checkpoint has {} trailing bytes", bytes.len() - r.at));
        }
        Ok(RequestCheckpoint {
            spec: RequestSpec { id, cond, seed, policy, record_traj, meta },
            x,
            step,
            since_full,
            cache,
            tap_boundaries,
            last_eps,
            blend_feat,
            tea_accum,
            tea_last_temb,
            stats,
            traj,
            prior_ms,
            ctl,
            feat_len,
            look,
        })
    }
}

/// Completed generation.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Request id (matches [`RequestSpec::id`]).
    pub id: u64,
    /// Conditioning class/prompt id.
    pub cond: i32,
    /// Policy family label ([`Policy::name`]).
    pub policy_name: String,
    /// Draft strategy the request predicted with ([`Policy::draft_name`];
    /// `-` for policies that never draft). Labels the verify trace so
    /// acceptance-rate-per-draft is a reportable axis.
    pub draft_name: String,
    /// final denoised latent x0
    pub latent: Vec<f32>,
    /// Outcome statistics (incl. the verify trace).
    pub stats: RequestStats,
    /// Recorded feature trajectory (empty unless requested).
    pub traj: Vec<Vec<f32>>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::SpeCaConfig;

    fn spec(policy: Policy) -> RequestSpec {
        let meta = JobMeta::default();
        RequestSpec { id: 1, cond: 0, seed: 42, policy, record_traj: false, meta }
    }

    #[test]
    fn tap_layout_last_layer() {
        // v = depth-1: boundaries v, v+1==depth — two taps
        assert_eq!(ReqState::tap_layout(7, 8), vec![7, 8]);
        // v interior: three taps
        assert_eq!(ReqState::tap_layout(3, 8), vec![3, 4, 8]);
        assert_eq!(ReqState::tap_layout(0, 8), vec![0, 1, 8]);
    }

    #[test]
    fn state_wiring() {
        let mut cfg = SpeCaConfig::default_for_depth(8);
        cfg.verify_layer = 3;
        let st = ReqState::new(spec(Policy::SpeCa(cfg)), vec![0.0; 16], 8, 32);
        assert_eq!(st.tap_boundaries, vec![3, 4, 8]);
        assert_eq!(st.tap_of(4), 1);
        assert_eq!(st.cache.taps.len(), 3);
        assert_eq!(st.cache.taps[0].feat_len(), 32);
    }

    #[test]
    fn non_cache_policy_defaults_to_last_layer() {
        let st = ReqState::new(spec(Policy::Full), vec![0.0; 16], 8, 32);
        assert_eq!(st.tap_boundaries, vec![7, 8]);
    }

    #[test]
    fn stats_speedup_fallback() {
        let mut s = RequestStats::default();
        s.full_steps = 10;
        assert!((s.speedup(100, 50) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn park_resume_preserves_every_field() {
        let mut cfg = SpeCaConfig::default_for_depth(8);
        cfg.verify_layer = 3;
        let mut st = ReqState::new(spec(Policy::SpeCa(cfg)), vec![0.5; 16], 8, 4);
        st.step = 7;
        st.since_full = 2;
        st.tea_accum = 0.125;
        st.last_eps = vec![1.0; 16];
        st.cache.refresh(5, &[&[1.0; 4], &[2.0; 4], &[3.0; 4]]);
        st.stats.full_steps = 3;
        st.stats.verify_trace.push((5, 0.01, 0.3));
        let trace = st.stats.verify_trace.clone();
        let ckpt = st.park();
        assert!(ckpt.prior_ms >= 0.0);
        let back = ReqState::resume(ckpt);
        assert_eq!(back.step, 7);
        assert_eq!(back.since_full, 2);
        assert_eq!(back.x, vec![0.5; 16]);
        assert_eq!(back.last_eps, vec![1.0; 16]);
        assert_eq!(back.cache.last_refresh_step, Some(5));
        assert_eq!(back.stats.verify_trace, trace);
        assert_eq!(back.pred_vin.len(), 4);
    }

    #[test]
    fn checkpoint_byte_codec_round_trips() {
        let mut cfg = SpeCaConfig::default_for_depth(8);
        cfg.verify_layer = 3;
        let policy = Policy::SpeCa(cfg);
        let mut st = ReqState::new(spec(policy.clone()), vec![0.25; 16], 8, 4);
        st.step = 9;
        st.since_full = 1;
        st.tea_accum = -0.5;
        st.blend_feat = vec![0.75; 4];
        st.tea_last_temb = vec![0.1, 0.2];
        st.cache.refresh(4, &[&[1.0; 4], &[2.0; 4], &[3.0; 4]]);
        st.cache.refresh(8, &[&[1.5; 4], &[2.5; 4], &[3.5; 4]]);
        st.stats.spec_steps = 4;
        st.stats.flops.verify = 1234;
        st.stats.verify_trace.push((8, 0.02, 0.31));
        st.traj.push(vec![9.0; 4]);
        let ckpt = st.park();
        let bytes = ckpt.to_bytes();
        let dec = RequestCheckpoint::from_bytes(&bytes, policy, JobMeta::default()).unwrap();
        assert_eq!(dec.spec.id, ckpt.spec.id);
        assert_eq!(dec.spec.seed, ckpt.spec.seed);
        assert_eq!(dec.x, ckpt.x);
        assert_eq!(dec.step, ckpt.step);
        assert_eq!(dec.since_full, ckpt.since_full);
        assert_eq!(dec.tap_boundaries, ckpt.tap_boundaries);
        assert_eq!(dec.last_eps, ckpt.last_eps);
        assert_eq!(dec.blend_feat, ckpt.blend_feat);
        assert_eq!(dec.tea_accum.to_bits(), ckpt.tea_accum.to_bits());
        assert_eq!(dec.tea_last_temb, ckpt.tea_last_temb);
        assert_eq!(dec.stats, ckpt.stats);
        assert_eq!(dec.traj, ckpt.traj);
        assert_eq!(dec.prior_ms.to_bits(), ckpt.prior_ms.to_bits());
        assert_eq!(dec.feat_len, ckpt.feat_len);
        assert_eq!(dec.cache.last_refresh_step, ckpt.cache.last_refresh_step);
        for (a, b) in dec.cache.taps.iter().zip(&ckpt.cache.taps) {
            assert_eq!(a.factors(), b.factors());
            assert_eq!(a.updates(), b.updates());
            assert_eq!(a.interval(), b.interval());
        }
        // corrupt/truncated images error instead of panicking
        let trunc = RequestCheckpoint::from_bytes(&bytes[..10], Policy::Full, JobMeta::default());
        assert!(trunc.is_err());
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(RequestCheckpoint::from_bytes(&bad, Policy::Full, JobMeta::default()).is_err());
    }

    #[test]
    fn mid_run_park_resume_reopens_the_lookahead_run() {
        let mut cfg = SpeCaConfig::default_for_depth(8);
        cfg.lookahead = 4;
        let policy = Policy::SpeCa(cfg);
        let mut st = ReqState::new(spec(policy.clone()), vec![0.5; 8], 8, 4);
        assert_eq!(st.look_snaps.len(), 3, "cap − 1 preallocated slots");
        assert_eq!(st.stats.prefix_hist.len(), 5, "cap + 1 histogram buckets");
        // simulate two intermediate steps of a run
        st.last_eps = vec![0.25; 8];
        for s in 0..2 {
            st.step = 3 + s;
            st.since_full = 1 + s;
            st.push_look_snap();
            st.pred_vin.fill(s as f32);
            st.pred_vout.fill(10.0 + s as f32);
            st.stash_look_preds();
        }
        assert_eq!(st.spec_run, 2);
        let snaps = st.look_snaps[..2].to_vec();
        // in-memory park/resume
        let ckpt = st.park();
        assert_eq!(ckpt.look, snaps);
        let back = ReqState::resume(ckpt);
        assert_eq!(back.spec_run, 2);
        assert_eq!(back.look_snaps.len(), 3, "slot pool topped back up");
        assert_eq!(back.look_snaps[..2], snaps[..]);
        // byte codec: v3 round-trips the run and the histogram
        let bytes = back.park().to_bytes();
        let dec = RequestCheckpoint::from_bytes(&bytes, policy, JobMeta::default()).unwrap();
        assert_eq!(dec.look, snaps);
        assert_eq!(dec.stats.prefix_hist, vec![0; 5]);
        // canonical re-encode
        assert_eq!(dec.to_bytes(), bytes);
    }
}
