//! Dynamic batcher: groups same-phase work into the AOT batch buckets.
//!
//! Artifacts are compiled for fixed batch sizes (manifest `buckets`, e.g.
//! {1, 2, 4, 8}); a tick's worth of same-phase requests is decomposed into
//! chunks that map 1:1 onto compiled executables. Two strategies:
//!
//! * `Binary` — greedy largest-bucket-first decomposition (no padding;
//!   compute-optimal on CPU where cost scales with batch).
//! * `PadUp`  — single chunk padded up to the smallest covering bucket
//!   (fewer dispatches; wins when per-dispatch overhead dominates).
//!
//! The perf pass (EXPERIMENTS.md §Perf) quantifies both.

/// How a tick's same-phase work decomposes into compiled batch buckets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchStrategy {
    /// Greedy largest-bucket-first decomposition (no padding).
    Binary,
    /// Single chunk padded up to the smallest covering bucket.
    PadUp,
}

impl BatchStrategy {
    /// Parse `binary` / `pad` / `padup` / `pad-up`.
    pub fn parse(s: &str) -> Option<BatchStrategy> {
        match s {
            "binary" => Some(BatchStrategy::Binary),
            "pad" | "padup" | "pad-up" => Some(BatchStrategy::PadUp),
            _ => None,
        }
    }
}

/// One executable dispatch: `bucket` slots, the first `len` filled with
/// the contiguous member span `start..start + len` of the phase list (the
/// rest padded by replicating member 0). A plain `Copy` span — chunk
/// planning into a reused buffer is what keeps the engine's per-tick
/// bookkeeping allocation-free (DESIGN.md §11).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chunk {
    /// Compiled batch size this chunk dispatches at.
    pub bucket: usize,
    /// First phase-list index of the occupied span.
    pub start: usize,
    /// Occupied slots (`start..start + len` are the members).
    pub len: usize,
}

impl Chunk {
    /// Indices (into the phase list) of the occupied slots, in order.
    pub fn members(&self) -> std::ops::Range<usize> {
        self.start..self.start + self.len
    }
    /// Occupied slots.
    pub fn used(&self) -> usize {
        self.len
    }
    /// Padded (replicated) slots.
    pub fn padding(&self) -> usize {
        self.bucket - self.len
    }
}

/// Split `items` (indices into the tick's phase list) into chunks.
/// `buckets` must be sorted ascending and non-empty.
pub fn plan_chunks(n_items: usize, buckets: &[usize], strategy: BatchStrategy) -> Vec<Chunk> {
    let mut chunks = Vec::new();
    plan_chunks_into(n_items, buckets, strategy, &mut chunks);
    chunks
}

/// [`plan_chunks`] into a reused buffer (cleared, then filled) — the
/// engine's hot-path form; capacity persists across ticks so steady-state
/// planning is allocation-free.
pub fn plan_chunks_into(
    n_items: usize,
    buckets: &[usize],
    strategy: BatchStrategy,
    chunks: &mut Vec<Chunk>,
) {
    assert!(!buckets.is_empty(), "no batch buckets");
    debug_assert!(buckets.windows(2).all(|w| w[0] < w[1]), "buckets must be sorted");
    chunks.clear();
    let mut next = 0usize;
    let mut remaining = n_items;
    let largest = *buckets.last().unwrap();
    while remaining > 0 {
        let bucket = match strategy {
            BatchStrategy::Binary => {
                // largest bucket that fits entirely, else the smallest
                *buckets.iter().rev().find(|b| **b <= remaining).unwrap_or(&buckets[0])
            }
            BatchStrategy::PadUp => {
                // smallest bucket covering everything left (capped at max)
                *buckets.iter().find(|b| **b >= remaining).unwrap_or(&largest)
            }
        };
        let take = bucket.min(remaining);
        chunks.push(Chunk { bucket, start: next, len: take });
        next += take;
        remaining -= take;
    }
}

/// Gather per-member rows into a padded flat buffer of `bucket` rows,
/// reusing `buf`'s capacity (the engine keeps one scratch buffer per
/// input kind, so the large gathers stop allocating once warmed up —
/// EXPERIMENTS.md §Perf). Pads by replicating the first member's row
/// (outputs past `used()` are discarded by the caller).
pub fn gather_rows_into<F: Fn(usize, &mut [f32])>(
    buf: &mut Vec<f32>,
    chunk: &Chunk,
    row_len: usize,
    fill: F,
) {
    buf.clear();
    buf.resize(chunk.bucket * row_len, 0.0);
    for (slot, m) in chunk.members().enumerate() {
        let (dst, _) = buf[slot * row_len..].split_at_mut(row_len);
        fill(m, dst);
    }
    pad_rows(buf, chunk.used(), chunk.bucket, row_len);
}

/// Replicate row 0 of `buf` into the padding slots `used..bucket` (the
/// shared padding policy for every dispatch kind).
pub fn pad_rows(buf: &mut [f32], used: usize, bucket: usize, row_len: usize) {
    if used == 0 || used >= bucket {
        return;
    }
    let (proto, rest) = buf.split_at_mut(row_len);
    for slot in used..bucket {
        let off = (slot - 1) * row_len;
        rest[off..off + row_len].copy_from_slice(proto);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    const BUCKETS: &[usize] = &[1, 2, 4, 8];

    #[test]
    fn binary_decomposition() {
        let chunks = plan_chunks(7, BUCKETS, BatchStrategy::Binary);
        let sizes: Vec<usize> = chunks.iter().map(|c| c.bucket).collect();
        assert_eq!(sizes, vec![4, 2, 1]);
        assert!(chunks.iter().all(|c| c.padding() == 0));
    }

    #[test]
    fn padup_single_chunk() {
        let chunks = plan_chunks(7, BUCKETS, BatchStrategy::PadUp);
        assert_eq!(chunks.len(), 1);
        assert_eq!(chunks[0].bucket, 8);
        assert_eq!(chunks[0].padding(), 1);
    }

    #[test]
    fn padup_overflow_splits() {
        let chunks = plan_chunks(19, BUCKETS, BatchStrategy::PadUp);
        let total: usize = chunks.iter().map(|c| c.used()).sum();
        assert_eq!(total, 19);
        assert!(chunks.iter().all(|c| c.bucket <= 8));
    }

    #[test]
    fn empty_is_empty() {
        assert!(plan_chunks(0, BUCKETS, BatchStrategy::Binary).is_empty());
    }

    #[test]
    fn gather_pads_with_first_member() {
        let chunk = Chunk { bucket: 4, start: 10, len: 2 };
        let mut buf = Vec::new();
        gather_rows_into(&mut buf, &chunk, 2, |m, dst| {
            dst[0] = m as f32;
            dst[1] = m as f32 + 0.5;
        });
        assert_eq!(buf, vec![10.0, 10.5, 11.0, 11.5, 10.0, 10.5, 10.0, 10.5]);
    }

    #[test]
    fn gather_into_reuses_buffer_across_sizes() {
        let mut buf = Vec::new();
        let big = Chunk { bucket: 4, start: 0, len: 3 };
        gather_rows_into(&mut buf, &big, 3, |m, dst| dst.fill(m as f32));
        assert_eq!(buf.len(), 12);
        assert_eq!(&buf[9..12], &[0.0, 0.0, 0.0]); // padded with member 0
        let cap = buf.capacity();
        let small = Chunk { bucket: 2, start: 5, len: 2 };
        gather_rows_into(&mut buf, &small, 3, |m, dst| dst.fill(m as f32));
        assert_eq!(buf, vec![5.0, 5.0, 5.0, 6.0, 6.0, 6.0]);
        assert_eq!(buf.capacity(), cap, "no reallocation on shrink");
    }

    #[test]
    fn plan_into_reuses_chunk_buffer() {
        let mut chunks = Vec::new();
        plan_chunks_into(7, BUCKETS, BatchStrategy::Binary, &mut chunks);
        assert_eq!(chunks.len(), 3);
        let cap = chunks.capacity();
        plan_chunks_into(3, BUCKETS, BatchStrategy::Binary, &mut chunks);
        assert_eq!(chunks.iter().map(Chunk::used).sum::<usize>(), 3);
        assert_eq!(chunks.capacity(), cap, "steady-state planning must not reallocate");
    }

    /// Property: every member appears exactly once, in order, regardless of
    /// strategy and item count; chunk buckets are always valid.
    #[test]
    fn prop_chunks_partition_items() {
        prop_check(300, 0xBA7C4, |rng| {
            let n = rng.below(40);
            let strategy = if rng.below(2) == 0 {
                BatchStrategy::Binary
            } else {
                BatchStrategy::PadUp
            };
            let chunks = plan_chunks(n, BUCKETS, strategy);
            let flat: Vec<usize> = chunks.iter().flat_map(|c| c.members()).collect();
            if flat != (0..n).collect::<Vec<_>>() {
                return Err(format!("n={n} {strategy:?}: bad partition {flat:?}"));
            }
            for c in &chunks {
                if !BUCKETS.contains(&c.bucket) {
                    return Err(format!("invalid bucket {}", c.bucket));
                }
                if c.used() > c.bucket {
                    return Err("overfull chunk".to_string());
                }
                if c.used() == 0 {
                    return Err("empty chunk".to_string());
                }
            }
            Ok(())
        });
    }

    /// Property: binary strategy never pads; padup pads at most
    /// bucket_max − 1 in total.
    #[test]
    fn prop_padding_bounds() {
        prop_check(200, 0xFADE, |rng| {
            let n = 1 + rng.below(64);
            let b = plan_chunks(n, BUCKETS, BatchStrategy::Binary);
            if b.iter().any(|c| c.padding() != 0) {
                return Err("binary padded".into());
            }
            let p = plan_chunks(n, BUCKETS, BatchStrategy::PadUp);
            let pad: usize = p.iter().map(|c| c.padding()).sum();
            if pad >= 8 {
                return Err(format!("padup wasted {pad}"));
            }
            Ok(())
        });
    }
}
