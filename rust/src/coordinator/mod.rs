//! L3 coordinator — the paper's system contribution: per-request
//! forecast-then-verify state machines, dynamic batching across the AOT
//! batch buckets, and the policy zoo used by the evaluation tables.

pub mod batcher;
pub mod engine;
pub mod policy;
pub mod pool;
pub mod state;

pub use engine::{Engine, EngineConfig};
pub use policy::{ErrorMetric, Plan, Policy, SpeCaConfig};
pub use pool::{
    EngineShardPool, PoolConfig, PoolEvent, PoolOutcome, RouterPolicy, ShardRouter, ShardStats,
};
pub use state::{Completion, ReqState, RequestSpec, RequestStats};
