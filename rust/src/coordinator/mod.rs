//! L3 coordinator — the paper's system contribution: per-request
//! forecast-then-verify state machines, dynamic batching across the AOT
//! batch buckets, the policy zoo used by the evaluation tables, and the
//! job-lifecycle layer (priorities, deadlines, cancellation) the serving
//! front-end is built on.

pub mod adaptive;
pub mod batcher;
pub mod engine;
pub mod job;
pub mod policy;
pub mod pool;
pub mod state;

pub use adaptive::{AdaptiveController, AdaptiveSnap, CtlCheckpoint};
pub use engine::{Admission, Engine, EngineConfig};
pub use job::{
    CancelToken, GroupCounts, GroupId, JobCounts, JobEvent, JobHandle, JobId, JobManager, JobMeta,
    JobOutcome, JobProgress, JobStatus, Priority, RejectReason, SubmitOptions, Termination,
    TerminationCause,
};
pub use policy::{ErrorMetric, Plan, Policy, SpeCaConfig};
pub use pool::{
    EngineShardPool, PoolConfig, PoolOutcome, RouterPolicy, ShardRouter, ShardStats,
    SpilledCheckpoint,
};
pub use state::{Completion, LookSnap, ReqState, RequestCheckpoint, RequestSpec, RequestStats};
