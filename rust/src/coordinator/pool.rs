//! Sharded serving: N worker threads, each owning an [`Engine`] over one
//! shared `Send + Sync` backend, fed by a round-robin / least-loaded
//! router (DESIGN.md §8).
//!
//! Threading model:
//! * every shard worker runs the same loop the single-threaded server
//!   used — ingest without blocking while there is work, tick, drain —
//!   so per-request behaviour is identical to a lone engine;
//! * the router picks a shard at submit time from a load snapshot
//!   (per-shard `AtomicUsize` of requests in flight) and is `Clone`, so
//!   any number of connection threads can submit concurrently without a
//!   central funnel;
//! * events from all shards merge onto one [`JobEvent`] stream (the
//!   full job lifecycle: admission onto a shard, per-tick progress,
//!   completion, rejection, cancellation, abort). Events arrive in
//!   nondeterministic order across shards but in lifecycle order per
//!   shard, and every event carries its request id, so callers re-order
//!   (or route replies) by id — and because backends are
//!   batching-transparent and requests share no state, a request's
//!   completion is *identical* regardless of shard count (the parity
//!   suite in `tests/shard_pool.rs` asserts it).
//!
//! Job lifecycle on a shard: the engine's queue is priority-ordered, a
//! fired cancel token frees the request's slot at the next step
//! boundary ([`JobEvent::Cancelled`]), and a deadline that expires
//! while the request is still queued sheds it with a structured
//! [`JobEvent::Rejected`] instead of running doomed work — see
//! `coordinator::job` for the state machine.
//!
//! Shutdown is two-mode: `drain` stops ingestion and finishes everything
//! already routed; `halt` abandons in-flight work. Both join every
//! worker before returning.
//!
//! Failure containment: a backend error poisons only the shard that hit
//! it. The dying worker tombstones its load gauge (releasing its
//! in-flight accounting so admission control never counts dead
//! requests, and steering the router away), drains its channel one last
//! time, and emits a [`JobEvent::Aborted`] per abandoned request (so
//! waiters get an error reply, never a hang — see `abandon_inflight`
//! for why the tombstone-then-drain order makes this race-free); the
//! error itself resurfaces as `Err` from [`EngineShardPool::shutdown`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::job::{JobEvent, RejectReason, TerminationCause};
use crate::coordinator::state::{Completion, RequestSpec};
use crate::coordinator::{Engine, EngineConfig};
use crate::metrics::flops::FlopsCounter;
use crate::runtime::ModelBackend;

/// How the router spreads requests over shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Cycle through shards regardless of load.
    RoundRobin,
    /// Pick the shard with the least *expected remaining work* in flight
    /// — the sum of each routed request's service-time hint
    /// ([`crate::coordinator::JobMeta::cost_hint`], fed by the
    /// [`JobManager`](crate::coordinator::job::JobManager)'s per-policy
    /// EWMA), decayed linearly as the request's serve steps complete
    /// (`decay_weight`) so a mostly-finished heavy request no longer
    /// repels traffic. Unhinted requests weigh one nominal unit each,
    /// which degrades exactly to fewest-requests-in-flight routing;
    /// ties go to the smaller request count, then the lowest index, so
    /// routing is deterministic for a given load state.
    LeastLoaded,
}

impl RouterPolicy {
    /// Parse `rr` / `round-robin` / `ll` / `least-loaded`.
    pub fn parse(s: &str) -> Option<RouterPolicy> {
        match s {
            "rr" | "round-robin" => Some(RouterPolicy::RoundRobin),
            "ll" | "least-loaded" => Some(RouterPolicy::LeastLoaded),
            _ => None,
        }
    }

    /// Pure routing decision over a load snapshot: `loads` counts
    /// requests in flight per shard (`usize::MAX` marks a dead shard),
    /// `work` their summed expected-work weights (µ-units, see
    /// [`work_weight_us`]), `rr_ticket` the submission ordinal for
    /// round-robin. A dead shard's work gauge is stale (its weights are
    /// never released), so least-loaded treats tombstoned shards as
    /// infinitely heavy — they are only ever picked when every shard is
    /// dead.
    pub fn pick(&self, loads: &[usize], work: &[u64], rr_ticket: usize) -> usize {
        let n = loads.len().max(1);
        match self {
            // round-robin never reads either gauge (callers may pass an
            // empty work snapshot)
            RouterPolicy::RoundRobin => rr_ticket % n,
            RouterPolicy::LeastLoaded => {
                debug_assert_eq!(loads.len(), work.len());
                loads
                    .iter()
                    .enumerate()
                    .min_by_key(|(i, l)| {
                        let w = if **l == usize::MAX {
                            u64::MAX
                        } else {
                            work.get(*i).copied().unwrap_or(u64::MAX)
                        };
                        (w, **l, *i)
                    })
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            }
        }
    }
}

/// Nominal work weight (µ-units) of a request without a service-time
/// hint: one millisecond. Booked by [`work_weight_us`] at submit and
/// used as the release fallback on any path where a shard worker has no
/// recorded weight for an id — the two must stay identical or the work
/// gauges drift.
const NOMINAL_WORK_US: u64 = 1000;

/// Expected-work weight of one request in the router's work gauges
/// (microsecond units so the gauges stay integral atomics): the job's
/// service-time hint when present, [`NOMINAL_WORK_US`] otherwise — so
/// hinted and unhinted traffic compose, and an all-unhinted workload
/// reduces to request counting.
pub fn work_weight_us(spec: &RequestSpec) -> u64 {
    if spec.meta.cost_hint > 0.0 {
        ((spec.meta.cost_hint * 1000.0) as u64).max(1)
    } else {
        NOMINAL_WORK_US
    }
}

/// Shard-pool shape.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// worker threads (each owns one engine); clamped to ≥ 1
    pub shards: usize,
    /// How submissions spread over shards.
    pub router: RouterPolicy,
    /// per-shard engine configuration (`max_inflight` is per shard)
    pub engine: EngineConfig,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            shards: 1,
            router: RouterPolicy::LeastLoaded,
            engine: EngineConfig::default(),
        }
    }
}

enum ShardMsg {
    Submit(RequestSpec),
    Stats(Sender<ShardStats>),
    /// stop ingesting, finish everything already routed, exit
    Drain,
    /// exit now, abandoning in-flight requests
    Halt,
}

/// Counter snapshot of one shard (or, merged, of the whole pool).
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    /// Requests completed.
    pub completed: u64,
    /// Requests admitted or queued right now.
    pub inflight: usize,
    /// Engine ticks executed.
    pub ticks: u64,
    /// Aggregate booked FLOPs.
    pub flops: FlopsCounter,
}

impl ShardStats {
    fn merge(&mut self, other: &ShardStats) {
        self.completed += other.completed;
        self.inflight += other.inflight;
        self.ticks += other.ticks;
        self.flops.merge(&other.flops);
    }
}

/// Load-gauge tombstone. A dying worker stores this into its gauge
/// *before* its final channel drain; real in-flight counts stay far
/// below it, and transient ±1 traffic around a tombstone stays ≥ DEAD.
/// The tombstone is what makes shard death race-free: a submitter
/// re-checks the gauge after a successful send, so a request can never
/// be silently stranded on a channel nobody will read (see `submit`).
const DEAD: usize = usize::MAX / 2;

/// Cloneable submission handle: connection threads route directly to
/// shard queues — no single-engine channel funnel in between.
///
/// # Examples
///
/// ```
/// use std::sync::Arc;
/// use speca::config::ModelConfig;
/// use speca::coordinator::{EngineShardPool, PoolConfig};
/// use speca::runtime::{ModelBackend, NativeBackend};
/// use speca::workload::{batch_requests, parse_policy};
///
/// let model = Arc::new(NativeBackend::seeded(ModelConfig::native_test(), 1));
/// let depth = model.entry().config.depth;
/// let pool = EngineShardPool::new(model, PoolConfig { shards: 2, ..PoolConfig::default() });
/// let router = pool.router(); // cloneable; each connection thread keeps one
/// let policy = parse_policy("speca:N=4,O=2", depth).unwrap();
/// for spec in batch_requests(4, 4, &policy, 0, false) {
///     router.submit(spec).unwrap();
/// }
/// let out = pool.shutdown(true).unwrap(); // drain: finish everything routed
/// assert_eq!(out.completions.len(), 4);
/// ```
#[derive(Clone)]
pub struct ShardRouter {
    policy: RouterPolicy,
    txs: Vec<Sender<ShardMsg>>,
    loads: Vec<Arc<AtomicUsize>>,
    /// expected remaining work per shard in µ-units ([`work_weight_us`]):
    /// incremented at submit, decayed per serve step as the worker
    /// observes progress (`decay_weight`), and fully released when the
    /// request reaches any terminal state
    work: Vec<Arc<AtomicU64>>,
    rr: Arc<AtomicUsize>,
}

impl ShardRouter {
    /// Number of shards this router feeds (dead ones included).
    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Requests in flight per shard (admitted + queued on the shard). A
    /// shard whose worker has died reports `usize::MAX`.
    pub fn loads(&self) -> Vec<usize> {
        self.loads
            .iter()
            .map(|l| {
                let v = l.load(Ordering::SeqCst);
                if v >= DEAD { usize::MAX } else { v }
            })
            .collect()
    }

    /// Expected remaining work per shard in µ-units (the least-loaded
    /// routing signal; a dead shard's value is meaningless and its
    /// `loads()` tombstone is authoritative).
    pub fn work_us(&self) -> Vec<u64> {
        self.work.iter().map(|w| w.load(Ordering::SeqCst)).collect()
    }

    /// Total requests in flight across live shards (a dead shard has
    /// released its in-flight accounting).
    pub fn inflight(&self) -> usize {
        self.loads().iter().filter(|l| **l != usize::MAX).sum()
    }

    /// Route one request; returns the shard index it landed on. Dead
    /// shards (tombstoned gauge) are excluded and the pick retried, so
    /// one dead shard never blackholes new submissions while live shards
    /// have capacity; when every worker is gone this fails fast.
    pub fn submit(&self, spec: RequestSpec) -> Result<usize> {
        let mut spec = spec;
        let weight = work_weight_us(&spec);
        let n = self.txs.len();
        let mut loads = self.loads();
        // one work snapshot per submit, and none at all for round-robin
        // (which ignores the gauges); retries only happen on dead shards,
        // which the locally-updated `loads` already excludes
        let work = match self.policy {
            RouterPolicy::LeastLoaded => self.work_us(),
            RouterPolicy::RoundRobin => Vec::new(),
        };
        loop {
            let mut shard =
                self.policy.pick(&loads, &work, self.rr.fetch_add(1, Ordering::SeqCst));
            if loads[shard] == usize::MAX {
                // round-robin ignores load (and a dead shard's stale work
                // gauge can still look attractive), so a pick can land on
                // a known-dead shard; fall forward to the next live one
                match (0..n).map(|k| (shard + k) % n).find(|&s| loads[s] != usize::MAX) {
                    Some(live) => shard = live,
                    None => bail!("all shard workers are gone"),
                }
            }
            // reserve a slot on the gauge before handing over; a
            // tombstone means the worker died — undo and retry elsewhere
            if self.loads[shard].fetch_add(1, Ordering::SeqCst) >= DEAD {
                self.loads[shard].fetch_sub(1, Ordering::SeqCst);
                loads[shard] = usize::MAX;
                continue;
            }
            self.work[shard].fetch_add(weight, Ordering::SeqCst);
            match self.txs[shard].send(ShardMsg::Submit(spec)) {
                Ok(()) => {
                    // Close the death race: the worker tombstones its
                    // gauge *before* its final channel drain, so a live
                    // gauge here proves our message lands before that
                    // drain (it will be aborted, not lost). A tombstone
                    // means the message may never be read — report
                    // failure; the caller's error reply at worst
                    // duplicates the worker's abort notice, never hangs.
                    if self.loads[shard].load(Ordering::SeqCst) >= DEAD {
                        bail!("shard {shard} worker died during submit");
                    }
                    return Ok(shard);
                }
                Err(unsent) => {
                    // undo the reservation — unless the dying worker has
                    // tombstoned the gauge since our reservation, which
                    // absorbed it (decrementing would leave DEAD-1: an
                    // absurd *live* load that wedges admission control)
                    let _ = self.loads[shard].fetch_update(
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                        |v| if v >= DEAD { None } else { Some(v - 1) },
                    );
                    // the work gauge has no tombstone: a dead shard's
                    // value is never read once loads() reports MAX, so a
                    // plain undo is safe (and keeps live-path accounting
                    // exact when the send failure races a drain)
                    self.work[shard].fetch_sub(weight, Ordering::SeqCst);
                    loads[shard] = usize::MAX;
                    let ShardMsg::Submit(s) = unsent.0 else { unreachable!() };
                    spec = s;
                }
            }
        }
    }

    /// Merged counter snapshot across all live shards. All probes go out
    /// before any reply is awaited (a worker replies between ticks), so
    /// the wall time is the slowest single shard, not the sum.
    pub fn stats(&self) -> ShardStats {
        let probes: Vec<_> = self
            .txs
            .iter()
            .filter_map(|tx| {
                let (rtx, rrx) = channel();
                tx.send(ShardMsg::Stats(rtx)).ok().map(|_| rrx)
            })
            .collect();
        let mut agg = ShardStats::default();
        for rrx in probes {
            if let Ok(s) = rrx.recv_timeout(Duration::from_secs(10)) {
                agg.merge(&s);
            }
        }
        agg
    }
}

/// Everything a finished pool hands back. The per-request vectors hold
/// only events not consumed through [`EngineShardPool::take_event_rx`];
/// a consumer that took the stream (e.g. a
/// [`JobManager`](crate::coordinator::job::JobManager) dispatcher) sees
/// them there instead.
pub struct PoolOutcome {
    /// Requests that finished normally.
    pub completions: Vec<Completion>,
    /// `(id, error)` of requests abandoned by dead/halted shards.
    pub aborted: Vec<(u64, String)>,
    /// `(id, reason)` of requests shed by queued-deadline expiry.
    pub rejected: Vec<(u64, RejectReason)>,
    /// Ids of requests dropped after their cancel token fired.
    pub cancelled: Vec<u64>,
    /// Merged counter snapshot across workers.
    pub stats: ShardStats,
}

/// N engines over one shared backend. See module docs for the threading
/// model.
pub struct EngineShardPool {
    router: ShardRouter,
    workers: Vec<JoinHandle<(ShardStats, Option<String>)>>,
    events: Option<Receiver<JobEvent>>,
    /// set once [`Self::take_event_rx`] hands the stream to a consumer;
    /// until then workers skip the Admitted/Progress chatter so a
    /// closed-loop user (bench runners, parity tests) does not buffer
    /// requests × steps events nobody will read
    chatter: Arc<AtomicBool>,
}

impl EngineShardPool {
    /// Spawn `cfg.shards` worker threads over one shared backend.
    pub fn new(model: Arc<dyn ModelBackend + Send + Sync>, cfg: PoolConfig) -> EngineShardPool {
        let shards = cfg.shards.max(1);
        let (ctx, crx) = channel();
        let chatter = Arc::new(AtomicBool::new(false));
        let mut txs = Vec::with_capacity(shards);
        let mut loads = Vec::with_capacity(shards);
        let mut work = Vec::with_capacity(shards);
        let mut workers = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx, rx) = channel();
            let load = Arc::new(AtomicUsize::new(0));
            let work_gauge = Arc::new(AtomicU64::new(0));
            let worker_model = model.clone();
            let worker_cfg = cfg.engine.clone();
            let worker_ctx = ShardCtx {
                shard,
                load: load.clone(),
                work: work_gauge.clone(),
                events: ctx.clone(),
                chatter: chatter.clone(),
                weights: HashMap::new(),
            };
            workers.push(
                thread::Builder::new()
                    .name(format!("speca-shard-{shard}"))
                    .spawn(move || shard_worker(worker_model, worker_cfg, worker_ctx, rx))
                    .expect("spawning shard worker"),
            );
            txs.push(tx);
            loads.push(load);
            work.push(work_gauge);
        }
        EngineShardPool {
            router: ShardRouter {
                policy: cfg.router,
                txs,
                loads,
                work,
                rr: Arc::new(AtomicUsize::new(0)),
            },
            workers,
            events: Some(crx),
            chatter,
        }
    }

    /// A cloneable submission handle (connection threads each keep one).
    pub fn router(&self) -> ShardRouter {
        self.router.clone()
    }

    /// Route one request to a shard (see [`ShardRouter::submit`]).
    pub fn submit(&self, spec: RequestSpec) -> Result<usize> {
        self.router.submit(spec)
    }

    /// Merged counter snapshot (see [`ShardRouter::stats`]).
    pub fn stats(&self) -> ShardStats {
        self.router.stats()
    }

    /// Take ownership of the merged [`JobEvent`] stream (e.g. for a job
    /// dispatcher thread). Taking it also turns on the per-tick
    /// Admitted/Progress lifecycle chatter, which is suppressed while
    /// nobody consumes the stream. If never taken, [`Self::shutdown`]
    /// drains the buffered terminal events into the [`PoolOutcome`]
    /// vectors.
    pub fn take_event_rx(&mut self) -> Option<Receiver<JobEvent>> {
        let rx = self.events.take();
        if rx.is_some() {
            self.chatter.store(true, Ordering::SeqCst);
        }
        rx
    }

    /// Stop the pool and join every worker. `drain` finishes all work
    /// already submitted first; `!drain` abandons it. A worker that hit a
    /// backend error (or panicked) surfaces here as `Err`, mirroring the
    /// single-engine path where `tick()?` propagates.
    pub fn shutdown(mut self, drain: bool) -> Result<PoolOutcome> {
        for tx in &self.router.txs {
            let _ = tx.send(if drain { ShardMsg::Drain } else { ShardMsg::Halt });
        }
        let rx = self.events.take();
        // drop the router's senders so a worker that missed the message
        // still observes the disconnect and exits
        let EngineShardPool { router, workers, .. } = self;
        drop(router);
        let mut stats = ShardStats::default();
        let mut errors = Vec::new();
        let mut panicked = 0usize;
        for w in workers {
            match w.join() {
                Ok((s, err)) => {
                    stats.merge(&s);
                    errors.extend(err);
                }
                Err(_) => panicked += 1,
            }
        }
        let mut completions = Vec::new();
        let mut aborted = Vec::new();
        let mut rejected = Vec::new();
        let mut cancelled = Vec::new();
        if let Some(rx) = rx {
            while let Ok(ev) = rx.try_recv() {
                match ev {
                    JobEvent::Completed(c) => completions.push(*c),
                    JobEvent::Aborted { id, error } => aborted.push((id, error)),
                    JobEvent::Rejected { id, reason } => rejected.push((id, reason)),
                    JobEvent::Cancelled { id } => cancelled.push(id),
                    JobEvent::Admitted { .. } | JobEvent::Progress(_) => {}
                }
            }
        }
        if panicked > 0 {
            bail!("{panicked} shard worker(s) panicked");
        }
        if !errors.is_empty() {
            bail!("shard worker error(s): {}", errors.join("; "));
        }
        Ok(PoolOutcome { completions, aborted, rejected, cancelled, stats })
    }
}

fn snapshot(engine: &Engine<'_>, completed: u64) -> ShardStats {
    ShardStats {
        completed,
        inflight: engine.pending(),
        ticks: engine.ticks,
        flops: engine.flops.clone(),
    }
}

/// Everything a shard worker needs besides its engine and channel: shard
/// identity, the router-facing gauges, the merged event sender, the
/// chatter switch, and the per-request work-weight ledger.
struct ShardCtx {
    shard: usize,
    load: Arc<AtomicUsize>,
    work: Arc<AtomicU64>,
    events: Sender<JobEvent>,
    chatter: Arc<AtomicBool>,
    /// `(initial, remaining)` expected-work weight of every request this
    /// shard ingested, keyed by id. `remaining` is decayed linearly as
    /// serve steps complete (`decay_weight`) and released from the
    /// router's work gauge at each terminal state, so least-loaded
    /// routing tracks *remaining* work, not cumulative throughput — a
    /// nearly-done heavy request weighs close to nothing.
    weights: HashMap<u64, (u64, u64)>,
}

/// Decay one request's expected-remaining-work booking as its serve
/// steps complete: the shard's work gauge drops linearly from the full
/// admission-time weight toward one µ-unit at the final step (the floor
/// keeps every in-flight request visible to the router until its
/// terminal release). Monotonic — `remaining` only shrinks — so
/// replayed or throttled progress snapshots can never re-inflate the
/// gauge, and the terminal release of `remaining` keeps the gauge
/// arithmetic exact.
fn decay_weight(ctx: &mut ShardCtx, id: u64, step: usize, total_steps: usize) {
    let Some((initial, remaining)) = ctx.weights.get_mut(&id) else { return };
    let left = total_steps.saturating_sub(step) as u64;
    let want = (*initial * left / total_steps.max(1) as u64).max(1);
    if want < *remaining {
        ctx.work.fetch_sub(*remaining - want, Ordering::SeqCst);
        *remaining = want;
    }
}

/// Pull every message still queued on the shard channel into the engine
/// (so work the router already counted is accounted for) and answer any
/// pending stats probes. Used on the abandon paths only.
fn ingest_remaining(
    engine: &mut Engine<'_>,
    rx: &Receiver<ShardMsg>,
    ctx: &mut ShardCtx,
    completed: u64,
) {
    while let Ok(msg) = rx.try_recv() {
        match msg {
            ShardMsg::Submit(spec) => {
                let w = work_weight_us(&spec);
                ctx.weights.insert(spec.id, (w, w));
                engine.submit(spec)
            }
            ShardMsg::Stats(reply) => {
                let _ = reply.send(snapshot(engine, completed));
            }
            ShardMsg::Drain | ShardMsg::Halt => {}
        }
    }
}

/// Turn the engine's pending terminations (fired cancel tokens, queued
/// deadlines) into lifecycle events. `release_load` decrements the load
/// and work gauges per termination — true on the live path, false once
/// the gauge is tombstoned (the tombstone already released all
/// accounting, and a dead shard's work gauge is never read).
fn emit_terminations(engine: &mut Engine<'_>, ctx: &mut ShardCtx, release_load: bool) {
    for t in engine.drain_terminations() {
        let w = ctx.weights.remove(&t.id).map_or(NOMINAL_WORK_US, |(_, rem)| rem);
        if release_load {
            ctx.load.fetch_sub(1, Ordering::SeqCst);
            ctx.work.fetch_sub(w, Ordering::SeqCst);
        }
        let _ = ctx.events.send(match t.cause {
            TerminationCause::Cancelled => JobEvent::Cancelled { id: t.id },
            TerminationCause::DeadlineExpired => {
                JobEvent::Rejected { id: t.id, reason: RejectReason::DeadlineExpired }
            }
        });
    }
}

/// Abandon everything in flight on an exiting shard: tombstone the load
/// gauge (releasing this shard's in-flight accounting and steering the
/// router away), pull in whatever the channel still holds, and emit one
/// [`JobEvent::Aborted`] per abandoned request so waiters get an
/// explicit error instead of hanging (terminations already reaped by
/// the engine keep their precise cancelled/rejected cause).
///
/// Ordering is load-bearing: the tombstone goes in *before* the final
/// channel drain. A submitter whose post-send gauge check still reads
/// live therefore sent before the tombstone, which means its message is
/// in the channel before this drain runs — it is ingested and aborted
/// here, never silently lost. A submitter that reads the tombstone
/// reports failure itself (`ShardRouter::submit`).
fn abandon_inflight(
    engine: &mut Engine<'_>,
    rx: &Receiver<ShardMsg>,
    ctx: &mut ShardCtx,
    completed: u64,
    error: &str,
) {
    ctx.load.store(DEAD, Ordering::SeqCst);
    ingest_remaining(engine, rx, ctx, completed);
    emit_terminations(engine, ctx, false);
    for id in engine.abandon() {
        let _ = ctx.events.send(JobEvent::Aborted { id, error: error.to_string() });
    }
}

fn shard_worker(
    model: Arc<dyn ModelBackend + Send + Sync>,
    cfg: EngineConfig,
    mut ctx: ShardCtx,
    rx: Receiver<ShardMsg>,
) -> (ShardStats, Option<String>) {
    let model: Arc<dyn ModelBackend> = model;
    // denominator of the linear weight decay (captured before the engine
    // takes the backend): a request at step s has (steps−s)/steps of its
    // admission-time work left
    let serve_steps = model.entry().config.serve_steps;
    let mut engine = Engine::new(model, cfg);
    let mut completed = 0u64;
    let mut draining = false;
    let mut disconnected = false;
    loop {
        // ingest everything available; block briefly only when idle so
        // drain/halt stay responsive without busy-waiting
        loop {
            let msg = if engine.pending() > 0 || draining || disconnected {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        disconnected = true;
                        None
                    }
                }
            } else {
                match rx.recv_timeout(Duration::from_millis(20)) {
                    Ok(m) => Some(m),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => {
                        disconnected = true;
                        None
                    }
                }
            };
            let Some(msg) = msg else { break };
            match msg {
                ShardMsg::Submit(spec) => {
                    let id = spec.id;
                    let w = work_weight_us(&spec);
                    ctx.weights.insert(id, (w, w));
                    engine.submit(spec);
                    if ctx.chatter.load(Ordering::SeqCst) {
                        let _ = ctx.events.send(JobEvent::Admitted { id, shard: ctx.shard });
                    }
                }
                ShardMsg::Stats(reply) => {
                    let _ = reply.send(snapshot(&engine, completed));
                }
                ShardMsg::Drain => draining = true,
                ShardMsg::Halt => {
                    abandon_inflight(&mut engine, &rx, &mut ctx, completed, "shard halted");
                    return (snapshot(&engine, completed), None);
                }
            }
        }
        if engine.pending() > 0 {
            if let Err(e) = engine.tick() {
                // a backend failure poisons this shard only; abandoned
                // requests are abort-notified and the error resurfaces
                // from shutdown()
                let err = format!("{e:#}");
                eprintln!("speca: shard worker tick failed: {err}");
                abandon_inflight(&mut engine, &rx, &mut ctx, completed, &err);
                return (snapshot(&engine, completed), Some(err));
            }
            for c in engine.drain_completions() {
                completed += 1;
                ctx.load.fetch_sub(1, Ordering::SeqCst);
                ctx.work.fetch_sub(
                    ctx.weights.remove(&c.id).map_or(NOMINAL_WORK_US, |(_, rem)| rem),
                    Ordering::SeqCst,
                );
                let _ = ctx.events.send(JobEvent::Completed(Box::new(c)));
            }
            // cancelled / deadline-expired requests free their slot here
            emit_terminations(&mut engine, &mut ctx, true);
            // one progress sweep per tick: always decay the router-facing
            // work gauge (least-loaded routing must see remaining work
            // shrink whether or not anyone consumes the event stream),
            // and emit Progress chatter only when someone does —
            // throttled to every 4th step (first included): `poll` needs
            // coarse freshness, and one event per request per tick would
            // serialize on the job-table mutex for nothing
            let chatter = ctx.chatter.load(Ordering::SeqCst);
            for p in engine.progress() {
                decay_weight(&mut ctx, p.id, p.step, serve_steps);
                if chatter && p.step % 4 == 1 {
                    let _ = ctx.events.send(JobEvent::Progress(p));
                }
            }
        } else if draining || disconnected {
            // same tombstone + final-drain protocol as the error exit: a
            // submit racing this edge is aborted with an explicit event,
            // not silently destroyed with the channel (when nothing
            // raced, the engine and channel are empty — no events fire)
            abandon_inflight(&mut engine, &rx, &mut ctx, completed, "shard shutting down");
            return (snapshot(&engine, completed), None);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Work gauge matching an unhinted load snapshot (the nominal unit
    /// per request — what the router accumulates when no hint is set).
    fn uniform_work(loads: &[usize]) -> Vec<u64> {
        loads.iter().map(|l| *l as u64 * NOMINAL_WORK_US).collect()
    }

    #[test]
    fn least_loaded_picks_min_with_deterministic_ties() {
        let p = RouterPolicy::LeastLoaded;
        assert_eq!(p.pick(&[3, 1, 2], &uniform_work(&[3, 1, 2]), 0), 1);
        let l = [2usize, 0, 0, 1];
        assert_eq!(p.pick(&l, &uniform_work(&l), 7), 1, "tie breaks to lowest index");
        assert_eq!(p.pick(&[0], &[0], 5), 0);
        assert_eq!(p.pick(&[], &[], 5), 0, "degenerate snapshot is safe");
    }

    #[test]
    fn least_loaded_weighs_expected_work_over_request_counts() {
        let p = RouterPolicy::LeastLoaded;
        // shard 0 holds one heavy request (60 ms), shard 1 two cheap ones
        // (5 ms each): expected-work routing picks the cheap backlog even
        // though it holds more requests
        assert_eq!(p.pick(&[1, 2], &[60_000, 10_000], 0), 1);
        // equal work falls back to the smaller request count
        assert_eq!(p.pick(&[2, 1], &[10_000, 10_000], 0), 1);
    }

    #[test]
    fn least_loaded_never_prefers_a_dead_shard_on_stale_work() {
        let p = RouterPolicy::LeastLoaded;
        // shard 0 died holding one cheap job: its work gauge is frozen
        // small, but the tombstone must outrank any live shard's backlog
        let loads = [usize::MAX, 3, 1];
        assert_eq!(p.pick(&loads, &[1_000, 90_000, 120_000], 0), 1);
        // only when every shard is dead does the pick fall out at all
        // (submit() then fails fast)
        let all_dead = [usize::MAX, usize::MAX];
        assert_eq!(p.pick(&all_dead, &[5, 1], 0), 0);
    }

    #[test]
    fn round_robin_cycles_regardless_of_load() {
        let p = RouterPolicy::RoundRobin;
        let picks: Vec<usize> =
            (0..5).map(|t| p.pick(&[9, 0, 0], &uniform_work(&[9, 0, 0]), t)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1]);
    }

    #[test]
    fn decay_weight_shrinks_monotonically_and_never_reinflates() {
        let (tx, _rx) = channel();
        let mut ctx = ShardCtx {
            shard: 0,
            load: Arc::new(AtomicUsize::new(0)),
            work: Arc::new(AtomicU64::new(10_000)),
            events: tx,
            chatter: Arc::new(AtomicBool::new(false)),
            weights: HashMap::new(),
        };
        ctx.weights.insert(7, (10_000, 10_000));
        // step 0: nothing done yet, full weight stays booked
        decay_weight(&mut ctx, 7, 0, 10);
        assert_eq!(ctx.work.load(Ordering::SeqCst), 10_000);
        // halfway: gauge holds half the admission-time weight
        decay_weight(&mut ctx, 7, 5, 10);
        assert_eq!(ctx.work.load(Ordering::SeqCst), 5_000);
        // a stale (smaller-step) snapshot must not re-inflate the gauge
        decay_weight(&mut ctx, 7, 3, 10);
        assert_eq!(ctx.work.load(Ordering::SeqCst), 5_000);
        // final step: floor of one µ-unit until the terminal release
        decay_weight(&mut ctx, 7, 10, 10);
        assert_eq!(ctx.work.load(Ordering::SeqCst), 1);
        assert_eq!(ctx.weights.get(&7), Some(&(10_000, 1)));
        // unknown id (already released) is a no-op
        decay_weight(&mut ctx, 99, 5, 10);
        assert_eq!(ctx.work.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn router_policy_parses() {
        assert_eq!(RouterPolicy::parse("least-loaded"), Some(RouterPolicy::LeastLoaded));
        assert_eq!(RouterPolicy::parse("ll"), Some(RouterPolicy::LeastLoaded));
        assert_eq!(RouterPolicy::parse("round-robin"), Some(RouterPolicy::RoundRobin));
        assert_eq!(RouterPolicy::parse("rr"), Some(RouterPolicy::RoundRobin));
        assert_eq!(RouterPolicy::parse("hash"), None);
    }
}
