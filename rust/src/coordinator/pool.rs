//! Sharded serving: N worker threads, each owning an [`Engine`] over one
//! shared `Send + Sync` backend, fed by a round-robin / least-loaded
//! router (DESIGN.md §8).
//!
//! Threading model:
//! * every shard worker runs the same loop the single-threaded server
//!   used — ingest without blocking while there is work, tick, drain —
//!   so per-request behaviour is identical to a lone engine;
//! * the router picks a shard at submit time from a load snapshot
//!   (per-shard `AtomicUsize` of requests in flight) and is `Clone`, so
//!   any number of connection threads can submit concurrently without a
//!   central funnel;
//! * events from all shards merge onto one [`JobEvent`] stream (the
//!   full job lifecycle: admission onto a shard, per-tick progress,
//!   completion, rejection, cancellation, abort). Events arrive in
//!   nondeterministic order across shards but in lifecycle order per
//!   shard, and every event carries its request id, so callers re-order
//!   (or route replies) by id — and because backends are
//!   batching-transparent and requests share no state, a request's
//!   completion is *identical* regardless of shard count (the parity
//!   suite in `tests/shard_pool.rs` asserts it).
//!
//! Job lifecycle on a shard: the engine's queue is priority-ordered, a
//! fired cancel token frees the request's slot at the next step
//! boundary ([`JobEvent::Cancelled`]), and a deadline that expires
//! while the request is still queued sheds it with a structured
//! [`JobEvent::Rejected`] instead of running doomed work — see
//! `coordinator::job` for the state machine.
//!
//! Shutdown is two-mode: `drain` stops ingestion and finishes everything
//! already routed; `halt` abandons in-flight work. Both join every
//! worker before returning.
//!
//! Failure containment: a backend error poisons only the shard that hit
//! it. The dying worker tombstones its load gauge (releasing its
//! in-flight accounting so admission control never counts dead
//! requests, and steering the router away), drains its channel one last
//! time, then *evacuates*: every request the engine rolled back to a
//! step boundary is parked into a
//! [`RequestCheckpoint`](crate::coordinator::RequestCheckpoint) and
//! handed to the least-loaded live peer, which resumes it
//! bitwise-identically (DESIGN.md §13) — waiters see their job
//! complete, not abort. Only when no live peer exists (1-shard pool,
//! pool-wide drain) do the units fall back to [`JobEvent::Aborted`] (so
//! waiters get an error reply, never a hang — see `evacuate` for why
//! the tombstone-then-drain order makes this race-free); the error
//! itself resurfaces as `Err` from [`EngineShardPool::shutdown`].
//!
//! Work-stealing ([`PoolConfig::steal`]): an idle worker pulls one
//! admission unit — queued work, or a parked preemptible checkpoint —
//! from the peer holding the most expected remaining work on its
//! router gauge, so one shard's backlog spreads to idle capacity
//! mid-request instead of only at admission time.
//! [`EngineShardPool::drain_shard`] retires one shard the same way
//! (park everything, migrate to peers, exit) for elastic downscale.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::job::{JobEvent, RejectReason, TerminationCause};
use crate::coordinator::state::{Completion, RequestSpec};
use crate::coordinator::{Admission, Engine, EngineConfig};
use crate::metrics::flops::FlopsCounter;
use crate::runtime::ModelBackend;

/// How the router spreads requests over shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Cycle through shards regardless of load.
    RoundRobin,
    /// Pick the shard with the least *expected remaining work* in flight
    /// — the sum of each routed request's service-time hint
    /// ([`crate::coordinator::JobMeta::cost_hint`], fed by the
    /// [`JobManager`](crate::coordinator::job::JobManager)'s per-policy
    /// EWMA), decayed linearly as the request's serve steps complete
    /// (`decay_weight`) so a mostly-finished heavy request no longer
    /// repels traffic. Unhinted requests weigh one nominal unit each,
    /// which degrades exactly to fewest-requests-in-flight routing;
    /// ties go to the smaller request count, then the lowest index, so
    /// routing is deterministic for a given load state.
    LeastLoaded,
}

impl RouterPolicy {
    /// Parse `rr` / `round-robin` / `ll` / `least-loaded`.
    pub fn parse(s: &str) -> Option<RouterPolicy> {
        match s {
            "rr" | "round-robin" => Some(RouterPolicy::RoundRobin),
            "ll" | "least-loaded" => Some(RouterPolicy::LeastLoaded),
            _ => None,
        }
    }

    /// Pure routing decision over a load snapshot: `loads` counts
    /// requests in flight per shard (`usize::MAX` marks a dead shard),
    /// `work` their summed expected-work weights (µ-units, see
    /// [`work_weight_us`]), `rr_ticket` the submission ordinal for
    /// round-robin. A dead shard's work gauge is stale (its weights are
    /// never released), so least-loaded treats tombstoned shards as
    /// infinitely heavy — they are only ever picked when every shard is
    /// dead.
    pub fn pick(&self, loads: &[usize], work: &[u64], rr_ticket: usize) -> usize {
        let n = loads.len().max(1);
        match self {
            // round-robin never reads either gauge (callers may pass an
            // empty work snapshot)
            RouterPolicy::RoundRobin => rr_ticket % n,
            RouterPolicy::LeastLoaded => {
                debug_assert_eq!(loads.len(), work.len());
                loads
                    .iter()
                    .enumerate()
                    .min_by_key(|(i, l)| {
                        let w = if **l == usize::MAX {
                            u64::MAX
                        } else {
                            work.get(*i).copied().unwrap_or(u64::MAX)
                        };
                        (w, **l, *i)
                    })
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            }
        }
    }
}

/// Nominal work weight (µ-units) of a request without a service-time
/// hint: one millisecond. Booked by [`work_weight_us`] at submit and
/// used as the release fallback on any path where a shard worker has no
/// recorded weight for an id — the two must stay identical or the work
/// gauges drift.
const NOMINAL_WORK_US: u64 = 1000;

/// Expected-work weight of one request in the router's work gauges
/// (microsecond units so the gauges stay integral atomics): the job's
/// service-time hint when present, [`NOMINAL_WORK_US`] otherwise — so
/// hinted and unhinted traffic compose, and an all-unhinted workload
/// reduces to request counting.
pub fn work_weight_us(spec: &RequestSpec) -> u64 {
    if spec.meta.cost_hint > 0.0 {
        ((spec.meta.cost_hint * 1000.0) as u64).max(1)
    } else {
        NOMINAL_WORK_US
    }
}

/// Shard-pool shape.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// worker threads (each owns one engine); clamped to ≥ 1
    pub shards: usize,
    /// How submissions spread over shards.
    pub router: RouterPolicy,
    /// per-shard engine configuration (`max_inflight` is per shard)
    pub engine: EngineConfig,
    /// Let idle workers steal admission units (queued work or parked
    /// preemptible checkpoints) from loaded peers. Off by default so
    /// closed-loop parity harnesses keep deterministic shard placement;
    /// the server turns it on.
    pub steal: bool,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            shards: 1,
            router: RouterPolicy::LeastLoaded,
            engine: EngineConfig::default(),
            steal: false,
        }
    }
}

/// One in-flight request's checkpoint image captured by a live spill
/// sweep ([`ShardRouter::spill`]): the SPCK byte image plus the wire
/// descriptions a remote process needs to re-attach what the codec
/// deliberately leaves out — the policy travels as its canonical
/// [`Policy::describe`](crate::coordinator::Policy::describe) string;
/// job metadata is re-derived by the receiving manager.
#[derive(Debug, Clone)]
pub struct SpilledCheckpoint {
    /// Request id in the spilling process (ids are per-process; a
    /// resuming manager assigns its own).
    pub id: u64,
    /// Next serve step the checkpoint resumes at.
    pub step: usize,
    /// SPCK byte image ([`RequestCheckpoint::to_bytes`](crate::coordinator::RequestCheckpoint::to_bytes)).
    pub bytes: Vec<u8>,
    /// Canonical policy description
    /// ([`Policy::describe`](crate::coordinator::Policy::describe)).
    pub policy: String,
}

enum ShardMsg {
    Submit(RequestSpec),
    /// a unit migrated from an exiting peer, with its `(initial,
    /// remaining)` work-weight ledger entry (the sender reserved this
    /// shard's gauges before handing over, mirroring `submit`)
    Resume(Admission, (u64, u64)),
    /// a live checkpoint-spill sweep: park, serialize and immediately
    /// resume everything in flight, replying with the byte images
    Spill {
        reply: Sender<Vec<SpilledCheckpoint>>,
    },
    /// a work-stealing probe: reply with one admission unit (and its
    /// weight ledger entry) or `None`; the victim releases its gauges
    /// for a donated unit before replying, the thief re-reserves them
    Steal {
        reply: Sender<Option<(Admission, (u64, u64))>>,
    },
    Stats(Sender<ShardStats>),
    /// stop ingesting; migrate in-flight work to live peers if any,
    /// else finish everything already routed, then exit
    Drain,
    /// exit now, abandoning in-flight requests
    Halt,
}

/// Counter snapshot of one shard (or, merged, of the whole pool).
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    /// Requests completed.
    pub completed: u64,
    /// Requests admitted or queued right now.
    pub inflight: usize,
    /// Engine ticks executed.
    pub ticks: u64,
    /// Aggregate booked FLOPs.
    pub flops: FlopsCounter,
    /// Checkpoints parked at a step boundary (preemption, stealing,
    /// migration — the park side).
    pub parked: u64,
    /// Checkpoints resumed into a slot (any origin).
    pub resumed: u64,
    /// Units this shard pulled from loaded peers while idle.
    pub stolen: u64,
    /// Units this shard received from dying/draining peers.
    pub migrated: u64,
}

impl ShardStats {
    fn merge(&mut self, other: &ShardStats) {
        self.completed += other.completed;
        self.inflight += other.inflight;
        self.ticks += other.ticks;
        self.flops.merge(&other.flops);
        self.parked += other.parked;
        self.resumed += other.resumed;
        self.stolen += other.stolen;
        self.migrated += other.migrated;
    }
}

/// Load-gauge tombstone. A dying worker stores this into its gauge
/// *before* its final channel drain; real in-flight counts stay far
/// below it, and transient ±1 traffic around a tombstone stays ≥ DEAD.
/// The tombstone is what makes shard death race-free: a submitter
/// re-checks the gauge after a successful send, so a request can never
/// be silently stranded on a channel nobody will read (see `submit`).
const DEAD: usize = usize::MAX / 2;

/// Cloneable submission handle: connection threads route directly to
/// shard queues — no single-engine channel funnel in between.
///
/// # Examples
///
/// ```
/// use std::sync::Arc;
/// use speca::config::ModelConfig;
/// use speca::coordinator::{EngineShardPool, PoolConfig};
/// use speca::runtime::{ModelBackend, NativeBackend};
/// use speca::workload::{batch_requests, parse_policy};
///
/// let model = Arc::new(NativeBackend::seeded(ModelConfig::native_test(), 1));
/// let depth = model.entry().config.depth;
/// let pool = EngineShardPool::new(model, PoolConfig { shards: 2, ..PoolConfig::default() });
/// let router = pool.router(); // cloneable; each connection thread keeps one
/// let policy = parse_policy("speca:N=4,O=2", depth).unwrap();
/// for spec in batch_requests(4, 4, &policy, 0, false) {
///     router.submit(spec).unwrap();
/// }
/// let out = pool.shutdown(true).unwrap(); // drain: finish everything routed
/// assert_eq!(out.completions.len(), 4);
/// ```
#[derive(Clone)]
pub struct ShardRouter {
    policy: RouterPolicy,
    txs: Vec<Sender<ShardMsg>>,
    loads: Vec<Arc<AtomicUsize>>,
    /// expected remaining work per shard in µ-units ([`work_weight_us`]):
    /// incremented at submit, decayed per serve step as the worker
    /// observes progress (`decay_weight`), and fully released when the
    /// request reaches any terminal state
    work: Vec<Arc<AtomicU64>>,
    rr: Arc<AtomicUsize>,
}

impl ShardRouter {
    /// Number of shards this router feeds (dead ones included).
    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Requests in flight per shard (admitted + queued on the shard). A
    /// shard whose worker has died reports `usize::MAX`.
    pub fn loads(&self) -> Vec<usize> {
        self.loads
            .iter()
            .map(|l| {
                let v = l.load(Ordering::SeqCst);
                if v >= DEAD { usize::MAX } else { v }
            })
            .collect()
    }

    /// Expected remaining work per shard in µ-units (the least-loaded
    /// routing signal; a dead shard's value is meaningless and its
    /// `loads()` tombstone is authoritative).
    pub fn work_us(&self) -> Vec<u64> {
        self.work.iter().map(|w| w.load(Ordering::SeqCst)).collect()
    }

    /// Total requests in flight across live shards (a dead shard has
    /// released its in-flight accounting).
    pub fn inflight(&self) -> usize {
        self.loads().iter().filter(|l| **l != usize::MAX).sum()
    }

    /// Route one request; returns the shard index it landed on. Dead
    /// shards (tombstoned gauge) are excluded and the pick retried, so
    /// one dead shard never blackholes new submissions while live shards
    /// have capacity; when every worker is gone this fails fast.
    pub fn submit(&self, spec: RequestSpec) -> Result<usize> {
        let mut spec = spec;
        let weight = work_weight_us(&spec);
        let n = self.txs.len();
        let mut loads = self.loads();
        // one work snapshot per submit, and none at all for round-robin
        // (which ignores the gauges); retries only happen on dead shards,
        // which the locally-updated `loads` already excludes
        let work = match self.policy {
            RouterPolicy::LeastLoaded => self.work_us(),
            RouterPolicy::RoundRobin => Vec::new(),
        };
        loop {
            let mut shard =
                self.policy.pick(&loads, &work, self.rr.fetch_add(1, Ordering::SeqCst));
            if loads[shard] == usize::MAX {
                // round-robin ignores load (and a dead shard's stale work
                // gauge can still look attractive), so a pick can land on
                // a known-dead shard; fall forward to the next live one
                match (0..n).map(|k| (shard + k) % n).find(|&s| loads[s] != usize::MAX) {
                    Some(live) => shard = live,
                    None => bail!("all shard workers are gone"),
                }
            }
            // reserve a slot on the gauge before handing over; a
            // tombstone means the worker died — undo and retry elsewhere
            if self.loads[shard].fetch_add(1, Ordering::SeqCst) >= DEAD {
                self.loads[shard].fetch_sub(1, Ordering::SeqCst);
                loads[shard] = usize::MAX;
                continue;
            }
            self.work[shard].fetch_add(weight, Ordering::SeqCst);
            match self.txs[shard].send(ShardMsg::Submit(spec)) {
                Ok(()) => {
                    // Close the death race: the worker tombstones its
                    // gauge *before* its final channel drain, so a live
                    // gauge here proves our message lands before that
                    // drain (it will be aborted, not lost). A tombstone
                    // means the message may never be read — report
                    // failure; the caller's error reply at worst
                    // duplicates the worker's abort notice, never hangs.
                    if self.loads[shard].load(Ordering::SeqCst) >= DEAD {
                        bail!("shard {shard} worker died during submit");
                    }
                    return Ok(shard);
                }
                Err(unsent) => {
                    // undo the reservation — unless the dying worker has
                    // tombstoned the gauge since our reservation, which
                    // absorbed it (decrementing would leave DEAD-1: an
                    // absurd *live* load that wedges admission control)
                    let _ = self.loads[shard].fetch_update(
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                        |v| if v >= DEAD { None } else { Some(v - 1) },
                    );
                    // the work gauge has no tombstone: a dead shard's
                    // value is never read once loads() reports MAX, so a
                    // plain undo is safe (and keeps live-path accounting
                    // exact when the send failure races a drain)
                    self.work[shard].fetch_sub(weight, Ordering::SeqCst);
                    loads[shard] = usize::MAX;
                    let ShardMsg::Submit(s) = unsent.0 else { unreachable!() };
                    spec = s;
                }
            }
        }
    }

    /// Route a parked checkpoint into the pool — the receiving side of
    /// cross-process failover (`submit_checkpoint` on the wire). Same
    /// reserve → send → tombstone-re-check death-race protocol as
    /// [`Self::submit`], but the unit lands as a resume, so the shard
    /// counts it `migrated` and its engine `resumed`. The work-weight
    /// ledger is rebuilt from the spec's cost hint ([`work_weight_us`]);
    /// mid-flight progress made in the dead process is deliberately not
    /// discounted — a conservative booking self-corrects via
    /// `decay_weight` within a few ticks.
    pub fn submit_parked(&self, adm: Admission) -> Result<usize> {
        let mut adm = adm;
        let weight = work_weight_us(adm.spec());
        let n = self.txs.len();
        let mut loads = self.loads();
        let work = match self.policy {
            RouterPolicy::LeastLoaded => self.work_us(),
            RouterPolicy::RoundRobin => Vec::new(),
        };
        loop {
            let mut shard =
                self.policy.pick(&loads, &work, self.rr.fetch_add(1, Ordering::SeqCst));
            if loads[shard] == usize::MAX {
                match (0..n).map(|k| (shard + k) % n).find(|&s| loads[s] != usize::MAX) {
                    Some(live) => shard = live,
                    None => bail!("all shard workers are gone"),
                }
            }
            if self.loads[shard].fetch_add(1, Ordering::SeqCst) >= DEAD {
                self.loads[shard].fetch_sub(1, Ordering::SeqCst);
                loads[shard] = usize::MAX;
                continue;
            }
            self.work[shard].fetch_add(weight, Ordering::SeqCst);
            match self.txs[shard].send(ShardMsg::Resume(adm, (weight, weight))) {
                Ok(()) => {
                    // post-send re-check closes the same death race as
                    // `submit` (see there for the ordering argument)
                    if self.loads[shard].load(Ordering::SeqCst) >= DEAD {
                        bail!("shard {shard} worker died during submit");
                    }
                    return Ok(shard);
                }
                Err(unsent) => {
                    let _ = self.loads[shard].fetch_update(
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                        |v| if v >= DEAD { None } else { Some(v - 1) },
                    );
                    self.work[shard].fetch_sub(weight, Ordering::SeqCst);
                    loads[shard] = usize::MAX;
                    let ShardMsg::Resume(a, _) = unsent.0 else { unreachable!() };
                    adm = a;
                }
            }
        }
    }

    /// Capture a checkpoint image of every in-flight request across
    /// live shards (the fabric's crash-durability sweep): each shard
    /// parks, serializes and immediately resumes its requests between
    /// ticks, so the sweep is bitwise-invisible to results. Queued
    /// fresh units are not captured — they have no state worth shipping
    /// and a from-scratch resubmit recreates them exactly. All probes
    /// go out before any reply is awaited, mirroring [`Self::stats`].
    pub fn spill(&self) -> Vec<SpilledCheckpoint> {
        let probes: Vec<_> = self
            .txs
            .iter()
            .filter_map(|tx| {
                let (rtx, rrx) = channel();
                tx.send(ShardMsg::Spill { reply: rtx }).ok().map(|_| rrx)
            })
            .collect();
        let mut out = Vec::new();
        for rrx in probes {
            if let Ok(mut s) = rrx.recv_timeout(Duration::from_secs(10)) {
                out.append(&mut s);
            }
        }
        out
    }

    /// Merged counter snapshot across all live shards. All probes go out
    /// before any reply is awaited (a worker replies between ticks), so
    /// the wall time is the slowest single shard, not the sum.
    pub fn stats(&self) -> ShardStats {
        let probes: Vec<_> = self
            .txs
            .iter()
            .filter_map(|tx| {
                let (rtx, rrx) = channel();
                tx.send(ShardMsg::Stats(rtx)).ok().map(|_| rrx)
            })
            .collect();
        let mut agg = ShardStats::default();
        for rrx in probes {
            if let Ok(s) = rrx.recv_timeout(Duration::from_secs(10)) {
                agg.merge(&s);
            }
        }
        agg
    }
}

/// Everything a finished pool hands back. The per-request vectors hold
/// only events not consumed through [`EngineShardPool::take_event_rx`];
/// a consumer that took the stream (e.g. a
/// [`JobManager`](crate::coordinator::job::JobManager) dispatcher) sees
/// them there instead.
pub struct PoolOutcome {
    /// Requests that finished normally.
    pub completions: Vec<Completion>,
    /// `(id, error)` of requests abandoned by dead/halted shards.
    pub aborted: Vec<(u64, String)>,
    /// `(id, reason)` of requests shed by queued-deadline expiry.
    pub rejected: Vec<(u64, RejectReason)>,
    /// Ids of requests dropped after their cancel token fired.
    pub cancelled: Vec<u64>,
    /// Merged counter snapshot across workers.
    pub stats: ShardStats,
}

/// N engines over one shared backend. See module docs for the threading
/// model.
pub struct EngineShardPool {
    router: ShardRouter,
    workers: Vec<JoinHandle<(ShardStats, Option<String>)>>,
    events: Option<Receiver<JobEvent>>,
    /// set once [`Self::take_event_rx`] hands the stream to a consumer;
    /// until then workers skip the Admitted/Progress chatter so a
    /// closed-loop user (bench runners, parity tests) does not buffer
    /// requests × steps events nobody will read
    chatter: Arc<AtomicBool>,
    /// per-shard drain flags, shared with every worker's mesh view:
    /// a draining shard is never a steal victim or migration target
    draining: Vec<Arc<AtomicBool>>,
}

impl EngineShardPool {
    /// Spawn `cfg.shards` worker threads over one shared backend.
    pub fn new(model: Arc<dyn ModelBackend + Send + Sync>, cfg: PoolConfig) -> EngineShardPool {
        let shards = cfg.shards.max(1);
        let (ctx, crx) = channel();
        let chatter = Arc::new(AtomicBool::new(false));
        // the whole mesh — channels, gauges, drain flags — exists before
        // any worker spawns, because every worker's ShardCtx carries a
        // view of all of it (stealing and migration are peer-to-peer)
        let mut txs = Vec::with_capacity(shards);
        let mut rxs = Vec::with_capacity(shards);
        let mut loads = Vec::with_capacity(shards);
        let mut work = Vec::with_capacity(shards);
        let mut draining = Vec::with_capacity(shards);
        for _ in 0..shards {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(rx);
            loads.push(Arc::new(AtomicUsize::new(0)));
            work.push(Arc::new(AtomicU64::new(0)));
            draining.push(Arc::new(AtomicBool::new(false)));
        }
        let mut workers = Vec::with_capacity(shards);
        for (shard, rx) in rxs.into_iter().enumerate() {
            let worker_model = model.clone();
            let worker_cfg = cfg.engine.clone();
            let worker_ctx = ShardCtx {
                shard,
                load: loads[shard].clone(),
                work: work[shard].clone(),
                events: ctx.clone(),
                chatter: chatter.clone(),
                weights: HashMap::new(),
                txs: txs.clone(),
                loads: loads.clone(),
                works: work.clone(),
                draining: draining.clone(),
                steal: cfg.steal,
                stolen: 0,
                migrated: 0,
            };
            workers.push(
                thread::Builder::new()
                    .name(format!("speca-shard-{shard}"))
                    .spawn(move || shard_worker(worker_model, worker_cfg, worker_ctx, rx))
                    .expect("spawning shard worker"),
            );
        }
        EngineShardPool {
            router: ShardRouter {
                policy: cfg.router,
                txs,
                loads,
                work,
                rr: Arc::new(AtomicUsize::new(0)),
            },
            workers,
            events: Some(crx),
            chatter,
            draining,
        }
    }

    /// A cloneable submission handle (connection threads each keep one).
    pub fn router(&self) -> ShardRouter {
        self.router.clone()
    }

    /// Route one request to a shard (see [`ShardRouter::submit`]).
    pub fn submit(&self, spec: RequestSpec) -> Result<usize> {
        self.router.submit(spec)
    }

    /// Merged counter snapshot (see [`ShardRouter::stats`]).
    pub fn stats(&self) -> ShardStats {
        self.router.stats()
    }

    /// Take ownership of the merged [`JobEvent`] stream (e.g. for a job
    /// dispatcher thread). Taking it also turns on the per-tick
    /// Admitted/Progress lifecycle chatter, which is suppressed while
    /// nobody consumes the stream. If never taken, [`Self::shutdown`]
    /// drains the buffered terminal events into the [`PoolOutcome`]
    /// vectors.
    pub fn take_event_rx(&mut self) -> Option<Receiver<JobEvent>> {
        let rx = self.events.take();
        if rx.is_some() {
            self.chatter.store(true, Ordering::SeqCst);
        }
        rx
    }

    /// Drain one shard without stopping the pool (elastic downscale):
    /// the shard stops ingesting, parks everything in flight and hands
    /// the checkpoints to live peers, then exits; its tombstoned gauge
    /// steers the router away from then on. Returns whether the drain
    /// message reached a live worker.
    pub fn drain_shard(&self, shard: usize) -> bool {
        let Some(flag) = self.draining.get(shard) else { return false };
        // flag first: peers must stop picking this shard as a steal
        // victim / migration target before it begins tearing down
        flag.store(true, Ordering::SeqCst);
        self.router.txs[shard].send(ShardMsg::Drain).is_ok()
    }

    /// Stop the pool and join every worker. `drain` finishes all work
    /// already submitted first; `!drain` abandons it. A worker that hit a
    /// backend error (or panicked) surfaces here as `Err`, mirroring the
    /// single-engine path where `tick()?` propagates.
    pub fn shutdown(mut self, drain: bool) -> Result<PoolOutcome> {
        if drain {
            // mark every shard draining *before* any Drain lands: with
            // no live non-draining peer to migrate to, each worker
            // serves its remaining work to completion locally — the
            // pool-wide drain contract — instead of bouncing
            // checkpoints between shards that are all about to exit
            for flag in &self.draining {
                flag.store(true, Ordering::SeqCst);
            }
        }
        for tx in &self.router.txs {
            let _ = tx.send(if drain { ShardMsg::Drain } else { ShardMsg::Halt });
        }
        let rx = self.events.take();
        // drop the router's senders; once the first worker exits via its
        // Drain/Halt message the mesh senders unwind with it and any
        // straggler observes the disconnect
        let EngineShardPool { router, workers, .. } = self;
        drop(router);
        let mut stats = ShardStats::default();
        let mut errors = Vec::new();
        let mut panicked = 0usize;
        for w in workers {
            match w.join() {
                Ok((s, err)) => {
                    stats.merge(&s);
                    errors.extend(err);
                }
                Err(_) => panicked += 1,
            }
        }
        let mut completions = Vec::new();
        let mut aborted = Vec::new();
        let mut rejected = Vec::new();
        let mut cancelled = Vec::new();
        if let Some(rx) = rx {
            while let Ok(ev) = rx.try_recv() {
                match ev {
                    JobEvent::Completed(c) => completions.push(*c),
                    JobEvent::Aborted { id, error } => aborted.push((id, error)),
                    JobEvent::Rejected { id, reason } => rejected.push((id, reason)),
                    JobEvent::Cancelled { id } => cancelled.push(id),
                    JobEvent::Admitted { .. } | JobEvent::Progress(_) => {}
                }
            }
        }
        if panicked > 0 {
            bail!("{panicked} shard worker(s) panicked");
        }
        if !errors.is_empty() {
            bail!("shard worker error(s): {}", errors.join("; "));
        }
        Ok(PoolOutcome { completions, aborted, rejected, cancelled, stats })
    }
}

fn snapshot(engine: &Engine<'_>, ctx: &ShardCtx, completed: u64) -> ShardStats {
    ShardStats {
        completed,
        inflight: engine.pending(),
        ticks: engine.ticks,
        flops: engine.flops,
        parked: engine.parked,
        resumed: engine.resumed,
        stolen: ctx.stolen,
        migrated: ctx.migrated,
    }
}

/// Everything a shard worker needs besides its engine and channel: shard
/// identity, the router-facing gauges, the merged event sender, the
/// chatter switch, the per-request work-weight ledger, and a view of
/// the whole mesh (peer channels, gauges, drain flags) for stealing and
/// migration.
struct ShardCtx {
    shard: usize,
    load: Arc<AtomicUsize>,
    work: Arc<AtomicU64>,
    events: Sender<JobEvent>,
    chatter: Arc<AtomicBool>,
    /// `(initial, remaining)` expected-work weight of every request this
    /// shard ingested, keyed by id. `remaining` is decayed linearly as
    /// serve steps complete (`decay_weight`) and released from the
    /// router's work gauge at each terminal state, so least-loaded
    /// routing tracks *remaining* work, not cumulative throughput — a
    /// nearly-done heavy request weighs close to nothing. The ledger
    /// entry travels with a unit that is stolen or migrated, so the
    /// receiving shard's gauge keeps decaying from the same baseline.
    weights: HashMap<u64, (u64, u64)>,
    /// every shard's submission channel (own index included, unused)
    txs: Vec<Sender<ShardMsg>>,
    /// every shard's in-flight gauge (own index == `load`)
    loads: Vec<Arc<AtomicUsize>>,
    /// every shard's expected-work gauge (own index == `work`)
    works: Vec<Arc<AtomicU64>>,
    /// every shard's drain flag — set before the Drain message lands,
    /// so peers stop targeting a leaving shard immediately
    draining: Vec<Arc<AtomicBool>>,
    /// whether this worker steals when idle ([`PoolConfig::steal`])
    steal: bool,
    /// units pulled from loaded peers while idle
    stolen: u64,
    /// units received from dying/draining peers
    migrated: u64,
}

/// Whether any peer of `ctx.shard` is alive and not draining — i.e.
/// whether evacuation has somewhere to send checkpoints.
fn live_peer_exists(ctx: &ShardCtx) -> bool {
    (0..ctx.txs.len()).any(|i| {
        i != ctx.shard
            && !ctx.draining[i].load(Ordering::SeqCst)
            && ctx.loads[i].load(Ordering::SeqCst) < DEAD
    })
}

/// Decay one request's expected-remaining-work booking as its serve
/// steps complete: the shard's work gauge drops linearly from the full
/// admission-time weight toward one µ-unit at the final step (the floor
/// keeps every in-flight request visible to the router until its
/// terminal release). Monotonic — `remaining` only shrinks — so
/// replayed or throttled progress snapshots can never re-inflate the
/// gauge, and the terminal release of `remaining` keeps the gauge
/// arithmetic exact.
fn decay_weight(ctx: &mut ShardCtx, id: u64, step: usize, total_steps: usize) {
    let Some((initial, remaining)) = ctx.weights.get_mut(&id) else { return };
    let left = total_steps.saturating_sub(step) as u64;
    let want = (*initial * left / total_steps.max(1) as u64).max(1);
    if want < *remaining {
        ctx.work.fetch_sub(*remaining - want, Ordering::SeqCst);
        *remaining = want;
    }
}

/// Preemption-aware admission fix: floor the work-gauge booking of every
/// *parked* queued unit at one nominal request. Parked checkpoints never
/// appear in `engine.progress()` (only resident requests do), so before
/// this sweep a shard that preempted a pile of nearly-done jobs kept
/// them booked at `decay_weight`'s 1 µ-unit floor — the router and the
/// steal heuristic both read the gauge and concluded the shard was idle,
/// then piled more work onto it. A parked unit costs at least a resume
/// plus its remaining serve steps, so it is floored at the same
/// [`NOMINAL_WORK_US`] an unhinted fresh request books. The ledger entry
/// is raised together with the gauge, so the terminal release and any
/// later post-resume decay stay arithmetically exact, and re-running the
/// sweep is idempotent (the floor condition is already met).
fn floor_parked_work(engine: &Engine<'_>, ctx: &mut ShardCtx) {
    for id in engine.parked_queued() {
        let Some((_, remaining)) = ctx.weights.get_mut(&id) else { continue };
        if *remaining < NOMINAL_WORK_US {
            ctx.work.fetch_add(NOMINAL_WORK_US - *remaining, Ordering::SeqCst);
            *remaining = NOMINAL_WORK_US;
        }
    }
}

/// Pull every message still queued on the shard channel into the engine
/// (so work the router already counted is accounted for), answer any
/// pending stats probes and refuse steal probes. Used on the exit paths
/// only.
fn ingest_remaining(
    engine: &mut Engine<'_>,
    rx: &Receiver<ShardMsg>,
    ctx: &mut ShardCtx,
    completed: u64,
) {
    while let Ok(msg) = rx.try_recv() {
        match msg {
            ShardMsg::Submit(spec) => {
                let w = work_weight_us(&spec);
                ctx.weights.insert(spec.id, (w, w));
                engine.submit(spec)
            }
            ShardMsg::Resume(adm, ledger) => {
                ctx.weights.insert(adm.id(), ledger);
                engine.submit_admission(adm);
            }
            ShardMsg::Steal { reply } => {
                // exiting shards donate nothing — the thief moves on
                let _ = reply.send(None);
            }
            ShardMsg::Spill { reply } => {
                // an exiting shard has nothing durable to offer — its
                // own evacuation/abandon path settles every request
                let _ = reply.send(Vec::new());
            }
            ShardMsg::Stats(reply) => {
                let _ = reply.send(snapshot(engine, ctx, completed));
            }
            ShardMsg::Drain | ShardMsg::Halt => {}
        }
    }
}

/// Turn the engine's pending terminations (fired cancel tokens, queued
/// deadlines) into lifecycle events. `release_load` decrements the load
/// and work gauges per termination — true on the live path, false once
/// the gauge is tombstoned (the tombstone already released all
/// accounting, and a dead shard's work gauge is never read).
fn emit_terminations(engine: &mut Engine<'_>, ctx: &mut ShardCtx, release_load: bool) {
    for t in engine.drain_terminations() {
        let w = ctx.weights.remove(&t.id).map_or(NOMINAL_WORK_US, |(_, rem)| rem);
        if release_load {
            ctx.load.fetch_sub(1, Ordering::SeqCst);
            ctx.work.fetch_sub(w, Ordering::SeqCst);
        }
        let _ = ctx.events.send(match t.cause {
            TerminationCause::Cancelled => JobEvent::Cancelled { id: t.id },
            TerminationCause::DeadlineExpired => {
                JobEvent::Rejected { id: t.id, reason: RejectReason::DeadlineExpired }
            }
        });
    }
}

/// Abandon everything in flight on an exiting shard: tombstone the load
/// gauge (releasing this shard's in-flight accounting and steering the
/// router away), pull in whatever the channel still holds, and emit one
/// [`JobEvent::Aborted`] per abandoned request so waiters get an
/// explicit error instead of hanging (terminations already reaped by
/// the engine keep their precise cancelled/rejected cause).
///
/// Ordering is load-bearing: the tombstone goes in *before* the final
/// channel drain. A submitter whose post-send gauge check still reads
/// live therefore sent before the tombstone, which means its message is
/// in the channel before this drain runs — it is ingested and aborted
/// here, never silently lost. A submitter that reads the tombstone
/// reports failure itself (`ShardRouter::submit`).
fn abandon_inflight(
    engine: &mut Engine<'_>,
    rx: &Receiver<ShardMsg>,
    ctx: &mut ShardCtx,
    completed: u64,
    error: &str,
) {
    ctx.load.store(DEAD, Ordering::SeqCst);
    ingest_remaining(engine, rx, ctx, completed);
    emit_terminations(engine, ctx, false);
    for id in engine.abandon() {
        let _ = ctx.events.send(JobEvent::Aborted { id, error: error.to_string() });
    }
}

/// Hand one admission unit to the least-loaded live, non-draining peer,
/// replicating the router's reserve → send → tombstone-re-check
/// protocol so a peer dying mid-handoff can never strand the unit
/// silently. Returns whether the unit is safely delivered; on `false`
/// the unit is gone (never sent, or sent into a tombstoned shard whose
/// own final drain may abort it) and the caller must abort-notify —
/// a duplicate abort is deduplicated downstream, a missing one would
/// hang waiters.
fn send_to_peer(ctx: &ShardCtx, adm: Admission, ledger: (u64, u64)) -> bool {
    let mut adm = adm;
    let n = ctx.txs.len();
    let mut tried = vec![false; n];
    loop {
        let mut best: Option<(usize, usize)> = None;
        for (i, load) in ctx.loads.iter().enumerate() {
            if i == ctx.shard || tried[i] || ctx.draining[i].load(Ordering::SeqCst) {
                continue;
            }
            let l = load.load(Ordering::SeqCst);
            if l < DEAD && best.is_none_or(|(_, bl)| l < bl) {
                best = Some((i, l));
            }
        }
        let Some((peer, _)) = best else { return false };
        tried[peer] = true;
        // reserve on the peer's gauges before handing over; a tombstone
        // means it died since the scan — undo and try the next peer
        if ctx.loads[peer].fetch_add(1, Ordering::SeqCst) >= DEAD {
            ctx.loads[peer].fetch_sub(1, Ordering::SeqCst);
            continue;
        }
        ctx.works[peer].fetch_add(ledger.1.max(1), Ordering::SeqCst);
        match ctx.txs[peer].send(ShardMsg::Resume(adm, ledger)) {
            // post-send re-check, exactly the router's death-race close:
            // a live gauge proves the message precedes the peer's final
            // drain; a tombstone means the peer may never read it
            Ok(()) => return ctx.loads[peer].load(Ordering::SeqCst) < DEAD,
            Err(unsent) => {
                let _ = ctx.loads[peer].fetch_update(
                    Ordering::SeqCst,
                    Ordering::SeqCst,
                    |v| if v >= DEAD { None } else { Some(v - 1) },
                );
                ctx.works[peer].fetch_sub(ledger.1.max(1), Ordering::SeqCst);
                let ShardMsg::Resume(a, _) = unsent.0 else { unreachable!() };
                adm = a;
            }
        }
    }
}

/// Evacuate an exiting shard instead of abandoning it: tombstone the
/// load gauge, pull in whatever the channel still holds, then park
/// every request at its rolled-back step boundary and hand the
/// checkpoints (and untouched queued units) to live peers, which resume
/// them bitwise-identically. Requests the failed tick left fully
/// advanced are retired and emitted as completions. A unit no peer will
/// take falls back to [`JobEvent::Aborted`] with `error` — on a 1-shard
/// pool this degrades to exactly the old abandon behaviour. The
/// tombstone-before-drain ordering is the same race-closure as
/// `abandon_inflight`.
fn evacuate(
    engine: &mut Engine<'_>,
    rx: &Receiver<ShardMsg>,
    ctx: &mut ShardCtx,
    completed: &mut u64,
    error: &str,
) {
    ctx.load.store(DEAD, Ordering::SeqCst);
    ingest_remaining(engine, rx, ctx, *completed);
    emit_terminations(engine, ctx, false);
    let units = engine.park_all();
    // park_all retires requests the aborted tick left at their final
    // boundary (the retire sweep never ran) — real completions, not
    // migration candidates
    for c in engine.drain_completions() {
        *completed += 1;
        ctx.weights.remove(&c.id);
        let _ = ctx.events.send(JobEvent::Completed(Box::new(c)));
    }
    for adm in units {
        let id = adm.id();
        let ledger = ctx.weights.remove(&id).unwrap_or((NOMINAL_WORK_US, NOMINAL_WORK_US));
        if !send_to_peer(ctx, adm, ledger) {
            let _ = ctx.events.send(JobEvent::Aborted { id, error: error.to_string() });
        }
    }
}

/// Live checkpoint-spill sweep (fabric crash-durability): park every
/// in-flight request at its step boundary, serialize the parked images,
/// then resume everything straight back into this engine. Resume is
/// bitwise-identical (DESIGN.md §13), so the sweep never perturbs
/// results — it only costs the park/resume bookkeeping (the engine's
/// `parked`/`resumed` counters advance once per resident request).
/// Queued fresh units are re-queued untouched and not captured; a
/// request the park finds at its final boundary retires as a completion
/// here (live path, so gauges release normally).
fn spill_inflight(
    engine: &mut Engine<'_>,
    ctx: &mut ShardCtx,
    completed: &mut u64,
) -> Vec<SpilledCheckpoint> {
    let units = engine.park_all();
    for c in engine.drain_completions() {
        *completed += 1;
        ctx.load.fetch_sub(1, Ordering::SeqCst);
        ctx.work.fetch_sub(
            ctx.weights.remove(&c.id).map_or(NOMINAL_WORK_US, |(_, rem)| rem),
            Ordering::SeqCst,
        );
        let _ = ctx.events.send(JobEvent::Completed(Box::new(c)));
    }
    let mut out = Vec::new();
    for adm in units {
        if let Admission::Parked(ckpt) = &adm {
            out.push(SpilledCheckpoint {
                id: ckpt.spec.id,
                step: ckpt.step,
                bytes: ckpt.to_bytes(),
                policy: ckpt.spec.policy.describe(),
            });
        }
        engine.submit_admission(adm);
    }
    out
}

/// The victim side of work-stealing: donate one admission unit,
/// releasing its slice of this shard's gauges before the reply (the
/// thief re-reserves under its own). A draining shard donates nothing —
/// it is already migrating everything it holds.
fn donate(
    engine: &mut Engine<'_>,
    ctx: &mut ShardCtx,
    draining: bool,
) -> Option<(Admission, (u64, u64))> {
    if draining {
        return None;
    }
    let adm = engine.steal_one()?;
    let ledger = ctx.weights.remove(&adm.id()).unwrap_or((NOMINAL_WORK_US, NOMINAL_WORK_US));
    ctx.load.fetch_sub(1, Ordering::SeqCst);
    ctx.work.fetch_sub(ledger.1, Ordering::SeqCst);
    Some((adm, ledger))
}

/// The thief side of work-stealing: pick the live, non-draining peer
/// with the most expected remaining work on its router gauge (skipping
/// peers with fewer than two units, where a steal would just move the
/// idleness), ask it for one admission unit, and requeue the donation
/// locally under this shard's gauges. Returns whether a unit arrived.
fn try_steal(engine: &mut Engine<'_>, ctx: &mut ShardCtx) -> bool {
    let mut best: Option<(usize, u64)> = None;
    for (i, work) in ctx.works.iter().enumerate() {
        if i == ctx.shard || ctx.draining[i].load(Ordering::SeqCst) {
            continue;
        }
        let l = ctx.loads[i].load(Ordering::SeqCst);
        if l < 2 || l >= DEAD {
            continue;
        }
        let w = work.load(Ordering::SeqCst);
        if best.is_none_or(|(_, bw)| w > bw) {
            best = Some((i, w));
        }
    }
    let Some((victim, _)) = best else { return false };
    let (rtx, rrx) = channel();
    if ctx.txs[victim].send(ShardMsg::Steal { reply: rtx }).is_err() {
        return false;
    }
    // the victim answers between ticks (or its exit path answers None);
    // a dropped reply sender surfaces as an error here, never a hang
    match rrx.recv_timeout(Duration::from_secs(10)) {
        Ok(Some((adm, ledger))) => {
            let id = adm.id();
            ctx.load.fetch_add(1, Ordering::SeqCst);
            ctx.work.fetch_add(ledger.1.max(1), Ordering::SeqCst);
            ctx.weights.insert(id, ledger);
            ctx.stolen += 1;
            if ctx.chatter.load(Ordering::SeqCst) {
                let _ = ctx.events.send(JobEvent::Admitted { id, shard: ctx.shard });
            }
            engine.submit_admission(adm);
            true
        }
        _ => false,
    }
}

fn shard_worker(
    model: Arc<dyn ModelBackend + Send + Sync>,
    cfg: EngineConfig,
    mut ctx: ShardCtx,
    rx: Receiver<ShardMsg>,
) -> (ShardStats, Option<String>) {
    let model: Arc<dyn ModelBackend> = model;
    // denominator of the linear weight decay (captured before the engine
    // takes the backend): a request at step s has (steps−s)/steps of its
    // admission-time work left
    let serve_steps = model.entry().config.serve_steps;
    let mut engine = Engine::new(model, cfg);
    let mut completed = 0u64;
    let mut draining = false;
    let mut disconnected = false;
    loop {
        // ingest everything available; block briefly only when idle so
        // drain/halt stay responsive without busy-waiting
        loop {
            let msg = if engine.pending() > 0 || draining || disconnected {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        disconnected = true;
                        None
                    }
                }
            } else {
                match rx.recv_timeout(Duration::from_millis(20)) {
                    Ok(m) => Some(m),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => {
                        disconnected = true;
                        None
                    }
                }
            };
            let Some(msg) = msg else { break };
            match msg {
                ShardMsg::Submit(spec) => {
                    let id = spec.id;
                    let w = work_weight_us(&spec);
                    ctx.weights.insert(id, (w, w));
                    engine.submit(spec);
                    if ctx.chatter.load(Ordering::SeqCst) {
                        let _ = ctx.events.send(JobEvent::Admitted { id, shard: ctx.shard });
                    }
                }
                ShardMsg::Resume(adm, ledger) => {
                    // a checkpoint (or untouched queued unit) migrated
                    // from an exiting peer; the sender already reserved
                    // this shard's gauges
                    let id = adm.id();
                    ctx.weights.insert(id, ledger);
                    ctx.migrated += 1;
                    engine.submit_admission(adm);
                    if ctx.chatter.load(Ordering::SeqCst) {
                        let _ = ctx.events.send(JobEvent::Admitted { id, shard: ctx.shard });
                    }
                }
                ShardMsg::Steal { reply } => {
                    let _ = reply.send(donate(&mut engine, &mut ctx, draining));
                }
                ShardMsg::Spill { reply } => {
                    // a draining shard's units are already on their way
                    // to peers (or being served out) — nothing to spill
                    let spills = if draining {
                        Vec::new()
                    } else {
                        spill_inflight(&mut engine, &mut ctx, &mut completed)
                    };
                    let _ = reply.send(spills);
                }
                ShardMsg::Stats(reply) => {
                    let _ = reply.send(snapshot(&engine, &ctx, completed));
                }
                ShardMsg::Drain => {
                    ctx.draining[ctx.shard].store(true, Ordering::SeqCst);
                    draining = true;
                }
                ShardMsg::Halt => {
                    abandon_inflight(&mut engine, &rx, &mut ctx, completed, "shard halted");
                    return (snapshot(&engine, &ctx, completed), None);
                }
            }
        }
        if draining && engine.pending() > 0 && live_peer_exists(&ctx) {
            // park-and-migrate drain (elastic downscale): hand the
            // backlog to live peers instead of serving it out locally.
            // Pool-wide shutdown marks every shard draining before any
            // Drain message lands, so this arm never fires there and the
            // run-to-completion drain contract is preserved.
            evacuate(&mut engine, &rx, &mut ctx, &mut completed, "shard drained");
            return (snapshot(&engine, &ctx, completed), None);
        }
        if engine.pending() > 0 {
            if let Err(e) = engine.tick() {
                // a backend failure poisons this shard only; the engine
                // rolled every survivor back to its step boundary, so
                // their checkpoints migrate to live peers (and abort
                // only when none exist), while the error resurfaces
                // from shutdown()
                let err = format!("{e:#}");
                eprintln!("speca: shard worker tick failed: {err}");
                evacuate(&mut engine, &rx, &mut ctx, &mut completed, &err);
                return (snapshot(&engine, &ctx, completed), Some(err));
            }
            for c in engine.drain_completions() {
                completed += 1;
                ctx.load.fetch_sub(1, Ordering::SeqCst);
                ctx.work.fetch_sub(
                    ctx.weights.remove(&c.id).map_or(NOMINAL_WORK_US, |(_, rem)| rem),
                    Ordering::SeqCst,
                );
                let _ = ctx.events.send(JobEvent::Completed(Box::new(c)));
            }
            // cancelled / deadline-expired requests free their slot here
            emit_terminations(&mut engine, &mut ctx, true);
            // one progress sweep per tick: always decay the router-facing
            // work gauge (least-loaded routing must see remaining work
            // shrink whether or not anyone consumes the event stream),
            // and emit Progress chatter only when someone does —
            // throttled to every 4th step (first included): `poll` needs
            // coarse freshness, and one event per request per tick would
            // serialize on the job-table mutex for nothing
            let chatter = ctx.chatter.load(Ordering::SeqCst);
            for p in engine.progress() {
                decay_weight(&mut ctx, p.id, p.step, serve_steps);
                if chatter && p.step % 4 == 1 {
                    let _ = ctx.events.send(JobEvent::Progress(p));
                }
            }
            // parked queued units are invisible to the progress sweep:
            // keep their remaining work on the gauge so a park-heavy
            // shard never reads as idle to routing or stealing
            floor_parked_work(&engine, &mut ctx);
        } else if draining || disconnected {
            // same tombstone + final-drain protocol as the error exit: a
            // submit racing this edge is aborted with an explicit event,
            // not silently destroyed with the channel (when nothing
            // raced, the engine and channel are empty — no events fire)
            abandon_inflight(&mut engine, &rx, &mut ctx, completed, "shard shutting down");
            return (snapshot(&engine, &ctx, completed), None);
        } else if ctx.steal {
            // idle with an empty queue: pull one unit from the most
            // loaded peer (the 20 ms recv timeout above paces probes)
            try_steal(&mut engine, &mut ctx);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Work gauge matching an unhinted load snapshot (the nominal unit
    /// per request — what the router accumulates when no hint is set).
    fn uniform_work(loads: &[usize]) -> Vec<u64> {
        loads.iter().map(|l| *l as u64 * NOMINAL_WORK_US).collect()
    }

    #[test]
    fn least_loaded_picks_min_with_deterministic_ties() {
        let p = RouterPolicy::LeastLoaded;
        assert_eq!(p.pick(&[3, 1, 2], &uniform_work(&[3, 1, 2]), 0), 1);
        let l = [2usize, 0, 0, 1];
        assert_eq!(p.pick(&l, &uniform_work(&l), 7), 1, "tie breaks to lowest index");
        assert_eq!(p.pick(&[0], &[0], 5), 0);
        assert_eq!(p.pick(&[], &[], 5), 0, "degenerate snapshot is safe");
    }

    #[test]
    fn least_loaded_weighs_expected_work_over_request_counts() {
        let p = RouterPolicy::LeastLoaded;
        // shard 0 holds one heavy request (60 ms), shard 1 two cheap ones
        // (5 ms each): expected-work routing picks the cheap backlog even
        // though it holds more requests
        assert_eq!(p.pick(&[1, 2], &[60_000, 10_000], 0), 1);
        // equal work falls back to the smaller request count
        assert_eq!(p.pick(&[2, 1], &[10_000, 10_000], 0), 1);
    }

    #[test]
    fn least_loaded_never_prefers_a_dead_shard_on_stale_work() {
        let p = RouterPolicy::LeastLoaded;
        // shard 0 died holding one cheap job: its work gauge is frozen
        // small, but the tombstone must outrank any live shard's backlog
        let loads = [usize::MAX, 3, 1];
        assert_eq!(p.pick(&loads, &[1_000, 90_000, 120_000], 0), 1);
        // only when every shard is dead does the pick fall out at all
        // (submit() then fails fast)
        let all_dead = [usize::MAX, usize::MAX];
        assert_eq!(p.pick(&all_dead, &[5, 1], 0), 0);
    }

    #[test]
    fn round_robin_cycles_regardless_of_load() {
        let p = RouterPolicy::RoundRobin;
        let picks: Vec<usize> =
            (0..5).map(|t| p.pick(&[9, 0, 0], &uniform_work(&[9, 0, 0]), t)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1]);
    }

    #[test]
    fn decay_weight_shrinks_monotonically_and_never_reinflates() {
        let (tx, _rx) = channel();
        let mut ctx = ShardCtx {
            shard: 0,
            load: Arc::new(AtomicUsize::new(0)),
            work: Arc::new(AtomicU64::new(10_000)),
            events: tx,
            chatter: Arc::new(AtomicBool::new(false)),
            weights: HashMap::new(),
            txs: Vec::new(),
            loads: Vec::new(),
            works: Vec::new(),
            draining: Vec::new(),
            steal: false,
            stolen: 0,
            migrated: 0,
        };
        ctx.weights.insert(7, (10_000, 10_000));
        // step 0: nothing done yet, full weight stays booked
        decay_weight(&mut ctx, 7, 0, 10);
        assert_eq!(ctx.work.load(Ordering::SeqCst), 10_000);
        // halfway: gauge holds half the admission-time weight
        decay_weight(&mut ctx, 7, 5, 10);
        assert_eq!(ctx.work.load(Ordering::SeqCst), 5_000);
        // a stale (smaller-step) snapshot must not re-inflate the gauge
        decay_weight(&mut ctx, 7, 3, 10);
        assert_eq!(ctx.work.load(Ordering::SeqCst), 5_000);
        // final step: floor of one µ-unit until the terminal release
        decay_weight(&mut ctx, 7, 10, 10);
        assert_eq!(ctx.work.load(Ordering::SeqCst), 1);
        assert_eq!(ctx.weights.get(&7), Some(&(10_000, 1)));
        // unknown id (already released) is a no-op
        decay_weight(&mut ctx, 99, 5, 10);
        assert_eq!(ctx.work.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn parked_units_floor_the_work_gauge_for_routing_and_steal() {
        use crate::config::ModelConfig;
        use crate::coordinator::job::JobMeta;
        use crate::runtime::NativeBackend;
        use crate::workload::parse_policy;

        let model = NativeBackend::seeded(ModelConfig::native_test(), 1);
        let depth = model.entry().config.depth;
        let mut engine = Engine::from_ref(
            &model,
            EngineConfig { max_inflight: 2, ..EngineConfig::default() },
        );
        let policy = parse_policy("speca:N=4,O=1", depth).unwrap();
        for id in 0..2u64 {
            let meta = JobMeta { preemptible: true, ..JobMeta::default() };
            engine.submit(RequestSpec {
                id,
                cond: 0,
                seed: id,
                policy: policy.clone(),
                record_traj: false,
                meta,
            });
        }
        engine.tick().unwrap();
        // engineer the park-heavy skew: park one of the two actives and
        // requeue it locally — a parked-but-unfinished unit this shard
        // still owes real work for
        let parked = engine.steal_one().expect("two preemptible actives");
        assert!(matches!(parked, Admission::Parked(_)));
        let parked_id = parked.id();
        engine.submit_admission(parked);
        assert_eq!(engine.parked_queued().collect::<Vec<_>>(), vec![parked_id]);

        // shard 0's ledger has the parked unit decayed to the 1 µ-unit
        // floor (nearly done when it was preempted)
        let (tx, _rx) = channel();
        let mut ctx = ShardCtx {
            shard: 0,
            load: Arc::new(AtomicUsize::new(2)),
            work: Arc::new(AtomicU64::new(1)),
            events: tx,
            chatter: Arc::new(AtomicBool::new(false)),
            weights: HashMap::new(),
            txs: Vec::new(),
            loads: Vec::new(),
            works: Vec::new(),
            draining: Vec::new(),
            steal: false,
            stolen: 0,
            migrated: 0,
        };
        ctx.weights.insert(parked_id, (10_000, 1));

        // regression: before the fix, least-loaded routing read the
        // park-heavy shard (1 µs booked, 2 units held) as far idler than
        // a peer holding a single fresh request
        let loads = [2usize, 1];
        let pre = [ctx.work.load(Ordering::SeqCst), NOMINAL_WORK_US];
        assert_eq!(RouterPolicy::LeastLoaded.pick(&loads, &pre, 0), 0, "the pre-fix skew");

        floor_parked_work(&engine, &mut ctx);
        assert_eq!(ctx.work.load(Ordering::SeqCst), NOMINAL_WORK_US);
        assert_eq!(ctx.weights.get(&parked_id), Some(&(10_000, NOMINAL_WORK_US)));
        let post = [ctx.work.load(Ordering::SeqCst), NOMINAL_WORK_US];
        assert_eq!(
            RouterPolicy::LeastLoaded.pick(&loads, &post, 0),
            1,
            "routing must avoid the shard holding parked work"
        );
        // idempotent: re-flooring never double-books the gauge
        floor_parked_work(&engine, &mut ctx);
        assert_eq!(ctx.work.load(Ordering::SeqCst), NOMINAL_WORK_US);
    }

    #[test]
    fn router_policy_parses() {
        assert_eq!(RouterPolicy::parse("least-loaded"), Some(RouterPolicy::LeastLoaded));
        assert_eq!(RouterPolicy::parse("ll"), Some(RouterPolicy::LeastLoaded));
        assert_eq!(RouterPolicy::parse("round-robin"), Some(RouterPolicy::RoundRobin));
        assert_eq!(RouterPolicy::parse("rr"), Some(RouterPolicy::RoundRobin));
        assert_eq!(RouterPolicy::parse("hash"), None);
    }
}
