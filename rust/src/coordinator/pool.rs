//! Sharded serving: N worker threads, each owning an [`Engine`] over one
//! shared `Send + Sync` backend, fed by a round-robin / least-loaded
//! router (DESIGN.md §8).
//!
//! Threading model:
//! * every shard worker runs the same loop the single-threaded server
//!   used — ingest without blocking while there is work, tick, drain —
//!   so per-request behaviour is identical to a lone engine;
//! * the router picks a shard at submit time from a load snapshot
//!   (per-shard `AtomicUsize` of requests in flight) and is `Clone`, so
//!   any number of connection threads can submit concurrently without a
//!   central funnel;
//! * completions from all shards merge onto one channel. They arrive in
//!   nondeterministic order across shards, but every [`Completion`]
//!   carries its request id, so callers re-order (or route replies) by
//!   id — and because backends are batching-transparent and requests
//!   share no state, a request's completion is *identical* regardless of
//!   shard count (the parity suite in `tests/shard_pool.rs` asserts it).
//!
//! Shutdown is two-mode: `drain` stops ingestion and finishes everything
//! already routed; `halt` abandons in-flight work. Both join every
//! worker before returning.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::state::{Completion, RequestSpec};
use crate::coordinator::{Engine, EngineConfig};
use crate::metrics::flops::FlopsCounter;
use crate::runtime::ModelBackend;

/// How the router spreads requests over shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Cycle through shards regardless of load.
    RoundRobin,
    /// Pick the shard with the fewest requests in flight (ties go to the
    /// lowest index, so routing is deterministic for a given load state).
    LeastLoaded,
}

impl RouterPolicy {
    pub fn parse(s: &str) -> Option<RouterPolicy> {
        match s {
            "rr" | "round-robin" => Some(RouterPolicy::RoundRobin),
            "ll" | "least-loaded" => Some(RouterPolicy::LeastLoaded),
            _ => None,
        }
    }

    /// Pure routing decision over a load snapshot (`rr_ticket` is the
    /// submission ordinal for round-robin).
    pub fn pick(&self, loads: &[usize], rr_ticket: usize) -> usize {
        let n = loads.len().max(1);
        match self {
            RouterPolicy::RoundRobin => rr_ticket % n,
            RouterPolicy::LeastLoaded => loads
                .iter()
                .enumerate()
                .min_by_key(|(i, l)| (**l, *i))
                .map(|(i, _)| i)
                .unwrap_or(0),
        }
    }
}

#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// worker threads (each owns one engine); clamped to ≥ 1
    pub shards: usize,
    pub router: RouterPolicy,
    /// per-shard engine configuration (`max_inflight` is per shard)
    pub engine: EngineConfig,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            shards: 1,
            router: RouterPolicy::LeastLoaded,
            engine: EngineConfig::default(),
        }
    }
}

enum ShardMsg {
    Submit(RequestSpec),
    Stats(Sender<ShardStats>),
    /// stop ingesting, finish everything already routed, exit
    Drain,
    /// exit now, abandoning in-flight requests
    Halt,
}

/// Counter snapshot of one shard (or, merged, of the whole pool).
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    pub completed: u64,
    pub inflight: usize,
    pub ticks: u64,
    pub flops: FlopsCounter,
}

impl ShardStats {
    fn merge(&mut self, other: &ShardStats) {
        self.completed += other.completed;
        self.inflight += other.inflight;
        self.ticks += other.ticks;
        self.flops.merge(&other.flops);
    }
}

/// Cloneable submission handle: connection threads route directly to
/// shard queues — no single-engine channel funnel in between.
#[derive(Clone)]
pub struct ShardRouter {
    policy: RouterPolicy,
    txs: Vec<Sender<ShardMsg>>,
    loads: Vec<Arc<AtomicUsize>>,
    rr: Arc<AtomicUsize>,
}

impl ShardRouter {
    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Requests in flight per shard (admitted + queued on the shard).
    pub fn loads(&self) -> Vec<usize> {
        self.loads.iter().map(|l| l.load(Ordering::SeqCst)).collect()
    }

    /// Total requests in flight across the pool.
    pub fn inflight(&self) -> usize {
        self.loads().iter().sum()
    }

    /// Route one request; returns the shard index it landed on.
    pub fn submit(&self, spec: RequestSpec) -> Result<usize> {
        let shard = self.policy.pick(&self.loads(), self.rr.fetch_add(1, Ordering::SeqCst));
        self.loads[shard].fetch_add(1, Ordering::SeqCst);
        if self.txs[shard].send(ShardMsg::Submit(spec)).is_err() {
            self.loads[shard].fetch_sub(1, Ordering::SeqCst);
            bail!("shard {shard} worker is gone");
        }
        Ok(shard)
    }

    /// Merged counter snapshot across all live shards (request/reply to
    /// each worker; a worker replies between ticks).
    pub fn stats(&self) -> ShardStats {
        let mut agg = ShardStats::default();
        for tx in &self.txs {
            let (rtx, rrx) = channel();
            if tx.send(ShardMsg::Stats(rtx)).is_err() {
                continue;
            }
            if let Ok(s) = rrx.recv_timeout(Duration::from_secs(10)) {
                agg.merge(&s);
            }
        }
        agg
    }
}

/// Everything a finished pool hands back.
pub struct PoolOutcome {
    /// completions not consumed through [`EngineShardPool::take_completion_rx`]
    pub completions: Vec<Completion>,
    pub stats: ShardStats,
}

/// N engines over one shared backend. See module docs for the threading
/// model.
pub struct EngineShardPool {
    router: ShardRouter,
    workers: Vec<JoinHandle<(ShardStats, Option<String>)>>,
    completions: Option<Receiver<Completion>>,
}

impl EngineShardPool {
    pub fn new(model: Arc<dyn ModelBackend + Send + Sync>, cfg: PoolConfig) -> EngineShardPool {
        let shards = cfg.shards.max(1);
        let (ctx, crx) = channel();
        let mut txs = Vec::with_capacity(shards);
        let mut loads = Vec::with_capacity(shards);
        let mut workers = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx, rx) = channel();
            let load = Arc::new(AtomicUsize::new(0));
            let worker_model = model.clone();
            let worker_cfg = cfg.engine.clone();
            let worker_load = load.clone();
            let worker_ctx = ctx.clone();
            workers.push(
                thread::Builder::new()
                    .name(format!("speca-shard-{shard}"))
                    .spawn(move || {
                        shard_worker(worker_model, worker_cfg, rx, worker_load, worker_ctx)
                    })
                    .expect("spawning shard worker"),
            );
            txs.push(tx);
            loads.push(load);
        }
        EngineShardPool {
            router: ShardRouter {
                policy: cfg.router,
                txs,
                loads,
                rr: Arc::new(AtomicUsize::new(0)),
            },
            workers,
            completions: Some(crx),
        }
    }

    /// A cloneable submission handle (connection threads each keep one).
    pub fn router(&self) -> ShardRouter {
        self.router.clone()
    }

    pub fn submit(&self, spec: RequestSpec) -> Result<usize> {
        self.router.submit(spec)
    }

    pub fn stats(&self) -> ShardStats {
        self.router.stats()
    }

    /// Take ownership of the merged completion stream (e.g. for a server
    /// dispatcher thread). If never taken, [`Self::shutdown`] drains it
    /// into [`PoolOutcome::completions`].
    pub fn take_completion_rx(&mut self) -> Option<Receiver<Completion>> {
        self.completions.take()
    }

    /// Stop the pool and join every worker. `drain` finishes all work
    /// already submitted first; `!drain` abandons it. A worker that hit a
    /// backend error (or panicked) surfaces here as `Err`, mirroring the
    /// single-engine path where `tick()?` propagates.
    pub fn shutdown(mut self, drain: bool) -> Result<PoolOutcome> {
        for tx in &self.router.txs {
            let _ = tx.send(if drain { ShardMsg::Drain } else { ShardMsg::Halt });
        }
        let rx = self.completions.take();
        // drop the router's senders so a worker that missed the message
        // still observes the disconnect and exits
        let EngineShardPool { router, workers, .. } = self;
        drop(router);
        let mut stats = ShardStats::default();
        let mut errors = Vec::new();
        let mut panicked = 0usize;
        for w in workers {
            match w.join() {
                Ok((s, err)) => {
                    stats.merge(&s);
                    errors.extend(err);
                }
                Err(_) => panicked += 1,
            }
        }
        let mut completions = Vec::new();
        if let Some(rx) = rx {
            while let Ok(c) = rx.try_recv() {
                completions.push(c);
            }
        }
        if panicked > 0 {
            bail!("{panicked} shard worker(s) panicked");
        }
        if !errors.is_empty() {
            bail!("shard worker error(s): {}", errors.join("; "));
        }
        Ok(PoolOutcome { completions, stats })
    }
}

fn snapshot(engine: &Engine<'_>, completed: u64) -> ShardStats {
    ShardStats {
        completed,
        inflight: engine.pending(),
        ticks: engine.ticks,
        flops: engine.flops.clone(),
    }
}

fn shard_worker(
    model: Arc<dyn ModelBackend + Send + Sync>,
    cfg: EngineConfig,
    rx: Receiver<ShardMsg>,
    load: Arc<AtomicUsize>,
    completions: Sender<Completion>,
) -> ShardStats {
    let model: Arc<dyn ModelBackend> = model;
    let mut engine = Engine::new(model, cfg);
    let mut completed = 0u64;
    let mut draining = false;
    let mut disconnected = false;
    loop {
        // ingest everything available; block briefly only when idle so
        // drain/halt stay responsive without busy-waiting
        loop {
            let msg = if engine.pending() > 0 || draining || disconnected {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        disconnected = true;
                        None
                    }
                }
            } else {
                match rx.recv_timeout(Duration::from_millis(20)) {
                    Ok(m) => Some(m),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => {
                        disconnected = true;
                        None
                    }
                }
            };
            let Some(msg) = msg else { break };
            match msg {
                ShardMsg::Submit(spec) => engine.submit(spec),
                ShardMsg::Stats(reply) => {
                    let _ = reply.send(snapshot(&engine, completed));
                }
                ShardMsg::Drain => draining = true,
                ShardMsg::Halt => return snapshot(&engine, completed),
            }
        }
        if engine.pending() > 0 {
            if let Err(e) = engine.tick() {
                // a backend failure poisons this shard only; in-flight
                // requests are reported as abandoned via the load gauge
                eprintln!("speca: shard worker tick failed: {e:#}");
                return snapshot(&engine, completed);
            }
            for c in engine.drain_completions() {
                completed += 1;
                load.fetch_sub(1, Ordering::SeqCst);
                let _ = completions.send(c);
            }
        } else if draining || disconnected {
            return snapshot(&engine, completed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn least_loaded_picks_min_with_deterministic_ties() {
        let p = RouterPolicy::LeastLoaded;
        assert_eq!(p.pick(&[3, 1, 2], 0), 1);
        assert_eq!(p.pick(&[2, 0, 0, 1], 7), 1, "tie breaks to lowest index");
        assert_eq!(p.pick(&[0], 5), 0);
        assert_eq!(p.pick(&[], 5), 0, "degenerate snapshot is safe");
    }

    #[test]
    fn round_robin_cycles_regardless_of_load() {
        let p = RouterPolicy::RoundRobin;
        let picks: Vec<usize> = (0..5).map(|t| p.pick(&[9, 0, 0], t)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1]);
    }

    #[test]
    fn router_policy_parses() {
        assert_eq!(RouterPolicy::parse("least-loaded"), Some(RouterPolicy::LeastLoaded));
        assert_eq!(RouterPolicy::parse("ll"), Some(RouterPolicy::LeastLoaded));
        assert_eq!(RouterPolicy::parse("round-robin"), Some(RouterPolicy::RoundRobin));
        assert_eq!(RouterPolicy::parse("rr"), Some(RouterPolicy::RoundRobin));
        assert_eq!(RouterPolicy::parse("hash"), None);
    }
}
