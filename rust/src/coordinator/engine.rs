//! The SpeCa serving engine (paper §3.2 workflow, Fig. 1).
//!
//! One `tick()` advances every in-flight request by exactly one serve step:
//!
//! 1. requests planning `Full` run the complete forward pass in dynamic
//!    batches (bucketed, see batcher.rs) and refresh their Taylor caches;
//! 2. requests planning `Spec` draft-predict their tap features natively
//!    (C_pred ≪ C), then — for SpeCa — the *verify block* runs batched on
//!    the predicted input (γ ≈ 1/depth) and the relative error decides
//!    accept/reject against τ_t = τ0·β^((T−t)/T);
//! 3. accepted speculations route the predicted head input through the
//!    output head; rejections fall back to a full pass in the same step
//!    (paper Eq. 6: the rejected step and all later predictions are
//!    discarded — later steps re-plan from the refreshed cache);
//! 4. Skip/Blend/Elide handle the baseline policies.
//!
//! With `lookahead=k` (> 1) a SpeCa request does not verify every
//! speculative step: it opens a *run* of up to k drafted steps, advances
//! the latent through the first k−1 on predict+head alone (each boundary
//! snapshotted into `ReqState::look_snaps`), and verifies only the run's
//! final step. An accepted verify ratifies the whole run; a rejected one
//! triggers a batched *audit* of the stored intermediate predictions, and
//! the request rolls latent + bookkeeping back to the longest prefix whose
//! per-step error stays under the (controller-clamped) threshold
//! (`run_lookahead_audits`, DESIGN.md §16). At k = 1 every run is a single
//! verified step and the engine is bitwise-identical to the pre-lookahead
//! behavior.
//!
//! Different policies coexist in one engine; batches group by phase (and
//! verify layer), not by policy — this is what enables the paper's
//! sample-adaptive computation allocation to emerge per request.
//!
//! Requests carry job-lifecycle metadata (`coordinator::job`): admission
//! pops the highest priority class first (FIFO within a class), and a
//! step-boundary sweep at the top of every tick drops requests whose
//! cancel token fired (freeing their slot mid-flight) or whose deadline
//! expired while still queued — reported via [`Engine::drain_terminations`]
//! so the serving layer can notify waiters.
//!
//! The engine owns an `Arc<dyn ModelBackend>` (DESIGN.md §3), so the same
//! scheduling loop drives the native CPU backend, PJRT artifacts, and
//! whatever backends later PRs add — and N engines can share one
//! `Send + Sync` backend from worker threads (the shard pool in
//! `coordinator::pool`). Every per-tick temporary — the large
//! latent/feature gather buffers *and* the small index bookkeeping (chunk
//! plans, phase lists, verify grouping, timestep-embedding staging) —
//! lives in reusable scratch presized at construction, so a steady-state
//! tick performs zero heap allocations on the native backend
//! (`tests/alloc_discipline.rs` asserts it; DESIGN.md §11).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::cache::draft::{self, DraftStrategy};
use crate::config::{ModelEntry, Schedule, ScheduleKind};
use crate::coordinator::adaptive::AdaptiveSnap;
use crate::coordinator::batcher::{
    gather_rows_into, pad_rows, plan_chunks_into, BatchStrategy, Chunk,
};
use crate::coordinator::job::{JobMeta, JobProgress, Priority, Termination, TerminationCause};
use crate::coordinator::policy::{Plan, Policy};
use crate::coordinator::state::{Completion, ReqState, RequestCheckpoint, RequestSpec};
use crate::math::{rel_l1, timestep_embedding_into};
use crate::metrics::flops::{FlopsCounter, FlopsModel};
use crate::runtime::ModelBackend;
use crate::sampler;
use crate::util::rng::Rng;

/// Engine shape knobs (per shard when run under the pool).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Admission cap: requests concurrently in flight.
    pub max_inflight: usize,
    /// How same-phase work maps onto the compiled batch buckets.
    pub strategy: BatchStrategy,
    /// execute the pallas-attention artifact variant for full passes
    /// (backends without one fall back to their default attention path)
    pub use_pallas: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { max_inflight: 8, strategy: BatchStrategy::Binary, use_pallas: false }
    }
}

/// One unit of admissible work: a fresh request (admission draws its
/// initial latent from the seed) or a checkpoint parked at a step
/// boundary (admission resumes it mid-flight). This is the currency
/// shard workers exchange when stealing or migrating work
/// (`coordinator::pool`): both variants are shard-independent, so a
/// unit queued on one engine can be re-queued on any other.
#[derive(Debug)]
pub enum Admission {
    /// Not yet started.
    Fresh(RequestSpec),
    /// Parked mid-flight; resume is bitwise (DESIGN.md §13).
    Parked(Box<RequestCheckpoint>),
}

impl Admission {
    /// Request id of the unit.
    pub fn id(&self) -> u64 {
        self.spec().id
    }

    /// Job-lifecycle metadata of the unit.
    pub fn meta(&self) -> &JobMeta {
        &self.spec().meta
    }

    /// The underlying request spec.
    pub fn spec(&self) -> &RequestSpec {
        match self {
            Admission::Fresh(spec) => spec,
            Admission::Parked(ckpt) => &ckpt.spec,
        }
    }
}

/// Per-request scalar record taken at the top of every tick: the
/// rollback ledger that returns a request to its pre-tick step boundary
/// when a dispatch fails mid-tick. Everything large (latent, tap
/// caches, blend features, TeaCache embedding) only mutates together
/// with `step` after a successful backend call, so a request whose
/// `step` did not move differs from its boundary state only in these
/// scalars plus verify-trace entries past `trace_len` — restoring them
/// makes the whole active set parkable bitwise-safely (DESIGN.md §13).
#[derive(Clone, Copy, Default)]
struct TickSnapshot {
    id: u64,
    step: usize,
    since_full: usize,
    tea_accum: f64,
    trace_len: usize,
    flops: FlopsCounter,
    full_steps: usize,
    spec_steps: usize,
    skip_steps: usize,
    blend_steps: usize,
    elided_steps: usize,
    rejects: usize,
    /// open lookahead-run length entering this tick (0 = no run open)
    spec_run: usize,
    /// sample-adaptive controller scalars (None for static requests)
    ctl: Option<AdaptiveSnap>,
}

/// Reusable batch-staging buffers. Presized from the model entry at
/// construction and capacity-stable across ticks, so the per-chunk
/// gathers are pure copies from the first tick on.
#[derive(Default)]
struct Scratch {
    /// latent rows for full passes
    x: Vec<f32>,
    /// feature rows for verify/head dispatches
    feat: Vec<f32>,
    /// timestep row
    t: Vec<f32>,
    /// condition row
    y: Vec<i32>,
    /// token-blended head inputs (ToCa/DuCa-sim)
    blend: Vec<f32>,
    /// chunk plan of the dispatch currently executing
    chunks: Vec<Chunk>,
    /// heavy partition of a full phase (cache/blend/traj consumers)
    heavy: Vec<usize>,
    /// light partition of a full phase (eps-only requests)
    light: Vec<usize>,
    /// per-step audit errors of the lookahead run being audited
    audit_e: Vec<f64>,
}

impl Scratch {
    /// Scratch with every buffer's capacity covering the worst-case tick
    /// of `max_inflight` requests over `entry`'s shapes.
    fn for_model(entry: &ModelEntry, max_inflight: usize) -> Scratch {
        let cfg = &entry.config;
        let bucket = cfg.buckets.last().copied().unwrap_or(1).max(1);
        let feat_len = cfg.tokens * cfg.dim;
        Scratch {
            x: Vec::with_capacity(bucket * cfg.latent_dim),
            feat: Vec::with_capacity(bucket * feat_len),
            t: Vec::with_capacity(bucket),
            y: Vec::with_capacity(bucket),
            blend: Vec::with_capacity(bucket * feat_len),
            chunks: Vec::with_capacity(max_inflight.max(1)),
            heavy: Vec::with_capacity(max_inflight.max(1)),
            light: Vec::with_capacity(max_inflight.max(1)),
            audit_e: Vec::with_capacity(8),
        }
    }
}

/// Per-tick phase lists (which request plans what) plus verify grouping.
/// Taken out of the engine at the top of `tick()` and put back at the end
/// so planning borrows never fight the `&mut self` dispatch helpers;
/// capacities are presized to `max_inflight`, so steady-state planning is
/// allocation-free.
#[derive(Default)]
struct PlanScratch {
    full: Vec<usize>,
    spec_verify: Vec<usize>,
    spec_direct: Vec<usize>,
    /// intermediate lookahead steps: draft + head this tick, verify later
    spec_ahead: Vec<usize>,
    skip: Vec<usize>,
    blend: Vec<usize>,
    elide: Vec<usize>,
    /// verify outcomes (accepted doubles as the head list)
    accepted: Vec<usize>,
    rejected: Vec<usize>,
    /// (verify layer, request index) pairs, sorted to group by layer
    verify_pairs: Vec<(usize, usize)>,
    /// contiguous member list of the verify group being dispatched
    verify_group: Vec<usize>,
}

impl PlanScratch {
    fn with_capacity(n: usize) -> PlanScratch {
        let n = n.max(1);
        PlanScratch {
            full: Vec::with_capacity(n),
            spec_verify: Vec::with_capacity(n),
            spec_direct: Vec::with_capacity(n),
            spec_ahead: Vec::with_capacity(n),
            skip: Vec::with_capacity(n),
            blend: Vec::with_capacity(n),
            elide: Vec::with_capacity(n),
            accepted: Vec::with_capacity(n),
            rejected: Vec::with_capacity(n),
            verify_pairs: Vec::with_capacity(n),
            verify_group: Vec::with_capacity(n),
        }
    }

    fn clear(&mut self) {
        self.full.clear();
        self.spec_verify.clear();
        self.spec_direct.clear();
        self.spec_ahead.clear();
        self.skip.clear();
        self.blend.clear();
        self.elide.clear();
        self.accepted.clear();
        self.rejected.clear();
        self.verify_pairs.clear();
        self.verify_group.clear();
    }
}

/// The SpeCa serving engine: one forecast-then-verify scheduling loop
/// over an owned (possibly thread-shared) [`ModelBackend`].
pub struct Engine<'a> {
    model: Arc<dyn ModelBackend + 'a>,
    flops_model: FlopsModel,
    cfg: EngineConfig,
    /// admission queues, one FIFO per priority class (admit pops the
    /// highest non-empty class — see `pop_next`); each entry is a fresh
    /// spec or a parked checkpoint awaiting resume
    queues: [VecDeque<Admission>; Priority::LEVELS],
    active: Vec<ReqState>,
    completions: Vec<Completion>,
    /// requests dropped at a step boundary (cancel / queued-deadline)
    terminations: Vec<Termination>,
    /// per-tick rollback ledger (presized; see [`TickSnapshot`])
    snapshots: Vec<TickSnapshot>,
    /// requests parked at a boundary (preemption, stealing, park_all)
    pub parked: u64,
    /// checkpoints resumed into a slot on this engine
    pub resumed: u64,
    /// set once any submitted request could actually cancel or expire;
    /// until then the per-tick lifecycle sweep is skipped, so
    /// fire-and-forget batch runs pay nothing for it
    lifecycle_sensitive: bool,
    /// aggregate FLOPs of everything completed so far
    pub flops: FlopsCounter,
    /// ticks executed since construction
    pub ticks: u64,
    /// TeaCache drift per serve step: `drift[i] = rel_l1(emb(t_i),
    /// emb(t_{i−1}))` over the fixed schedule (drift[0] = 0). Pure
    /// function of the schedule, so it is precomputed once here instead
    /// of evaluating two sinusoidal embeddings per TeaCache request per
    /// tick on the hot path.
    tea_drift: Vec<f64>,
    scratch: Scratch,
    plan: PlanScratch,
}

impl<'a> Engine<'a> {
    /// TeaCache drift signal dimension (heuristic, engine-local).
    const TEMB_DIM: usize = 64;

    /// Build an engine over a shared (possibly thread-shared) backend.
    pub fn new(model: Arc<dyn ModelBackend + 'a>, cfg: EngineConfig) -> Engine<'a> {
        let flops_model = FlopsModel::new(model.entry().flops.clone());
        let scratch = Scratch::for_model(model.entry(), cfg.max_inflight);
        let plan = PlanScratch::with_capacity(cfg.max_inflight);
        let snapshots = Vec::with_capacity(cfg.max_inflight.max(1));
        let t_model = &model.entry().schedule.t_model;
        let mut tea_drift = vec![0.0f64; t_model.len()];
        {
            let mut cur = Vec::new();
            let mut prev = Vec::new();
            for i in 1..t_model.len() {
                timestep_embedding_into(t_model[i], Self::TEMB_DIM, &mut cur);
                timestep_embedding_into(t_model[i - 1], Self::TEMB_DIM, &mut prev);
                tea_drift[i] = rel_l1(&cur, &prev);
            }
        }
        Engine {
            model,
            flops_model,
            cfg,
            queues: std::array::from_fn(|_| VecDeque::new()),
            active: Vec::new(),
            completions: Vec::new(),
            terminations: Vec::new(),
            snapshots,
            parked: 0,
            resumed: 0,
            lifecycle_sensitive: false,
            flops: FlopsCounter::default(),
            ticks: 0,
            tea_drift,
            scratch,
            plan,
        }
    }

    /// Build an engine over a borrowed backend (tests, benches, the
    /// single-threaded PJRT serving loop).
    pub fn from_ref(model: &'a dyn ModelBackend, cfg: EngineConfig) -> Engine<'a> {
        Engine::new(Arc::new(model), cfg)
    }

    /// The backend this engine dispatches to.
    pub fn model(&self) -> &dyn ModelBackend {
        &*self.model
    }

    /// Enqueue a request into its priority class (admitted on a later
    /// tick when a slot frees up; higher classes admit first).
    pub fn submit(&mut self, spec: RequestSpec) {
        self.submit_admission(Admission::Fresh(spec));
    }

    /// Enqueue a parked checkpoint for resume — the receiving half of
    /// preemption requeue, work-stealing and crash/drain migration.
    pub fn submit_checkpoint(&mut self, ckpt: Box<RequestCheckpoint>) {
        self.submit_admission(Admission::Parked(ckpt));
    }

    /// Enqueue any admission unit into its priority class.
    pub fn submit_admission(&mut self, adm: Admission) {
        // a deadline can expire on its own; a cancel token can only
        // fire if some other handle shares it — otherwise this request
        // never needs the per-tick lifecycle sweep
        let meta = adm.meta();
        if meta.deadline.is_some() || meta.cancel.is_shared() {
            self.lifecycle_sensitive = true;
        }
        let class = meta.priority.index();
        self.queues[class].push_back(adm);
    }

    /// Requests queued or in flight.
    pub fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum::<usize>() + self.active.len()
    }

    /// Take everything completed since the last drain.
    pub fn drain_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Take every request dropped at a step boundary since the last
    /// drain (fired cancel tokens, deadlines that expired while
    /// queued). Shard workers turn these into lifecycle events and
    /// release load accounting.
    pub fn drain_terminations(&mut self) -> Vec<Termination> {
        std::mem::take(&mut self.terminations)
    }

    /// Progress snapshot of every in-flight request (step, accepted
    /// speculations, rejections) — the source of
    /// [`JobEvent::Progress`](crate::coordinator::job::JobEvent) events.
    /// Lazy: callers that throttle emission pay nothing for the
    /// snapshots they skip.
    pub fn progress(&self) -> impl Iterator<Item = JobProgress> + '_ {
        self.active.iter().map(|st| JobProgress {
            id: st.spec.id,
            step: st.step,
            accepts: st.stats.spec_steps,
            rejects: st.stats.rejects,
        })
    }

    /// Length of the open lookahead run of an in-flight request: how many
    /// speculated steps it has advanced past its last verify point
    /// (0 = no run open, the k = 1 steady state; `None` = not resident).
    /// Observability hook for tests and the serving layer — a request
    /// parked mid-run carries this in its checkpoint (DESIGN.md §16).
    pub fn speculation_depth(&self, id: u64) -> Option<usize> {
        self.active.iter().find(|st| st.spec.id == id).map(|st| st.spec_run)
    }

    /// Ids of queued units that are parked checkpoints — work already
    /// mid-flight but not currently resident in a slot. The pool's work
    /// gauges floor these units at a nominal weight so a park-heavy
    /// shard never looks idle to routing or stealing (DESIGN.md §12).
    pub fn parked_queued(&self) -> impl Iterator<Item = u64> + '_ {
        self.queues.iter().flat_map(|q| {
            q.iter().filter_map(|adm| match adm {
                Admission::Parked(ckpt) => Some(ckpt.spec.id),
                Admission::Fresh(_) => None,
            })
        })
    }

    /// Drop every queued and active request, returning their ids. Shard
    /// workers use this on exit paths that abandon work (backend error,
    /// halt) so the pool can release load accounting and notify waiters.
    pub fn abandon(&mut self) -> Vec<u64> {
        let ids = self
            .queues
            .iter()
            .flat_map(|q| q.iter().map(|a| a.id()))
            .chain(self.active.iter().map(|r| r.spec.id))
            .collect();
        for q in &mut self.queues {
            q.clear();
        }
        self.active.clear();
        ids
    }

    /// Park every in-flight request at its current step boundary and
    /// pop everything queued, returning the lot as admission units a
    /// peer engine can re-queue verbatim. Requests already at their
    /// final boundary (a mid-tick error can leave them fully advanced
    /// with the retire sweep unrun) are retired into completions
    /// instead of parked. Drain/crash migration runs on this.
    pub fn park_all(&mut self) -> Vec<Admission> {
        let total = self.total_steps();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].step >= total {
                let st = self.active.swap_remove(i);
                self.finish(st);
            } else {
                i += 1;
            }
        }
        let active = std::mem::take(&mut self.active);
        let mut out = Vec::with_capacity(active.len() + self.pending());
        for st in active {
            self.parked += 1;
            out.push(Admission::Parked(Box::new(st.park())));
        }
        // queued units follow the parked actives, highest class first,
        // so a receiver's push_back keeps mid-flight work ahead of
        // not-yet-started work within each class
        for q in self.queues.iter_mut().rev() {
            out.extend(q.drain(..));
        }
        out
    }

    /// Donate one unit of work to an idle peer (the work-stealing
    /// victim side). Prefers queued work — lowest class, newest first,
    /// the units whose FIFO position costs least to move — and only
    /// when nothing is queued parks the least-advanced preemptible
    /// active request of the lowest priority class, keeping at least
    /// one active request so the donor never idles itself.
    pub fn steal_one(&mut self) -> Option<Admission> {
        for q in self.queues.iter_mut() {
            if let Some(adm) = q.pop_back() {
                return Some(adm);
            }
        }
        if self.active.len() < 2 {
            return None;
        }
        let victim = self
            .active
            .iter()
            .enumerate()
            .filter(|(_, st)| st.spec.meta.preemptible)
            .min_by_key(|(_, st)| (st.spec.meta.priority.index(), st.step))
            .map(|(i, _)| i)?;
        let st = self.active.swap_remove(victim);
        self.parked += 1;
        Some(Admission::Parked(Box::new(st.park())))
    }

    /// Pop the next admission unit: highest priority class first,
    /// FIFO within a class.
    fn pop_next(&mut self) -> Option<Admission> {
        self.queues.iter_mut().rev().find_map(|q| q.pop_front())
    }

    /// Highest priority class with queued work.
    fn highest_queued_class(&self) -> Option<usize> {
        (0..Priority::LEVELS).rev().find(|&c| !self.queues[c].is_empty())
    }

    /// Preemption step of `admit`: when every slot is occupied and the
    /// best queued class outranks some running preemptible job of a
    /// strictly lower class, park that victim (lowest class first, then
    /// least progress) and push it to the *front* of its class queue, so
    /// it resumes before anything queued behind it. Returns whether a
    /// slot was freed.
    fn try_preempt(&mut self) -> bool {
        let Some(waiting) = self.highest_queued_class() else { return false };
        let victim = self
            .active
            .iter()
            .enumerate()
            .filter(|(_, st)| st.spec.meta.preemptible && st.spec.meta.priority.index() < waiting)
            .min_by_key(|(_, st)| (st.spec.meta.priority.index(), st.step))
            .map(|(i, _)| i);
        let Some(i) = victim else { return false };
        let st = self.active.swap_remove(i);
        let class = st.spec.meta.priority.index();
        self.parked += 1;
        self.queues[class].push_front(Admission::Parked(Box::new(st.park())));
        true
    }

    /// Step-boundary lifecycle sweep: drop queued/active requests whose
    /// cancel token fired, and queued requests whose deadline passed
    /// (deadline-aware admission — doomed work never occupies a slot).
    /// Runs at the top of every tick, i.e. right after the previous
    /// step's verification, so a cancelled job frees its slot mid-flight
    /// without waiting for its remaining steps.
    fn reap(&mut self) {
        if !self.lifecycle_sensitive {
            return;
        }
        let now = Instant::now();
        let Engine { queues, active, terminations, .. } = self;
        for q in queues {
            // in-place retain keeps FIFO order without rotating every
            // queued unit through the deque on every tick; parked
            // checkpoints cancel/expire exactly like fresh specs
            q.retain(|adm| {
                let meta = adm.meta();
                let cause = if meta.cancel.is_cancelled() {
                    TerminationCause::Cancelled
                } else if meta.expired(now) {
                    TerminationCause::DeadlineExpired
                } else {
                    return true;
                };
                terminations.push(Termination { id: adm.id(), cause });
                false
            });
        }
        let mut i = 0;
        while i < active.len() {
            if active[i].spec.meta.cancel.is_cancelled() {
                let st = active.swap_remove(i);
                let cause = TerminationCause::Cancelled;
                terminations.push(Termination { id: st.spec.id, cause });
            } else {
                i += 1;
            }
        }
    }

    /// Run until queue and active set are empty; returns completions.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while self.tick()? {}
        Ok(self.drain_completions())
    }

    fn total_steps(&self) -> usize {
        self.model.entry().config.serve_steps
    }

    fn admit(&mut self, model: &dyn ModelBackend) {
        let cfg = &model.entry().config;
        loop {
            while self.active.len() < self.cfg.max_inflight {
                let Some(adm) = self.pop_next() else { return };
                let mut st = match adm {
                    Admission::Fresh(spec) => {
                        let mut rng = Rng::new(spec.seed);
                        let x = rng.normal_f32s(cfg.latent_dim);
                        ReqState::new(spec, x, cfg.depth, cfg.tokens * cfg.dim)
                    }
                    Admission::Parked(ckpt) => {
                        self.resumed += 1;
                        ReqState::resume(*ckpt)
                    }
                };
                // one upfront reservation (at most one verify-trace entry
                // per serve step), so steady-state pushes never reallocate
                st.stats.verify_trace.reserve(cfg.serve_steps);
                self.active.push(st);
            }
            // every slot occupied: park a lower-class preemptible job if
            // a higher class is waiting, then admit into the freed slot
            if !self.try_preempt() {
                return;
            }
        }
    }

    /// Advance every in-flight request one serve step. Returns false when
    /// fully idle.
    pub fn tick(&mut self) -> Result<bool> {
        // lifecycle sweep first: cancelled/expired requests must not
        // occupy a slot or be admitted this tick
        self.reap();
        // one refcount bump per tick; helpers borrow this local so the
        // hot path adds no per-dispatch atomic traffic
        let model = Arc::clone(&self.model);
        self.admit(&*model);
        if self.active.is_empty() {
            return Ok(false);
        }
        self.ticks += 1;
        let total = self.total_steps();

        // --- rollback ledger ---------------------------------------------
        // Scalar snapshot of every active request before anything this
        // tick mutates state, so a mid-tick dispatch failure can return
        // non-advanced requests to this boundary (`rollback_to_boundary`).
        // Presized at construction: steady-state ticks stay allocation-free.
        self.snapshots.clear();
        for st in &self.active {
            self.snapshots.push(TickSnapshot {
                id: st.spec.id,
                step: st.step,
                since_full: st.since_full,
                tea_accum: st.tea_accum,
                trace_len: st.stats.verify_trace.len(),
                flops: st.stats.flops,
                full_steps: st.stats.full_steps,
                spec_steps: st.stats.spec_steps,
                skip_steps: st.stats.skip_steps,
                blend_steps: st.stats.blend_steps,
                elided_steps: st.stats.elided_steps,
                rejects: st.stats.rejects,
                spec_run: st.spec_run,
                ctl: st.ctl.as_ref().map(|c| c.snap()),
            });
        }

        // --- update TeaCache drift accumulators, then plan ---------------
        // (drift is a pure function of the step over the fixed schedule,
        // precomputed at construction — one table lookup per request)
        {
            let Engine { active, tea_drift, .. } = &mut *self;
            for st in active.iter_mut() {
                if let Policy::TeaCache { .. } = st.spec.policy {
                    if st.step > 0 {
                        st.tea_accum += tea_drift[st.step];
                    }
                }
            }
        }

        // phase lists live in presized scratch, taken out for the tick so
        // the dispatch helpers below can borrow `&mut self` — and put
        // back even when a dispatch errors, so a caller that recovers
        // from a transient backend failure keeps the warm buffers
        let mut tk = std::mem::take(&mut self.plan);
        tk.clear();
        for (i, st) in self.active.iter_mut().enumerate() {
            let plan = st.spec.policy.plan(st.step, total, st.since_full, st.tea_accum);
            match plan {
                Plan::Full => tk.full.push(i),
                Plan::Spec => {
                    if !st.cache.ready() {
                        tk.full.push(i);
                    } else if st.ctl.as_ref().is_some_and(|c| c.wants_dense()) {
                        // controller-forced dense step: budget spent or
                        // the rejection-streak fallback is latched
                        // (probational — the controller decides when to
                        // retry speculation). The controller only mutates
                        // at verify points and dense steps, so this can
                        // never fire with a lookahead run still open.
                        debug_assert_eq!(st.spec_run, 0, "dense step inside an open run");
                        if let Some(c) = st.ctl.as_mut() {
                            c.on_dense_step();
                        }
                        tk.full.push(i);
                    } else if matches!(st.spec.policy, Policy::SpeCa(_)) {
                        // lookahead routing: a run verifies at its k-th
                        // step, at the final serve step, and before any
                        // step the policy would not speculate — otherwise
                        // this is an intermediate step (draft + head only,
                        // boundary snapshotted for the eventual audit)
                        let cap = ReqState::look_cap_of(&st.spec.policy);
                        let k_eff = st
                            .ctl
                            .as_ref()
                            .map(|c| c.lookahead())
                            .unwrap_or(cap)
                            .clamp(1, cap);
                        let is_vp = st.spec_run + 1 >= k_eff
                            || st.step + 1 >= total
                            || st.spec.policy.plan(
                                st.step + 1,
                                total,
                                st.since_full + 1,
                                st.tea_accum,
                            ) != Plan::Spec;
                        if is_vp {
                            tk.spec_verify.push(i)
                        } else {
                            st.push_look_snap();
                            tk.spec_ahead.push(i)
                        }
                    } else {
                        tk.spec_direct.push(i)
                    }
                }
                Plan::Skip => tk.skip.push(i),
                Plan::Blend => tk.blend.push(i),
                Plan::Elide => tk.elide.push(i),
            }
        }
        for &i in &tk.elide {
            let st = &mut self.active[i];
            st.stats.elided_steps += 1;
            st.step += 1;
            st.since_full += 1;
        }

        let res = self.run_phases(&*model, &mut tk, total);
        self.plan = tk;
        if let Err(e) = res {
            self.rollback_to_boundary();
            return Err(e);
        }

        // --- retire completed requests ------------------------------------
        let total = self.total_steps();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].step >= total {
                let st = self.active.swap_remove(i);
                self.finish(st);
            } else {
                i += 1;
            }
        }
        Ok(true)
    }

    /// The fallible dispatch phases of one tick (predictions, verify,
    /// heads, skips, blends, fulls), over phase lists planned into `tk`.
    fn run_phases(
        &mut self,
        model: &dyn ModelBackend,
        tk: &mut PlanScratch,
        total: usize,
    ) -> Result<()> {
        // --- speculative phase: draft predictions ------------------------
        for &i in tk.spec_verify.iter().chain(tk.spec_direct.iter()) {
            self.run_predict(model, i);
        }
        // intermediate lookahead steps draft the same three taps, then
        // stash the verify-pair prediction in the boundary snapshot taken
        // at plan time — the eventual audit replays it if the run's
        // verify point rejects
        for &i in &tk.spec_ahead {
            self.run_predict(model, i);
            self.active[i].stash_look_preds();
        }

        // --- verification (grouped by verify layer) ----------------------
        // Group by sorting (layer, index) pairs in presized scratch: same
        // ascending-layer, ascending-index dispatch order the old BTreeMap
        // grouping produced, without its per-tick allocations.
        if !tk.spec_verify.is_empty() {
            for &i in &tk.spec_verify {
                tk.verify_pairs.push((self.verify_layer_of(i), i));
            }
            tk.verify_pairs.sort_unstable();
            let mut k = 0;
            while k < tk.verify_pairs.len() {
                let layer = tk.verify_pairs[k].0;
                tk.verify_group.clear();
                while k < tk.verify_pairs.len() && tk.verify_pairs[k].0 == layer {
                    tk.verify_group.push(tk.verify_pairs[k].1);
                    k += 1;
                }
                self.run_verify(
                    &*model,
                    layer,
                    &tk.verify_group,
                    &mut tk.accepted,
                    &mut tk.rejected,
                )?;
            }
        }

        // --- audits: rejected runs ratify their longest passing prefix ---
        self.run_lookahead_audits(model, &tk.rejected, total)?;

        // --- heads for accepted + direct + intermediate speculations -----
        // the first `n_ratified` entries closed a lookahead run at an
        // accepted verify point; run_heads commits the run's histogram
        // event alongside their step advance
        let n_ratified = tk.accepted.len();
        tk.accepted.extend_from_slice(&tk.spec_direct);
        tk.accepted.extend_from_slice(&tk.spec_ahead);
        self.run_heads(&*model, &tk.accepted, n_ratified)?;

        // --- skips --------------------------------------------------------
        for &i in &tk.skip {
            let st = &mut self.active[i];
            let eps = std::mem::take(&mut st.last_eps);
            Self::apply_model_out(&model.entry().schedule, st, &eps, total);
            st.last_eps = eps;
            self.flops_model.book_spec_step(&mut st.stats.flops, 1);
            st.stats.skip_steps += 1;
            st.step += 1;
            st.since_full += 1;
        }

        // --- blends (ToCa/DuCa-sim) ---------------------------------------
        self.run_blend(&*model, &tk.blend)?;

        // --- full passes (planned + rejected fallbacks) -------------------
        // (reject bookkeeping — counters, histogram, draft reset — is
        // committed per request by `run_lookahead_audits` above, at the
        // same single mutation point as the prefix rollback)
        tk.full.extend_from_slice(&tk.rejected);
        self.run_full(&*model, &tk.full)?;
        Ok(())
    }

    /// Draft-predict one request's tap features into its prediction
    /// buffers. The strategy is a trait object shared across shards
    /// (SpeCa carries its `Draft` handle in the policy; cache policies
    /// without one draft with the default Taylor strategy). Infallible:
    /// runs natively against the tap history, no backend dispatch.
    fn run_predict(&mut self, model: &dyn ModelBackend, i: usize) {
        let v = self.verify_layer_of(i);
        let depth = model.entry().config.depth;
        let st = &mut self.active[i];
        let k = st.cache.k_for_step(st.step).expect("cache ready");
        let strategy: &dyn DraftStrategy = match (&st.ctl, &st.spec.policy) {
            // sample-adaptive requests draft with the controller's
            // current ladder rung — mid-request strategy switching
            // (DESIGN.md §14)
            (Some(ctl), _) => ctl.strategy(st.spec.policy.order()).0,
            (None, Policy::SpeCa(c)) => &*c.draft,
            (None, _) => draft::taylor_default(),
        };
        // book prediction cost at the strategy's effective order, not
        // the policy's configured one (reuse does order-0 work no
        // matter what O= says; richardson always does order-2) — the
        // per-draft FLOPs comparison depends on this being honest
        let order = strategy.max_order(st.spec.policy.order());
        let n_taps = st.tap_boundaries.len();
        if matches!(st.spec.policy, Policy::SpeCa(_)) {
            let tv = st.tap_of(v);
            let tvo = st.tap_of(v + 1);
            let tl = st.tap_of(depth);
            st.cache.taps[tv].predict_with(strategy, k, &mut st.pred_vin);
            st.cache.taps[tvo].predict_with(strategy, k, &mut st.pred_vout);
            if tl != tvo {
                st.cache.taps[tl].predict_with(strategy, k, &mut st.pred_last);
            } else {
                st.pred_last.copy_from_slice(&st.pred_vout);
            }
        } else {
            let tl = st.tap_of(depth);
            st.cache.taps[tl].predict_with(strategy, k, &mut st.pred_last);
        }
        self.flops_model.book_predict(&mut st.stats.flops, order, n_taps, 1);
    }

    fn verify_layer_of(&self, i: usize) -> usize {
        match &self.active[i].spec.policy {
            Policy::SpeCa(c) => c.verify_layer.min(self.model.entry().config.depth - 1),
            _ => self.model.entry().config.depth - 1,
        }
    }

    /// Return every request whose `step` did not move this tick to its
    /// pre-tick boundary by restoring the scalar ledger. Requests that
    /// advanced before the failing dispatch already sit at the *next*
    /// boundary and are kept as-is: after this sweep the whole active
    /// set is at valid boundaries and [`Self::park_all`] yields
    /// checkpoints whose resume replays the interrupted work
    /// bitwise-identically (no double-booked FLOPs, no duplicate
    /// verify-trace entries).
    fn rollback_to_boundary(&mut self) {
        let Engine { active, snapshots, .. } = self;
        for (st, snap) in active.iter_mut().zip(snapshots.iter()) {
            debug_assert_eq!(st.spec.id, snap.id, "rollback ledger out of sync");
            if st.step != snap.step {
                continue;
            }
            st.since_full = snap.since_full;
            st.tea_accum = snap.tea_accum;
            // a committed audit whose accepted prefix was the whole run
            // (j = m) leaves `step` unmoved yet bumps the histogram; the
            // restored reject counter is the tell. Undo the event so a
            // retried tick replays it exactly once. (Audits that rolled
            // the latent back moved `step` and are kept above.)
            if st.stats.rejects != snap.rejects {
                if let Some(last) = st.stats.prefix_hist.len().checked_sub(1) {
                    let b = snap.spec_run.min(last);
                    st.stats.prefix_hist[b] = st.stats.prefix_hist[b].saturating_sub(1);
                }
            }
            st.spec_run = snap.spec_run;
            st.stats.verify_trace.truncate(snap.trace_len);
            st.stats.flops = snap.flops;
            st.stats.full_steps = snap.full_steps;
            st.stats.spec_steps = snap.spec_steps;
            st.stats.skip_steps = snap.skip_steps;
            st.stats.blend_steps = snap.blend_steps;
            st.stats.elided_steps = snap.elided_steps;
            st.stats.rejects = snap.rejects;
            if let (Some(ctl), Some(s)) = (st.ctl.as_mut(), snap.ctl) {
                ctl.restore(s);
            }
        }
    }

    fn finish(&mut self, st: ReqState) {
        let mut st = st;
        st.stats.latency_ms = st.prior_ms + st.started.elapsed().as_secs_f64() * 1e3;
        self.flops.merge(&st.stats.flops);
        self.completions.push(Completion {
            id: st.spec.id,
            cond: st.spec.cond,
            policy_name: st.spec.policy.name().to_string(),
            draft_name: st.spec.policy.draft_name().to_string(),
            latent: st.x,
            stats: st.stats,
            traj: st.traj,
        });
    }

    /// Denoising update honoring step-reduction jumps.
    fn apply_model_out(
        schedule: &Schedule,
        st: &mut ReqState,
        model_out: &[f32],
        total: usize,
    ) {
        let i = st.step;
        // next step this request will actually execute (elides are jumped)
        let next = (i + 1..total).find(|j| {
            st.spec.policy.plan(*j, total, 1, f64::INFINITY) != Plan::Elide
        });
        match schedule.kind {
            ScheduleKind::Ddim => {
                let ab_t = schedule.ab_t[i];
                let ab_prev = next.map(|j| schedule.ab_t[j]).unwrap_or(1.0);
                sampler::ddim_step(&mut st.x, model_out, ab_t, ab_prev);
            }
            ScheduleKind::RectifiedFlow => {
                let gap = next.unwrap_or(total) - i;
                sampler::rf_step(&mut st.x, model_out, schedule.dt * gap as f32);
            }
        }
    }

    /// Gather (t, y) rows for a chunk into the scratch buffers.
    fn gather_ty(&mut self, sched: &Schedule, chunk: &Chunk, idxs: &[usize]) {
        let Engine { active, scratch, .. } = self;
        scratch.t.clear();
        scratch.t.resize(chunk.bucket, 0.0);
        scratch.y.clear();
        scratch.y.resize(chunk.bucket, 0);
        for (slot, m) in chunk.members().enumerate() {
            let st = &active[idxs[m]];
            scratch.t[slot] = sched.t_model[st.step];
            scratch.y[slot] = st.spec.cond;
        }
        // padding replicates slot 0
        for slot in chunk.used()..chunk.bucket {
            scratch.t[slot] = scratch.t[0];
            scratch.y[slot] = scratch.y[0];
        }
    }

    /// Execute full forward passes for `idxs`, refresh caches, advance.
    /// Requests that never read the feature cache take the eps-only
    /// entry point (no boundary-stack transfer — EXPERIMENTS.md §Perf).
    fn run_full(&mut self, model: &dyn ModelBackend, idxs: &[usize]) -> Result<()> {
        if idxs.is_empty() {
            return Ok(());
        }
        let has_light = model.supports("full_eps");
        let mut heavy = std::mem::take(&mut self.scratch.heavy);
        let mut light = std::mem::take(&mut self.scratch.light);
        heavy.clear();
        light.clear();
        for &i in idxs {
            let st = &self.active[i];
            if !has_light
                || st.spec.policy.uses_cache()
                || st.spec.policy.reuse_frac() > 0.0
                || st.spec.record_traj
            {
                heavy.push(i);
            } else {
                light.push(i);
            }
        }
        let res = self
            .run_full_light(model, &light)
            .and_then(|()| self.run_full_heavy(model, &heavy));
        self.scratch.heavy = heavy;
        self.scratch.light = light;
        res
    }

    /// Boundary-materializing full passes (cache/blend/trajectory
    /// consumers).
    fn run_full_heavy(&mut self, model: &dyn ModelBackend, idxs: &[usize]) -> Result<()> {
        if idxs.is_empty() {
            return Ok(());
        }
        let entry = model.entry();
        let cfg = &entry.config;
        let latent = cfg.latent_dim;
        let feat = cfg.tokens * cfg.dim;
        let depth = cfg.depth;
        let total = self.total_steps();
        let mut chunks = std::mem::take(&mut self.scratch.chunks);
        plan_chunks_into(idxs.len(), &cfg.buckets, self.cfg.strategy, &mut chunks);
        for chunk in &chunks {
            self.gather_ty(&entry.schedule, chunk, idxs);
            {
                let Engine { active, scratch, .. } = &mut *self;
                gather_rows_into(&mut scratch.x, chunk, latent, |m, dst| {
                    dst.copy_from_slice(&active[idxs[m]].x)
                });
            }
            let dispatch = model.full(
                chunk.bucket,
                &self.scratch.x,
                &self.scratch.t,
                &self.scratch.y,
                self.cfg.use_pallas,
            );
            let (eps, bounds) = match dispatch {
                Ok(out) => out,
                Err(e) => {
                    self.scratch.chunks = chunks;
                    return Err(e);
                }
            };
            // bounds: [L+1, bucket, T, D]
            for (slot, m) in chunk.members().enumerate() {
                let ri = idxs[m];
                let st = &mut self.active[ri];
                let eps_row = eps.row(slot);
                if st.spec.policy.uses_cache() {
                    let bdata = &bounds.data;
                    st.cache.refresh_iter(
                        st.step,
                        st.tap_boundaries.iter().map(|b| {
                            let off = (b * chunk.bucket + slot) * feat;
                            &bdata[off..off + feat]
                        }),
                    );
                }
                // blend policies cache the last boundary
                if st.spec.policy.reuse_frac() > 0.0 {
                    let off = (depth * chunk.bucket + slot) * feat;
                    st.blend_feat.clear();
                    st.blend_feat.extend_from_slice(&bounds.data[off..off + feat]);
                }
                if st.spec.record_traj {
                    let off = (depth * chunk.bucket + slot) * feat;
                    st.traj.push(bounds.data[off..off + feat].to_vec());
                }
                st.last_eps.clear();
                st.last_eps.extend_from_slice(eps_row);
                st.tea_accum = 0.0;
                Self::apply_model_out(&entry.schedule, st, eps_row, total);
                self.flops_model.book_full(&mut st.stats.flops, chunk.bucket, 1);
                st.stats.full_steps += 1;
                st.step += 1;
                st.since_full = 0;
            }
        }
        self.scratch.chunks = chunks;
        Ok(())
    }

    /// Eps-only full passes (no cache refresh needed for these policies).
    fn run_full_light(&mut self, model: &dyn ModelBackend, idxs: &[usize]) -> Result<()> {
        if idxs.is_empty() {
            return Ok(());
        }
        let entry = model.entry();
        let latent = entry.config.latent_dim;
        let total = self.total_steps();
        let mut chunks = std::mem::take(&mut self.scratch.chunks);
        plan_chunks_into(idxs.len(), &entry.config.buckets, self.cfg.strategy, &mut chunks);
        for chunk in &chunks {
            self.gather_ty(&entry.schedule, chunk, idxs);
            {
                let Engine { active, scratch, .. } = &mut *self;
                gather_rows_into(&mut scratch.x, chunk, latent, |m, dst| {
                    dst.copy_from_slice(&active[idxs[m]].x)
                });
            }
            let dispatch = model.full_eps(
                chunk.bucket,
                &self.scratch.x,
                &self.scratch.t,
                &self.scratch.y,
            );
            let eps = match dispatch {
                Ok(out) => out,
                Err(e) => {
                    self.scratch.chunks = chunks;
                    return Err(e);
                }
            };
            for (slot, m) in chunk.members().enumerate() {
                let ri = idxs[m];
                let st = &mut self.active[ri];
                let eps_row = eps.row(slot);
                st.last_eps.clear();
                st.last_eps.extend_from_slice(eps_row);
                st.tea_accum = 0.0;
                Self::apply_model_out(&entry.schedule, st, eps_row, total);
                self.flops_model.book_full(&mut st.stats.flops, chunk.bucket, 1);
                st.stats.full_steps += 1;
                st.step += 1;
                st.since_full = 0;
            }
        }
        self.scratch.chunks = chunks;
        Ok(())
    }

    /// SpeCa verification: run the verify block on predicted inputs, accept
    /// iff the relative error beats τ_t.
    fn run_verify(
        &mut self,
        model: &dyn ModelBackend,
        layer: usize,
        idxs: &[usize],
        accepted: &mut Vec<usize>,
        rejected: &mut Vec<usize>,
    ) -> Result<()> {
        let entry = model.entry();
        let feat = entry.feat_len();
        let total = self.total_steps();
        let mut chunks = std::mem::take(&mut self.scratch.chunks);
        plan_chunks_into(idxs.len(), &entry.config.buckets, self.cfg.strategy, &mut chunks);
        for chunk in &chunks {
            self.gather_ty(&entry.schedule, chunk, idxs);
            {
                let Engine { active, scratch, .. } = &mut *self;
                gather_rows_into(&mut scratch.feat, chunk, feat, |m, dst| {
                    dst.copy_from_slice(&active[idxs[m]].pred_vin)
                });
            }
            let dispatch = model.block(
                chunk.bucket,
                layer as i32,
                &self.scratch.feat,
                &self.scratch.t,
                &self.scratch.y,
            );
            let actual = match dispatch {
                Ok(out) => out,
                Err(e) => {
                    self.scratch.chunks = chunks;
                    return Err(e);
                }
            };
            for (slot, m) in chunk.members().enumerate() {
                let ri = idxs[m];
                let st = &mut self.active[ri];
                let Policy::SpeCa(c) = &st.spec.policy else { unreachable!() };
                let e = c.metric.eval(&st.pred_vout, actual.row(slot));
                // sample-adaptive requests clamp the schedule's τ_t by
                // the controller's per-step allowance (remaining budget
                // over remaining steps, streak-scaled); the trace records
                // the threshold actually applied
                let base = c.tau_at(st.step, total);
                let tau = match &st.ctl {
                    Some(ctl) => ctl.threshold(base, total - st.step),
                    None => base,
                };
                st.stats.verify_trace.push((st.step, e, tau));
                self.flops_model.book_verify(&mut st.stats.flops, chunk.bucket, 1);
                if e <= tau {
                    if let Some(ctl) = st.ctl.as_mut() {
                        ctl.on_accept(e);
                    }
                    accepted.push(ri);
                } else {
                    if let Some(ctl) = st.ctl.as_mut() {
                        ctl.on_reject();
                    }
                    rejected.push(ri);
                }
            }
        }
        self.scratch.chunks = chunks;
        Ok(())
    }

    /// One prefix-histogram event: `ratified` steps were ratified at a
    /// verify point (full-run accept: k steps; audited rejection: the
    /// accepted prefix length j ∈ [0, k−1]). Clamped into the histogram,
    /// which is sized cap+1 at admission; default-constructed stats carry
    /// an empty histogram and count nothing.
    fn bump_hist(stats: &mut crate::coordinator::state::RequestStats, ratified: usize) {
        if let Some(last) = stats.prefix_hist.len().checked_sub(1) {
            stats.prefix_hist[ratified.min(last)] += 1;
        }
    }

    /// Accept-a-prefix audit of rejected lookahead runs (DESIGN.md §16).
    ///
    /// A run's intermediate steps execute on predict + head alone; only
    /// its final step verifies. When that verify point rejects, this
    /// sweep replays the stored intermediate predictions as one batched
    /// verify-block dispatch per run (chunked like any other phase),
    /// finds the longest prefix whose per-step error stays under the
    /// threshold the controller would have applied *at that step* (the
    /// pre-tick [`AdaptiveSnap`] — the run executed under that state),
    /// and rolls latent + bookkeeping back to the boundary after the
    /// last ratified step. All reject bookkeeping (counters, histogram,
    /// budget spend, draft reset) commits at one mutation point per
    /// request, after every audit chunk for that request succeeded, so a
    /// mid-audit backend failure leaves the request untouched for the
    /// boundary rollback. FLOPs booked for audit dispatches are never
    /// un-booked on rollback: the work really ran.
    fn run_lookahead_audits(
        &mut self,
        model: &dyn ModelBackend,
        rejected: &[usize],
        total: usize,
    ) -> Result<()> {
        let entry = model.entry();
        let feat = entry.feat_len();
        for &ri in rejected {
            let m = self.active[ri].spec_run;
            if m == 0 {
                // single-step run (k = 1): nothing speculated beyond the
                // rejected verify step — record the zero-length prefix
                // and fall through to the full-pass fallback
                let st = &mut self.active[ri];
                Self::bump_hist(&mut st.stats, 0);
                st.stats.rejects += 1;
                st.stats.flops.n_rejects += 1;
                // the speculative run ended in rejection: fire the
                // advisory reset hook on this request's strategy
                // (instance-wide — DESIGN.md §10; no-op for the shipped
                // stateless strategies)
                if let Policy::SpeCa(c) = &st.spec.policy {
                    c.draft.reset();
                }
                continue;
            }
            let layer = self.verify_layer_of(ri);
            self.scratch.audit_e.clear();
            let mut chunks = std::mem::take(&mut self.scratch.chunks);
            plan_chunks_into(m, &entry.config.buckets, self.cfg.strategy, &mut chunks);
            for chunk in &chunks {
                {
                    // rows sit at *different* steps (one per snapshot),
                    // so t is gathered per snapshot, not via gather_ty
                    let Engine { active, scratch, .. } = &mut *self;
                    let st = &active[ri];
                    scratch.t.clear();
                    scratch.t.resize(chunk.bucket, 0.0);
                    scratch.y.clear();
                    scratch.y.resize(chunk.bucket, 0);
                    for (slot, p) in chunk.members().enumerate() {
                        scratch.t[slot] = entry.schedule.t_model[st.look_snaps[p].step];
                        scratch.y[slot] = st.spec.cond;
                    }
                    for slot in chunk.used()..chunk.bucket {
                        scratch.t[slot] = scratch.t[0];
                        scratch.y[slot] = scratch.y[0];
                    }
                    gather_rows_into(&mut scratch.feat, chunk, feat, |p, dst| {
                        dst.copy_from_slice(&st.look_snaps[p].pred_vin)
                    });
                }
                let dispatch = model.block(
                    chunk.bucket,
                    layer as i32,
                    &self.scratch.feat,
                    &self.scratch.t,
                    &self.scratch.y,
                );
                let actual = match dispatch {
                    Ok(out) => out,
                    Err(e) => {
                        self.scratch.chunks = chunks;
                        return Err(e);
                    }
                };
                {
                    let Engine { active, scratch, .. } = &mut *self;
                    let st = &active[ri];
                    let Policy::SpeCa(c) = &st.spec.policy else { unreachable!() };
                    for (slot, p) in chunk.members().enumerate() {
                        scratch
                            .audit_e
                            .push(c.metric.eval(&st.look_snaps[p].pred_vout, actual.row(slot)));
                    }
                }
                self.flops_model.book_verify(
                    &mut self.active[ri].stats.flops,
                    chunk.bucket,
                    chunk.used(),
                );
            }
            self.scratch.chunks = chunks;

            // --- single mutation point: commit the audit verdict ---------
            let Engine { active, snapshots, scratch, .. } = &mut *self;
            let st = &mut active[ri];
            debug_assert_eq!(snapshots[ri].id, st.spec.id, "audit ledger out of sync");
            let snap_ctl = snapshots[ri].ctl;
            let mut j = m;
            {
                let Policy::SpeCa(c) = &st.spec.policy else { unreachable!() };
                for p in 0..m {
                    let step = st.look_snaps[p].step;
                    let base = c.tau_at(step, total);
                    let tau = match snap_ctl {
                        Some(s) => s.threshold(base, total - step),
                        None => base,
                    };
                    st.stats.verify_trace.push((step, scratch.audit_e[p], tau));
                    if j == m && scratch.audit_e[p] > tau {
                        j = p;
                    }
                }
            }
            if j >= 1 {
                if let Some(ctl) = st.ctl.as_mut() {
                    // one budget spend per run, mirroring the accept
                    // path's single on_accept at the verify point: the
                    // last ratified step's error bounds the drift the
                    // kept prefix actually incurred (errors within a run
                    // grow from the same refresh, so summing them would
                    // double-count the telescoped drift)
                    ctl.spend(scratch.audit_e[j - 1]);
                }
            }
            if j < m {
                // roll latent + bookkeeping back to the boundary after
                // the last ratified step; the tap cache needs no rollback
                // (it only mutates at full steps, and a run contains none)
                let snaps = std::mem::take(&mut st.look_snaps);
                let snap = &snaps[j];
                st.step = snap.step;
                st.since_full = snap.since_full;
                st.tea_accum = snap.tea_accum;
                st.stats.spec_steps = snap.spec_steps;
                st.traj.truncate(snap.traj_len);
                st.x.copy_from_slice(&snap.x);
                st.last_eps.clear();
                st.last_eps.extend_from_slice(&snap.last_eps);
                st.look_snaps = snaps;
            }
            Self::bump_hist(&mut st.stats, j);
            st.spec_run = 0;
            st.stats.rejects += 1;
            st.stats.flops.n_rejects += 1;
            if let Policy::SpeCa(c) = &st.spec.policy {
                c.draft.reset();
            }
        }
        Ok(())
    }

    /// Output heads over predicted last-boundary features (accepted SpeCa +
    /// TaylorSeer speculative steps). The first `n_ratified` entries of
    /// `idxs` closed a lookahead run at an accepted verify point: their
    /// run bookkeeping (histogram event, run reset) commits here, in the
    /// same per-slot block as the step advance, so the boundary-rollback
    /// invariant (step moved ⇔ this tick's mutations are kept) holds.
    fn run_heads(
        &mut self,
        model: &dyn ModelBackend,
        idxs: &[usize],
        n_ratified: usize,
    ) -> Result<()> {
        if idxs.is_empty() {
            return Ok(());
        }
        let entry = model.entry();
        let feat = entry.feat_len();
        let total = self.total_steps();
        let mut chunks = std::mem::take(&mut self.scratch.chunks);
        plan_chunks_into(idxs.len(), &entry.config.buckets, self.cfg.strategy, &mut chunks);
        for chunk in &chunks {
            self.gather_ty(&entry.schedule, chunk, idxs);
            {
                let Engine { active, scratch, .. } = &mut *self;
                gather_rows_into(&mut scratch.feat, chunk, feat, |m, dst| {
                    dst.copy_from_slice(&active[idxs[m]].pred_last)
                });
            }
            let dispatch = model.head(
                chunk.bucket,
                &self.scratch.feat,
                &self.scratch.t,
                &self.scratch.y,
            );
            let eps = match dispatch {
                Ok(out) => out,
                Err(e) => {
                    self.scratch.chunks = chunks;
                    return Err(e);
                }
            };
            for (slot, m) in chunk.members().enumerate() {
                let ri = idxs[m];
                let st = &mut self.active[ri];
                let eps_row = eps.row(slot);
                if st.spec.record_traj {
                    st.traj.push(st.pred_last.clone());
                }
                st.last_eps.clear();
                st.last_eps.extend_from_slice(eps_row);
                Self::apply_model_out(&entry.schedule, st, eps_row, total);
                self.flops_model.book_head(&mut st.stats.flops, chunk.bucket, 1);
                self.flops_model.book_spec_step(&mut st.stats.flops, 1);
                st.stats.spec_steps += 1;
                st.step += 1;
                st.since_full += 1;
                if m < n_ratified {
                    // the verify point ratified the whole run: a run of
                    // `spec_run` intermediates plus the verified step
                    let run = st.spec_run;
                    st.spec_run = 0;
                    Self::bump_hist(&mut st.stats, run + 1);
                }
            }
        }
        self.scratch.chunks = chunks;
        Ok(())
    }

    /// ToCa/DuCa-sim partial steps: recompute fully but emit a token-blended
    /// head input (reuse_frac of tokens come from the stale cache). FLOPs
    /// are booked at the simulated (1−R)·C cost — see DESIGN.md §2.
    fn run_blend(&mut self, model: &dyn ModelBackend, idxs: &[usize]) -> Result<()> {
        if idxs.is_empty() {
            return Ok(());
        }
        let entry = model.entry();
        let cfg = &entry.config;
        let latent = cfg.latent_dim;
        let feat = cfg.tokens * cfg.dim;
        let depth = cfg.depth;
        let tokens = cfg.tokens;
        let tok_len = cfg.dim;
        let total = self.total_steps();
        let mut chunks = std::mem::take(&mut self.scratch.chunks);
        plan_chunks_into(idxs.len(), &cfg.buckets, self.cfg.strategy, &mut chunks);
        for chunk in &chunks {
            self.gather_ty(&entry.schedule, chunk, idxs);
            {
                let Engine { active, scratch, .. } = &mut *self;
                gather_rows_into(&mut scratch.x, chunk, latent, |m, dst| {
                    dst.copy_from_slice(&active[idxs[m]].x)
                });
            }
            let dispatch = model.full(
                chunk.bucket,
                &self.scratch.x,
                &self.scratch.t,
                &self.scratch.y,
                false,
            );
            let (_eps, bounds) = match dispatch {
                Ok(out) => out,
                Err(e) => {
                    self.scratch.chunks = chunks;
                    return Err(e);
                }
            };
            // blend per request, then head over the blended features
            {
                let Engine { active, scratch, .. } = &mut *self;
                scratch.blend.clear();
                scratch.blend.resize(chunk.bucket * feat, 0.0);
                for (slot, m) in chunk.members().enumerate() {
                    let st = &active[idxs[m]];
                    let frac = st.spec.policy.reuse_frac();
                    let off = (depth * chunk.bucket + slot) * feat;
                    let fresh = &bounds.data[off..off + feat];
                    let dst = &mut scratch.blend[slot * feat..(slot + 1) * feat];
                    for tok in 0..tokens {
                        let reuse =
                            tok_hash(tok, st.step) < frac && !st.blend_feat.is_empty();
                        let src: &[f32] = if reuse { &st.blend_feat } else { fresh };
                        dst[tok * tok_len..(tok + 1) * tok_len]
                            .copy_from_slice(&src[tok * tok_len..(tok + 1) * tok_len]);
                    }
                }
                // padding rows replicate slot 0 so every row is well-formed
                pad_rows(&mut scratch.blend, chunk.used(), chunk.bucket, feat);
            }
            let dispatch = model.head(
                chunk.bucket,
                &self.scratch.blend,
                &self.scratch.t,
                &self.scratch.y,
            );
            let eps = match dispatch {
                Ok(out) => out,
                Err(e) => {
                    self.scratch.chunks = chunks;
                    return Err(e);
                }
            };
            let full_per = self.flops_model.table.full_step.get(&1).copied().unwrap_or(0);
            for (slot, m) in chunk.members().enumerate() {
                let ri = idxs[m];
                let st = &mut self.active[ri];
                let frac = st.spec.policy.reuse_frac();
                let eps_row = eps.row(slot);
                st.last_eps.clear();
                st.last_eps.extend_from_slice(eps_row);
                if st.spec.record_traj {
                    st.traj
                        .push(self.scratch.blend[slot * feat..(slot + 1) * feat].to_vec());
                }
                Self::apply_model_out(&entry.schedule, st, eps_row, total);
                // simulated cost: (1−R) of a full pass + the head
                st.stats.flops.other += ((1.0 - frac) * full_per as f64) as u64;
                self.flops_model.book_head(&mut st.stats.flops, chunk.bucket, 1);
                self.flops_model.book_spec_step(&mut st.stats.flops, 1);
                st.stats.blend_steps += 1;
                st.step += 1;
                st.since_full += 1;
            }
        }
        self.scratch.chunks = chunks;
        Ok(())
    }
}

/// Deterministic per-(token, step) hash in [0, 1) for ToCa-style subsets.
fn tok_hash(tok: usize, step: usize) -> f64 {
    let mut h = (tok as u64).wrapping_mul(0x9E3779B97F4A7C15)
        ^ (step as u64).wrapping_mul(0xC2B2AE3D27D4EB4F);
    h ^= h >> 33;
    h = h.wrapping_mul(0xFF51AFD7ED558CCD);
    h ^= h >> 33;
    (h >> 11) as f64 / (1u64 << 53) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tok_hash_uniformish() {
        let n = 10_000;
        let mean: f64 = (0..n).map(|i| tok_hash(i, 3)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
        // deterministic
        assert_eq!(tok_hash(5, 7), tok_hash(5, 7));
        assert_ne!(tok_hash(5, 7), tok_hash(5, 8));
    }
}
