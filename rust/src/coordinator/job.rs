//! First-class job lifecycle for the serving stack (DESIGN.md §8).
//!
//! SpeCa's sample-adaptive computation makes per-request cost
//! unpredictable *by design* — two requests with identical shapes can
//! differ by the whole accept/reject trajectory. A blocking
//! request/reply channel is the wrong surface for that: callers need to
//! submit, observe, shed and abandon work. This module is that surface:
//!
//! * [`JobManager`] — the submission front door over an
//!   [`EngineShardPool`]: assigns [`JobId`]s, applies the admission
//!   rules (queue cap, deadline feasibility), tracks every job in a
//!   shared [`JobTable`], and turns the pool's merged [`JobEvent`]
//!   stream into per-job status transitions.
//! * [`JobHandle`] — what a submitter holds: `poll` (non-blocking
//!   status snapshot), `wait` (block until terminal), `cancel` (fire
//!   the job's [`CancelToken`]; the engine observes it at the next step
//!   boundary and frees the shard slot mid-flight).
//! * [`JobEvent`] — the pool's event stream, subsuming the old
//!   completion-or-abort pair with the full lifecycle: `Admitted`,
//!   `Progress`, `Completed`, `Rejected`, `Cancelled`, `Aborted`.
//!
//! The state machine (every job ends in exactly one terminal state):
//!
//! ```text
//! Queued ──► Admitted{shard} ──► Running{step,accepts,rejects} ──► Completed
//!   │not admitted: queue full /        │ cancel token observed at a
//!   │deadline infeasible / expired     │ step boundary, or shard death
//!   ▼                                  ▼
//! Rejected{reason}                 Cancelled / Aborted{error}
//! ```
//!
//! Admission sheds load *before* queueing doomed work: a submit against
//! a full queue or with a deadline the current service-time estimate
//! says cannot be met terminates immediately as
//! [`JobStatus::Rejected`], and a queued job whose deadline passes
//! before a shard picks it up is rejected with
//! [`RejectReason::DeadlineExpired`] instead of burning a slot.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::cache::Draft;
use crate::coordinator::engine::Admission;
use crate::coordinator::policy::Policy;
use crate::coordinator::pool::{
    EngineShardPool, PoolConfig, ShardRouter, ShardStats, SpilledCheckpoint,
};
use crate::coordinator::state::{Completion, RequestCheckpoint, RequestSpec};
use crate::runtime::ModelBackend;

/// Identifier of one submitted job (unique within one manager/server).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl fmt::Display for JobId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "job-{}", self.0)
    }
}

/// Identifier of a job group. Members submitted without an explicit
/// cancel token share the group's [`CancelToken`], so one
/// [`JobManager::cancel_group`] drops every queued/running member at its
/// next step boundary. Ids are caller-chosen (the wire layer passes them
/// through verbatim); the registry entry — token included — is reclaimed
/// when the group's last member reaches a terminal state, so a later
/// submit reusing the id starts a fresh group.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GroupId(pub u64);

impl fmt::Display for GroupId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "group-{}", self.0)
    }
}

/// Scheduling class of a job. Shard queues admit strictly by priority
/// (FIFO within a class), so a `High` job overtakes every queued
/// `Normal`/`Low` job but never preempts work already in flight —
/// unless the in-flight job opted in to checkpoint preemption
/// ([`SubmitOptions::preemptible`]), in which case the engine parks it
/// at a step boundary and resumes it later, bitwise-identically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    /// Admitted only when no normal/high work is queued.
    Low,
    /// The default class.
    #[default]
    Normal,
    /// Overtakes queued normal/low jobs at admission time.
    High,
}

impl Priority {
    /// Number of priority classes (sizes the engine's queue array).
    pub const LEVELS: usize = 3;

    /// Queue index of this class (ascending urgency).
    pub fn index(self) -> usize {
        self as usize
    }

    /// Parse `low` / `normal` / `high` (case-insensitive).
    pub fn parse(s: &str) -> Option<Priority> {
        match s.to_ascii_lowercase().as_str() {
            "low" => Some(Priority::Low),
            "normal" => Some(Priority::Normal),
            "high" => Some(Priority::High),
            _ => None,
        }
    }

    /// Wire/report label.
    pub fn label(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

/// Shared cancellation flag. Cloning shares the flag (an `Arc` bump), so
/// a handle, the wire layer and the in-flight request state all observe
/// one cancel. The engine checks it at every step boundary — a
/// cancelled job frees its shard slot mid-flight instead of running its
/// remaining steps to completion.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// A fresh, un-cancelled token.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Fire the token. Idempotent; never blocks.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    /// Whether the token has been fired.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }

    /// Whether any other handle shares this token (someone who could
    /// still fire it). A token nobody else holds can never be
    /// cancelled, which lets the engine skip its lifecycle sweep for
    /// fire-and-forget work.
    pub fn is_shared(&self) -> bool {
        Arc::strong_count(&self.0) > 1
    }
}

/// Job-lifecycle metadata carried by every [`RequestSpec`] into the
/// engine: the scheduling class, the absolute deadline (if any) and the
/// shared cancellation token. `Default` is a normal-priority,
/// deadline-less, un-cancelled job — exactly the old fire-and-forget
/// request semantics.
#[derive(Debug, Clone, Default)]
pub struct JobMeta {
    /// Scheduling class (shard queues admit by priority).
    pub priority: Priority,
    /// Absolute deadline; a job still queued past it is rejected.
    pub deadline: Option<Instant>,
    /// Cancellation flag, checked at every step boundary.
    pub cancel: CancelToken,
    /// Expected service time in milliseconds (0 = unknown). Set by the
    /// [`JobManager`] from its per-policy EWMA at submission, and read by
    /// [`ShardRouter`] least-loaded routing as the request's weight in
    /// the per-shard *expected remaining work* gauge — so a shard holding
    /// one heavy job yields to a shard holding two cheap ones.
    pub cost_hint: f64,
    /// Whether the engine may park this request mid-flight (checkpoint
    /// preemption / work-stealing, DESIGN.md §13) to free its slot for
    /// higher-priority work. Off by default: preemption is opt-in.
    pub preemptible: bool,
}

impl JobMeta {
    /// Whether the deadline (if any) has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.map(|d| now >= d).unwrap_or(false)
    }
}

/// Per-submission options for [`JobManager::submit`], built fluently:
///
/// ```
/// use speca::coordinator::job::{GroupId, Priority, SubmitOptions};
///
/// let opts = SubmitOptions::new()
///     .priority(Priority::High)
///     .deadline_ms(5_000)
///     .preemptible(true)
///     .group(GroupId(7));
/// assert_eq!(opts.priority, Priority::High);
/// assert!(opts.preemptible);
/// ```
///
/// `#[non_exhaustive]` on purpose: new submission knobs (this release
/// added `preemptible` and `group`) must not break downstream code, so
/// external callers construct via [`SubmitOptions::new`] / `Default`
/// plus the chainable setters, never a struct literal.
#[derive(Debug, Clone, Default)]
#[non_exhaustive]
pub struct SubmitOptions {
    /// Scheduling class (default [`Priority::Normal`]).
    pub priority: Priority,
    /// Relative deadline in milliseconds from submission. Admission
    /// rejects a deadline the service-time estimate says cannot be met;
    /// a queued job whose deadline passes is rejected before admission.
    pub deadline_ms: Option<u64>,
    /// Cancellation token to share; `None` mints a fresh token (or the
    /// group's shared token when [`Self::group`] is set), reachable via
    /// [`JobHandle::cancel`].
    pub cancel: Option<CancelToken>,
    /// Draft-strategy override for SpeCa policies (the same override
    /// surface as the wire `draft` field).
    pub draft: Option<Draft>,
    /// Total rel-error budget for sample-adaptive allocation on SpeCa
    /// policies (the same surface as the `adaptive=` policy key): the
    /// job gets a per-request
    /// [`AdaptiveController`](crate::coordinator::adaptive::AdaptiveController).
    /// Under backlog, admission shrinks a low-priority job's budget
    /// deadline-aware (see [`JobManager::submit`]).
    pub adaptive: Option<f64>,
    /// Lookahead cap override for SpeCa policies (the same surface as
    /// the `lookahead=` policy key and the wire `lookahead` field): the
    /// engine speculates runs of up to this many steps per verify point
    /// (DESIGN.md §16). Clamped to ≥ 1; `None` keeps the policy's own
    /// cap (default 1 = verify every speculative step).
    pub lookahead: Option<usize>,
    /// Keep the final latent in the job record so `poll`/`wait` can
    /// return it (the wire `return_latent` field).
    pub return_latent: bool,
    /// Allow the engine to park this job mid-flight — checkpoint it at a
    /// step boundary and resume it later (possibly on another shard) —
    /// to free its slot for higher-priority work or rebalancing. Resume
    /// is bitwise-identical (DESIGN.md §13). Default `false`.
    pub preemptible: bool,
    /// Join a job group: members without an explicit `cancel` token
    /// share the group's token, and the group appears in per-group
    /// lifecycle counts ([`JobManager::group_counts`]).
    pub group: Option<GroupId>,
}

impl SubmitOptions {
    /// Default options (normal priority, no deadline, fresh token).
    pub fn new() -> SubmitOptions {
        SubmitOptions::default()
    }

    /// Set the scheduling class.
    pub fn priority(mut self, priority: Priority) -> SubmitOptions {
        self.priority = priority;
        self
    }

    /// Set a relative deadline in milliseconds from submission.
    pub fn deadline_ms(mut self, ms: u64) -> SubmitOptions {
        self.deadline_ms = Some(ms);
        self
    }

    /// Share an existing cancellation token.
    pub fn cancel(mut self, token: CancelToken) -> SubmitOptions {
        self.cancel = Some(token);
        self
    }

    /// Override the SpeCa draft strategy for this job.
    pub fn draft(mut self, draft: Draft) -> SubmitOptions {
        self.draft = Some(draft);
        self
    }

    /// Attach a sample-adaptive error budget (total rel-L1 tolerance
    /// spread over the schedule) to this job's SpeCa policy.
    pub fn adaptive(mut self, budget: f64) -> SubmitOptions {
        self.adaptive = Some(budget);
        self
    }

    /// Cap this job's lookahead runs at `k` speculated steps per verify
    /// point (SpeCa policies only; clamped to ≥ 1 at submission).
    pub fn lookahead(mut self, k: usize) -> SubmitOptions {
        self.lookahead = Some(k);
        self
    }

    /// Keep the final latent in the job record for `poll`/`wait`.
    pub fn return_latent(mut self, yes: bool) -> SubmitOptions {
        self.return_latent = yes;
        self
    }

    /// Opt this job into checkpoint preemption / work-stealing.
    pub fn preemptible(mut self, yes: bool) -> SubmitOptions {
        self.preemptible = yes;
        self
    }

    /// Join the given job group.
    pub fn group(mut self, gid: GroupId) -> SubmitOptions {
        self.group = Some(gid);
        self
    }
}

/// Why a job was rejected instead of queued or served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The manager-wide live-job cap (`max_queue`) was reached.
    QueueFull,
    /// The requested deadline is shorter than the current backlog-scaled
    /// service-time estimate — queueing it would be doomed work.
    DeadlineInfeasible,
    /// The deadline passed while the job was still queued on its shard.
    DeadlineExpired,
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            // exactly the v1 wire string, so the compat shim's error
            // reply is byte-identical to the old queue-full reply
            RejectReason::QueueFull => write!(f, "queue full"),
            RejectReason::DeadlineInfeasible => {
                write!(f, "deadline infeasible under current load")
            }
            RejectReason::DeadlineExpired => {
                write!(f, "deadline expired before admission")
            }
        }
    }
}

/// Why the engine dropped a request at a step boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TerminationCause {
    /// The request's [`CancelToken`] fired.
    Cancelled,
    /// The request was still queued when its deadline passed.
    DeadlineExpired,
}

/// One request dropped by the engine (cancellation or deadline expiry),
/// reported through [`Engine::drain_terminations`](crate::coordinator::Engine::drain_terminations)
/// so the shard worker can release load accounting and notify waiters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Termination {
    /// Id of the dropped request.
    pub id: u64,
    /// Why it was dropped.
    pub cause: TerminationCause,
}

/// Progress snapshot of one in-flight request (engine → shard worker →
/// [`JobEvent::Progress`]).
#[derive(Debug, Clone, Copy)]
pub struct JobProgress {
    /// Request id.
    pub id: u64,
    /// Next serve step to execute.
    pub step: usize,
    /// Speculative steps accepted so far.
    pub accepts: usize,
    /// Verifications that failed so far.
    pub rejects: usize,
}

/// The shard pool's merged event stream: every lifecycle transition of
/// every job, in per-shard order (cross-shard order is nondeterministic;
/// every event carries its job id).
#[derive(Debug, Clone)]
pub enum JobEvent {
    /// The job landed on a shard's queue.
    Admitted {
        /// Job id.
        id: u64,
        /// Index of the shard that ingested it.
        shard: usize,
    },
    /// Periodic progress of an in-flight job (shard workers throttle
    /// emission to every few steps — `poll` freshness, not a tick log).
    Progress(JobProgress),
    /// The job finished normally. Boxed: completions dwarf the other
    /// variants (latent + stats + trace), and boxing keeps channel
    /// sends and matches a pointer move.
    Completed(Box<Completion>),
    /// The job was shed without running (admission or queued-deadline).
    Rejected {
        /// Job id.
        id: u64,
        /// Structured reason (also the wire error string).
        reason: RejectReason,
    },
    /// The job's cancel token fired and the engine dropped it at a step
    /// boundary, freeing its shard slot.
    Cancelled {
        /// Job id.
        id: u64,
    },
    /// The job was abandoned by a dying/halting shard.
    Aborted {
        /// Job id.
        id: u64,
        /// Why the shard abandoned it.
        error: String,
    },
}

/// Where a job currently is in its lifecycle. `Completed`, `Rejected`,
/// `Cancelled` and `Aborted` are terminal: once reached, the status
/// never changes again.
#[derive(Debug, Clone)]
pub enum JobStatus {
    /// Accepted by the manager, not yet on a shard.
    Queued,
    /// On a shard's queue / active set.
    Admitted {
        /// Index of the shard serving it.
        shard: usize,
    },
    /// In flight: the engine is advancing it step by step.
    Running {
        /// Next serve step to execute.
        step: usize,
        /// Speculative steps accepted so far.
        accepts: usize,
        /// Verifications that failed so far.
        rejects: usize,
    },
    /// Finished; carries the full completion (latent, stats, trace).
    /// `Arc`'d so polling a finished job clones a refcount, not the
    /// latent tensor.
    Completed(Arc<Completion>),
    /// Shed by admission control or queued-deadline expiry.
    Rejected {
        /// Structured reason.
        reason: RejectReason,
    },
    /// Dropped at a step boundary after its cancel token fired.
    Cancelled,
    /// Abandoned by a dying/halting shard (or unroutable).
    Aborted {
        /// What went wrong.
        error: String,
    },
}

impl JobStatus {
    /// Whether this status is final.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobStatus::Completed(_)
                | JobStatus::Rejected { .. }
                | JobStatus::Cancelled
                | JobStatus::Aborted { .. }
        )
    }

    /// Wire/report label (`queued` … `aborted`).
    pub fn label(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Admitted { .. } => "admitted",
            JobStatus::Running { .. } => "running",
            JobStatus::Completed(_) => "completed",
            JobStatus::Rejected { .. } => "rejected",
            JobStatus::Cancelled => "cancelled",
            JobStatus::Aborted { .. } => "aborted",
        }
    }
}

/// Monotonic job counters (snapshot via [`JobManager::counts`]). The
/// lifecycle invariant every shutdown path preserves:
/// `completed + rejected + cancelled + aborted == submitted` once the
/// pool has drained — no job is ever silently lost.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct JobCounts {
    /// Jobs handed to [`JobManager::submit`].
    pub submitted: u64,
    /// Jobs that finished normally.
    pub completed: u64,
    /// Jobs shed by admission or queued-deadline expiry.
    pub rejected: u64,
    /// Jobs dropped after their cancel token fired.
    pub cancelled: u64,
    /// Jobs abandoned by dead/halted shards.
    pub aborted: u64,
}

impl JobCounts {
    /// Jobs that reached a terminal state.
    pub fn terminal(&self) -> u64 {
        self.completed + self.rejected + self.cancelled + self.aborted
    }
}

#[derive(Default)]
struct Counters {
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
    cancelled: AtomicU64,
    aborted: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> JobCounts {
        JobCounts {
            submitted: self.submitted.load(Ordering::SeqCst),
            completed: self.completed.load(Ordering::SeqCst),
            rejected: self.rejected.load(Ordering::SeqCst),
            cancelled: self.cancelled.load(Ordering::SeqCst),
            aborted: self.aborted.load(Ordering::SeqCst),
        }
    }

    /// Bump the counter matching a terminal status. Called while the
    /// job-table lock is held, so a waiter woken by the transition can
    /// never observe a stale counter (reply-then-stats reads line up).
    fn bump_terminal(&self, status: &JobStatus) {
        let counter = match status {
            JobStatus::Completed(_) => &self.completed,
            JobStatus::Rejected { .. } => &self.rejected,
            JobStatus::Cancelled => &self.cancelled,
            JobStatus::Aborted { .. } => &self.aborted,
            _ => return,
        };
        counter.fetch_add(1, Ordering::SeqCst);
    }
}

/// Lifecycle counts of one live job group (snapshot via
/// [`JobManager::group_counts`]). Counts cover members that passed
/// admission — a submit shed by the queue cap or deadline feasibility
/// never joins its group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupCounts {
    /// The group's id.
    pub id: u64,
    /// Members admitted under this id since the group was (re)minted.
    pub submitted: u64,
    /// Members that finished normally.
    pub completed: u64,
    /// Members not yet in a terminal state.
    pub live: u64,
}

#[derive(Default)]
struct GroupEntry {
    cancel: CancelToken,
    submitted: u64,
    completed: u64,
    live: u64,
}

#[derive(Default)]
struct GroupInner {
    groups: HashMap<u64, GroupEntry>,
    by_job: HashMap<u64, u64>,
}

/// Registry of live job groups: the shared cancel token per group plus
/// member counts. An entry lives while any member is live and is
/// reclaimed — token included — when the last member terminates, so
/// registry memory is bounded by the live-job cap even against clients
/// that mint a fresh group id per request.
#[derive(Default)]
struct GroupRegistry {
    inner: Mutex<GroupInner>,
}

impl GroupRegistry {
    /// The group's shared cancel token, minting the group on first use.
    fn token(&self, gid: GroupId) -> CancelToken {
        let mut g = self.inner.lock().unwrap();
        g.groups.entry(gid.0).or_default().cancel.clone()
    }

    /// Count job `id` as a live member of `gid`.
    fn note_submit(&self, gid: GroupId, id: u64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.groups.entry(gid.0).or_default();
        e.submitted += 1;
        e.live += 1;
        g.by_job.insert(id, gid.0);
    }

    /// Record a member's terminal transition. Callers gate on
    /// [`JobTable::finish`] returning true, so duplicate terminal events
    /// never double-decrement; non-member ids are a no-op.
    fn note_terminal(&self, id: u64, completed: bool) {
        let mut g = self.inner.lock().unwrap();
        let Some(gid) = g.by_job.remove(&id) else { return };
        let Some(e) = g.groups.get_mut(&gid) else { return };
        e.live -= 1;
        if completed {
            e.completed += 1;
        }
        if e.live == 0 {
            g.groups.remove(&gid);
        }
    }

    /// Fire a group's shared token; returns whether the group currently
    /// has a live member (a reclaimed or unknown id is a no-op).
    fn cancel(&self, gid: GroupId) -> bool {
        let g = self.inner.lock().unwrap();
        match g.groups.get(&gid.0) {
            Some(e) => {
                e.cancel.cancel();
                true
            }
            None => false,
        }
    }

    /// Snapshot of every live group, ascending by id.
    fn counts(&self) -> Vec<GroupCounts> {
        let g = self.inner.lock().unwrap();
        let mut out: Vec<GroupCounts> = g
            .groups
            .iter()
            .map(|(&id, e)| GroupCounts {
                id,
                submitted: e.submitted,
                completed: e.completed,
                live: e.live,
            })
            .collect();
        out.sort_by_key(|c| c.id);
        out
    }
}

struct JobEntry {
    status: JobStatus,
    return_latent: bool,
    cancel: CancelToken,
    /// waiters currently parked on this record; eviction never removes
    /// a record a blocked `wait` still needs
    waiters: usize,
}

struct TableInner {
    jobs: HashMap<u64, JobEntry>,
    /// jobs in a non-terminal state (the `max_queue` admission gauge).
    /// Every record is either live or terminal, so the retained
    /// terminal count is always `jobs.len() - live` — derived, never
    /// hand-synchronized.
    live: usize,
    /// retained terminal record ids, oldest first (eviction order; may
    /// hold stale ids for records a consuming wait already removed)
    terminal_order: std::collections::VecDeque<u64>,
}

impl TableInner {
    /// Terminal records still retained (not yet consumed/forgotten).
    fn retained_terminal(&self) -> usize {
        self.jobs.len() - self.live
    }
}

/// Shared registry of every job the manager has seen: status snapshots
/// for `poll`, a condvar for `wait`, cancel-token lookup for `cancel`.
/// Completed/failed records stay until a consuming wait removes them
/// (the v1 shim and the open-loop client always consume), so repeated
/// polls of a finished job are idempotent — but at most `terminal_cap`
/// terminal records are retained: beyond that the *oldest* unconsumed
/// terminal record is evicted (a later poll/wait of it reports an
/// unknown job). Together with the live-job cap this bounds table
/// memory even against clients that submit and never collect.
pub struct JobTable {
    inner: Mutex<TableInner>,
    cv: Condvar,
    terminal_cap: usize,
}

impl Default for JobTable {
    fn default() -> Self {
        JobTable::new(1024)
    }
}

impl JobTable {
    /// Empty table retaining at most `terminal_cap` uncollected
    /// terminal records (clamped to ≥ 1).
    pub fn new(terminal_cap: usize) -> JobTable {
        JobTable {
            inner: Mutex::new(TableInner {
                jobs: HashMap::new(),
                live: 0,
                terminal_order: std::collections::VecDeque::new(),
            }),
            cv: Condvar::new(),
            terminal_cap: terminal_cap.max(1),
        }
    }

    /// Record that `id` just became a retained terminal record, then
    /// evict oldest-first down to the cap. Records a blocked `wait` is
    /// parked on are kept (re-queued for later eviction), so the cap
    /// can be exceeded transiently by at most the number of parked
    /// waiters — bounded by connection threads. Caller holds the lock.
    fn note_terminal(&self, g: &mut TableInner, id: u64) {
        g.terminal_order.push_back(id);
        let mut scans = g.terminal_order.len();
        while g.retained_terminal() > self.terminal_cap && scans > 0 {
            scans -= 1;
            let Some(old) = g.terminal_order.pop_front() else { break };
            // None: stale id (record already consumed/forgotten or the
            // id re-examined is live — impossible for pushed ids)
            let keep = match g.jobs.get(&old) {
                Some(e) if e.status.is_terminal() => Some(e.waiters > 0),
                _ => None,
            };
            match keep {
                Some(true) => g.terminal_order.push_back(old),
                Some(false) => {
                    g.jobs.remove(&old);
                }
                None => {}
            }
        }
        // consuming waits / forget remove records without touching the
        // deque, so stale ids accumulate between cap-pressure pops —
        // compact when they dominate (amortized O(1) per terminal), so
        // the deque tracks retained records, not all-time history
        if g.terminal_order.len() > 2 * self.terminal_cap + 16 {
            let TableInner { jobs, terminal_order, .. } = g;
            terminal_order
                .retain(|i| jobs.get(i).map(|e| e.status.is_terminal()).unwrap_or(false));
        }
    }

    /// Jobs currently in a non-terminal state.
    pub fn live(&self) -> usize {
        self.inner.lock().unwrap().live
    }

    /// Register a job as `Queued` unless the live-job count has reached
    /// `max_live` (the admission check and the registration are one
    /// critical section, so the cap holds exactly under concurrent
    /// submitters). Returns whether the job was registered.
    fn try_insert(
        &self,
        id: u64,
        return_latent: bool,
        cancel: CancelToken,
        max_live: usize,
    ) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.live >= max_live {
            return false;
        }
        g.live += 1;
        g.jobs
            .insert(id, JobEntry { status: JobStatus::Queued, return_latent, cancel, waiters: 0 });
        true
    }

    /// Record a non-terminal transition; ignored once the job is
    /// terminal (events can race completion) or unknown.
    fn advance(&self, id: u64, status: JobStatus) {
        debug_assert!(!status.is_terminal());
        let mut g = self.inner.lock().unwrap();
        if let Some(e) = g.jobs.get_mut(&id) {
            if !e.status.is_terminal() {
                e.status = status;
            }
        }
    }

    /// Record a terminal transition, bumping the matching counter
    /// inside the critical section (a waiter woken by this transition
    /// reacquires the lock, so it can never read a stale counter).
    /// Returns true iff this call moved the job out of a live state
    /// (duplicate terminal events — e.g. a submit-failure abort racing
    /// a worker abort — are dropped, so counters never double-count).
    fn finish(&self, id: u64, status: JobStatus, counters: &Counters) -> bool {
        debug_assert!(status.is_terminal());
        let mut g = self.inner.lock().unwrap();
        let Some(e) = g.jobs.get_mut(&id) else { return false };
        if e.status.is_terminal() {
            return false;
        }
        counters.bump_terminal(&status);
        e.status = status;
        g.live -= 1;
        self.note_terminal(&mut g, id);
        self.cv.notify_all();
        true
    }

    /// Status snapshot plus the job's `return_latent` flag.
    pub fn status(&self, id: u64) -> Option<(JobStatus, bool)> {
        let g = self.inner.lock().unwrap();
        g.jobs.get(&id).map(|e| (e.status.clone(), e.return_latent))
    }

    /// Remove a job's record if (and only if) it is already terminal;
    /// returns whether a record was removed. The wire layer uses this
    /// after a terminal submit ack: such a job was answered in the ack
    /// itself and will never receive the consuming `wait`, so keeping
    /// its record would leak one entry per shed request under overload.
    pub fn forget(&self, id: u64) -> bool {
        let mut g = self.inner.lock().unwrap();
        let removable = g
            .jobs
            .get(&id)
            .map(|e| e.status.is_terminal() && e.waiters == 0)
            .unwrap_or(false);
        if removable {
            g.jobs.remove(&id);
            return true;
        }
        false
    }

    /// Fire a job's cancel token; returns its status at that instant
    /// (`None` for unknown ids). The engine observes the token at the
    /// next step boundary; a job that is already terminal is unaffected.
    pub fn cancel(&self, id: u64) -> Option<JobStatus> {
        let g = self.inner.lock().unwrap();
        g.jobs.get(&id).map(|e| {
            e.cancel.cancel();
            e.status.clone()
        })
    }

    /// Block until the job reaches a terminal state (or the timeout
    /// elapses — then the current non-terminal status is returned; check
    /// [`JobStatus::is_terminal`]). `consume` removes a terminal record,
    /// freeing its memory; polls of a consumed job return `None`.
    pub fn wait(
        &self,
        id: u64,
        timeout: Option<Duration>,
        consume: bool,
    ) -> Option<(JobStatus, bool)> {
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut g = self.inner.lock().unwrap();
        let mut registered = false;
        loop {
            let (terminal, rl) = match g.jobs.get(&id) {
                // record gone (another waiter consumed it) — the entry
                // took our registration with it, nothing to undo
                None => return None,
                Some(e) => (e.status.is_terminal(), e.return_latent),
            };
            if terminal {
                if consume {
                    let status = g.jobs.remove(&id).map(|e| e.status).unwrap();
                    return Some((status, rl));
                }
                let e = g.jobs.get_mut(&id).unwrap();
                if registered {
                    e.waiters -= 1;
                }
                return Some((e.status.clone(), rl));
            }
            // mark the record waited-on before parking, so terminal-cap
            // eviction cannot reclaim it between its completion and this
            // thread re-acquiring the lock
            if !registered {
                g.jobs.get_mut(&id).unwrap().waiters += 1;
                registered = true;
            }
            match deadline {
                None => g = self.cv.wait(g).unwrap(),
                Some(dl) => {
                    let now = Instant::now();
                    if now >= dl {
                        let e = g.jobs.get_mut(&id).unwrap();
                        if registered {
                            e.waiters -= 1;
                        }
                        return Some((e.status.clone(), e.return_latent));
                    }
                    let (g2, _) = self.cv.wait_timeout(g, dl - now).unwrap();
                    g = g2;
                }
            }
        }
    }
}

/// What a submitter holds: the job id, a view into the shared
/// [`JobTable`], and the job's cancel token. Cloning shares all three.
#[derive(Clone)]
pub struct JobHandle {
    id: JobId,
    table: Arc<JobTable>,
    cancel: CancelToken,
    /// Terminal verdict delivered at submission time (admission
    /// rejection). Such a job never enters the table — a transient
    /// reject record would churn terminal-cap eviction and could evict
    /// a genuine uncollected completion — so the handle carries the
    /// status itself.
    early: Option<JobStatus>,
}

impl JobHandle {
    /// The job's id.
    pub fn id(&self) -> JobId {
        self.id
    }

    /// Fire the job's cancel token. The engine drops the job at its
    /// next step boundary (freeing the shard slot); terminal jobs are
    /// unaffected. Idempotent; never blocks.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// The submission-time terminal verdict, or "record consumed" for a
    /// job whose table record a consuming wait already collected.
    fn early_or_consumed(&self) -> JobStatus {
        self.early
            .clone()
            .unwrap_or(JobStatus::Aborted { error: "job record consumed".into() })
    }

    /// Non-blocking status snapshot.
    pub fn poll(&self) -> JobStatus {
        match self.table.status(self.id.0) {
            Some((s, _)) => s,
            None => self.early_or_consumed(),
        }
    }

    /// Block until the job reaches a terminal state.
    pub fn wait(&self) -> JobStatus {
        match self.table.wait(self.id.0, None, false) {
            Some((s, _)) => s,
            None => self.early_or_consumed(),
        }
    }

    /// [`Self::wait`] with a timeout; a non-terminal return means the
    /// timeout elapsed first.
    pub fn wait_timeout(&self, timeout: Duration) -> JobStatus {
        match self.table.wait(self.id.0, Some(timeout), false) {
            Some((s, _)) => s,
            None => self.early_or_consumed(),
        }
    }
}

/// Outcome of a [`JobManager::shutdown`].
#[derive(Debug, Clone)]
pub struct JobOutcome {
    /// Merged engine counters across shard workers.
    pub stats: ShardStats,
    /// Final lifecycle accounting
    /// (`counts.terminal() == counts.submitted` after a clean shutdown).
    pub counts: JobCounts,
}

/// The job-lifecycle front door: an [`EngineShardPool`] plus the shared
/// [`JobTable`], admission control and the dispatcher thread that folds
/// the pool's [`JobEvent`] stream into per-job status.
///
/// # Examples
///
/// ```
/// use std::sync::Arc;
/// use speca::config::ModelConfig;
/// use speca::coordinator::job::{JobManager, JobStatus, SubmitOptions};
/// use speca::coordinator::PoolConfig;
/// use speca::runtime::{ModelBackend, NativeBackend};
/// use speca::workload::parse_policy;
///
/// let model = Arc::new(NativeBackend::seeded(ModelConfig::native_test(), 1));
/// let depth = model.entry().config.depth;
/// let mgr = JobManager::new(model, PoolConfig::default(), 64);
/// let policy = parse_policy("speca:N=4,O=2", depth).unwrap();
/// let handle = mgr.submit(0, Some(7), policy, SubmitOptions::default());
/// let status = handle.wait();
/// assert!(matches!(status, JobStatus::Completed(_)));
/// let out = mgr.shutdown(true).unwrap();
/// assert_eq!(out.counts.completed, 1);
/// assert_eq!(out.counts.terminal(), out.counts.submitted);
/// ```
pub struct JobManager {
    router: ShardRouter,
    table: Arc<JobTable>,
    counters: Arc<Counters>,
    /// EWMA of completed-job latency, stored as f64 bits (0 ⇒ no data).
    est_service_ms: Arc<AtomicU64>,
    /// Per-policy-family latency EWMAs (keyed by [`Policy::name`]): the
    /// service-time hints stamped onto submissions so the router weighs
    /// expected remaining work rather than raw request counts.
    policy_est_ms: Arc<Mutex<HashMap<String, f64>>>,
    groups: Arc<GroupRegistry>,
    pool: Mutex<Option<EngineShardPool>>,
    dispatcher: Mutex<Option<JoinHandle<()>>>,
    next_id: AtomicU64,
    max_queue: usize,
    /// per-shard engine concurrency (`max_inflight`), so the deadline
    /// feasibility estimate accounts for requests running in parallel
    slots_per_shard: usize,
}

impl JobManager {
    /// Spawn the shard pool and the event dispatcher. `max_queue` caps
    /// jobs in a non-terminal state across the whole manager.
    pub fn new(
        model: Arc<dyn ModelBackend + Send + Sync>,
        cfg: PoolConfig,
        max_queue: usize,
    ) -> JobManager {
        let slots_per_shard = cfg.engine.max_inflight.max(1);
        let mut pool = EngineShardPool::new(model, cfg);
        let events = pool.take_event_rx().expect("fresh pool has its event stream");
        let router = pool.router();
        // live jobs and retained terminal records are capped alike, so
        // table memory is bounded even against submit-and-never-collect
        // clients (at most 2·max_queue records)
        let table = Arc::new(JobTable::new(max_queue.max(1)));
        let counters = Arc::new(Counters::default());
        let est = Arc::new(AtomicU64::new(0));
        let policy_est = Arc::new(Mutex::new(HashMap::new()));
        let groups = Arc::new(GroupRegistry::default());
        let dispatcher = {
            let table = table.clone();
            let counters = counters.clone();
            let est = est.clone();
            let policy_est = policy_est.clone();
            let groups = groups.clone();
            std::thread::Builder::new()
                .name("speca-job-dispatcher".into())
                .spawn(move || {
                    dispatch_events(events, &table, &counters, &est, &policy_est, &groups)
                })
                .expect("spawning job dispatcher")
        };
        JobManager {
            router,
            table,
            counters,
            est_service_ms: est,
            policy_est_ms: policy_est,
            groups,
            pool: Mutex::new(Some(pool)),
            dispatcher: Mutex::new(Some(dispatcher)),
            next_id: AtomicU64::new(0),
            max_queue: max_queue.max(1),
            slots_per_shard,
        }
    }

    /// Submit one generation job. `seed` defaults to the assigned job id
    /// (the v1 wire default). Never blocks: when admission sheds the job
    /// the returned handle is already terminal (`Rejected`, carried on
    /// the handle itself — a shed job never enters the table), and an
    /// unroutable submit (all shards dead) ends `Aborted`.
    pub fn submit(
        &self,
        cond: i32,
        seed: Option<u64>,
        policy: Policy,
        opts: SubmitOptions,
    ) -> JobHandle {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.counters.submitted.fetch_add(1, Ordering::SeqCst);
        // an explicit token wins; otherwise a group member shares the
        // group's token (one cancel drops every member), and a loner
        // gets a fresh one
        let cancel = match (&opts.cancel, opts.group) {
            (Some(c), _) => c.clone(),
            (None, Some(gid)) => self.groups.token(gid),
            (None, None) => CancelToken::new(),
        };

        // deadline-aware admission: don't queue doomed work. The engine
        // serves up to `slots_per_shard` requests concurrently and the
        // EWMA latency is measured under that same concurrency, so the
        // projection counts *waves* of backlog ahead of this job, not
        // individual requests (est · backlog would over-reject ~8×).
        let mut adaptive = opts.adaptive;
        if let Some(ms) = opts.deadline_ms {
            let est = f64::from_bits(self.est_service_ms.load(Ordering::SeqCst));
            if est > 0.0 {
                // backlog per *live* shard: a dead shard serves nothing,
                // so its slot must not dilute the estimate
                let loads = self.router.loads();
                let live = loads.iter().filter(|l| **l != usize::MAX).count().max(1);
                let inflight: usize = loads.iter().filter(|l| **l != usize::MAX).sum();
                let backlog = inflight as f64 / live as f64;
                let waves = (backlog / self.slots_per_shard as f64).ceil();
                if est * (waves + 1.0) > ms as f64 {
                    return self.rejected_handle(id, cancel, RejectReason::DeadlineInfeasible);
                }
                // sample-adaptive admission integration: under backlog,
                // a low-priority job with thin deadline headroom gets
                // its error budget shrunk (down to 0 ⇒ fully dense). A
                // rejected speculation costs predict + verify + the full
                // fallback — more than the dense pass it degenerates to —
                // so thin-headroom jobs are steered onto the predictable
                // dense schedule instead of gambling the deadline on
                // acceptance: quality headroom traded for certainty.
                if waves >= 1.0 && matches!(opts.priority, Priority::Low) {
                    if let Some(b) = adaptive {
                        let headroom = ms as f64 / (est * (waves + 1.0));
                        adaptive = Some(b * (headroom - 1.0).clamp(0.0, 1.0));
                    }
                }
            }
        }
        // queue cap: check-and-register is one critical section
        if !self.table.try_insert(id, opts.return_latent, cancel.clone(), self.max_queue) {
            return self.rejected_handle(id, cancel, RejectReason::QueueFull);
        }
        // group membership follows admission (shed jobs never join), so
        // the registry's live counts mirror the table's
        if let Some(gid) = opts.group {
            self.groups.note_submit(gid, id);
        }

        let mut policy = policy;
        if let Some(d) = &opts.draft {
            crate::workload::apply_draft(&mut policy, d);
        }
        if let (Some(b), Policy::SpeCa(c)) = (adaptive, &mut policy) {
            c.adaptive = Some(b);
        }
        if let Some(k) = opts.lookahead {
            crate::workload::apply_lookahead(&mut policy, k);
        }
        // service-time hint for work-weighted routing: the policy
        // family's own EWMA when it has completions, else the global one
        // (0 before any completion — the router then weighs this job at
        // the nominal unit, i.e. plain request counting)
        let cost_hint = self
            .est_for_policy(policy.name())
            .unwrap_or_else(|| f64::from_bits(self.est_service_ms.load(Ordering::SeqCst)));
        let spec = RequestSpec {
            id,
            cond,
            seed: seed.unwrap_or(id),
            policy,
            record_traj: false,
            meta: JobMeta {
                priority: opts.priority,
                deadline: opts.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms)),
                cancel: cancel.clone(),
                cost_hint,
                preemptible: opts.preemptible,
            },
        };
        if let Err(e) = self.router.submit(spec) {
            let status = JobStatus::Aborted { error: format!("{e:#}") };
            if self.table.finish(id, status, &self.counters) {
                self.groups.note_terminal(id, false);
            }
        }
        JobHandle { id: JobId(id), table: self.table.clone(), cancel, early: None }
    }

    /// Resume a parked checkpoint under this manager — the receiving
    /// side of cross-process failover: a router re-queues a dead
    /// worker's spilled SPCK image here and the job completes
    /// bitwise-identically to an uninterrupted run (DESIGN.md §13/§15).
    ///
    /// The checkpoint's id is rewritten to a **fresh local id** (ids
    /// are per-process; the spilling process's id could collide with a
    /// live local job). That is sound because the id never enters the
    /// computation — the generation is a function of `cond`/`seed`/
    /// `policy`/the checkpointed state, all of which travel in the
    /// image. The caller learns the assigned id from the returned
    /// handle. Admission applies the queue cap but not deadline
    /// feasibility (the job was already accepted once; shedding it now
    /// would break the fabric's no-lost-accepted-jobs contract).
    pub fn submit_checkpoint(
        &self,
        ckpt: Box<RequestCheckpoint>,
        return_latent: bool,
    ) -> JobHandle {
        let mut ckpt = ckpt;
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.counters.submitted.fetch_add(1, Ordering::SeqCst);
        ckpt.spec.id = id;
        let cancel = ckpt.spec.meta.cancel.clone();
        if !self.table.try_insert(id, return_latent, cancel.clone(), self.max_queue) {
            return self.rejected_handle(id, cancel, RejectReason::QueueFull);
        }
        // weigh the resume like a fresh submit of its policy family —
        // conservative (mid-flight progress isn't discounted), and the
        // per-step decay self-corrects within a few ticks
        ckpt.spec.meta.cost_hint = self
            .est_for_policy(ckpt.spec.policy.name())
            .unwrap_or_else(|| f64::from_bits(self.est_service_ms.load(Ordering::SeqCst)));
        if let Err(e) = self.router.submit_parked(Admission::Parked(ckpt)) {
            let status = JobStatus::Aborted { error: format!("{e:#}") };
            if self.table.finish(id, status, &self.counters) {
                self.groups.note_terminal(id, false);
            }
        }
        JobHandle { id: JobId(id), table: self.table.clone(), cancel, early: None }
    }

    /// Capture a checkpoint image of every in-flight request (see
    /// [`ShardRouter::spill`]) — what a fabric worker ships to its
    /// router at heartbeat boundaries so accepted jobs survive this
    /// process dying.
    pub fn spill(&self) -> Vec<SpilledCheckpoint> {
        self.router.spill()
    }

    /// Expected remaining work per shard in µ-units (see
    /// [`ShardRouter::work_us`]) — the weighted-routing gauge a fabric
    /// worker reports in heartbeat replies.
    pub fn shard_work_us(&self) -> Vec<u64> {
        self.router.work_us()
    }

    /// A handle for a job shed at admission: the rejection is counted
    /// and carried on the handle; the table is never touched (transient
    /// reject records would churn terminal-cap eviction).
    fn rejected_handle(&self, id: u64, cancel: CancelToken, reason: RejectReason) -> JobHandle {
        self.counters.rejected.fetch_add(1, Ordering::SeqCst);
        JobHandle {
            id: JobId(id),
            table: self.table.clone(),
            cancel,
            early: Some(JobStatus::Rejected { reason }),
        }
    }

    /// Status snapshot plus the job's `return_latent` flag (`None` for
    /// unknown/consumed ids).
    pub fn poll(&self, id: u64) -> Option<(JobStatus, bool)> {
        self.table.status(id)
    }

    /// Block until job `id` is terminal (see [`JobTable::wait`]).
    pub fn wait(
        &self,
        id: u64,
        timeout: Option<Duration>,
        consume: bool,
    ) -> Option<(JobStatus, bool)> {
        self.table.wait(id, timeout, consume)
    }

    /// Fire job `id`'s cancel token; returns its status at that instant.
    pub fn cancel(&self, id: u64) -> Option<JobStatus> {
        self.table.cancel(id)
    }

    /// Fire a group's shared cancel token: every member that shares it
    /// is dropped at its next step boundary. Returns whether the group
    /// currently has a live member (unknown/reclaimed ids are a no-op).
    pub fn cancel_group(&self, gid: GroupId) -> bool {
        self.groups.cancel(gid)
    }

    /// Per-group lifecycle counts, ascending by group id. A group's
    /// entry is reclaimed when its last member terminates, so this
    /// reports groups with live members only.
    pub fn group_counts(&self) -> Vec<GroupCounts> {
        self.groups.counts()
    }

    /// Drop job `id`'s record if it is already terminal (see
    /// [`JobTable::forget`]).
    pub fn forget(&self, id: u64) -> bool {
        self.table.forget(id)
    }

    /// Lifecycle counter snapshot.
    pub fn counts(&self) -> JobCounts {
        self.counters.snapshot()
    }

    /// Jobs currently in a non-terminal state.
    pub fn live(&self) -> usize {
        self.table.live()
    }

    /// Requests in flight per shard (`usize::MAX` marks a dead shard).
    pub fn shard_loads(&self) -> Vec<usize> {
        self.router.loads()
    }

    /// Total requests in flight across live shards.
    pub fn inflight(&self) -> usize {
        self.router.inflight()
    }

    /// Number of shards (dead ones included).
    pub fn shards(&self) -> usize {
        self.router.shards()
    }

    /// Merged engine counter snapshot across live shards.
    pub fn stats(&self) -> ShardStats {
        self.router.stats()
    }

    /// Current EWMA of completed-job latency in ms (0 before any
    /// completion) — the signal behind deadline-feasibility admission.
    pub fn est_service_ms(&self) -> f64 {
        f64::from_bits(self.est_service_ms.load(Ordering::SeqCst))
    }

    /// Per-policy-family latency EWMA in ms (`None` before any completion
    /// of that family) — the service-time hint stamped onto submissions
    /// for work-weighted least-loaded routing.
    pub fn est_for_policy(&self, policy: &str) -> Option<f64> {
        self.policy_est_ms.lock().unwrap().get(policy).copied()
    }

    /// Stop the pool (`drain`: finish everything admitted; `!drain`:
    /// abandon it) and join the dispatcher. Every live job reaches a
    /// terminal state before this returns, so blocked `wait`ers always
    /// wake. Safe to call once; later calls error.
    pub fn shutdown(&self, drain: bool) -> Result<JobOutcome> {
        let pool = self.pool.lock().unwrap().take();
        let Some(pool) = pool else { bail!("job manager already shut down") };
        let res = pool.shutdown(drain);
        // workers are joined, so their event senders are gone and the
        // dispatcher's loop ends once it finishes folding the stream
        if let Some(d) = self.dispatcher.lock().unwrap().take() {
            let _ = d.join();
        }
        let out = res?;
        Ok(JobOutcome { stats: out.stats, counts: self.counts() })
    }
}

/// Fold the pool's event stream into table transitions + counters.
/// Group membership retires on the same edge as the table transition
/// ([`JobTable::finish`] returning true), so duplicate terminal events
/// can never double-decrement a group's live count.
fn dispatch_events(
    events: Receiver<JobEvent>,
    table: &JobTable,
    counters: &Counters,
    est_service_ms: &AtomicU64,
    policy_est_ms: &Mutex<HashMap<String, f64>>,
    groups: &GroupRegistry,
) {
    for ev in events.iter() {
        match ev {
            JobEvent::Admitted { id, shard } => {
                table.advance(id, JobStatus::Admitted { shard });
            }
            JobEvent::Progress(p) => {
                let running =
                    JobStatus::Running { step: p.step, accepts: p.accepts, rejects: p.rejects };
                table.advance(p.id, running);
            }
            JobEvent::Completed(c) => {
                let lat = c.stats.latency_ms;
                let prev = f64::from_bits(est_service_ms.load(Ordering::SeqCst));
                let next = if prev <= 0.0 { lat } else { 0.8 * prev + 0.2 * lat };
                est_service_ms.store(next.to_bits(), Ordering::SeqCst);
                {
                    let mut g = policy_est_ms.lock().unwrap();
                    let e = g.entry(c.policy_name.clone()).or_insert(lat);
                    *e = 0.8 * *e + 0.2 * lat;
                }
                let id = c.id;
                if table.finish(id, JobStatus::Completed(Arc::from(c)), counters) {
                    groups.note_terminal(id, true);
                }
            }
            JobEvent::Rejected { id, reason } => {
                if table.finish(id, JobStatus::Rejected { reason }, counters) {
                    groups.note_terminal(id, false);
                }
            }
            JobEvent::Cancelled { id } => {
                if table.finish(id, JobStatus::Cancelled, counters) {
                    groups.note_terminal(id, false);
                }
            }
            JobEvent::Aborted { id, error } => {
                if table.finish(id, JobStatus::Aborted { error }, counters) {
                    groups.note_terminal(id, false);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_parses_and_orders() {
        assert_eq!(Priority::parse("HIGH"), Some(Priority::High));
        assert_eq!(Priority::parse("normal"), Some(Priority::Normal));
        assert_eq!(Priority::parse("Low"), Some(Priority::Low));
        assert_eq!(Priority::parse("urgent"), None);
        assert!(Priority::High > Priority::Normal);
        assert!(Priority::Normal > Priority::Low);
        assert_eq!(Priority::default(), Priority::Normal);
        assert_eq!(Priority::High.index(), 2);
    }

    #[test]
    fn cancel_token_is_shared_across_clones() {
        let t = CancelToken::new();
        assert!(!t.is_shared(), "a lone token can never be fired by anyone else");
        let u = t.clone();
        assert!(t.is_shared() && u.is_shared());
        assert!(!u.is_cancelled());
        t.cancel();
        assert!(u.is_cancelled());
        drop(t);
        assert!(!u.is_shared(), "sharing ends when the other handle drops");
    }

    #[test]
    fn job_meta_deadline_expiry() {
        let now = Instant::now();
        let mut m = JobMeta::default();
        assert!(!m.expired(now), "no deadline never expires");
        m.deadline = Some(now + Duration::from_secs(60));
        assert!(!m.expired(now));
        assert!(m.expired(now + Duration::from_secs(61)));
    }

    #[test]
    fn submit_options_builder_chains() {
        let opts = SubmitOptions::new()
            .priority(Priority::Low)
            .deadline_ms(250)
            .return_latent(true)
            .preemptible(true)
            .adaptive(0.4)
            .lookahead(3)
            .group(GroupId(3));
        assert_eq!(opts.priority, Priority::Low);
        assert_eq!(opts.deadline_ms, Some(250));
        assert!(opts.return_latent && opts.preemptible);
        assert_eq!(opts.adaptive, Some(0.4));
        assert_eq!(opts.lookahead, Some(3));
        assert_eq!(opts.group, Some(GroupId(3)));
        assert_eq!(SubmitOptions::default().adaptive, None);
        assert_eq!(SubmitOptions::default().lookahead, None, "lookahead is opt-in");
        assert!(!SubmitOptions::default().preemptible, "preemption is opt-in");
        assert_eq!(format!("{}", GroupId(3)), "group-3");
    }

    #[test]
    fn group_registry_shares_tokens_and_reclaims() {
        let reg = GroupRegistry::default();
        let t1 = reg.token(GroupId(1));
        let t2 = reg.token(GroupId(1));
        t1.cancel();
        assert!(t2.is_cancelled(), "members share one token");
        reg.note_submit(GroupId(1), 10);
        reg.note_submit(GroupId(1), 11);
        let c = reg.counts();
        assert_eq!(c.len(), 1);
        assert_eq!((c[0].submitted, c[0].live, c[0].completed), (2, 2, 0));
        reg.note_terminal(10, true);
        assert_eq!(reg.counts()[0].completed, 1);
        reg.note_terminal(10, true);
        assert_eq!(reg.counts()[0].completed, 1, "repeat terminal is a no-op");
        reg.note_terminal(11, false);
        assert!(reg.counts().is_empty(), "last terminal reclaims the entry");
        assert!(!reg.cancel(GroupId(1)), "reclaimed group is unknown");
        assert!(!reg.token(GroupId(1)).is_cancelled(), "id reuse mints a fresh token");
    }

    #[test]
    fn table_wait_consume_and_cap() {
        let table = JobTable::new(8);
        let counters = Counters::default();
        assert!(table.try_insert(1, false, CancelToken::new(), 1));
        assert!(!table.try_insert(2, false, CancelToken::new(), 1), "cap holds");
        assert_eq!(table.live(), 1);
        assert!(table.finish(1, JobStatus::Cancelled, &counters));
        assert!(!table.finish(1, JobStatus::Cancelled, &counters), "duplicate terminal dropped");
        assert_eq!(counters.snapshot().cancelled, 1, "duplicates must not double-count");
        assert_eq!(table.live(), 0);
        let (s, _) = table.wait(1, None, true).unwrap();
        assert!(matches!(s, JobStatus::Cancelled));
        assert!(table.status(1).is_none(), "consumed record is gone");
    }

    #[test]
    fn forget_reclaims_only_terminal_records() {
        let table = JobTable::new(8);
        let counters = Counters::default();
        assert!(table.try_insert(1, false, CancelToken::new(), 8));
        assert!(!table.forget(1), "live records must not be reclaimed");
        assert!(table.finish(1, JobStatus::Cancelled, &counters));
        assert!(table.forget(1));
        assert!(table.status(1).is_none());
        assert!(!table.forget(1), "idempotent on missing records");
    }

    #[test]
    fn terminal_records_evict_oldest_beyond_the_cap() {
        let table = JobTable::new(2);
        let counters = Counters::default();
        for id in 0..3u64 {
            assert!(table.try_insert(id, false, CancelToken::new(), 8));
            assert!(table.finish(id, JobStatus::Cancelled, &counters));
        }
        // cap 2: the oldest unconsumed terminal record was evicted
        assert!(table.status(0).is_none(), "oldest terminal record must be evicted");
        assert!(table.status(1).is_some());
        assert!(table.status(2).is_some());
        // consuming one frees headroom for the next terminal record
        assert!(table.wait(1, None, true).is_some());
        assert!(table.try_insert(3, false, CancelToken::new(), 8));
        assert!(table.finish(3, JobStatus::Cancelled, &counters));
        assert!(table.status(2).is_some(), "within cap — nothing evicted");
        assert!(table.status(3).is_some());
    }

    #[test]
    fn table_wait_timeout_returns_nonterminal() {
        let table = JobTable::new(8);
        assert!(table.try_insert(7, true, CancelToken::new(), 8));
        let (s, rl) = table.wait(7, Some(Duration::from_millis(10)), true).unwrap();
        assert!(!s.is_terminal());
        assert!(rl);
        assert!(table.status(7).is_some(), "timeout must not consume");
    }

    #[test]
    fn reject_reason_wire_strings() {
        assert_eq!(RejectReason::QueueFull.to_string(), "queue full");
        assert!(RejectReason::DeadlineExpired.to_string().contains("deadline"));
    }

    #[test]
    fn status_labels() {
        assert_eq!(JobStatus::Queued.label(), "queued");
        assert_eq!(JobStatus::Cancelled.label(), "cancelled");
        assert!(JobStatus::Cancelled.is_terminal());
        assert!(!JobStatus::Running { step: 1, accepts: 0, rejects: 0 }.is_terminal());
        assert_eq!(format!("{}", JobId(4)), "job-4");
    }
}
