//! Experiment runners: one per paper table/figure (see DESIGN.md §6).
//! Shared by the CLI (`speca bench <name>`), `rust/benches/*` and examples.

pub mod runner;
pub mod tables;
