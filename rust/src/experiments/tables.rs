//! Table/figure regenerators (paper §4 + appendices). Each prints the same
//! row structure the paper reports and writes a CSV under `results/`.
//!
//! Absolute numbers differ from the paper (simulated backbones on CPU,
//! DESIGN.md §2); the comparisons to check are the *shapes*: who wins at
//! matched acceleration, where baselines collapse, how α maps to speedup
//! (Eq. 8).
//!
//! Every runner resolves an execution backend through
//! `runtime::resolve` first (DESIGN.md §3): PJRT artifacts when compiled
//! with the `pjrt` feature, a working runtime and `artifacts/` are
//! present, otherwise the seeded zero-artifact native models — so the
//! whole harness runs on a bare checkout (`--backend native|pjrt|auto`
//! overrides, default auto). `--shards N` fans a runner's engine out over
//! the shard pool (native backend only).

use std::io::BufReader;
use std::net::TcpStream;
use std::path::PathBuf;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::cache::{DraftKind, DraftRegistry, TapCache};
use crate::coordinator::policy::{ErrorMetric, Policy};
use crate::fabric;
use crate::metrics::pca::pca2;
use crate::metrics::stats::pearson;
use crate::runtime::resolve::{self, BackendRequest};
use crate::runtime::{ClassifierBackend, ModelBackend, ResolvedModel};
use crate::server::{self, client, ServerConfig};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::parse_policy;

use super::runner::{
    evaluate_quality, latency_hist, run_policy, write_csv, Quality, RunOpts, RunResult,
};

/// Dispatch `speca bench <name>` to its table/figure runner.
pub fn run(args: &Args) -> Result<()> {
    let name = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("table3");
    match name {
        "table1" => table_quality("table1", "flux-sim", TABLE1_ROWS, args),
        "table2" => table_quality("table2", "video-sim", TABLE2_ROWS, args),
        "table3" => table_quality("table3", "dit-sim", TABLE3_ROWS, args),
        "table4" => table_sweep("table4", args, SweepKind::Beta),
        "table5" => table_sweep("table5", args, SweepKind::Tau0),
        "table6" => table6(args),
        "table7" => table7(args),
        "table8" => table8(args),
        "drafts" => drafts_table(args),
        "adaptive" => adaptive_bench(args),
        "lookahead" => lookahead_bench(args),
        "serve-openloop" => serve_openloop(args),
        "fig2" => fig2(args),
        "fig6" => fig6(args),
        "fig8" => fig8(args),
        "fig9" => fig9(args),
        "speedup-law" => speedup_law(args),
        _ => bail!("unknown bench '{name}' (see `speca help`)"),
    }
}

/// Path of a CSV artifact under `results/`.
pub fn results_path(file: &str) -> PathBuf {
    PathBuf::from("results").join(file)
}

/// Resolve a model + classifier backend pair and run `f` against it
/// (the shared resolver with the runner's pinned model name).
fn with_backends<R>(
    model_name: &str,
    args: &Args,
    f: impl FnOnce(&ResolvedModel<'_>, &dyn ClassifierBackend) -> Result<R>,
) -> Result<R> {
    let req = BackendRequest::from_args(args).with_model(model_name);
    resolve::with_backends(&req, |model, cls| f(&model, cls))
}

/// Model-only variant for the figure runners that need no classifier.
fn with_model<R>(
    model_name: &str,
    args: &Args,
    f: impl FnOnce(&ResolvedModel<'_>) -> Result<R>,
) -> Result<R> {
    with_backends(model_name, args, |model, _cls| f(model))
}

fn sample_count(args: &Args, default: usize) -> usize {
    if args.bool("quick") {
        (default / 4).max(8)
    } else {
        args.usize("n", default)
    }
}

/// One measured row of a quality table.
pub struct Row {
    /// Row label.
    pub label: String,
    /// Draft strategy the run predicted with (`-` for non-draft policies).
    pub draft: String,
    /// Median request latency (ms).
    pub latency_ms: f64,
    /// Total booked GFLOPs across the run.
    pub gflops_total: f64,
    /// FLOPs acceleration vs full computation of every step.
    pub speed: f64,
    /// Measured acceptance rate α.
    pub alpha: f64,
    /// Measured verification cost ratio γ.
    pub gamma: f64,
    /// Verification rejections across the run.
    pub rejects: u64,
    /// Mean relative error observed at verification (over every entry of
    /// every request's verify trace; 0 when nothing was verified). In the
    /// policy's verification metric — run with `metric=l1` for rel-L1.
    pub verify_err: f64,
    /// Quality metrics vs the matching-seed full-compute reference.
    pub q: Quality,
}

/// Run one policy row and evaluate every reported metric against the
/// shared full-compute reference run.
pub fn eval_row(
    model: &ResolvedModel<'_>,
    cls: &dyn ClassifierBackend,
    reference: &RunResult,
    desc: &str,
    label: &str,
    opts: &RunOpts,
) -> Result<Row> {
    let policy = parse_policy(desc, model.entry().config.depth)?;
    let run = run_policy(model, &policy, label, opts)?;
    let q = evaluate_quality(&run, reference, &model.entry().config, cls)?;
    let mut lat = latency_hist(&run);
    let full1 = model.entry().flops.full_step[&1];
    let steps = model.entry().config.serve_steps;
    let ideal = (opts.n * steps) as u64 * full1;
    let (mut err_sum, mut err_n) = (0.0f64, 0usize);
    for c in run.completions_by_id.values() {
        for (_, e, _) in &c.stats.verify_trace {
            err_sum += *e;
            err_n += 1;
        }
    }
    let draft = run
        .completions_by_id
        .values()
        .next()
        .map(|c| c.draft_name.clone())
        .unwrap_or_else(|| "-".to_string());
    Ok(Row {
        label: label.to_string(),
        draft,
        latency_ms: lat.percentile(0.5),
        gflops_total: run.flops.total() as f64 / 1e9,
        speed: ideal as f64 / run.flops.total().max(1) as f64,
        alpha: run.flops.acceptance_rate(),
        gamma: run.flops.gamma(),
        rejects: run.flops.n_rejects,
        verify_err: if err_n > 0 { err_sum / err_n as f64 } else { 0.0 },
        q,
    })
}

/// Policy rows mirroring paper Table 1 (FLUX / flux-sim).
const TABLE1_ROWS: &[(&str, &str)] = &[
    ("full (reference)", "full"),
    ("60% steps", "steps:keep=30"),
    ("50% steps", "steps:keep=25"),
    ("34% steps", "steps:keep=17"),
    ("FORA N=6", "fora:N=6"),
    ("ToCa N=8 R=0.9", "toca:N=8,R=0.9"),
    ("DuCa N=8 R=0.7", "duca:N=8,R=0.7"),
    ("TeaCache l=0.8", "teacache:l=0.8"),
    ("TaylorSeer N=5 O=2", "taylorseer:N=5,O=2"),
    ("SpeCa N=5 O=2 t0=.3", "speca:N=5,O=2,tau0=0.3,beta=0.05"),
    ("FORA N=7", "fora:N=7"),
    ("ToCa N=10 R=0.9", "toca:N=10,R=0.9"),
    ("DuCa N=9 R=0.9", "duca:N=9,R=0.9"),
    ("TeaCache l=1.2", "teacache:l=1.2"),
    ("TaylorSeer N=7 O=2", "taylorseer:N=7,O=2"),
    ("SpeCa N=7 O=2 t0=.4", "speca:N=7,O=2,tau0=0.4,beta=0.05"),
    ("FORA N=9", "fora:N=9"),
    ("ToCa N=12 R=0.9", "toca:N=12,R=0.9"),
    ("DuCa N=12 R=0.8", "duca:N=12,R=0.8"),
    ("TeaCache l=1.4", "teacache:l=1.4"),
    ("TaylorSeer N=9 O=2", "taylorseer:N=9,O=2"),
    ("SpeCa N=9 O=2 t0=.5", "speca:N=9,O=2,tau0=0.5,beta=0.05"),
];

/// Paper Table 2 (HunyuanVideo / video-sim).
const TABLE2_ROWS: &[(&str, &str)] = &[
    ("full (reference)", "full"),
    ("22% steps", "steps:keep=11"),
    ("TeaCache l=1.0", "teacache:l=1.0"),
    ("FORA N=5", "fora:N=5"),
    ("ToCa N=5 R=0.9", "toca:N=5,R=0.9"),
    ("DuCa N=5 R=0.9", "duca:N=5,R=0.9"),
    ("TeaCache l=1.3", "teacache:l=1.3"),
    ("TaylorSeer N=5 O=1", "taylorseer:N=5,O=1"),
    ("SpeCa N=5 O=1 t0=.6", "speca:N=5,O=1,tau0=0.6,beta=0.1"),
    ("TaylorSeer N=6 O=1", "taylorseer:N=6,O=1"),
    ("SpeCa N=6 O=1 t0=.8", "speca:N=6,O=1,tau0=0.8,beta=0.1"),
];

/// Paper Table 3 (DiT-XL/2 / dit-sim).
const TABLE3_ROWS: &[(&str, &str)] = &[
    ("DDIM-50 (reference)", "full"),
    ("DDIM-25", "steps:keep=25"),
    ("DDIM-12", "steps:keep=12"),
    ("DDIM-10", "steps:keep=10"),
    ("DDIM-8", "steps:keep=8"),
    ("DDIM-7", "steps:keep=7"),
    ("FORA N=6", "fora:N=6"),
    ("ToCa N=9 R=0.95", "toca:N=9,R=0.95"),
    ("DuCa N=6 R=0.95", "duca:N=6,R=0.95"),
    ("TaylorSeer N=6 O=4", "taylorseer:N=6,O=4"),
    ("SpeCa ~5x", "speca:N=6,O=2,tau0=0.3,beta=0.05"),
    ("FORA N=7", "fora:N=7"),
    ("ToCa N=13 R=0.95", "toca:N=13,R=0.95"),
    ("DuCa N=12 R=0.95", "duca:N=12,R=0.95"),
    ("TaylorSeer N=8 O=4", "taylorseer:N=8,O=4"),
    ("SpeCa ~6.8x", "speca:N=8,O=2,tau0=0.5,beta=0.05"),
    ("FORA N=8", "fora:N=8"),
    ("ToCa N=13 R=0.98", "toca:N=13,R=0.98"),
    ("DuCa N=18 R=0.95", "duca:N=18,R=0.95"),
    ("TaylorSeer N=9 O=4", "taylorseer:N=9,O=4"),
    ("SpeCa N=12 t0=.5", "speca:N=12,O=2,tau0=0.5,beta=0.05"),
    ("SpeCa N=16 t0=.8", "speca:N=16,O=2,tau0=0.8,beta=0.05"),
    ("SpeCa N=16 t0=1.2", "speca:N=16,O=2,tau0=1.2,beta=0.05"),
];

fn table_quality(
    name: &str,
    model_name: &str,
    rows: &[(&str, &str)],
    args: &Args,
) -> Result<()> {
    with_backends(model_name, args, |model, cls| {
        let entry = model.entry();
        let n = sample_count(args, 48);
        let opts = RunOpts::from_args(args, n)?;
        let video = entry.config.frames > 1;

        println!("== {name} ({model_name} on {}, n={n} samples/policy) ==", model.kind());
        let reference =
            run_policy(model, &parse_policy("full", entry.config.depth)?, "full", &opts)?;

        let hdr = if video {
            format!(
                "{:<22} {:>8} {:>9} {:>7} {:>7} {:>8} {:>8} {:>8}",
                "method", "lat ms", "GFLOPs", "speed", "VBench*", "fid*", "alpha", "rejects"
            )
        } else {
            format!(
                "{:<22} {:>8} {:>9} {:>7} {:>8} {:>8} {:>8} {:>8} {:>7}",
                "method", "lat ms", "GFLOPs", "speed", "FID*", "sFID*", "IS*", "ImgRwd*", "GenEv*"
            )
        };
        println!("{hdr}");
        let mut csv = Vec::new();
        for (label, desc) in rows {
            let row = eval_row(model, cls, &reference, desc, label, &opts)?;
            if video {
                println!(
                    "{:<22} {:>8.1} {:>9.3} {:>6.2}x {:>7.2} {:>8.4} {:>8.3} {:>8}",
                    row.label,
                    row.latency_ms,
                    row.gflops_total,
                    row.speed,
                    row.q.vbench,
                    row.q.fidelity,
                    row.alpha,
                    row.rejects
                );
            } else {
                println!(
                    "{:<22} {:>8.1} {:>9.3} {:>6.2}x {:>8.3} {:>8.3} {:>8.2} {:>8.4} {:>7.3}",
                    row.label,
                    row.latency_ms,
                    row.gflops_total,
                    row.speed,
                    row.q.fid,
                    row.q.sfid,
                    row.q.is,
                    row.q.fidelity,
                    row.q.agreement
                );
            }
            csv.push(format!(
                "{},{},{:.2},{:.4},{:.3},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4},{}",
                row.label.replace(',', ";"),
                desc.replace(',', ";"),
                row.latency_ms,
                row.gflops_total,
                row.speed,
                row.q.fid,
                row.q.sfid,
                row.q.is,
                row.q.fidelity,
                row.q.agreement,
                row.q.vbench,
                row.alpha,
                row.rejects
            ));
        }
        write_csv(
            &results_path(&format!("{name}.csv")),
            "label,policy,latency_ms,gflops,speed,fid,sfid,is,fidelity,agreement,vbench,alpha,rejects",
            &csv,
        )?;
        println!("wrote results/{name}.csv");
        Ok(())
    })
}

enum SweepKind {
    Beta,
    Tau0,
}

/// Tables 4 & 5: β / τ0 ablations on dit-sim at N=12, O=2.
fn table_sweep(name: &str, args: &Args, kind: SweepKind) -> Result<()> {
    with_backends("dit-sim", args, |model, cls| {
        let entry = model.entry();
        let n = sample_count(args, 48);
        let opts = RunOpts::from_args(args, n)?;

        let reference =
            run_policy(model, &parse_policy("full", entry.config.depth)?, "full", &opts)?;

        let (title, grid): (&str, Vec<(String, String)>) = match kind {
            SweepKind::Beta => (
                "decay rate β (τ0=0.5)",
                [0.12, 0.10, 0.08, 0.05, 0.03, 0.01]
                    .iter()
                    .map(|b| {
                        (format!("beta={b}"), format!("speca:N=12,O=2,tau0=0.5,beta={b}"))
                    })
                    .collect(),
            ),
            SweepKind::Tau0 => (
                "base threshold τ0 (β=0.05)",
                [0.1, 0.3, 0.5, 0.8, 1.0, 1.2]
                    .iter()
                    .map(|t| {
                        (format!("tau0={t}"), format!("speca:N=12,O=2,tau0={t},beta=0.05"))
                    })
                    .collect(),
            ),
        };
        println!("== {name}: {title} (n={n}) ==");
        println!(
            "{:<12} {:>9} {:>7} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
            "param", "GFLOPs", "speed", "FID*", "sFID*", "IS*", "ImgRwd*", "alpha", "rejects"
        );
        let mut csv = Vec::new();
        for (label, desc) in &grid {
            let row = eval_row(model, cls, &reference, desc, label, &opts)?;
            println!(
                "{:<12} {:>9.3} {:>6.2}x {:>8.3} {:>8.3} {:>8.2} {:>8.4} {:>8.3} {:>8}",
                row.label, row.gflops_total, row.speed, row.q.fid, row.q.sfid, row.q.is,
                row.q.fidelity, row.alpha, row.rejects
            );
            csv.push(format!(
                "{},{:.4},{:.3},{:.4},{:.4},{:.4},{:.4},{:.4},{}",
                row.label, row.gflops_total, row.speed, row.q.fid, row.q.sfid, row.q.is,
                row.q.fidelity, row.alpha, row.rejects
            ));
        }
        write_csv(
            &results_path(&format!("{name}.csv")),
            "param,gflops,speed,fid,sfid,is,fidelity,alpha,rejects",
            &csv,
        )?;
        println!("wrote results/{name}.csv");
        Ok(())
    })
}

/// Table 6: verification-layer ablation at ~5× on dit-sim.
fn table6(args: &Args) -> Result<()> {
    with_backends("dit-sim", args, |model, cls| {
        let n = sample_count(args, 48);
        let opts = RunOpts::from_args(args, n)?;
        let depth = model.entry().config.depth;

        let reference = run_policy(model, &parse_policy("full", depth)?, "full", &opts)?;
        let layers = [0usize, depth / 4, 2 * depth / 3, depth - 1];
        println!("== table6: verify-layer ablation (depth={depth}, n={n}) ==");
        println!(
            "{:<16} {:>8} {:>8} {:>8} {:>7} {:>8}",
            "verify layer", "FID*", "sFID*", "IS*", "speed", "rejects"
        );
        let mut csv = Vec::new();
        for v in layers {
            let desc = format!("speca:N=6,O=2,tau0=0.3,beta=0.05,layer={v}");
            let label = if v == depth - 1 {
                format!("layer{v} (last)")
            } else if v == 0 {
                "layer0 (first)".to_string()
            } else {
                format!("layer{v}")
            };
            let row = eval_row(model, cls, &reference, &desc, &label, &opts)?;
            println!(
                "{:<16} {:>8.3} {:>8.3} {:>8.2} {:>6.2}x {:>8}",
                row.label, row.q.fid, row.q.sfid, row.q.is, row.speed, row.rejects
            );
            csv.push(format!(
                "{v},{:.4},{:.4},{:.4},{:.3},{}",
                row.q.fid, row.q.sfid, row.q.is, row.speed, row.rejects
            ));
        }
        write_csv(&results_path("table6.csv"), "layer,fid,sfid,is,speed,rejects", &csv)?;
        println!("wrote results/table6.csv");
        Ok(())
    })
}

/// Table 7: draft-model ablation on flux-sim (reuse / AB / Taylor, ±verify).
fn table7(args: &Args) -> Result<()> {
    if args.opt("draft").is_some() {
        // the global --draft override (RunOpts) would silently replace
        // every row's explicit draft= key and mislabel the ablation —
        // same guard as `bench drafts`
        bail!("table7 is the draft-model ablation; drop --draft");
    }
    let rows: &[(&str, &str)] = &[
        ("AB (w/o SpeCa)", "taylorseer:N=5,O=1"),
        ("SpeCa (reuse draft)", "speca:N=5,O=2,tau0=0.3,beta=0.05,draft=reuse"),
        ("SpeCa (Adams-Bashforth)", "speca:N=5,O=2,tau0=0.3,beta=0.05,draft=adams"),
        ("SpeCa (TaylorSeer)", "speca:N=5,O=2,tau0=0.3,beta=0.05,draft=taylor"),
    ];
    small_flux_table("table7", "draft-model ablation", rows, args)
}

/// Table 8: verification error-metric ablation on flux-sim.
fn table8(args: &Args) -> Result<()> {
    let rows: &[(&str, &str)] = &[
        ("cosine", "speca:N=5,O=2,tau0=0.12,beta=0.05,metric=cos"),
        ("l1", "speca:N=5,O=2,tau0=0.3,beta=0.05,metric=l1"),
        ("l2", "speca:N=5,O=2,tau0=0.3,beta=0.05,metric=l2"),
        ("linf", "speca:N=5,O=2,tau0=0.6,beta=0.05,metric=linf"),
    ];
    small_flux_table("table8", "error-metric ablation", rows, args)
}

/// Draft-strategy comparison (EXPERIMENTS.md §Drafts): sweep every
/// strategy in [`DraftRegistry::global`] under one SpeCa operating point
/// on the native backend and report acceptance rate, the mean relative
/// L1 error observed at verification (`metric=l1`, so the verify trace
/// *is* rel-L1), FLOPs saved vs full compute, and quality. Rows are
/// generated from the registry, so a newly registered strategy shows up
/// without touching this runner.
fn drafts_table(args: &Args) -> Result<()> {
    if args.opt("draft").is_some() {
        // RunOpts::from_args would thread --draft into every run_policy
        // call, collapsing every registry row onto one strategy — reject
        // it rather than emit a table that silently compares X with itself
        bail!("`bench drafts` sweeps every registered strategy; drop --draft");
    }
    with_backends("dit-sim", args, |model, cls| {
        let n = sample_count(args, 32);
        let opts = RunOpts::from_args(args, n)?;
        let depth = model.entry().config.depth;
        let reference = run_policy(model, &parse_policy("full", depth)?, "full", &opts)?;
        let point = "N=6,O=2,tau0=0.3,beta=0.05,metric=l1";
        println!("== drafts: strategy comparison (dit-sim, speca:{point}, n={n}) ==");
        println!(
            "{:<18} {:>7} {:>10} {:>8} {:>9} {:>8} {:>8} {:>8}",
            "draft", "alpha", "relL1@ver", "rejects", "GFLOPs", "saved", "speed", "FID*"
        );
        let mut csv = Vec::new();
        for name in DraftRegistry::global().names() {
            let desc = format!("speca:{point},draft={name}");
            let row = eval_row(model, cls, &reference, &desc, name, &opts)?;
            let saved = 1.0 - 1.0 / row.speed.max(1e-9);
            println!(
                "{:<18} {:>7.3} {:>10.4} {:>8} {:>9.3} {:>7.1}% {:>7.2}x {:>8.3}",
                row.draft,
                row.alpha,
                row.verify_err,
                row.rejects,
                row.gflops_total,
                saved * 100.0,
                row.speed,
                row.q.fid
            );
            csv.push(format!(
                "{},{:.4},{:.5},{},{:.4},{:.4},{:.3},{:.4},{:.4}",
                row.draft,
                row.alpha,
                row.verify_err,
                row.rejects,
                row.gflops_total,
                saved,
                row.speed,
                row.q.fid,
                row.q.fidelity
            ));
        }
        write_csv(
            &results_path("drafts.csv"),
            "draft,alpha,rel_l1_at_verify,rejects,gflops,flops_saved,speed,fid,fidelity",
            &csv,
        )?;
        println!("wrote results/drafts.csv");
        Ok(())
    })
}

/// Sample-adaptive allocation sweep (EXPERIMENTS.md §Adaptive): run the
/// scripted-drift backend ([`crate::workload::scripted::ScriptedBackend`])
/// at three difficulty buckets — easy/medium/hard per-step rel-L1 drift —
/// under a sweep of `adaptive=` error budgets, and report FLOPs saved vs
/// full compute together with the *realized* rel-L1 latent error against
/// a dense run of the same scripts, to `results/adaptive.csv`. The shape
/// to check: at a fixed budget, harder buckets burn the budget sooner and
/// fall back to dense (lower `flops_saved`, bounded `rel_l1`), while the
/// static-threshold columns of `bench drafts`/`table4` have no such knob.
fn adaptive_bench(args: &Args) -> Result<()> {
    use crate::workload::scripted::ScriptedBackend;

    let quick = args.bool("quick");
    let n = if quick { 4 } else { args.usize("n", 16) };
    let budgets: &[f64] = if quick { &[0.1, 1.0] } else { &[0.05, 0.2, 0.5, 1.0, 2.0] };
    let cfg = crate::config::ModelConfig::native_test();
    let depth = cfg.depth;
    let steps = cfg.serve_steps;
    let buckets: &[(&str, &[f32])] = &[("easy", &[0.0005]), ("medium", &[0.05]), ("hard", &[0.5])];
    println!("== adaptive: budget sweep over scripted difficulty buckets (n={n}) ==");
    println!(
        "{:<8} {:>7} {:>8} {:>9} {:>7} {:>6} {:>6} {:>8}",
        "bucket", "budget", "saved", "rel_l1", "alpha", "full", "spec", "rejects"
    );
    let mut csv = Vec::new();
    for (label, drift) in buckets {
        let model = ScriptedBackend::new(cfg.clone(), drift);
        let full_flops = crate::metrics::flops::FlopsModel::new(model.entry().flops.clone())
            .full_step_flops();
        let dense = run_scripted(&model, &parse_policy("full", depth)?, n)?;
        for &budget in budgets {
            let base = "speca:N=4,O=1,tau0=0.3,beta=0.05,draft=reuse,metric=l1";
            let desc = format!("{base},adaptive={budget}");
            let done = run_scripted(&model, &parse_policy(&desc, depth)?, n)?;
            let mut saved = 0.0;
            let mut rel_l1 = 0.0;
            let mut alpha = 0.0;
            let (mut fulls, mut specs, mut rejects) = (0u64, 0u64, 0u64);
            for (c, d) in done.iter().zip(&dense) {
                debug_assert_eq!(c.id, d.id);
                saved += 1.0 - 1.0 / c.stats.speedup(full_flops, steps).max(1e-9);
                let num: f64 = c
                    .latent
                    .iter()
                    .zip(&d.latent)
                    .map(|(a, b)| (*a as f64 - *b as f64).abs())
                    .sum();
                let den: f64 = d.latent.iter().map(|v| (*v as f64).abs()).sum();
                rel_l1 += num / (den + 1e-8);
                alpha += c.stats.flops.acceptance_rate();
                fulls += c.stats.full_steps as u64;
                specs += c.stats.spec_steps as u64;
                rejects += c.stats.rejects as u64;
            }
            let inv = 1.0 / n as f64;
            let (saved, rel_l1, alpha) = (saved * inv, rel_l1 * inv, alpha * inv);
            println!(
                "{:<8} {:>7.2} {:>7.1}% {:>9.5} {:>7.3} {:>6} {:>6} {:>8}",
                label,
                budget,
                saved * 100.0,
                rel_l1,
                alpha,
                fulls,
                specs,
                rejects
            );
            csv.push(format!(
                "{label},{budget},{saved:.5},{rel_l1:.6},{alpha:.4},{fulls},{specs},{rejects}"
            ));
        }
    }
    write_csv(
        &results_path("adaptive.csv"),
        "bucket,budget,flops_saved,rel_l1,alpha,full_steps,spec_steps,rejects",
        &csv,
    )?;
    println!("wrote results/adaptive.csv");
    Ok(())
}

/// Lookahead-k sweep (EXPERIMENTS.md §Lookahead): run the scripted-drift
/// backend at an easy and a hard difficulty bucket under every
/// combination of lookahead cap k and draft strategy (reuse, taylor,
/// spectral), and report FLOPs saved vs full compute, realized rel-L1
/// against a dense run of the same scripts, and the accepted-prefix-
/// length histogram (column `pj` = verify events that ratified exactly j
/// steps), to `results/lookahead.csv`. The shapes to check: on the easy
/// bucket `flops_saved` grows monotonically in k (fewer verify blocks
/// for the same speculated steps, every run fully ratified → mass in the
/// top histogram bucket), while the hard bucket's mass collapses onto
/// the short-prefix buckets and saved stays flat — lookahead only pays
/// where the drift lets runs survive.
fn lookahead_bench(args: &Args) -> Result<()> {
    use crate::workload::scripted::ScriptedBackend;

    const KMAX: usize = 6;
    let quick = args.bool("quick");
    let n = if quick { 4 } else { args.usize("n", 16) };
    let ks: &[usize] = if quick { &[1, 2, 4] } else { &[1, 2, 3, 4, 6] };
    let drafts = ["reuse", "taylor", "spectral"];
    let cfg = crate::config::ModelConfig::native_test();
    let depth = cfg.depth;
    let steps = cfg.serve_steps;
    let buckets: &[(&str, &[f32])] = &[("easy", &[0.0005]), ("hard", &[0.5])];
    println!("== lookahead: k × draft sweep over scripted difficulty buckets (n={n}) ==");
    println!(
        "{:<6} {:<10} {:>3} {:>8} {:>9} {:>7} {:>6} {:>6} {:>8}  prefix hist p0..p{KMAX}",
        "bucket", "draft", "k", "saved", "rel_l1", "alpha", "full", "spec", "rejects"
    );
    let mut csv = Vec::new();
    for (label, drift) in buckets {
        let model = ScriptedBackend::new(cfg.clone(), drift);
        let full_flops = crate::metrics::flops::FlopsModel::new(model.entry().flops.clone())
            .full_step_flops();
        let dense = run_scripted(&model, &parse_policy("full", depth)?, n)?;
        for draft in drafts {
            for &k in ks {
                let desc = format!(
                    "speca:N=8,O=1,tau0=0.3,beta=1,draft={draft},metric=l1,lookahead={k}"
                );
                let done = run_scripted(&model, &parse_policy(&desc, depth)?, n)?;
                let mut saved = 0.0;
                let mut rel_l1 = 0.0;
                let mut alpha = 0.0;
                let (mut fulls, mut specs, mut rejects) = (0u64, 0u64, 0u64);
                let mut hist = [0u64; KMAX + 1];
                for (c, d) in done.iter().zip(&dense) {
                    debug_assert_eq!(c.id, d.id);
                    saved += 1.0 - 1.0 / c.stats.speedup(full_flops, steps).max(1e-9);
                    let num: f64 = c
                        .latent
                        .iter()
                        .zip(&d.latent)
                        .map(|(a, b)| (*a as f64 - *b as f64).abs())
                        .sum();
                    let den: f64 = d.latent.iter().map(|v| (*v as f64).abs()).sum();
                    rel_l1 += num / (den + 1e-8);
                    alpha += c.stats.flops.acceptance_rate();
                    fulls += c.stats.full_steps as u64;
                    specs += c.stats.spec_steps as u64;
                    rejects += c.stats.rejects as u64;
                    for (j, h) in c.stats.prefix_hist.iter().enumerate() {
                        hist[j.min(KMAX)] += h;
                    }
                }
                let inv = 1.0 / n as f64;
                let (saved, rel_l1, alpha) = (saved * inv, rel_l1 * inv, alpha * inv);
                let hist_cols =
                    hist.iter().map(|h| h.to_string()).collect::<Vec<_>>().join(",");
                println!(
                    "{:<6} {:<10} {:>3} {:>7.1}% {:>9.5} {:>7.3} {:>6} {:>6} {:>8}  [{}]",
                    label,
                    draft,
                    k,
                    saved * 100.0,
                    rel_l1,
                    alpha,
                    fulls,
                    specs,
                    rejects,
                    hist_cols
                );
                csv.push(format!(
                    "{label},{draft},{k},{saved:.5},{rel_l1:.6},{alpha:.4},{fulls},{specs},\
                     {rejects},{hist_cols}"
                ));
            }
        }
    }
    write_csv(
        &results_path("lookahead.csv"),
        "bucket,draft,k,flops_saved,rel_l1,alpha,full_steps,spec_steps,rejects,\
         p0,p1,p2,p3,p4,p5,p6",
        &csv,
    )?;
    println!("wrote results/lookahead.csv");
    Ok(())
}

/// Run one closed-loop batch on an engine over `model`, completions
/// sorted by request id (the scripted runs this serves are matched
/// pairwise against a dense reference on the same seeds).
fn run_scripted(
    model: &crate::workload::scripted::ScriptedBackend,
    policy: &Policy,
    n: usize,
) -> Result<Vec<crate::coordinator::state::Completion>> {
    use crate::coordinator::{Engine, EngineConfig};

    let num_classes = model.entry().config.num_classes;
    let mut engine =
        Engine::from_ref(model, EngineConfig { max_inflight: n, ..EngineConfig::default() });
    for req in crate::workload::batch_requests(n, num_classes, policy, 7, false) {
        engine.submit(req);
    }
    let mut done = engine.run_to_completion()?;
    done.sort_by_key(|c| c.id);
    Ok(done)
}

/// Open-loop serving bench (EXPERIMENTS.md §Open-loop): spin up the
/// sharded server in-process, calibrate per-request service time with a
/// few closed-loop generates, then sweep Poisson arrival rates as
/// multiples of the measured capacity, recording queueing-inclusive
/// p50/p99/p999 latency and the rejection rate (deadline shedding +
/// queue-full) per rate to `results/openloop.csv`. Rejection rising and
/// tail latency staying bounded as offered load passes capacity is the
/// behaviour the job-lifecycle admission rules exist to produce. Each
/// row also records the checkpoint-machinery counters (`parked`,
/// `resumed`, `stolen`, `migrated`; DESIGN.md §13) differenced across
/// the rate's window, so preemption and work-stealing activity under
/// overload is visible in the same table.
fn serve_openloop(args: &Args) -> Result<()> {
    if args.opt("workers").is_some() {
        return serve_openloop_fabric(args);
    }
    with_model(&args.str("model", "dit-sim"), args, |model| {
        let Some(shared) = model.shared() else {
            bail!("serve-openloop needs a Send + Sync backend (use --backend native)");
        };
        let quick = args.bool("quick");
        let shards = args.usize("shards", 2);
        let addr = args.str("addr", "127.0.0.1:17452");
        let opts = RunOpts::from_args(args, 0)?;
        let policy = args.str("policy", "speca:N=5,O=2,tau0=0.3,beta=0.05");

        let server_cfg = ServerConfig {
            addr: addr.clone(),
            max_queue: args.usize("max-queue", 256),
            shards,
            router: opts.router,
            default_draft: opts.draft.clone(),
        };
        let engine_cfg = opts.engine_config();
        let srv = thread::spawn(move || {
            server::serve_sharded(shared, engine_cfg, &server_cfg).map_err(|e| format!("{e:#}"))
        });

        // everything that talks to the server runs inside this closure,
        // so the shutdown + join below execute on every exit path — an
        // early `?` must not leak the listening server thread
        let mut csv = Vec::new();
        let sweep = |csv: &mut Vec<String>| -> Result<()> {
            // wait for the listener, then calibrate the service time
            let mut stream = None;
            for _ in 0..200 {
                match TcpStream::connect(&addr) {
                    Ok(s) => {
                        stream = Some(s);
                        break;
                    }
                    Err(_) => thread::sleep(Duration::from_millis(25)),
                }
            }
            let Some(mut stream) = stream else { bail!("server did not come up at {addr}") };
            let mut reader = BufReader::new(stream.try_clone()?);
            let calib = if quick { 2u64 } else { 4 };
            let t0 = Instant::now();
            for i in 0..calib {
                client::generate_once(&mut stream, &mut reader, 0, 9_000 + i, &policy)?;
            }
            let service_s = t0.elapsed().as_secs_f64() / calib as f64;
            let capacity = shards as f64 / service_s.max(1e-6);

            let mults: Vec<f64> = match args.opt("rates") {
                Some(list) => {
                    let mut v = Vec::new();
                    for s in list.split(',').filter(|s| !s.is_empty()) {
                        let Ok(m) = s.trim().parse::<f64>() else {
                            bail!("--rates expects comma-separated capacity multiples, got '{s}'");
                        };
                        if m <= 0.0 || !m.is_finite() {
                            bail!("--rates multiples must be positive and finite, got '{s}'");
                        }
                        v.push(m);
                    }
                    v
                }
                None if quick => vec![0.5, 2.0],
                None => vec![0.25, 0.5, 1.0, 2.0, 4.0],
            };
            let n = sample_count(args, 48);
            // default deadline: 8 service times — generous at low load,
            // infeasible once the backlog grows, so shedding is observable
            let deadline_ms = if args.opt("deadline-ms").is_some() {
                args.u64("deadline-ms", 0)
            } else {
                ((8.0 * service_s * 1e3).ceil() as u64).max(1)
            };

            println!(
                "== serve-openloop: {shards} shard(s), service≈{:.1} ms, capacity≈{:.2} req/s, \
                 deadline={deadline_ms} ms, n={n} per rate ==",
                service_s * 1e3,
                capacity
            );
            println!(
                "{:<8} {:>9} {:>9} {:>6} {:>6} {:>6} {:>9} {:>9} {:>9} {:>9} {:>5} {:>5} {:>5} \
                 {:>5}",
                "load", "offered", "achieved", "done", "rej", "abrt", "p50 ms", "p99 ms",
                "p999 ms", "rej-rate", "park", "resum", "steal", "migr"
            );
            // checkpoint counters (DESIGN.md §13) are cumulative on the
            // server; difference them across each rate's window
            let ckpt = |j: &Json| -> (u64, u64, u64, u64) {
                let g = |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                (g("parked"), g("resumed"), g("stolen"), g("migrated"))
            };
            for m in &mults {
                let cfg = client::OpenLoopConfig {
                    addr: addr.clone(),
                    rate: capacity * m,
                    requests: n,
                    policy: policy.clone(),
                    num_classes: 8,
                    seed: args.u64("seed", 0) + (m * 1000.0) as u64,
                    deadline_ms: Some(deadline_ms),
                    priority: None,
                    waiters: 8,
                };
                let before = ckpt(&client::stats(&addr)?);
                let mut r = client::run_open_loop(&cfg)?;
                let after = ckpt(&client::stats(&addr)?);
                let (parked, resumed) = (after.0 - before.0, after.1 - before.1);
                let (stolen, migrated) = (after.2 - before.2, after.3 - before.3);
                let p50 = r.latency.percentile(0.5);
                let p99 = r.latency.percentile(0.99);
                // a p999 over < 1000 samples is just the sample max — leave
                // the column blank rather than report an unsupported stat
                let p999 = if r.completed >= 1000 {
                    format!("{:.3}", r.latency.percentile(0.999))
                } else {
                    String::new()
                };
                println!(
                    "{:<8} {:>9.2} {:>9.2} {:>6} {:>6} {:>6} {:>9.1} {:>9.1} {:>9} {:>9.3} \
                     {:>5} {:>5} {:>5} {:>5}",
                    format!("{m}x"),
                    r.offered_rps,
                    r.achieved_rps,
                    r.completed,
                    r.rejected,
                    r.aborted,
                    p50,
                    p99,
                    if p999.is_empty() { "-".to_string() } else { p999.clone() },
                    r.reject_rate(),
                    parked,
                    resumed,
                    stolen,
                    migrated
                );
                csv.push(format!(
                    "{m},{:.4},{:.4},{},{},{},{},{:.3},{:.3},{p999},{:.5},{parked},{resumed},\
                     {stolen},{migrated}",
                    r.offered_rps,
                    r.achieved_rps,
                    r.submitted,
                    r.completed,
                    r.rejected,
                    r.aborted,
                    p50,
                    p99,
                    r.reject_rate()
                ));
            }
            Ok(())
        };
        let outcome = sweep(&mut csv);
        client::shutdown(&addr);
        match srv.join() {
            Ok(res) => {
                res.map_err(|e| anyhow::anyhow!("server error: {e}"))?;
            }
            Err(_) => bail!("server thread panicked"),
        }
        outcome?;
        write_csv(
            &results_path("openloop.csv"),
            "load_mult,offered_rps,achieved_rps,submitted,completed,rejected,aborted,\
             p50_ms,p99_ms,p999_ms,reject_rate,parked,resumed,stolen,migrated",
            &csv,
        )?;
        println!("wrote results/openloop.csv");
        Ok(())
    })
}

/// `bench serve-openloop --workers N` (EXPERIMENTS.md §Fabric): spawn
/// the whole fabric locally — a router plus `w` worker pools joined
/// over loopback TCP — for each worker count `w` in `1..=N`, calibrate
/// per-request service time through the router, drive the same
/// open-loop Poisson load at multiples of the fabric's nominal capacity
/// (`w × shards / service`), and record capacity scaling to
/// `results/fabric.csv`. The failover counters ride along in every row:
/// a healthy sweep keeps them at zero, so a nonzero value in the CSV is
/// itself a finding. Each worker count gets a fresh fabric (ports
/// chosen by the OS), torn down by a router `shutdown` + drained worker
/// joins before the next one starts.
fn serve_openloop_fabric(args: &Args) -> Result<()> {
    with_model(&args.str("model", "dit-sim"), args, |model| {
        if model.shared().is_none() {
            bail!("serve-openloop --workers needs a Send + Sync backend (use --backend native)");
        }
        let quick = args.bool("quick");
        let max_workers = args.usize("workers", 2).max(1);
        let shards = args.usize("shards", 1).max(1);
        let opts = RunOpts::from_args(args, 0)?;
        let policy = args.str("policy", "speca:N=5,O=2,tau0=0.3,beta=0.05");
        let n = sample_count(args, 48);
        let mults: Vec<f64> = if quick { vec![2.0] } else { vec![0.5, 2.0] };
        println!(
            "== serve-openloop fabric: 1..={max_workers} workers × {shards} shard(s), \
             n={n} per rate =="
        );
        println!(
            "{:<8} {:<8} {:>9} {:>9} {:>6} {:>6} {:>6} {:>9} {:>9} {:>9} {:>5} {:>5}",
            "workers", "load", "offered", "achieved", "done", "rej", "abrt", "p50 ms", "p99 ms",
            "rej-rate", "fail", "requ"
        );
        let mut csv = Vec::new();
        for w in 1..=max_workers {
            let router = fabric::spawn_router(&fabric::RouterConfig {
                addr: "127.0.0.1:0".into(),
                workers_addr: "127.0.0.1:0".into(),
                heartbeat_ms: 50,
                ..fabric::RouterConfig::default()
            })?;
            let addr = router.addr().to_string();
            let mut workers = Vec::new();
            for _ in 0..w {
                let shared = model.shared().expect("checked above");
                let cfg = fabric::WorkerConfig {
                    join: router.workers_addr().to_string(),
                    addr: "127.0.0.1:0".into(),
                    max_queue: args.usize("max-queue", 256),
                    shards,
                    router: opts.router,
                    default_draft: opts.draft.clone(),
                };
                workers.push(fabric::spawn_worker(shared, opts.engine_config(), &cfg)?);
            }
            for _ in 0..400 {
                if router.workers_live() >= w {
                    break;
                }
                thread::sleep(Duration::from_millis(5));
            }
            // all fabric traffic runs inside this closure so teardown
            // below executes on every exit path
            let drive = |csv: &mut Vec<String>| -> Result<()> {
                if router.workers_live() < w {
                    bail!("only {}/{w} workers joined the fabric", router.workers_live());
                }
                let mut stream = TcpStream::connect(&addr)?;
                let mut reader = BufReader::new(stream.try_clone()?);
                client::hello_exchange(&mut stream, &mut reader)?;
                let calib = if quick { 2u64 } else { 4 };
                let t0 = Instant::now();
                for i in 0..calib {
                    client::generate_once(&mut stream, &mut reader, 0, 9_000 + i, &policy)?;
                }
                let service_s = t0.elapsed().as_secs_f64() / calib as f64;
                let capacity = (w * shards) as f64 / service_s.max(1e-6);
                for m in &mults {
                    let cfg = client::OpenLoopConfig {
                        addr: addr.clone(),
                        rate: capacity * m,
                        requests: n,
                        policy: policy.clone(),
                        num_classes: 8,
                        seed: args.u64("seed", 0) + w as u64 * 10_000 + (m * 1000.0) as u64,
                        deadline_ms: None,
                        priority: None,
                        waiters: 8,
                    };
                    let mut r = client::run_open_loop(&cfg)?;
                    let p50 = r.latency.percentile(0.5);
                    let p99 = r.latency.percentile(0.99);
                    println!(
                        "{:<8} {:<8} {:>9.2} {:>9.2} {:>6} {:>6} {:>6} {:>9.1} {:>9.1} \
                         {:>9.3} {:>5} {:>5}",
                        w,
                        format!("{m}x"),
                        r.offered_rps,
                        r.achieved_rps,
                        r.completed,
                        r.rejected,
                        r.aborted,
                        p50,
                        p99,
                        r.reject_rate(),
                        router.failovers(),
                        router.requeued_jobs()
                    );
                    csv.push(format!(
                        "{w},{shards},{m},{:.4},{:.4},{},{},{},{},{p50:.3},{p99:.3},{:.5},{},{}",
                        r.offered_rps,
                        r.achieved_rps,
                        r.submitted,
                        r.completed,
                        r.rejected,
                        r.aborted,
                        r.reject_rate(),
                        router.failovers(),
                        router.requeued_jobs()
                    ));
                }
                // the metrics plane must stay parseable under load
                let text = client::metrics(&addr)?;
                if !text.contains("# TYPE speca_workers_live gauge") {
                    bail!("router metrics text is missing the speca_workers_live family");
                }
                Ok(())
            };
            let outcome = drive(&mut csv);
            client::shutdown(&addr);
            let routed = router.join();
            let mut served = 0u64;
            for wk in workers {
                match wk.join() {
                    Ok(c) => served += c,
                    Err(e) => eprintln!("speca: fabric worker teardown: {e:#}"),
                }
            }
            routed?;
            outcome?;
            println!("   fabric({w}): drained cleanly, {served} jobs served across workers");
        }
        write_csv(
            &results_path("fabric.csv"),
            "workers,shards_per_worker,load_mult,offered_rps,achieved_rps,submitted,completed,\
             rejected,aborted,p50_ms,p99_ms,reject_rate,failovers,requeued_jobs",
            &csv,
        )?;
        println!("wrote results/fabric.csv");
        Ok(())
    })
}

fn small_flux_table(
    name: &str,
    title: &str,
    rows: &[(&str, &str)],
    args: &Args,
) -> Result<()> {
    with_backends("flux-sim", args, |model, cls| {
        let n = sample_count(args, 48);
        let opts = RunOpts::from_args(args, n)?;
        let reference = run_policy(
            model,
            &parse_policy("full", model.entry().config.depth)?,
            "full",
            &opts,
        )?;
        println!("== {name}: {title} (flux-sim, n={n}) ==");
        println!(
            "{:<26} {:>8} {:>8} {:>7} {:>8}",
            "variant", "CLIP*", "ImgRwd*", "speed", "rejects"
        );
        let mut csv = Vec::new();
        for (label, desc) in rows {
            let row = eval_row(model, cls, &reference, desc, label, &opts)?;
            println!(
                "{:<26} {:>8.3} {:>8.4} {:>6.2}x {:>8}",
                row.label, row.q.agreement, row.q.fidelity, row.speed, row.rejects
            );
            csv.push(format!(
                "{},{:.4},{:.4},{:.3},{}",
                row.label.replace(',', ";"),
                row.q.agreement,
                row.q.fidelity,
                row.speed,
                row.rejects
            ));
        }
        write_csv(
            &results_path(&format!("{name}.csv")),
            "variant,agreement,fidelity,speed,rejects",
            &csv,
        )?;
        println!("wrote results/{name}.csv");
        Ok(())
    })
}

/// Fig. 2: FID*/IS* vs acceleration curves per method family (dit-sim).
fn fig2(args: &Args) -> Result<()> {
    with_backends("dit-sim", args, |model, cls| {
        let n = sample_count(args, 32);
        let opts = RunOpts::from_args(args, n)?;
        let reference = run_policy(
            model,
            &parse_policy("full", model.entry().config.depth)?,
            "full",
            &opts,
        )?;

        let families: Vec<(&str, Vec<String>)> = vec![
            ("ddim", (0..5).map(|i| format!("steps:keep={}", [25, 15, 10, 8, 7][i])).collect()),
            ("fora", (0..5).map(|i| format!("fora:N={}", [3, 5, 6, 7, 9][i])).collect()),
            (
                "taylorseer",
                (0..5).map(|i| format!("taylorseer:N={},O=2", [3, 5, 6, 8, 9][i])).collect(),
            ),
            (
                "speca",
                (0..5)
                    .map(|i| {
                        format!(
                            "speca:N={},O=2,tau0={},beta=0.05",
                            [3, 5, 6, 8, 9][i],
                            [0.2, 0.3, 0.3, 0.5, 0.5][i]
                        )
                    })
                    .collect(),
            ),
        ];
        println!("== fig2: quality vs acceleration curves (n={n}) ==");
        let mut csv = Vec::new();
        for (family, descs) in &families {
            for desc in descs {
                let row = eval_row(model, cls, &reference, desc, desc, &opts)?;
                println!(
                    "{:<12} {:<34} speed={:>5.2}x FID*={:>7.3} IS*={:>6.2}",
                    family, desc, row.speed, row.q.fid, row.q.is
                );
                csv.push(format!(
                    "{family},{},{:.3},{:.4},{:.4},{:.4}",
                    desc.replace(',', ";"),
                    row.speed,
                    row.q.fid,
                    row.q.sfid,
                    row.q.is
                ));
            }
        }
        write_csv(&results_path("fig2.csv"), "family,policy,speed,fid,sfid,is", &csv)?;
        println!("wrote results/fig2.csv");
        Ok(())
    })
}

/// Fig. 6: correlation between per-layer activation error and final output
/// error. Runs a TaylorSeer trajectory with shadow full computes so every
/// boundary's prediction error is measured against its true value.
fn fig6(args: &Args) -> Result<()> {
    with_model("dit-sim", args, |model| {
        let model = model.backend();
        let entry = model.entry();
        let cfg = &entry.config;
        let depth = cfg.depth;
        let feat = cfg.tokens * cfg.dim;
        let steps = cfg.serve_steps;
        let m = sample_count(args, 32).max(24);
        let interval = args.usize("interval", 5);
        let order = args.usize("order", 2);
        let sched = &entry.schedule;

        println!("== fig6: layer-error ↔ final-error correlation ({m} samples) ==");
        let mut per_layer_err = vec![Vec::with_capacity(m); depth + 1];
        let mut final_err = Vec::with_capacity(m);
        for s in 0..m {
            let seed = 1000 + s as u64;
            let mut rng = Rng::new(seed);
            let x_init = rng.normal_f32s(cfg.latent_dim);
            let y = vec![(s % cfg.num_classes) as i32];

            // reference trajectory (full compute)
            let mut x_ref = x_init.clone();
            for i in 0..steps {
                let t = vec![sched.t_model[i]];
                let (eps, _) = model.full(1, &x_ref, &t, &y, false)?;
                apply(sched, i, steps, &mut x_ref, &eps.data);
            }

            // TaylorSeer trajectory with shadow full computes on spec steps
            let mut caches: Vec<TapCache> =
                (0..=depth).map(|_| TapCache::new(order, feat, interval)).collect();
            let mut x = x_init.clone();
            let mut last_refresh = 0usize;
            let mut errs = vec![0.0f64; depth + 1];
            let mut n_spec = 0usize;
            for i in 0..steps {
                let t = vec![sched.t_model[i]];
                if i % interval == 0 {
                    let (eps, bounds) = model.full(1, &x, &t, &y, false)?;
                    for (b, cache) in caches.iter_mut().enumerate() {
                        cache.refresh(&bounds.data[b * feat..(b + 1) * feat]);
                    }
                    last_refresh = i;
                    apply(sched, i, steps, &mut x, &eps.data);
                } else {
                    let k = (i - last_refresh) as f32;
                    // shadow: true boundaries at the current x
                    let (_, bounds) = model.full(1, &x, &t, &y, false)?;
                    let mut pred_last = vec![0.0f32; feat];
                    for (b, cache) in caches.iter().enumerate() {
                        let pred = cache.predict(k, DraftKind::Taylor);
                        let actual = &bounds.data[b * feat..(b + 1) * feat];
                        errs[b] += ErrorMetric::L2.eval(&pred, actual);
                        if b == depth {
                            pred_last = pred;
                        }
                    }
                    n_spec += 1;
                    let eps = model.head(1, &pred_last, &t, &y)?;
                    apply(sched, i, steps, &mut x, &eps.data);
                }
            }
            for b in 0..=depth {
                per_layer_err[b].push(errs[b] / n_spec.max(1) as f64);
            }
            final_err.push(ErrorMetric::L2.eval(&x, &x_ref));
        }

        let mut csv = Vec::new();
        println!("{:<10} {:>9}", "boundary", "pearson r");
        for b in 0..=depth {
            let r = pearson(&per_layer_err[b], &final_err);
            let tag = if b == depth {
                " (deepest block output)"
            } else if b == 0 {
                " (raw embedding of x_t — trivially tracks latent drift)"
            } else {
                ""
            };
            println!("{:<10} {:>9.3}{tag}", b, r);
            csv.push(format!("{b},{r:.4}"));
        }
        write_csv(&results_path("fig6.csv"), "boundary,pearson_r", &csv)?;
        println!("wrote results/fig6.csv");
        Ok(())
    })
}

/// Fig. 8: τ0 × β sensitivity surface (denser grid over Tables 4/5).
fn fig8(args: &Args) -> Result<()> {
    with_backends("dit-sim", args, |model, cls| {
        let n = sample_count(args, 24);
        let opts = RunOpts::from_args(args, n)?;
        let reference = run_policy(
            model,
            &parse_policy("full", model.entry().config.depth)?,
            "full",
            &opts,
        )?;
        let taus = [0.1, 0.3, 0.5, 0.8, 1.2];
        let betas = [0.01, 0.05, 0.12];
        println!("== fig8: τ0×β sensitivity (n={n}) ==");
        let mut csv = Vec::new();
        for b in betas {
            for t in taus {
                let desc = format!("speca:N=12,O=2,tau0={t},beta={b}");
                let row = eval_row(model, cls, &reference, &desc, &desc, &opts)?;
                println!(
                    "tau0={t:<4} beta={b:<5} speed={:>5.2}x FID*={:>7.3} sFID*={:>7.3}",
                    row.speed, row.q.fid, row.q.sfid
                );
                csv.push(format!(
                    "{t},{b},{:.3},{:.4},{:.4},{:.4}",
                    row.speed, row.q.fid, row.q.sfid, row.q.is
                ));
            }
        }
        write_csv(&results_path("fig8.csv"), "tau0,beta,speed,fid,sfid,is", &csv)?;
        println!("wrote results/fig8.csv");
        Ok(())
    })
}

/// Fig. 9: PCA trajectories of the last-boundary feature per policy.
fn fig9(args: &Args) -> Result<()> {
    with_model("dit-sim", args, |model| {
        let entry = model.entry();
        let seed = args.u64("seed", 4);
        let policies: &[(&str, &str)] = &[
            ("full", "full"),
            ("fora", "fora:N=5"),
            ("taylorseer", "taylorseer:N=5,O=2"),
            ("speca", "speca:N=5,O=2,tau0=0.3,beta=0.05"),
        ];
        println!("== fig9: PCA feature trajectories ==");
        let mut all_rows: Vec<f32> = Vec::new();
        let mut meta: Vec<(String, usize)> = Vec::new();
        let feat = entry.config.tokens * entry.config.dim;
        let opts =
            RunOpts { n: 1, seed, inflight: 1, record_traj: true, ..RunOpts::default() };
        for (label, desc) in policies {
            let policy = parse_policy(desc, entry.config.depth)?;
            let run = run_policy(model, &policy, label, &opts)?;
            let c = run.completions_by_id.values().next().unwrap();
            for row in &c.traj {
                all_rows.extend_from_slice(row);
            }
            meta.push((label.to_string(), c.traj.len()));
            println!("  {label}: {} recorded steps", c.traj.len());
        }
        let n = all_rows.len() / feat;
        let (_, proj) = pca2(&all_rows, n, feat, 7);
        let mut csv = Vec::new();
        let mut at = 0usize;
        for (label, steps) in &meta {
            for s in 0..*steps {
                csv.push(format!(
                    "{label},{s},{:.5},{:.5}",
                    proj[(at + s) * 2],
                    proj[(at + s) * 2 + 1]
                ));
            }
            at += steps;
        }
        write_csv(&results_path("fig9.csv"), "policy,step,pc1,pc2", &csv)?;
        println!("wrote results/fig9.csv ({n} points)");
        Ok(())
    })
}

/// §G.3: measured acceptance α vs the speedup law S = 1/(1−α+αγ).
fn speedup_law(args: &Args) -> Result<()> {
    with_model("dit-sim", args, |model| {
        let entry = model.entry();
        let n = sample_count(args, 16);
        let opts = RunOpts::from_args(args, n)?;
        let full1 = entry.flops.full_step[&1];
        println!("== speedup law: S vs 1/(1−α+αγ) ==");
        println!(
            "{:<34} {:>7} {:>8} {:>9} {:>10}",
            "policy", "alpha", "gamma", "S (meas)", "S (law)"
        );
        let mut csv = Vec::new();
        for tau in [0.1, 0.2, 0.3, 0.5, 0.8, 1.2] {
            for interval in [4usize, 6, 9] {
                let desc = format!("speca:N={interval},O=2,tau0={tau},beta=0.05");
                let policy = parse_policy(&desc, entry.config.depth)?;
                let run = run_policy(model, &policy, &desc, &opts)?;
                let a = run.flops.acceptance_rate();
                let g = run.flops.gamma();
                let s = run.flops.speedup(full1);
                let law = run.flops.predicted_speedup();
                println!("{desc:<34} {a:>7.3} {g:>8.4} {s:>8.2}x {law:>9.2}x");
                csv.push(format!("{},{a:.4},{g:.4},{s:.4},{law:.4}", desc.replace(',', ";")));
            }
        }
        write_csv(&results_path("speedup_law.csv"), "policy,alpha,gamma,measured,law", &csv)?;
        println!("wrote results/speedup_law.csv");
        Ok(())
    })
}

fn apply(
    sched: &crate::config::Schedule,
    i: usize,
    total: usize,
    x: &mut [f32],
    out: &[f32],
) {
    match sched.kind {
        crate::config::ScheduleKind::Ddim => {
            let ab_prev = if i + 1 < total { sched.ab_t[i + 1] } else { 1.0 };
            crate::sampler::ddim_step(x, out, sched.ab_t[i], ab_prev);
        }
        crate::config::ScheduleKind::RectifiedFlow => {
            crate::sampler::rf_step(x, out, sched.dt);
        }
    }
}
