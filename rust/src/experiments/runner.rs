//! Shared experiment harness: run a policy over a request batch, compute
//! the quality metrics of DESIGN.md §2 (FID*/sFID*/IS*, ImageReward*,
//! GenEval*, VBench*) against golden-seed references, dump artifacts.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::cache::{Draft, DraftRegistry};
use crate::config::ModelConfig;
use crate::coordinator::batcher::BatchStrategy;
use crate::coordinator::policy::Policy;
use crate::coordinator::state::Completion;
use crate::coordinator::{Engine, EngineConfig, EngineShardPool, PoolConfig, RouterPolicy};
use crate::metrics::flops::FlopsCounter;
use crate::metrics::frechet::fid_vs_reference;
use crate::metrics::stats::{
    class_agreement, fidelity_score, inception_score, vbench_star, Histogram,
};
use crate::runtime::{ClassifierBackend, ResolvedModel};
use crate::util::cli::Args;
use crate::workload::batch_requests;

/// Outcome of one (policy, n-sample) run.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Row label the run was evaluated under.
    pub label: String,
    /// Completions keyed by request id (deterministic iteration order).
    pub completions_by_id: BTreeMap<u64, Completion>,
    /// Aggregate booked FLOPs across the run.
    pub flops: FlopsCounter,
    /// Wall-clock seconds of the whole run.
    pub wall_s: f64,
}

/// How to drive a policy run: workload size, engine shape, sharding.
#[derive(Debug, Clone)]
pub struct RunOpts {
    /// Closed-loop request count.
    pub n: usize,
    /// Workload seed (request seeds derive from it).
    pub seed: u64,
    /// per-engine (per-shard) admission cap
    pub inflight: usize,
    /// engine worker threads; > 1 requires a `Send + Sync` backend
    pub shards: usize,
    /// How submissions spread over shards.
    pub router: RouterPolicy,
    /// Batch decomposition strategy.
    pub strategy: BatchStrategy,
    /// Run the pallas-attention artifact variant for full passes.
    pub use_pallas: bool,
    /// Record per-step feature trajectories (Fig. 9).
    pub record_traj: bool,
    /// `--draft <name>`: override the draft strategy of every SpeCa
    /// policy driven through [`run_policy`] (resolved via the registry).
    pub draft: Option<Draft>,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            n: 8,
            seed: 0,
            inflight: 8,
            shards: 1,
            router: RouterPolicy::LeastLoaded,
            strategy: BatchStrategy::Binary,
            use_pallas: false,
            record_traj: false,
            draft: None,
        }
    }
}

impl RunOpts {
    /// Read the shared engine/workload flags (`--seed`, `--inflight`,
    /// `--shards`, `--router`, `--draft`) with `n` supplied by the caller.
    pub fn from_args(args: &Args, n: usize) -> Result<RunOpts> {
        let router = args.str("router", "least-loaded");
        let Some(router) = RouterPolicy::parse(&router) else {
            bail!("unknown router '{router}' (expected least-loaded|round-robin)");
        };
        let draft = match args.opt("draft") {
            Some(name) => Some(DraftRegistry::global().resolve(name)?),
            None => None,
        };
        Ok(RunOpts {
            n,
            seed: args.u64("seed", 0),
            inflight: args.usize("inflight", 8),
            shards: args.usize("shards", 1),
            router,
            draft,
            ..RunOpts::default()
        })
    }

    /// The engine configuration these options describe.
    pub fn engine_config(&self) -> EngineConfig {
        EngineConfig {
            max_inflight: self.inflight,
            strategy: self.strategy,
            use_pallas: self.use_pallas,
        }
    }
}

/// Drive `n` closed-loop requests with one policy through a fresh engine
/// (or, with `opts.shards > 1`, through a fresh shard pool).
pub fn run_policy(
    model: &ResolvedModel<'_>,
    policy: &Policy,
    label: &str,
    opts: &RunOpts,
) -> Result<RunResult> {
    let mut policy = policy.clone();
    if let Some(d) = &opts.draft {
        crate::workload::apply_draft(&mut policy, d);
    }
    let reqs = batch_requests(
        opts.n,
        model.entry().config.num_classes,
        &policy,
        opts.seed,
        opts.record_traj,
    );
    let t0 = std::time::Instant::now();
    let (completions, flops) = if opts.shards > 1 {
        let Some(shared) = model.shared() else {
            bail!(
                "--shards {} needs a Send + Sync backend; the PJRT runtime is \
                 single-threaded (use --backend native)",
                opts.shards
            );
        };
        let pool = EngineShardPool::new(
            shared,
            PoolConfig {
                shards: opts.shards,
                router: opts.router,
                engine: opts.engine_config(),
                // parity harnesses need deterministic shard placement
                steal: false,
            },
        );
        for r in reqs {
            pool.submit(r)?;
        }
        let out = pool.shutdown(true)?;
        (out.completions, out.stats.flops)
    } else {
        let mut engine = Engine::new(model.backend(), opts.engine_config());
        for r in reqs {
            engine.submit(r);
        }
        let completions = engine.run_to_completion()?;
        (completions, engine.flops)
    };
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(RunResult {
        label: label.to_string(),
        completions_by_id: completions.into_iter().map(|c| (c.id, c)).collect(),
        flops,
        wall_s,
    })
}

/// Quality metrics of a run, all relative to the paper's estimators.
#[derive(Debug, Clone, Default)]
pub struct Quality {
    /// Fréchet distance of classifier features vs the real-data reference
    pub fid: f64,
    /// Fréchet distance of pooled pixels vs reference (sFID analog)
    pub sfid: f64,
    /// Inception-style score from classifier posteriors
    pub is: f64,
    /// mean reference-fidelity vs the full-compute output (ImageReward*/CLIP*)
    pub fidelity: f64,
    /// classifier agreement with the conditioning class (GenEval*)
    pub agreement: f64,
    /// VBench* composite (video models only; 0 otherwise)
    pub vbench: f64,
}

/// Classify a batch of frames through the metrics classifier, greedily
/// using the largest compiled buckets.
pub fn classify_frames(
    cls: &dyn ClassifierBackend,
    frames: &[f32],
    n: usize,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let latent = cls.latent_dim();
    let k = cls.num_classes();
    let fd = cls.feat_dim();
    let buckets = cls.buckets();
    let mut logits = vec![0f32; n * k];
    let mut feats = vec![0f32; n * fd];
    let mut done = 0usize;
    while done < n {
        let remaining = n - done;
        let b = *buckets.iter().rev().find(|b| **b <= remaining).unwrap_or(&buckets[0]);
        // pad by replicating the last frame when remaining < smallest bucket
        let mut chunk = vec![0f32; b * latent];
        for slot in 0..b {
            let src = (done + slot).min(n - 1);
            chunk[slot * latent..(slot + 1) * latent]
                .copy_from_slice(&frames[src * latent..(src + 1) * latent]);
        }
        let (lg, ft) = cls.classify(b, &chunk)?;
        let take = b.min(remaining);
        logits[done * k..(done + take) * k].copy_from_slice(&lg.data[..take * k]);
        feats[done * fd..(done + take) * fd].copy_from_slice(&ft.data[..take * fd]);
        done += take;
    }
    Ok((logits, feats))
}

/// 2× mean-pool a [img, img] frame to 8×8 (sFID* feature space; mirrors
/// train.py::reference_stats).
pub fn pool_to_8x8(frame: &[f32], img: usize) -> Vec<f32> {
    let f = img / 8;
    let mut out = vec![0f32; 64];
    for i in 0..8 {
        for j in 0..8 {
            let mut acc = 0.0f32;
            for di in 0..f {
                for dj in 0..f {
                    acc += frame[(i * f + di) * img + (j * f + dj)];
                }
            }
            out[i * 8 + j] = acc / (f * f) as f32;
        }
    }
    out
}

/// Compute every quality metric for a run, using the matching-seed full
/// compute run as the reference (`reference` may be the run itself).
pub fn evaluate_quality(
    run: &RunResult,
    reference: &RunResult,
    cfg: &ModelConfig,
    cls: &dyn ClassifierBackend,
) -> Result<Quality> {
    let n = run.completions_by_id.len();
    let frame_len = cls.latent_dim();
    let frames_per = cfg.frames;
    assert_eq!(cfg.latent_dim, frame_len * frames_per);

    // middle frame of every completion → classifier inputs
    let mid = frames_per / 2;
    let mut frames = Vec::with_capacity(n * frame_len);
    let mut labels = Vec::with_capacity(n);
    let mut fid_sum = 0.0;
    let mut vb_sum = 0.0;
    let mut pooled = Vec::with_capacity(n * 64);
    for (id, c) in &run.completions_by_id {
        frames.extend_from_slice(&c.latent[mid * frame_len..(mid + 1) * frame_len]);
        labels.push((c.cond as usize) % cls.num_classes());
        pooled.extend(pool_to_8x8(
            &c.latent[mid * frame_len..(mid + 1) * frame_len],
            cfg.image_size,
        ));
        let r = reference
            .completions_by_id
            .get(id)
            .context("reference run missing a completion id")?;
        fid_sum += fidelity_score(&c.latent, &r.latent);
        if frames_per > 1 {
            vb_sum += vbench_star(&c.latent, &r.latent, frames_per);
        }
    }
    let (logits, feats) = classify_frames(cls, &frames, n)?;
    let fid =
        fid_vs_reference(&feats, n, cls.feat_dim(), &cls.fid_mu().data, &cls.fid_cov().data);
    let sfid = fid_vs_reference(&pooled, n, 64, &cls.sfid_mu().data, &cls.sfid_cov().data);
    let is = inception_score(&logits, n, cls.num_classes());
    let agreement = class_agreement(&logits, &labels, cls.num_classes());
    Ok(Quality {
        fid,
        sfid,
        is,
        fidelity: fid_sum / n as f64,
        agreement,
        vbench: if frames_per > 1 { vb_sum / n as f64 } else { 0.0 },
    })
}

/// Aggregate per-request latency distribution of a run.
pub fn latency_hist(run: &RunResult) -> Histogram {
    let mut h = Histogram::new();
    for c in run.completions_by_id.values() {
        h.record(c.stats.latency_ms);
    }
    h
}

/// Save completions as PGM grayscale images (Figs. 4/5 qualitative dumps).
pub fn dump_pgm(completions: &[Completion], cfg: &ModelConfig, dir: &str) -> Result<()> {
    fs::create_dir_all(dir)?;
    let img = cfg.image_size;
    let frame_len = img * img * cfg.channels;
    for c in completions {
        for f in 0..cfg.frames {
            let frame = &c.latent[f * frame_len..(f + 1) * frame_len];
            let mut pgm = format!("P2\n{img} {img}\n255\n");
            for row in 0..img {
                let line: Vec<String> = (0..img)
                    .map(|col| {
                        let v = frame[row * img + col].clamp(-1.0, 1.0);
                        format!("{}", ((v + 1.0) * 127.5) as u8)
                    })
                    .collect();
                pgm.push_str(&line.join(" "));
                pgm.push('\n');
            }
            let name = if cfg.frames > 1 {
                format!("{dir}/req{:03}_{}_f{f}.pgm", c.id, c.policy_name)
            } else {
                format!("{dir}/req{:03}_{}.pgm", c.id, c.policy_name)
            };
            fs::write(&name, pgm)?;
        }
    }
    Ok(())
}

/// Write a CSV file under results/ (creating the directory).
pub fn write_csv(path: &Path, header: &str, rows: &[String]) -> Result<()> {
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let mut out = String::from(header);
    out.push('\n');
    for r in rows {
        out.push_str(r);
        out.push('\n');
    }
    fs::write(path, out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pooling_means() {
        // 16×16 constant image pools to constant 8×8
        let frame = vec![0.5f32; 256];
        let p = pool_to_8x8(&frame, 16);
        assert_eq!(p.len(), 64);
        assert!(p.iter().all(|v| (*v - 0.5).abs() < 1e-6));
        // gradient image: pooled value = mean of its 2×2 block
        let g: Vec<f32> = (0..256).map(|i| i as f32).collect();
        let p = pool_to_8x8(&g, 16);
        assert!((p[0] - (0.0 + 1.0 + 16.0 + 17.0) / 4.0).abs() < 1e-5);
    }
}
