//! FLOPs accounting (paper §3.5 / Theorem G.3).
//!
//! Every engine action books its analytic cost (from the manifest's tables,
//! derived in configs.py) into a counter; the bench harness reports
//! FLOPs(T), the acceleration ratio vs full computation, the measured
//! acceptance rate α and verification cost ratio γ, and checks them against
//! the paper's speedup law  S = 1 / (1 − α·(1 − γ)).

use crate::config::FlopsTable;

/// Booked analytic FLOPs + step counts for one request or aggregate.
///
/// `Copy` + `Eq` on purpose: the engine snapshots these per tick for its
/// rollback-to-boundary crash protocol, and the checkpoint parity tests
/// assert counters bitwise.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct FlopsCounter {
    /// complete forward passes
    pub full: u64,
    /// verification block runs
    pub verify: u64,
    /// head evaluations on speculative steps
    pub head: u64,
    /// draft-model predictions
    pub predict: u64,
    /// simulated partial-recompute costs (ToCa/DuCa-sim blend steps)
    pub other: u64,
    /// step counts by category (per *sample*, not per batch)
    pub n_full_steps: u64,
    /// Speculative steps served (accepted SpeCa + TaylorSeer + skips).
    pub n_spec_steps: u64,
    /// SpeCa verifications that rejected.
    pub n_rejects: u64,
}

impl FlopsCounter {
    /// Total booked FLOPs across categories.
    pub fn total(&self) -> u64 {
        self.full + self.verify + self.head + self.predict + self.other
    }

    /// Paper's α: fraction of sampling steps served speculatively.
    pub fn acceptance_rate(&self) -> f64 {
        let t = self.n_full_steps + self.n_spec_steps;
        if t == 0 {
            0.0
        } else {
            self.n_spec_steps as f64 / t as f64
        }
    }

    /// Paper's γ: verification cost as a fraction of a full pass, measured
    /// from booked FLOPs.
    pub fn gamma(&self) -> f64 {
        if self.n_spec_steps == 0 || self.n_full_steps == 0 {
            return 0.0;
        }
        let per_verify = self.verify as f64 / self.n_spec_steps as f64;
        let per_full = self.full as f64 / self.n_full_steps as f64;
        per_verify / per_full
    }

    /// Measured FLOPs speedup vs running every step fully.
    pub fn speedup(&self, full_step_flops: u64) -> f64 {
        let t = self.n_full_steps + self.n_spec_steps;
        if self.total() == 0 {
            return 1.0;
        }
        (t * full_step_flops) as f64 / self.total() as f64
    }

    /// Theoretical speedup from the paper's law (Eq. 8) at this counter's
    /// measured α and γ.
    pub fn predicted_speedup(&self) -> f64 {
        let a = self.acceptance_rate();
        let g = self.gamma();
        1.0 / (1.0 - a + a * g)
    }

    /// Accumulate another counter into this one.
    pub fn merge(&mut self, other: &FlopsCounter) {
        self.full += other.full;
        self.verify += other.verify;
        self.head += other.head;
        self.predict += other.predict;
        self.other += other.other;
        self.n_full_steps += other.n_full_steps;
        self.n_spec_steps += other.n_spec_steps;
        self.n_rejects += other.n_rejects;
    }
}

/// Books analytic per-action costs for one model; batch-aware (per-sample
/// attribution: a bucket-B batch costs table[B]/B per sample).
#[derive(Debug, Clone)]
pub struct FlopsModel {
    /// Per-bucket analytic cost tables (from the manifest / configs.py).
    pub table: FlopsTable,
}

impl FlopsModel {
    /// Model over one cost table.
    pub fn new(table: FlopsTable) -> FlopsModel {
        FlopsModel { table }
    }

    fn per_sample(&self, map: &std::collections::BTreeMap<usize, u64>, bucket: usize) -> u64 {
        let v = map
            .get(&bucket)
            .or_else(|| map.values().next_back())
            .copied()
            .unwrap_or(0);
        v / bucket.max(1) as u64
    }

    /// Book `samples` full forward passes dispatched at `bucket`.
    pub fn book_full(&self, c: &mut FlopsCounter, bucket: usize, samples: usize) {
        c.full += self.per_sample(&self.table.full_step, bucket) * samples as u64;
        c.n_full_steps += samples as u64;
    }

    /// Book `samples` verification-block runs dispatched at `bucket`.
    pub fn book_verify(&self, c: &mut FlopsCounter, bucket: usize, samples: usize) {
        c.verify += self.per_sample(&self.table.block, bucket) * samples as u64;
    }

    /// Book `samples` head evaluations dispatched at `bucket`.
    pub fn book_head(&self, c: &mut FlopsCounter, bucket: usize, samples: usize) {
        c.head += self.per_sample(&self.table.head, bucket) * samples as u64;
    }

    /// Book draft predictions of the given order across `taps` taps.
    pub fn book_predict(&self, c: &mut FlopsCounter, order: usize, taps: usize, samples: usize) {
        c.predict +=
            self.table.predict_per_order * (order as u64 + 1) * taps as u64 * samples as u64;
    }

    /// Count `samples` speculative serve steps.
    pub fn book_spec_step(&self, c: &mut FlopsCounter, samples: usize) {
        c.n_spec_steps += samples as u64;
    }

    /// Bucket-1 cost of one full step (the speedup baseline).
    pub fn full_step_flops(&self) -> u64 {
        self.table.full_step.get(&1).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn table() -> FlopsTable {
        let mut full = BTreeMap::new();
        full.insert(1, 800u64);
        full.insert(4, 3200u64);
        let mut block = BTreeMap::new();
        block.insert(1, 100u64);
        block.insert(4, 400u64);
        let mut head = BTreeMap::new();
        head.insert(1, 10u64);
        head.insert(4, 40u64);
        FlopsTable { full_step: full, block, head, predict_per_order: 2 }
    }

    #[test]
    fn speedup_law_identity() {
        // 1 full step + 9 spec steps with gamma = 100/800 = 0.125:
        // S = 10·800 / (800 + 9·(100+10+pred))
        let fm = FlopsModel::new(table());
        let mut c = FlopsCounter::default();
        fm.book_full(&mut c, 1, 1);
        for _ in 0..9 {
            fm.book_spec_step(&mut c, 1);
            fm.book_verify(&mut c, 1, 1);
            fm.book_head(&mut c, 1, 1);
            fm.book_predict(&mut c, 2, 3, 1);
        }
        assert_eq!(c.n_full_steps, 1);
        assert_eq!(c.n_spec_steps, 9);
        assert!((c.acceptance_rate() - 0.9).abs() < 1e-12);
        assert!((c.gamma() - 0.125).abs() < 1e-12);
        let s = c.speedup(800);
        let expect = 8000.0 / (800.0 + 9.0 * (100.0 + 10.0 + 18.0)) as f64;
        assert!((s - expect).abs() < 1e-9);
        // paper's law ignores head+predict: predicted >= measured
        assert!(c.predicted_speedup() >= s);
    }

    #[test]
    fn batch_attribution_is_per_sample() {
        let fm = FlopsModel::new(table());
        let mut c1 = FlopsCounter::default();
        fm.book_full(&mut c1, 1, 1);
        let mut c4 = FlopsCounter::default();
        fm.book_full(&mut c4, 4, 4);
        assert_eq!(c4.full, 4 * c1.full);
    }

    #[test]
    fn merge_accumulates() {
        let fm = FlopsModel::new(table());
        let mut a = FlopsCounter::default();
        let mut b = FlopsCounter::default();
        fm.book_full(&mut a, 1, 1);
        fm.book_full(&mut b, 1, 2);
        a.merge(&b);
        assert_eq!(a.n_full_steps, 3);
    }
}
