//! Metrics pipeline: FLOPs accounting + quality estimators (FID*, IS*,
//! reference fidelity, VBench*), correlation and PCA analyses.

pub mod flops;
pub mod frechet;
pub mod linalg;
pub mod pca;
pub mod stats;
