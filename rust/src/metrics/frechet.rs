//! Fréchet distance between Gaussian fits of feature sets — the same
//! estimator as FID (Heusel et al.), applied to the build-time classifier's
//! features (FID*) and to downsampled raw pixels (sFID* analog).
//!
//!   d² = ‖μ₁−μ₂‖² + Tr(Σ₁ + Σ₂ − 2·(Σ₁Σ₂)^{1/2})
//!
//! with Tr((Σ₁Σ₂)^{1/2}) computed stably as Tr(√(√Σ₁·Σ₂·√Σ₁)).

use super::linalg::{matmul, sqrtm_psd, trace};

/// Sample mean + covariance of row-major observations [n, d].
pub fn mean_cov(rows: &[f32], n: usize, d: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(rows.len(), n * d);
    assert!(n > 1, "need at least two samples for covariance");
    let mut mu = vec![0.0f64; d];
    for r in 0..n {
        for j in 0..d {
            mu[j] += rows[r * d + j] as f64;
        }
    }
    for m in mu.iter_mut() {
        *m /= n as f64;
    }
    let mut cov = vec![0.0f64; d * d];
    for r in 0..n {
        for i in 0..d {
            let di = rows[r * d + i] as f64 - mu[i];
            for j in i..d {
                cov[i * d + j] += di * (rows[r * d + j] as f64 - mu[j]);
            }
        }
    }
    let denom = (n - 1) as f64;
    for i in 0..d {
        for j in i..d {
            cov[i * d + j] /= denom;
            cov[j * d + i] = cov[i * d + j];
        }
    }
    (mu, cov)
}

/// Fréchet distance between two Gaussians (μ₁,Σ₁), (μ₂,Σ₂) of dim d.
pub fn frechet_distance(mu1: &[f64], cov1: &[f64], mu2: &[f64], cov2: &[f64], d: usize) -> f64 {
    let mean_term: f64 = mu1.iter().zip(mu2).map(|(a, b)| (a - b) * (a - b)).sum();
    let s1 = sqrtm_psd(cov1, d);
    let inner = matmul(&matmul(&s1, cov2, d), &s1, d);
    let sqrt_inner = sqrtm_psd(&inner, d);
    let tr = trace(cov1, d) + trace(cov2, d) - 2.0 * trace(&sqrt_inner, d);
    (mean_term + tr).max(0.0)
}

/// Convenience: Fréchet distance of samples vs a stored reference Gaussian.
pub fn fid_vs_reference(
    feats: &[f32],
    n: usize,
    d: usize,
    ref_mu: &[f32],
    ref_cov: &[f32],
) -> f64 {
    let (mu, cov) = mean_cov(feats, n, d);
    let rmu: Vec<f64> = ref_mu.iter().map(|x| *x as f64).collect();
    let rcov: Vec<f64> = ref_cov.iter().map(|x| *x as f64).collect();
    frechet_distance(&mu, &cov, &rmu, &rcov, d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_distributions_are_zero() {
        let mu = vec![1.0, -2.0];
        let cov = vec![2.0, 0.3, 0.3, 1.0];
        let d = frechet_distance(&mu, &cov, &mu, &cov, 2);
        assert!(d < 1e-9, "{d}");
    }

    #[test]
    fn mean_shift_is_squared_distance() {
        // equal covariances ⇒ d² = ‖Δμ‖²
        let cov = vec![1.0, 0.0, 0.0, 1.0];
        let d = frechet_distance(&[0.0, 0.0], &cov, &[3.0, 4.0], &cov, 2);
        assert!((d - 25.0).abs() < 1e-9);
    }

    #[test]
    fn scalar_case_closed_form() {
        // 1-D: d² = (μ₁−μ₂)² + (σ₁−σ₂)²
        let d = frechet_distance(&[1.0], &[4.0], &[2.0], &[9.0], 1);
        assert!((d - (1.0 + 1.0)).abs() < 1e-9, "{d}");
    }

    #[test]
    fn sampled_estimate_converges() {
        let mut rng = Rng::new(5);
        let n = 20_000;
        let d = 3;
        let mut a = Vec::with_capacity(n * d);
        let mut b = Vec::with_capacity(n * d);
        for _ in 0..n {
            for j in 0..d {
                a.push(rng.normal() as f32);
                b.push((rng.normal() + if j == 0 { 1.0 } else { 0.0 }) as f32);
            }
        }
        let (mu_a, cov_a) = mean_cov(&a, n, d);
        let (mu_b, cov_b) = mean_cov(&b, n, d);
        let dist = frechet_distance(&mu_a, &cov_a, &mu_b, &cov_b, d);
        assert!((dist - 1.0).abs() < 0.1, "{dist}"); // ‖Δμ‖² = 1
    }

    #[test]
    fn mean_cov_basics() {
        let rows = vec![1.0, 2.0, 3.0, 4.0]; // two samples of dim 2
        let (mu, cov) = mean_cov(&rows, 2, 2);
        assert_eq!(mu, vec![2.0, 3.0]);
        assert!((cov[0] - 2.0).abs() < 1e-12); // var of {1,3} (ddof=1)
    }
}
