//! Scalar statistics: Inception-style score, Pearson correlation, latency
//! histogram, reference-fidelity quality proxies (ImageReward*/VBench*).

/// Inception-style score from classifier logits [n, k]:
/// IS = exp( E_i KL(p(y|x_i) ‖ p(y)) ).
pub fn inception_score(logits: &[f32], n: usize, k: usize) -> f64 {
    assert_eq!(logits.len(), n * k);
    assert!(n > 0);
    let mut probs = vec![0.0f64; n * k];
    for i in 0..n {
        let row = &logits[i * k..(i + 1) * k];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let mut z = 0.0;
        for j in 0..k {
            let e = ((row[j] as f64) - mx).exp();
            probs[i * k + j] = e;
            z += e;
        }
        for j in 0..k {
            probs[i * k + j] /= z;
        }
    }
    let mut marginal = vec![0.0f64; k];
    for i in 0..n {
        for j in 0..k {
            marginal[j] += probs[i * k + j] / n as f64;
        }
    }
    let mut kl_sum = 0.0;
    for i in 0..n {
        for j in 0..k {
            let p = probs[i * k + j];
            if p > 1e-12 {
                kl_sum += p * (p / marginal[j].max(1e-12)).ln();
            }
        }
    }
    (kl_sum / n as f64).exp()
}

/// Fraction of rows whose argmax logit equals the expected label — the
/// GenEval*/CLIP* conditioning-faithfulness proxy.
pub fn class_agreement(logits: &[f32], labels: &[usize], k: usize) -> f64 {
    let n = labels.len();
    if n == 0 {
        return 0.0;
    }
    let mut hits = 0usize;
    for (i, lab) in labels.iter().enumerate() {
        let row = &logits[i * k..(i + 1) * k];
        let arg = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if arg == *lab {
            hits += 1;
        }
    }
    hits as f64 / n as f64
}

/// Pearson correlation coefficient (Fig. 6 layer-error analysis).
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if x.is_empty() {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (a, b) in x.iter().zip(y) {
        let dx = a - mx;
        let dy = b - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx <= 0.0 || syy <= 0.0 {
        return 0.0;
    }
    sxy / (sxx.sqrt() * syy.sqrt())
}

/// Reference-fidelity quality proxy in [0, 1]: exp(−rel-L2(output, reference)).
/// Stands in for ImageReward/CLIP on the flux-sim tables (DESIGN.md §2) —
/// identical outputs score 1, decorrelated outputs → 0.
pub fn fidelity_score(out: &[f32], reference: &[f32]) -> f64 {
    let num = crate::tensor::Tensor::l2_dist(out, reference);
    let den = crate::tensor::Tensor::l2_norm(reference).max(1e-9);
    (-(num / den)).exp()
}

/// Temporal-consistency score for video latents [frames × frame_len]:
/// penalizes frame-to-frame deltas that deviate from the reference's deltas.
pub fn temporal_consistency(out: &[f32], reference: &[f32], frames: usize) -> f64 {
    assert_eq!(out.len(), reference.len());
    if frames < 2 {
        return 1.0;
    }
    let fl = out.len() / frames;
    let mut acc = 0.0;
    for f in 0..frames - 1 {
        let d_out: Vec<f32> = (0..fl)
            .map(|i| out[(f + 1) * fl + i] - out[f * fl + i])
            .collect();
        let d_ref: Vec<f32> = (0..fl)
            .map(|i| reference[(f + 1) * fl + i] - reference[f * fl + i])
            .collect();
        acc += fidelity_score(&d_out, &d_ref);
    }
    acc / (frames - 1) as f64
}

/// VBench* composite: 70 % per-frame fidelity + 30 % temporal consistency,
/// scaled to the 0-100 range VBench reports.
pub fn vbench_star(out: &[f32], reference: &[f32], frames: usize) -> f64 {
    let fid = fidelity_score(out, reference);
    let tc = temporal_consistency(out, reference, frames);
    100.0 * (0.7 * fid + 0.3 * tc)
}

/// Latency histogram with exact percentiles (stores samples; serving runs
/// here are ≤ millions of points).
#[derive(Debug, Default, Clone)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one sample.
    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    /// Samples recorded.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// Whether no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Mean of the recorded samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Exact q-quantile (sorts lazily; 0 when empty).
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let idx = ((self.samples.len() as f64 - 1.0) * q).round() as usize;
        self.samples[idx]
    }

    /// `(mean, p50, p95, p99)` of the recorded samples.
    pub fn summary(&mut self) -> (f64, f64, f64, f64) {
        (self.mean(), self.percentile(0.5), self.percentile(0.95), self.percentile(0.99))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn is_bounds() {
        // uniform posteriors -> IS = 1
        let logits = vec![0.0f32; 4 * 5];
        let is = inception_score(&logits, 4, 5);
        assert!((is - 1.0).abs() < 1e-9);
        // perfectly confident + diverse -> IS = k
        let mut l = vec![-100.0f32; 4 * 4];
        for i in 0..4 {
            l[i * 4 + i] = 100.0;
        }
        let is = inception_score(&l, 4, 4);
        assert!((is - 4.0).abs() < 1e-6, "{is}");
    }

    #[test]
    fn agreement() {
        let logits = vec![
            1.0, 0.0, //
            0.0, 1.0, //
            1.0, 0.0,
        ];
        assert!((class_agreement(&logits, &[0, 1, 1], 2) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let y = vec![2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let ny: Vec<f64> = y.iter().map(|v| -v).collect();
        assert!((pearson(&x, &ny) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_degenerate() {
        assert_eq!(pearson(&[1.0, 1.0], &[2.0, 3.0]), 0.0);
    }

    #[test]
    fn fidelity_endpoints() {
        let a = vec![1.0f32, 2.0, 3.0];
        assert!((fidelity_score(&a, &a) - 1.0).abs() < 1e-12);
        let far = vec![100.0f32, -50.0, 7.0];
        assert!(fidelity_score(&far, &a) < 0.01);
    }

    #[test]
    fn temporal_identity() {
        let v = vec![0.1f32; 12];
        assert!((temporal_consistency(&v, &v, 3) - 1.0).abs() < 1e-12);
        assert!((vbench_star(&v, &v, 3) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.len(), 100);
        assert!((h.percentile(0.5) - 50.0).abs() <= 1.0);
        assert!((h.percentile(0.99) - 99.0).abs() <= 1.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }
}
