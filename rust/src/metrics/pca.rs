//! 2-D PCA projection of per-step feature trajectories (paper Fig. 9).
//! Top components via power iteration with deflation on the covariance,
//! evaluated matrix-free (d can be tokens·dim ≈ 10⁴).

use crate::util::rng::Rng;

/// rows: [n, d] observations. Returns (components [2, d], projected [n, 2]).
pub fn pca2(rows: &[f32], n: usize, d: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(rows.len(), n * d);
    assert!(n >= 2);
    let mut mu = vec![0.0f64; d];
    for r in 0..n {
        for j in 0..d {
            mu[j] += rows[r * d + j] as f64;
        }
    }
    for m in mu.iter_mut() {
        *m /= n as f64;
    }

    // centered row access
    let centered = |r: usize, j: usize| rows[r * d + j] as f64 - mu[j];

    // matrix-free covariance-vector product: C v = 1/(n-1) Σ_r x_r (x_rᵀ v)
    let cov_mul = |v: &[f64], out: &mut Vec<f64>| {
        out.iter_mut().for_each(|o| *o = 0.0);
        for r in 0..n {
            let mut dot = 0.0;
            for j in 0..d {
                dot += centered(r, j) * v[j];
            }
            for j in 0..d {
                out[j] += centered(r, j) * dot;
            }
        }
        let s = 1.0 / (n as f64 - 1.0);
        out.iter_mut().for_each(|o| *o *= s);
    };

    let mut rng = Rng::new(seed);
    let mut comps: Vec<Vec<f64>> = Vec::new();
    for _ in 0..2 {
        let mut v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        normalize(&mut v);
        let mut tmp = vec![0.0f64; d];
        for _ in 0..60 {
            cov_mul(&v, &mut tmp);
            // deflate previously found components
            for c in &comps {
                let dot: f64 = tmp.iter().zip(c).map(|(a, b)| a * b).sum();
                for (t, ci) in tmp.iter_mut().zip(c) {
                    *t -= dot * ci;
                }
            }
            let norm = normalize(&mut tmp);
            std::mem::swap(&mut v, &mut tmp);
            if norm < 1e-14 {
                break;
            }
        }
        comps.push(v);
    }

    let mut proj = vec![0.0f64; n * 2];
    for r in 0..n {
        for (ci, c) in comps.iter().enumerate() {
            let mut dot = 0.0;
            for j in 0..d {
                dot += centered(r, j) * c[j];
            }
            proj[r * 2 + ci] = dot;
        }
    }
    let mut flat = Vec::with_capacity(2 * d);
    for c in comps {
        flat.extend(c);
    }
    (flat, proj)
}

fn normalize(v: &mut [f64]) -> f64 {
    let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if norm > 0.0 {
        v.iter_mut().for_each(|x| *x /= norm);
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn recovers_dominant_direction() {
        // points along (1, 1, 0)/√2 with small noise: PC1 ≈ that axis
        let mut rng = Rng::new(3);
        let n = 200;
        let d = 3;
        let mut rows = Vec::with_capacity(n * d);
        for _ in 0..n {
            let t = rng.normal() * 10.0;
            rows.push((t + rng.normal() * 0.01) as f32);
            rows.push((t + rng.normal() * 0.01) as f32);
            rows.push((rng.normal() * 0.01) as f32);
        }
        let (comps, proj) = pca2(&rows, n, d, 1);
        let c1 = &comps[..d];
        let expected = 1.0 / 2.0f64.sqrt();
        assert!((c1[0].abs() - expected).abs() < 0.01, "{c1:?}");
        assert!((c1[1].abs() - expected).abs() < 0.01);
        assert!(c1[2].abs() < 0.05);
        // PC1 variance should dominate PC2
        let var = |k: usize| -> f64 {
            let m: f64 = (0..n).map(|r| proj[r * 2 + k]).sum::<f64>() / n as f64;
            (0..n).map(|r| (proj[r * 2 + k] - m).powi(2)).sum::<f64>() / n as f64
        };
        assert!(var(0) > 100.0 * var(1));
    }

    #[test]
    fn components_orthogonal() {
        let mut rng = Rng::new(9);
        let n = 50;
        let d = 6;
        let rows: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let (comps, _) = pca2(&rows, n, d, 2);
        let dot: f64 = (0..d).map(|j| comps[j] * comps[d + j]).sum();
        assert!(dot.abs() < 1e-6, "{dot}");
    }
}
