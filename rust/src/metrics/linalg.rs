//! Small dense linear algebra for the metrics pipeline (no BLAS offline):
//! symmetric Jacobi eigendecomposition, PSD matrix square root, matmul.
//! Matrices are row-major `Vec<f64>` of size n×n (n ≤ ~64 here).

/// C ← A·B for n×n row-major matrices.
pub fn matmul(a: &[f64], b: &[f64], n: usize) -> Vec<f64> {
    let mut c = vec![0.0; n * n];
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            if aik == 0.0 {
                continue;
            }
            let brow = &b[k * n..(k + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
    c
}

/// Transpose of an n×n row-major matrix.
pub fn transpose(a: &[f64], n: usize) -> Vec<f64> {
    let mut t = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            t[j * n + i] = a[i * n + j];
        }
    }
    t
}

/// Trace of an n×n row-major matrix.
pub fn trace(a: &[f64], n: usize) -> f64 {
    (0..n).map(|i| a[i * n + i]).sum()
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
/// Returns (eigenvalues, eigenvectors as columns of V) with A = V·Λ·Vᵀ.
pub fn jacobi_eigh(a_in: &[f64], n: usize) -> (Vec<f64>, Vec<f64>) {
    let mut a = a_in.to_vec();
    let mut v = vec![0.0; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    for _sweep in 0..100 {
        // off-diagonal Frobenius norm
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a[i * n + j] * a[i * n + j];
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[p * n + q];
                if apq.abs() < 1e-15 {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p,q of A
                for k in 0..n {
                    let akp = a[k * n + p];
                    let akq = a[k * n + q];
                    a[k * n + p] = c * akp - s * akq;
                    a[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[p * n + k];
                    let aqk = a[q * n + k];
                    a[p * n + k] = c * apk - s * aqk;
                    a[q * n + k] = s * apk + c * aqk;
                }
                // accumulate rotations into V
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let eig: Vec<f64> = (0..n).map(|i| a[i * n + i]).collect();
    (eig, v)
}

/// Symmetric PSD matrix square root via eigendecomposition; negative
/// eigenvalues (numerical noise) are clamped to zero.
pub fn sqrtm_psd(a: &[f64], n: usize) -> Vec<f64> {
    let (eig, v) = jacobi_eigh(a, n);
    let mut sv = vec![0.0; n * n];
    for (i, e) in eig.iter().enumerate() {
        sv[i * n + i] = e.max(0.0).sqrt();
    }
    let vs = matmul(&v, &sv, n);
    matmul(&vs, &transpose(&v, n), n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eigh_diagonal() {
        let a = vec![3.0, 0.0, 0.0, 7.0];
        let (mut eig, _) = jacobi_eigh(&a, 2);
        eig.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((eig[0] - 3.0).abs() < 1e-10);
        assert!((eig[1] - 7.0).abs() < 1e-10);
    }

    #[test]
    fn eigh_reconstructs() {
        // A = VΛVᵀ for a random-ish symmetric matrix
        let n = 4;
        let a = vec![
            4.0, 1.0, 0.5, 0.2, //
            1.0, 3.0, 0.7, 0.1, //
            0.5, 0.7, 2.0, 0.3, //
            0.2, 0.1, 0.3, 1.0,
        ];
        let (eig, v) = jacobi_eigh(&a, n);
        let mut lam = vec![0.0; n * n];
        for i in 0..n {
            lam[i * n + i] = eig[i];
        }
        let rec = matmul(&matmul(&v, &lam, n), &transpose(&v, n), n);
        for (x, y) in a.iter().zip(&rec) {
            assert!((x - y).abs() < 1e-8, "{x} vs {y}");
        }
    }

    #[test]
    fn sqrtm_squares_back() {
        let n = 3;
        let a = vec![2.0, 0.5, 0.1, 0.5, 1.5, 0.2, 0.1, 0.2, 1.0];
        let s = sqrtm_psd(&a, n);
        let sq = matmul(&s, &s, n);
        for (x, y) in a.iter().zip(&sq) {
            assert!((x - y).abs() < 1e-8);
        }
    }

    #[test]
    fn trace_and_transpose() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(trace(&a, 2), 5.0);
        assert_eq!(transpose(&a, 2), vec![1.0, 3.0, 2.0, 4.0]);
    }
}
