//! Allocation discipline of the serving hot path (DESIGN.md §11).
//!
//! This binary installs the counting global allocator and proves the
//! tentpole guarantee end to end: after warmup, a steady-state engine
//! tick over the native backend performs **zero** heap allocations —
//! workspace arenas cover the forward-pass temporaries, the tensor
//! buffer pool covers result storage, and the engine's presized scratch
//! covers every piece of per-tick bookkeeping (phase lists, chunk plans,
//! verify grouping, gathers).
//!
//! Everything runs inside **one** `#[test]`: the allocation counters are
//! process-wide, and with a single test libtest has nothing else to
//! schedule or print while a measured window is open — so the zero
//! asserts are exact under plain parallel `cargo test`, not just under
//! the CI thread-stress leg's `RUST_TEST_THREADS=1`.

use speca::config::ModelConfig;
use speca::runtime::{ModelBackend, NativeBackend};
use speca::util::alloc::{allocations, CountingAllocator};
use speca::util::rng::Rng;
use speca::workload::steady_state_alloc_probe;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Phase 1: bare backend — warmed entry points allocate nothing.
fn native_forward_is_alloc_free_after_warmup() {
    let model = NativeBackend::seeded(ModelConfig::native_test(), 0xA110C);
    let cfg = model.entry().config.clone();
    let feat = cfg.tokens * cfg.dim;
    model.warmup(&["full", "full_eps", "block", "head"], &cfg.buckets).unwrap();

    let mut rng = Rng::new(3);
    let x = rng.normal_f32s(2 * cfg.latent_dim);
    let f = rng.normal_f32s(2 * feat);
    let t = vec![500.0f32; 2];
    let y = vec![1i32; 2];
    // one settling pass per entry point (results drop at statement end,
    // refilling the buffer pool)
    ModelBackend::full(&model, 2, &x, &t, &y, false).unwrap();
    model.full_eps(2, &x, &t, &y).unwrap();
    model.block(2, (cfg.depth - 1) as i32, &f, &t, &y).unwrap();
    model.head(2, &f, &t, &y).unwrap();

    let a0 = allocations();
    for _ in 0..5 {
        ModelBackend::full(&model, 2, &x, &t, &y, false).unwrap();
        model.full_eps(2, &x, &t, &y).unwrap();
        model.block(2, (cfg.depth - 1) as i32, &f, &t, &y).unwrap();
        model.head(2, &f, &t, &y).unwrap();
    }
    let spent = allocations() - a0;
    assert_eq!(
        spent, 0,
        "steady-state native forward passes must not allocate ({spent} allocations across \
         20 warmed-up entry-point calls)"
    );
    assert_eq!(model.workspaces_created(), 1, "sequential calls share one workspace");
}

/// Phase 2: full engine — steady-state ticks allocate nothing. The
/// measured window is `workload::steady_state_alloc_probe`, the same
/// shared definition the `micro_runtime` perf-gate metric uses, so the
/// CI gate and this test provably assert the same invariant.
fn steady_state_engine_tick_is_alloc_free_on_native() {
    let model = NativeBackend::seeded(ModelConfig::native_test(), 0x5EED5);
    for b in [1usize, 4] {
        let (spent, measured) = steady_state_alloc_probe(&model, b).unwrap();
        assert_eq!(
            spent, 0,
            "steady-state engine ticks must not allocate ({spent} allocations across \
             {measured} ticks of {b} in-flight speca requests)"
        );
        assert!(measured > 0);
    }
    assert_eq!(model.workspaces_created(), 1, "one engine thread ⇒ one workspace");
}

#[test]
fn steady_state_is_alloc_free() {
    native_forward_is_alloc_free_after_warmup();
    steady_state_engine_tick_is_alloc_free_on_native();
}
